"""Measured memory telemetry (utils/memprof.py)."""
import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models.vit import init_vit, init_vit_states, vit_loss
from repro.utils.memprof import (
    LiveWatermark,
    live_bytes,
    measured_residual_bytes,
    role_residual_bytes,
    summarize_roles,
)

KEY = jax.random.PRNGKey(3)


def test_live_bytes_sees_allocations():
    before = live_bytes()
    x = jnp.ones((512, 512), jnp.float32)  # 1 MiB
    jax.block_until_ready(x)
    assert live_bytes() - before >= x.size * 4
    del x


def test_watermark_tracks_peak():
    wm = LiveWatermark()
    x = jnp.ones((256, 1024), jnp.float32)
    jax.block_until_ready(x)
    high = wm.sample()
    del x
    low = wm.sample()
    assert wm.peak == max(high, low) >= wm.baseline
    m = wm.metrics()
    assert m["mem_live_peak_mib"] >= m["mem_live_mib"]


def test_measured_residual_bytes_simple_fn():
    """sin's backward needs exactly its input: the probe must report ~x."""
    x = jnp.ones((128, 64), jnp.float32)
    rep = measured_residual_bytes(lambda x_: jnp.sin(x_).sum(), x)
    assert rep.total_bytes >= x.size * 4
    assert rep.total_bytes <= 2 * x.size * 4
    assert rep.n_arrays >= 1


def test_wasi_residual_bytes_below_vanilla_smoke_vit():
    """The tentpole claim, measured end to end: training-loss residual
    bytes of the factored WASI smoke ViT (paper Fig. 5 mlp scope) strictly
    below vanilla."""
    base = configs.get_smoke("vit-base")
    batch = {"patches": jax.random.normal(KEY, (16, 16, 24)),
             "labels": jnp.zeros((16,), jnp.int32)}

    def probe(cfg):
        params = init_vit(KEY, cfg, 4, 24, 16)
        states = init_vit_states(KEY, cfg, 16, 16) \
            if cfg.wasi.compress_acts else None
        return measured_residual_bytes(
            lambda p: vit_loss(p, batch, cfg, states=states),
            params, has_aux=True).total_bytes

    vanilla = probe(base.replace(wasi=dataclasses.replace(
        base.wasi, method="none")))
    wasi = probe(base.replace(wasi=dataclasses.replace(
        base.wasi, method="wasi", scope="mlp", update_mode="factored",
        rank_frac=0.25)))
    assert wasi < vanilla, (wasi, vanilla)


def test_role_residual_accounting():
    base = configs.get_smoke("vit-base")
    wasi_cfg = base.replace(wasi=dataclasses.replace(
        base.wasi, method="wasi", scope="all", update_mode="factored"))
    recs = role_residual_bytes(wasi_cfg, batch=16, seq=17)
    assert {r["role"] for r in recs} == {"mlp_up", "mlp_down",
                                         "attn_qkv", "attn_out"}
    assert all(r["kind"] == "tucker" for r in recs)
    assert all(r["bytes"] < r["dense_bytes"] for r in recs)
    total = summarize_roles(recs)
    assert total["ratio"] > 1.0

    none_cfg = base.replace(wasi=dataclasses.replace(base.wasi, method="none"))
    recs = role_residual_bytes(none_cfg, batch=16, seq=17)
    assert all(r["kind"] == "dense" and r["bytes"] == r["dense_bytes"]
               for r in recs)

    # wsi factored (no ASI): exact sketch-saving backward saves x + h
    wsi_cfg = base.replace(wasi=dataclasses.replace(
        base.wasi, method="wsi", scope="mlp", update_mode="factored"))
    recs = {r["role"]: r for r in role_residual_bytes(wsi_cfg, 16, 17)}
    assert recs["mlp_up"]["kind"] == "x+sketch"
    assert recs["attn_qkv"]["kind"] == "dense"  # out of scope


def test_train_loop_memprof_columns():
    """train_loop(memprof=True) must emit the measured columns."""
    from repro.config import TrainConfig
    from repro.data.synthetic import SyntheticVision
    from repro.train.loop import train_loop
    from repro.train.step import make_train_state, make_train_step

    cfg = configs.get_smoke("vit-base")
    params = init_vit(KEY, cfg, 4, 24, 16)
    states = init_vit_states(KEY, cfg, 8, 16)
    tcfg = TrainConfig(optimizer="sgd", lr=0.05, steps=3, checkpoint_every=0)
    state = make_train_state(KEY, params, cfg, tcfg, asi_states=states)
    step = make_train_step(vit_loss, cfg, tcfg)
    data = SyntheticVision(n_classes=4, n_patches=16, patch_dim=24,
                           global_batch=8, seed=0)
    _, hist = train_loop(state, step, lambda s: data.batch(s), tcfg,
                         memprof=True, log_every=1, log_fn=lambda *_: None)
    assert hist and all("mem_live_mib" in h and "mem_live_peak_mib" in h
                        for h in hist)
    assert hist[-1]["mem_live_peak_mib"] > 0
