"""Weight Subspace Iteration (paper Alg. 1) behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.svd import truncated_svd
from repro.core.wsi import (
    wsi_apply,
    wsi_flops,
    wsi_init,
    wsi_refresh_factored,
    wsi_step,
)


def _w(seed=0, o=64, i=48, decay=0.85):
    key = jax.random.PRNGKey(seed)
    u = jnp.linalg.qr(jax.random.normal(key, (o, i)))[0]
    v = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(seed + 9), (i, i)))[0]
    return (u * decay ** jnp.arange(i)) @ v.T


def test_init_matches_truncated_svd():
    w = _w()
    st = wsi_init(w, 8)
    f = truncated_svd(w, 8)
    np.testing.assert_allclose(np.asarray(st.L @ st.R), np.asarray(f.L @ f.R),
                               atol=1e-4)


def test_iteration_tracks_svd_on_static_weights():
    w = _w(1)
    st = wsi_init(w, 8)
    for _ in range(3):
        st = wsi_step(w, st)
    best = truncated_svd(w, 8)
    err_wsi = float(jnp.linalg.norm(w - wsi_apply(st)))
    err_svd = float(jnp.linalg.norm(w - best.L @ best.R))
    assert err_wsi <= err_svd * 1.02  # within 2% of optimal


def test_subspace_stable_under_small_updates():
    """The paper's core hypothesis (§3.3, Fig. 3a): small gradient steps
    leave the essential subspace trackable by ONE iteration per step."""
    w = _w(2)
    st = wsi_init(w, 8)
    key = jax.random.PRNGKey(3)
    for t in range(20):
        key, sub = jax.random.split(key)
        w = w + 1e-3 * jax.random.normal(sub, w.shape)  # ~ small SGD step
        st = wsi_step(w, st)
    best = truncated_svd(w, 8)
    err_wsi = float(jnp.linalg.norm(w - wsi_apply(st)))
    err_svd = float(jnp.linalg.norm(w - best.L @ best.R))
    assert err_wsi <= err_svd * 1.05


def test_refresh_factored_preserves_product():
    key = jax.random.PRNGKey(4)
    L = jax.random.normal(key, (32, 6))
    R = jax.random.normal(jax.random.PRNGKey(5), (6, 24))
    from repro.core.wsi import WSIState

    st = wsi_refresh_factored(WSIState(L=L, R=R))
    np.testing.assert_allclose(np.asarray(st.L @ st.R), np.asarray(L @ R),
                               rtol=1e-4, atol=1e-4)
    from repro.core.orthogonal import orthonormality_error

    assert float(orthonormality_error(st.L)) < 1e-3


def test_batched_wsi_step():
    ws = jnp.stack([_w(s) for s in range(3)])
    st = jax.vmap(lambda w: wsi_init(w, 8))(ws)
    st2 = wsi_step(ws, st)  # batched path
    assert st2.L.shape == (3, 64, 8)
    for j in range(3):
        err = float(jnp.linalg.norm(ws[j] - st2.L[j] @ st2.R[j])
                    / jnp.linalg.norm(ws[j]))
        best = truncated_svd(ws[j], 8)
        err_svd = float(jnp.linalg.norm(ws[j] - best.L @ best.R)
                        / jnp.linalg.norm(ws[j]))
        assert err <= err_svd * 1.05


def test_wsi_flops_formula():
    assert wsi_flops(10, 20, 4) == 4 * 20 * 10 * 4 + 2 * 10 * 16
