"""Sharding rules + synthetic data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro.data.synthetic import SyntheticLM, SyntheticVision, host_shard
from repro.distributed.sharding import MeshPolicy, param_specs
from repro.models.lm import init_lm


def test_param_specs_rules():
    cfg = configs.get_smoke("qwen2-0.5b")
    params = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = param_specs(params, MeshPolicy())
    flat = jax.tree_util.tree_flatten_with_path(specs)[0]
    d = {"/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path): s
         for path, s in flat}
    # embeddings: vocab on model
    assert d["embed/w"] == P("model", None)
    # WASI factored mlp up: L sharded on d_ff, R replicated
    up_l = [v for k, v in d.items() if k.endswith("mlp/up/L")]
    up_r = [v for k, v in d.items() if k.endswith("mlp/up/R")]
    assert all(s[-2:] == ("model", None) for s in up_l)
    assert all(tuple(s) == () or s[-2:] == (None, None) for s in up_r)
    # down: R sharded on input (d_ff)
    dn_r = [v for k, v in d.items() if k.endswith("mlp/down/R")]
    assert all(s[-2:] == (None, "model") for s in dn_r)
    # norms replicated
    norms = [v for k, v in d.items() if "ln1/scale" in k]
    assert all(tuple(s) == () for s in norms)


def test_stacked_leading_dims_not_sharded():
    cfg = configs.get_smoke("qwen2-0.5b")
    params = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg))
    specs = param_specs(params, MeshPolicy())
    for leaf_spec, leaf in zip(jax.tree.leaves(specs,
                                               is_leaf=lambda x: isinstance(x, P)),
                               jax.tree.leaves(params)):
        if len(leaf_spec) == leaf.ndim and leaf.ndim >= 3:
            assert leaf_spec[0] is None  # scan/stack dim unsharded


def test_synthetic_lm_deterministic_and_learnable_structure():
    data = SyntheticLM(vocab_size=64, seq_len=16, global_batch=4, seed=7)
    b1, b2 = data.batch(3), data.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = data.batch(4)
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    # labels are next tokens
    np.testing.assert_array_equal(np.asarray(b1["tokens"][:, 1:]),
                                  np.asarray(b1["labels"][:, :-1]))


def test_host_shard_partitions_batch():
    data = SyntheticVision(n_classes=4, n_patches=8, patch_dim=6,
                           global_batch=8, seed=0)
    b = data.batch(0)
    parts = [host_shard(b, i, 4) for i in range(4)]
    got = np.concatenate([np.asarray(p["patches"]) for p in parts])
    np.testing.assert_array_equal(got, np.asarray(b["patches"]))


def test_host_shard_rejects_indivisible_batch():
    # a 7-row batch over 2 hosts must FAIL LOUDLY, not silently drop the
    # remainder row on every host (rows 6.. would never be trained on)
    data = SyntheticVision(n_classes=4, n_patches=8, patch_dim=6,
                           global_batch=7, seed=0)
    b = data.batch(0)
    with pytest.raises(ValueError, match="not divisible"):
        host_shard(b, 0, 2)
    # the message carries enough to debug: the offending shape and count
    with pytest.raises(ValueError, match=r"7.*process_count=2"):
        host_shard(b, 1, 2)
