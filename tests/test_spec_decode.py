"""Self-speculative decoding acceptance harness.

Two claims pin the implementation:

* LOSSLESS GREEDY: at temperature 0 the rejection rule accepts a draft
  token iff it equals the target argmax and emits the target argmax
  otherwise — so spec decode is token-for-token BITWISE identical to the
  non-spec engine, for every draft source (int8 factors, rank slice),
  both cache layouts (dense, paged), and every k. This is the strongest
  possible statement: the draft can be arbitrarily bad and only costs
  speed, never output.

* DISTRIBUTION-PRESERVING SAMPLING: at temperature > 0 the accept test
  u < p/q plus the corrected resample from normalize(max(p - q, 0))
  reproduces the target distribution exactly (Leviathan et al., Thm. 1).
  Realizations differ (spec consumes salted RNG streams), so the check
  is DISTRIBUTION-level: empirical next-token frequencies over many
  seeds, compared by total-variation distance and a two-sample
  chi-square — both against the self-distance of two independent
  non-spec runs, so the bar scales with sampling noise instead of a
  hand-tuned constant.

Params are briefly trained (the serve-fuzz precedent): random-init
logits have near-tied argmaxes below cross-shape reassociation noise.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_lm, init_lm_states, lm_loss
from repro.serve import SamplingParams, ServeEngine
from repro.train.step import make_train_state, make_train_step

MAX_CACHE = 32
MAX_NEW = 10


@pytest.fixture(scope="module")
def world():
    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg, jnp.dtype(cfg.dtype))
    states = init_lm_states(key, cfg, 8, 32)
    tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9,
                       checkpoint_every=0)
    state = make_train_state(key, params, cfg, tcfg, asi_states=states)
    jstep = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      seed=1)
    for i in range(40):
        state, _ = jstep(state, data.batch(i))
    params = state.params

    rng = np.random.default_rng(7)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (3, 7, 12)]

    def build(**kw):
        api.uninstall(cfg)
        base = dict(max_slots=2, max_cache=MAX_CACHE, buckets=(4, 8, 16))
        base.update(kw)
        return ServeEngine(params, cfg, **base)

    def generate(eng, sampling=None):
        hs = [eng.submit(p, max_new=MAX_NEW, sampling=sampling)
              for p in prompts]
        eng.run()
        return [h.generated for h in hs], hs

    baseline, _ = generate(build())
    return {"cfg": cfg, "params": params, "prompts": prompts,
            "build": build, "generate": generate, "baseline": baseline}


# ---------------------------------------------------------------------------
# Greedy: bitwise identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("draft", ["int8", "rank:0.5"])
@pytest.mark.parametrize("mode", ["dense", "paged"])
@pytest.mark.parametrize("k", [1, 2, 4, 8])
def test_greedy_bitwise_identity(world, draft, mode, k):
    kw = dict(spec_k=k, draft=draft)
    if mode == "paged":
        kw.update(paged=True, page_size=8, prefill_chunk=8)
    eng = world["build"](**kw)
    out, hs = world["generate"](eng)
    assert out == world["baseline"], (draft, mode, k)
    # every verify step landed on the handle, and the engine-level ledger
    # agrees with the per-request counts
    s = eng.summary()
    assert s["spec_steps"] > 0
    assert sum(sum(h.accepted_counts) for h in hs) \
        == s["spec_accepted_tokens"]
    for h in hs:
        assert h.acceptance_rate is not None
        assert 0.0 <= h.acceptance_rate <= 1.0


def test_nonspec_handle_has_no_acceptance(world):
    eng = world["build"]()
    _, hs = world["generate"](eng)
    for h in hs:
        assert h.accepted_counts == []
        assert h.acceptance_rate is None


def test_greedy_bitwise_midstream_admission(world):
    """Slots at different positions draft different lengths in the same
    lockstep tick; admission mid-flight must not perturb either output."""
    eng = world["build"](spec_k=4, draft="int8")
    h0 = eng.submit(world["prompts"][0], max_new=MAX_NEW)
    eng.step()
    eng.step()
    h1 = eng.submit(world["prompts"][2], max_new=MAX_NEW)
    eng.run()
    assert h0.generated == world["baseline"][0]
    assert h1.generated == world["baseline"][2]


# ---------------------------------------------------------------------------
# Engine construction contracts
# ---------------------------------------------------------------------------

def test_spec_k_must_fit_cache(world):
    with pytest.raises(ValueError, match="spec_k"):
        world["build"](spec_k=MAX_CACHE - 1)


def test_int8_draft_rejected_on_int8_engine(world):
    cfg, params = world["cfg"], world["params"]
    api.uninstall(cfg)
    from repro.api.convert import quantize
    plan = api.plan_of(cfg).quantized("int8")
    qparams = quantize(params, plan)
    api.uninstall(cfg)
    with pytest.raises(ValueError, match="rank"):
        ServeEngine(qparams, cfg, plan=plan, max_slots=2,
                    max_cache=MAX_CACHE, spec_k=4, draft="int8")
    # ...but a rank slice of the resident int8 factors is exactly the
    # self-speculative story for an int8 deployment
    api.uninstall(cfg)
    eng = ServeEngine(qparams, cfg, plan=plan, max_slots=2,
                      max_cache=MAX_CACHE, buckets=(4, 8, 16),
                      spec_k=4, draft="rank:0.5")
    h = eng.submit(world["prompts"][0], max_new=6)
    eng.run()
    assert len(h.generated) == 6
    api.uninstall(cfg)


def test_bad_draft_source_rejected(world):
    with pytest.raises(ValueError):
        world["build"](spec_k=4, draft="rank:0.0")
    with pytest.raises(ValueError):
        world["build"](spec_k=4, draft="fp4")


# ---------------------------------------------------------------------------
# Sampled: distribution-level acceptance
# ---------------------------------------------------------------------------

def _next_token_samples(world, spec, n, seed0):
    """Empirical samples of the SECOND generated token (the first one
    produced by the decode/spec path; the first comes from prefill, which
    spec decode does not touch) across n per-request seeds."""
    kw = dict(spec_k=4, draft="int8") if spec else {}
    eng = world["build"](max_slots=4, **kw)
    prompt = world["prompts"][1]
    out = []
    for s0 in range(seed0, seed0 + n, 4):
        hs = [eng.submit(prompt, sampling=SamplingParams(
                  max_new=3, temperature=0.9, top_k=8, top_p=1.0,
                  seed=s0 + j)) for j in range(4)]
        eng.run()
        out += [h.generated[1] for h in hs]
    return np.array(out)


def _tv(a, b, v):
    ca = np.bincount(a, minlength=v) / len(a)
    cb = np.bincount(b, minlength=v) / len(b)
    return 0.5 * np.abs(ca - cb).sum()


def _chi2_per_dof(a, b):
    """Two-sample Pearson chi-square per degree of freedom over the union
    support (small-count cells pooled into one bucket)."""
    support = sorted(set(a.tolist()) | set(b.tolist()))
    na = np.array([(a == t).sum() for t in support], np.float64)
    nb = np.array([(b == t).sum() for t in support], np.float64)
    keep = (na + nb) >= 5
    na = np.append(na[keep], na[~keep].sum())
    nb = np.append(nb[keep], nb[~keep].sum())
    tot = na + nb
    ea = tot * len(a) / (len(a) + len(b))
    eb = tot * len(b) / (len(a) + len(b))
    ok = tot > 0
    stat = ((na[ok] - ea[ok]) ** 2 / ea[ok]
            + (nb[ok] - eb[ok]) ** 2 / eb[ok]).sum()
    dof = max(int(ok.sum()) - 1, 1)
    return stat / dof


def test_sampled_distribution_matches(world):
    V = world["cfg"].vocab_size
    N = 400
    spec = _next_token_samples(world, True, N, 0)
    ref = _next_token_samples(world, False, N, 0)
    ref2 = _next_token_samples(world, False, N, 50_000)
    # the bar is the self-distance of two independent non-spec runs: spec
    # sampling must be statistically indistinguishable from resampling
    self_tv = _tv(ref, ref2, V)
    assert _tv(spec, ref, V) <= self_tv + 0.08, \
        (_tv(spec, ref, V), self_tv)
    # chi2/dof ~ 1 when the two samples share a distribution; 3 is a
    # generous ceiling far below any systematic q-vs-p mixup (which sends
    # it to tens)
    assert _chi2_per_dof(spec, ref) < 3.0, _chi2_per_dof(spec, ref)
    # and the harness itself can tell distributions apart: spec at a much
    # hotter temperature must NOT pass the same chi-square bar
    eng = world["build"](max_slots=4, spec_k=4, draft="int8")
    hot = []
    for s0 in range(0, N, 4):
        hs = [eng.submit(world["prompts"][1], sampling=SamplingParams(
                  max_new=3, temperature=3.0, top_k=0, top_p=1.0,
                  seed=s0 + j)) for j in range(4)]
        eng.run()
        hot += [h.generated[1] for h in hs]
    assert _chi2_per_dof(np.array(hot), ref) > 3.0


def test_sampled_mixed_batch_with_greedy_rows(world):
    """Greedy and sampled requests share one spec tick: temperature-0 rows
    stay bitwise-oracle while sampled rows ride the rejection path."""
    eng = world["build"](spec_k=4, draft="int8")
    hg = eng.submit(world["prompts"][0], max_new=MAX_NEW)
    hs = eng.submit(world["prompts"][1], sampling=SamplingParams(
        max_new=MAX_NEW, temperature=0.9, top_k=8, seed=3))
    eng.run()
    assert hg.generated == world["baseline"][0]
    assert len(hs.generated) == MAX_NEW


# ---------------------------------------------------------------------------
# Hypothesis property sweep
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.integers(1, 8), st.sampled_from(["int8", "rank:0.5"]),
       st.booleans())
def test_property_greedy_prefix_any_k(world, k, draft, paged):
    """For ANY draft length and source, a shorter-budget greedy request is
    an exact prefix of the oracle."""
    kw = dict(spec_k=k, draft=draft)
    if paged:
        kw.update(paged=True, page_size=8, prefill_chunk=8)
    eng = world["build"](**kw)
    n = 1 + (k % MAX_NEW)
    h = eng.submit(world["prompts"][1], max_new=n)
    eng.run()
    assert h.generated == world["baseline"][1][:n]
