"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import (flash_attention, gram, lowrank_matmul,
                           lowrank_matmul_fused, matmul)
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (100, 70, 50), (17, 33, 65),
                                   (512, 1024, 256), (1, 128, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(m, k, n, dtype):
    a = jax.random.normal(KEY, (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.fold_in(KEY, 1), (k, n)).astype(dtype)
    got = matmul(a, b)
    want = ref.matmul_ref(a, b)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * k)


@given(m=st.integers(1, 200), k=st.integers(1, 100), n=st.integers(1, 150),
       seed=st.integers(0, 10))
@settings(max_examples=12, deadline=None)
def test_matmul_property(m, k, n, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (m, k))
    b = jax.random.normal(jax.random.fold_in(key, 1), (k, n))
    np.testing.assert_allclose(np.asarray(matmul(a, b)),
                               np.asarray(ref.matmul_ref(a, b)),
                               rtol=1e-3, atol=1e-3 * max(k, 1))


@pytest.mark.parametrize("shape,kdim,odim", [((4, 32, 96), 24, 48),
                                             ((2, 100, 64), 16, 64),
                                             ((1, 1, 128), 32, 256),
                                             # ragged: nothing 8/128-aligned
                                             ((3, 17, 70), 5, 33),
                                             ((1, 257, 130), 100, 7),
                                             ((5, 1, 9), 3, 513)])
def test_lowrank_matmul(shape, kdim, odim):
    """Fused kernel vs the jnp oracle across ragged (O, I, K) shapes (the
    public lowrank_matmul dispatches to einsums off-TPU, so the kernel is
    exercised explicitly)."""
    x = jax.random.normal(KEY, shape)
    R = jax.random.normal(jax.random.fold_in(KEY, 1), (kdim, shape[-1]))
    L = jax.random.normal(jax.random.fold_in(KEY, 2), (odim, kdim))
    got = lowrank_matmul_fused(x, R, L)
    want = ref.lowrank_matmul_ref(x.reshape(-1, shape[-1]), R, L).reshape(
        shape[:-1] + (odim,))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_lowrank_matmul_fused_equals_unfused():
    """The single-launch fused kernel and the two-matmul path must agree —
    the fusion only removes the HBM round-trip of the rank-K intermediate."""
    from repro.kernels import lowrank_matmul_unfused

    x = jax.random.normal(KEY, (2, 37, 96))
    R = jax.random.normal(jax.random.fold_in(KEY, 1), (24, 96))
    L = jax.random.normal(jax.random.fold_in(KEY, 2), (48, 24))
    np.testing.assert_allclose(np.asarray(lowrank_matmul_fused(x, R, L)),
                               np.asarray(lowrank_matmul_unfused(x, R, L)),
                               rtol=1e-5, atol=1e-5)
    # and the public dispatcher agrees with both on every backend
    np.testing.assert_allclose(np.asarray(lowrank_matmul(x, R, L)),
                               np.asarray(lowrank_matmul_unfused(x, R, L)),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape,kdim,odim", [((2, 16, 48), 8, 24),
                                             ((1, 13, 30), 5, 17)])
def test_lowrank_matmul_grads(shape, kdim, odim):
    """custom-VJP backward (wsi factored training path) vs autodiff of the
    einsum reference, for x, R, and L."""
    x = jax.random.normal(KEY, shape)
    R = jax.random.normal(jax.random.fold_in(KEY, 1), (kdim, shape[-1]))
    L = jax.random.normal(jax.random.fold_in(KEY, 2), (odim, kdim))

    def fused(x, R, L):
        return (lowrank_matmul_fused(x, R, L) ** 2).sum()

    def reference(x, R, L):
        h = jnp.einsum("...i,ki->...k", x, R)
        return ((jnp.einsum("...k,ok->...o", h, L)) ** 2).sum()

    got = jax.grad(fused, argnums=(0, 1, 2))(x, R, L)
    want = jax.grad(reference, argnums=(0, 1, 2))(x, R, L)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("m,k", [(1000, 48), (64, 8), (4096, 128), (33, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_sweep(m, k, dtype):
    y = jax.random.normal(KEY, (m, k)).astype(dtype)
    got = gram(y)
    want = ref.gram_ref(y)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-3
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=tol, atol=tol * m)


@pytest.mark.parametrize("m,k,bm", [(512, 16, 128), (200, 24, 64),
                                    (64, 8, 64), (33, 7, 32), (1000, 48, 512)])
def test_choleskyqr_kernel_sweep(m, k, bm):
    """Fused single-launch CholeskyQR (kernels/qr.py) vs the jnp oracle:
    orthonormal Q, exact reconstruction Q @ (Q^T Y) = Y (full-rank Y), and
    agreement with the solve_triangular reference."""
    from repro.core.orthogonal import orthonormality_error
    from repro.kernels.qr import choleskyqr_tiled

    y = jax.random.normal(KEY, (m, k))
    q, mix = choleskyqr_tiled(y, bm=bm)
    assert float(orthonormality_error(q)) < 1e-3
    np.testing.assert_allclose(np.asarray(q @ mix), np.asarray(y),
                               rtol=1e-3, atol=1e-3)
    qr_, mixr = ref.choleskyqr_ref(y)
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr_),
                               rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(mix), np.asarray(mixr),
                               rtol=5e-3, atol=5e-3)


def test_choleskyqr_matches_wsi_refresh_semantics():
    """ops.cholesky_qr_mix (any backend) must preserve L @ R through the
    factored refresh: Q (Q^T L) == L up to the regularization shift."""
    from repro.kernels import cholesky_qr_mix

    L = jax.random.normal(KEY, (96, 12))
    q, mix = cholesky_qr_mix(L)
    np.testing.assert_allclose(np.asarray(q @ mix), np.asarray(L),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "b,sq,sk,h,kvh,dh,causal,window",
    [(2, 128, 128, 4, 2, 32, True, 0),
     (1, 256, 256, 4, 4, 64, True, 64),
     (2, 100, 100, 2, 1, 16, False, 0),
     (1, 384, 384, 2, 2, 128, True, 128),
     (1, 64, 64, 8, 2, 96, True, 0)])
def test_flash_attention_sweep(b, sq, sk, h, kvh, dh, causal, window):
    q = jax.random.normal(KEY, (b, sq, h, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, sk, kvh, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, sk, kvh, dh))
    got = flash_attention(q, k, v, causal=causal, window=window)
    g = h // kvh
    idx = jnp.arange(h) // g
    kr, vr = k[:, :, idx, :], v[:, :, idx, :]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kf = kr.transpose(0, 2, 1, 3).reshape(b * h, sk, dh)
    vf = vr.transpose(0, 2, 1, 3).reshape(b * h, sk, dh)
    want = ref.flash_attention_ref(qf, kf, vf, causal=causal,
                                   window=window).reshape(b, h, sq, dh)
    want = want.transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)


def test_flash_attention_bf16():
    q = jax.random.normal(KEY, (1, 128, 2, 64)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 128, 2, 64)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 128, 2, 64)).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True)
    want = ref.flash_attention_ref(
        q.transpose(0, 2, 1, 3).reshape(2, 128, 64),
        k.transpose(0, 2, 1, 3).reshape(2, 128, 64),
        v.transpose(0, 2, 1, 3).reshape(2, 128, 64), causal=True)
    want = want.reshape(1, 2, 128, 64).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2,
                               atol=5e-2)


@pytest.mark.parametrize("bz,s,h,dh,n,chunk", [(2, 32, 4, 8, 4, 8),
                                               (1, 64, 2, 16, 8, 16),
                                               (1, 128, 8, 32, 16, 32)])
def test_ssd_scan_kernel(bz, s, h, dh, n, chunk):
    from repro.kernels.ssd_scan import ssd_scan_tiled
    from repro.nn.mamba import _ssd_chunked

    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (bz, s, h, dh))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bz, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (bz, s, n))
    C = jax.random.normal(ks[4], (bz, s, n))
    want = _ssd_chunked(u, dt, A, B, C, jnp.zeros((h,)), chunk)
    got = ssd_scan_tiled(u, dt, A, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)
