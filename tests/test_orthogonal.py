"""CholeskyQR vs Gram-Schmidt — the TPU adaptation must span the SAME
subspace (DESIGN.md §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np

from hypothesis import given, settings, strategies as st

from repro.core.orthogonal import (
    cholesky_qr,
    cholesky_qr2,
    gram_schmidt,
    orthonormality_error,
)


@given(m=st.integers(8, 200), k=st.integers(1, 8), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_cholqr_orthonormal(m, k, seed):
    k = min(k, m)
    y = jax.random.normal(jax.random.PRNGKey(seed), (m, k))
    q = cholesky_qr(y)
    assert float(orthonormality_error(q)) < 1e-3


def test_same_subspace_as_gram_schmidt():
    y = jax.random.normal(jax.random.PRNGKey(0), (64, 6))
    q1, q2 = cholesky_qr(y), gram_schmidt(y)
    p1 = q1 @ q1.T
    p2 = q2 @ q2.T
    np.testing.assert_allclose(np.asarray(p1), np.asarray(p2), atol=1e-4)


def test_ill_conditioned_input_stays_finite():
    """cond(Y) ~ 1e6: plain CholeskyQR would NaN without the shift ladder."""
    key = jax.random.PRNGKey(1)
    u = jnp.linalg.qr(jax.random.normal(key, (128, 16)))[0]
    y = u * jnp.logspace(0, -6, 16)
    q = cholesky_qr(y)
    assert bool(jnp.isfinite(q).all())
    # at moderate conditioning the two-pass variant restores orthonormality
    y2 = u * jnp.logspace(0, -3, 16)
    q2 = cholesky_qr2(y2)
    assert float(orthonormality_error(q2)) < 1e-2


def test_rank_deficient_input_stays_finite():
    y = jnp.concatenate([jax.random.normal(jax.random.PRNGKey(2), (50, 3))] * 2,
                        axis=1)  # rank 3, 6 columns
    q = cholesky_qr(y)
    assert bool(jnp.isfinite(q).all())


def test_batched_cholqr():
    y = jax.random.normal(jax.random.PRNGKey(3), (5, 40, 4))
    q = cholesky_qr(y)
    assert q.shape == y.shape
    errs = orthonormality_error(q)
    assert float(jnp.max(errs)) < 1e-3
