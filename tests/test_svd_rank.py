"""Rank selection + truncated SVD (paper Eq. 5-7) — unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.svd import (
    explained_variance,
    pick_rank,
    rank_for_threshold,
    reconstruction_rel_error,
    truncated_svd,
)


def _matrix(seed, m=48, n=32, decay=0.8):
    key = jax.random.PRNGKey(seed)
    u = jnp.linalg.qr(jax.random.normal(key, (m, n)))[0]
    v = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(seed + 1), (n, n)))[0]
    s = decay ** jnp.arange(n)
    return (u * s) @ v.T


def test_explained_variance_sums_to_one():
    s = jnp.array([3.0, 2.0, 1.0, 0.5])
    ev = explained_variance(s)
    np.testing.assert_allclose(float(ev.sum()), 1.0, rtol=1e-6)


@given(eps1=st.floats(0.1, 0.9), eps2=st.floats(0.1, 0.9))
@settings(max_examples=20, deadline=None)
def test_rank_monotonic_in_eps(eps1, eps2):
    w = _matrix(0)
    s = jnp.linalg.svd(w, compute_uv=False)
    k1, k2 = int(rank_for_threshold(s, eps1)), int(rank_for_threshold(s, eps2))
    if eps1 <= eps2:
        assert k1 <= k2
    else:
        assert k1 >= k2


def test_rank_bounds():
    w = _matrix(1)
    s = jnp.linalg.svd(w, compute_uv=False)
    assert int(rank_for_threshold(s, 0.0)) >= 1
    assert int(rank_for_threshold(s, 1.0)) <= len(s)


def test_truncated_svd_is_best_rank_k():
    """Eckart-Young: SVD truncation error == sqrt(sum of trailing s^2)."""
    w = _matrix(2)
    s = jnp.linalg.svd(w, compute_uv=False)
    for k in (1, 4, 16):
        f = truncated_svd(w, k)
        err = reconstruction_rel_error(w, f)
        expect = jnp.sqrt(jnp.sum(s[k:] ** 2)) / jnp.linalg.norm(w)
        np.testing.assert_allclose(float(err), float(expect), rtol=1e-4, atol=1e-6)


def test_epsilon_controls_error():
    """Higher eps => kept variance >= eps (the paper's control knob)."""
    w = _matrix(3)
    for eps in (0.4, 0.6, 0.8, 0.9):
        k = pick_rank(w, eps)
        f = truncated_svd(w, k)
        err = float(reconstruction_rel_error(w, f))
        assert err ** 2 <= 1 - eps + 1e-5, (eps, err)


def test_align_rounds_up_only():
    w = _matrix(4, 256, 256, decay=0.95)
    k_unaligned = pick_rank(w, 0.8, align=1)
    k_aligned = pick_rank(w, 0.8, align=128)
    assert k_aligned >= k_unaligned
    assert k_aligned % 128 == 0 or k_aligned == 256
