"""SubspacePlan resolve/bind: spec resolution, plan lookup, typed apply
dispatch."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro import api
from repro.api import bind
from repro.api.plan import SubspacePlan, plan_of, resolve_linear_spec
from repro.config import AsiConfig, WasiConfig


def _wasi(**kw):
    kw.setdefault("method", "wasi")
    kw.setdefault("rank_align", 8)
    return WasiConfig(**kw)


# ---------------------------------------------------------------------------
# resolve
# ---------------------------------------------------------------------------

def test_modes_follow_method_and_scope():
    cfg = configs.get_smoke("qwen2-0.5b")
    for method, update, want in [("none", "factored", "dense"),
                                 ("asi", "factored", "dense"),
                                 ("wasi", "factored", "factored"),
                                 ("wsi", "factored", "factored"),
                                 ("wasi", "project", "project")]:
        c = cfg.replace(wasi=dataclasses.replace(
            cfg.wasi, method=method, update_mode=update))
        plan = api.resolve(c)
        assert plan.spec("mlp/up").mode == want, (method, update)


def test_scope_mlp_keeps_attn_dense():
    cfg = configs.get_smoke("qwen2-0.5b")
    c = cfg.replace(wasi=dataclasses.replace(cfg.wasi, scope="mlp"))
    plan = api.resolve(c)
    assert plan.spec("attn/wq").mode == "dense"
    assert plan.spec("mlp/up").mode == "factored"


def test_sites_cover_block_kinds():
    lm = api.resolve(configs.get_smoke("qwen2-0.5b"))
    assert {"attn/wq", "mlp/up"} <= {s.name for s in lm.specs}
    mamba = api.resolve(configs.get_smoke("falcon-mamba-7b"))
    assert {"ssm/in_proj", "ssm/out_proj"} <= {s.name for s in mamba.specs}
    moe = api.resolve(configs.get_smoke("mixtral-8x7b"))
    assert {"moe/w_gate", "moe/w_down"} <= {s.name for s in moe.specs}
    vit = api.resolve(configs.get_smoke("vit-base"))
    assert {"attn/wq", "mlp/up"} <= {s.name for s in vit.specs}
    assert "mlp/gate" not in {s.name for s in vit.specs}  # gelu MLP


def test_asi_ranks_only_with_shape_hint():
    cfg = configs.get_smoke("qwen2-0.5b")
    assert api.resolve(cfg).spec("mlp/up").asi_ranks is None
    plan = api.resolve(cfg, batch=2, seq=16)
    ranks = plan.spec("mlp/up").asi_ranks
    assert ranks is not None and len(ranks) == 3
    assert ranks[0] == 2  # skip_batch: identity over the batch mode


def test_calibrated_ranks_track_spectrum():
    """A near-low-rank weight must calibrate to a much smaller rank than a
    full-spectrum one under the same eps."""
    w = _wasi(method="wsi", epsilon=0.9)
    key = jax.random.PRNGKey(0)
    lowrank_w = (jax.random.normal(key, (64, 8)) @
                 jax.random.normal(key, (8, 64)))
    flat_w = jax.random.normal(key, (64, 64))
    s_low = resolve_linear_spec(w, "mlp/up", "mlp", 64, 64, weight=lowrank_w)
    s_flat = resolve_linear_spec(w, "mlp/up", "mlp", 64, 64, weight=flat_w)
    assert s_low.rank <= 8
    assert s_flat.rank > 2 * s_low.rank


def test_plan_json_roundtrip():
    cfg = configs.get_smoke("zamba2-7b")   # hybrid: ssm + shared attn + mlp
    plan = api.resolve(cfg, batch=2, seq=8)
    back = SubspacePlan.loads(plan.dumps())
    assert back.model == plan.model        # ModelConfig fully reconstructed
    assert back.specs == plan.specs
    assert back.batch == 2 and back.seq == 8


def test_plan_sharding_stamp_roundtrips_and_summarizes():
    """with_sharding stamps every spec with per-leaf PartitionSpec entries
    that survive dumps/loads EXACTLY (tuples, not JSON lists) and show up
    in the summary — a checkpointed plan replays onto a mesh unchanged."""
    cfg = configs.get_smoke("qwen2-0.5b")
    plan = api.resolve(cfg, batch=2, seq=8)
    assert not plan.is_sharded
    sp = plan.with_sharding()
    assert sp.is_sharded and all(s.sharding for s in sp.specs)
    for s in sp.specs:
        if s.mode != "factored":
            continue
        leaves = dict(s.sharding)
        assert set(leaves) >= {"L", "R"}
        # the K-dim (L's dim 1, R's dim 0) is NEVER mesh-sharded — it is
        # exactly the rank-K payload the factor-only collectives move
        lL, lR = leaves["L"], leaves["R"]
        assert len(lL) < 2 or lL[1] is None, (s.name, lL)
        assert len(lR) < 1 or lR[0] is None, (s.name, lR)
    # TP actually engages somewhere: some leaf lands on the model axis
    assert any("model" in dict(s.sharding).get("L", ())
               or "model" in dict(s.sharding).get("R", ())
               or "model" in dict(s.sharding).get("w", ())
               for s in sp.specs)
    back = SubspacePlan.loads(sp.dumps())
    assert back.specs == sp.specs          # sharding tuples bit-identical
    assert back.is_sharded
    assert "shard=" in sp.summary()
    # unstamped plan round-trips to unstamped (None, not empty tuple)
    back0 = SubspacePlan.loads(plan.dumps())
    assert not back0.is_sharded


def test_plan_of_memoizes_and_install_overrides():
    cfg = configs.get_smoke("qwen2-0.5b")
    assert plan_of(cfg) is plan_of(cfg)
    custom = api.resolve(cfg, batch=4, seq=32)
    api.install(custom)
    try:
        assert plan_of(cfg) is custom
    finally:
        api.uninstall(cfg)
    assert plan_of(cfg) is not custom


def test_linear_lookup_falls_back_on_dim_override():
    plan = api.resolve(configs.get_smoke("qwen2-0.5b"))
    base = plan.linear("mlp/up")
    odd = plan.linear("mlp/up", 48, 96)    # non-config dims: fresh resolve
    assert odd.in_dim == 48 and odd.out_dim == 96
    assert odd.mode == base.mode           # same policy either way


def test_vmem_check_recorded():
    w = _wasi(method="wsi")
    small = resolve_linear_spec(w, "mlp/up", "mlp", 128, 128)
    huge = resolve_linear_spec(w, "mlp/up", "mlp", 16384, 16384)
    assert small.bwd_fits_vmem is True
    assert huge.bwd_fits_vmem is False
    dense = resolve_linear_spec(WasiConfig(), "mlp/up", "mlp", 128, 128)
    assert dense.bwd_fits_vmem is None


# ---------------------------------------------------------------------------
# bind
# ---------------------------------------------------------------------------

def test_bind_apply_dense_matches_einsum():
    w = WasiConfig(method="none")
    spec = resolve_linear_spec(w, "mlp/up", "mlp", 16, 24)
    key = jax.random.PRNGKey(0)
    p = bind.init_params(key, spec, bias=True)
    x = jax.random.normal(key, (2, 5, 16))
    y, ns = bind.apply(spec, p, x, w)
    assert ns is None
    ref = jnp.einsum("...i,oi->...o", x, p["w"]) + p["b"]
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-6)


def test_bind_apply_factored_matches_factor_product():
    w = _wasi(method="wsi")
    spec = resolve_linear_spec(w, "mlp/up", "mlp", 16, 24)
    key = jax.random.PRNGKey(1)
    p = bind.init_params(key, spec)
    assert set(p) == {"L", "R"} and p["L"].shape == (24, spec.rank)
    x = jax.random.normal(key, (2, 5, 16))
    y, _ = bind.apply(spec, p, x, w)
    ref = jnp.einsum("...k,ok->...o",
                     jnp.einsum("...i,ki->...k", x, p["R"]), p["L"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_bind_project_without_factors_falls_back_dense():
    w = _wasi(method="wasi", update_mode="project",
              asi=AsiConfig())
    spec = resolve_linear_spec(w, "mlp/up", "mlp", 16, 24)
    assert spec.mode == "project"
    key = jax.random.PRNGKey(2)
    p = bind.init_params(key, spec)
    assert set(p) == {"w"}                 # project inits dense
    x = jax.random.normal(key, (2, 3, 16))
    y, _ = bind.apply(spec, p, x, w, None)
    ref = jnp.einsum("...i,oi->...o", x, p["w"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-6)


def test_extract_project_factors_roundtrip():
    tree = {"mlp": {"up": {"w": jnp.ones((8, 4)), "L": jnp.ones((8, 2)),
                           "R": jnp.ones((2, 4))}},
            "norm": {"scale": jnp.ones((4,))}}
    stripped, factors = bind.extract_project_factors(tree)
    assert set(stripped["mlp"]["up"]) == {"w"}
    assert list(factors) == ["mlp/up/w"]
    assert factors["mlp/up/w"].L.shape == (8, 2)
    # trees without factors pass through untouched
    same, none = bind.extract_project_factors(stripped)
    assert none == {} and same is stripped


def test_engine_rejects_conflicting_installed_plan():
    """ServeEngine must not silently override a live installed plan for an
    equal config with a different one (global dispatch state)."""
    import dataclasses

    from repro.models.lm import init_lm
    from repro.serve import ServeEngine

    cfg = configs.get_smoke("qwen2-0.5b").replace(
        wasi=dataclasses.replace(configs.get_smoke("qwen2-0.5b").wasi,
                                 method="wsi"))
    live = api.install(api.resolve(cfg, batch=2, seq=8))
    other = api.resolve(cfg)               # no shape hints: differs
    assert other != live
    try:
        params = init_lm(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError):
            ServeEngine(params, plan=other, max_slots=1, max_cache=8)
        # the matching plan (and plain cfg construction) still work
        ServeEngine(params, plan=live, max_slots=1, max_cache=8)
        ServeEngine(params, cfg, max_slots=1, max_cache=8)
    finally:
        api.uninstall(cfg)
