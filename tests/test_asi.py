"""Activation Subspace Iteration (paper Alg. 2, App. A.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.asi import (
    asi_init,
    asi_step,
    compression_ratio,
    flr_weight_grad_3d,
    flr_weight_grad_4d,
    tucker_reconstruct,
    tucker_rel_error,
    tucker_storage,
)


def _lowrank_tensor(key, b, n, i, r):
    u = jax.random.normal(key, (b, n, r))
    v = jax.random.normal(jax.random.fold_in(key, 1), (r, i))
    return u @ v


def _tucker_tensor(key, dims, ranks):
    """True Tucker-structured tensor: core x_m U_m (exact at those ranks)."""
    ks = jax.random.split(key, len(dims) + 1)
    a = jax.random.normal(ks[0], ranks)
    for m, (d, r) in enumerate(zip(dims, ranks)):
        u = jax.random.normal(ks[m + 1], (d, r))
        a = jnp.moveaxis(jnp.moveaxis(a, m, -1) @ u.T, -1, m)
    return a


def test_exact_on_lowrank_input():
    key = jax.random.PRNGKey(0)
    a = _tucker_tensor(key, (4, 24, 48), (3, 6, 8))
    st_ = asi_init(key, a.shape, (4, 12, 12))  # ranks >= true Tucker ranks
    for _ in range(4):
        ft, st_ = asi_step(a, st_)
    assert float(tucker_rel_error(a, ft)) < 0.05


def test_warm_start_improves_iterations():
    """Error decreases (or stays) across warm-started steps — the PowerSGD
    property ASI inherits (§3.2)."""
    key = jax.random.PRNGKey(1)
    a = _lowrank_tensor(key, 4, 24, 48, 10) + \
        0.05 * jax.random.normal(key, (4, 24, 48))
    st_ = asi_init(key, a.shape, (4, 12, 10))
    errs = []
    for _ in range(5):
        ft, st_ = asi_step(a, st_)
        errs.append(float(tucker_rel_error(a, ft)))
    assert errs[-1] <= errs[0] + 1e-6


def test_identity_mode_exact():
    """rank == dim => identity factor (None), no error in that mode."""
    key = jax.random.PRNGKey(2)
    a = jax.random.normal(key, (3, 16, 32))
    st_ = asi_init(key, a.shape, (3, 16, 32))  # all full rank
    ft, st_ = asi_step(a, st_)
    assert all(u is None for u in ft.us)
    np.testing.assert_allclose(np.asarray(tucker_reconstruct(ft)),
                               np.asarray(a), atol=1e-6)


@given(b=st.integers(2, 6), n=st.integers(4, 24), i=st.integers(4, 32),
       seed=st.integers(0, 20))
@settings(max_examples=15, deadline=None)
def test_storage_formula(b, n, i, seed):
    ranks = (b, max(1, n // 2), max(1, i // 2))
    assert tucker_storage((b, n, i), ranks) == \
        ranks[0] * ranks[1] * ranks[2] + b * ranks[0] + n * ranks[1] + i * ranks[2]
    assert compression_ratio((b, n, i), ranks) == pytest.approx(
        (b * n * i) / tucker_storage((b, n, i), ranks))


def test_flr_3d_matches_reconstruction_oracle():
    """f_LR on factors == dense grad on the reconstruction (both paths)."""
    key = jax.random.PRNGKey(3)
    a = jax.random.normal(key, (4, 24, 48))
    dy = jax.random.normal(jax.random.fold_in(key, 7), (4, 24, 10))
    # general path (batch compressed, paper-faithful)
    st_ = asi_init(key, a.shape, (3, 12, 16))
    ft, _ = asi_step(a, st_)
    oracle = jnp.einsum("bno,bni->oi", dy, tucker_reconstruct(ft))
    np.testing.assert_allclose(np.asarray(flr_weight_grad_3d(ft, dy)),
                               np.asarray(oracle), rtol=1e-3, atol=1e-3)
    # identity-batch path (scale mode)
    st2 = asi_init(key, a.shape, (4, 12, 16))
    ft2, _ = asi_step(a, st2)
    assert ft2.us[0] is None
    oracle2 = jnp.einsum("bno,bni->oi", dy, tucker_reconstruct(ft2))
    np.testing.assert_allclose(np.asarray(flr_weight_grad_3d(ft2, dy)),
                               np.asarray(oracle2), rtol=1e-3, atol=1e-3)


def test_flr_4d_matches_reconstruction_oracle():
    key = jax.random.PRNGKey(4)
    a = jax.random.normal(key, (2, 8, 8, 24))
    dy = jax.random.normal(jax.random.fold_in(key, 7), (2, 8, 8, 6))
    for ranks in [(2, 4, 4, 8), (2, 8, 8, 8), (1, 4, 4, 8)]:
        st_ = asi_init(key, a.shape, ranks)
        ft, _ = asi_step(a, st_)
        oracle = jnp.einsum("bhwo,bhwi->oi", dy, tucker_reconstruct(ft))
        got = flr_weight_grad_4d(ft, dy)
        np.testing.assert_allclose(np.asarray(got), np.asarray(oracle),
                                   rtol=1e-3, atol=1e-3)


def test_state_shapes_stable_across_steps():
    """Warm-start state must be jit/scan loop-invariant."""
    key = jax.random.PRNGKey(5)
    a = jax.random.normal(key, (4, 16, 32))
    st_ = asi_init(key, a.shape, (4, 8, 8))
    ft, st2 = asi_step(a, st_)
    assert jax.tree.structure(st_) == jax.tree.structure(st2)
    for u1, u2 in zip(st_.us, st2.us):
        if u1 is not None:
            assert u1.shape == u2.shape
