"""Roofline analyzer conventions + math."""
import pytest

import repro.configs as configs
from repro.config import SHAPES
from repro.launch.roofline import (
    active_params,
    head_flops,
    loop_correction,
    model_flops,
    roofline_terms,
)


def test_loop_correction_counts_layers_and_microbatches():
    cfg = configs.get("qwen2-0.5b")       # 24 uniform layers, 1 body
    assert loop_correction(cfg, SHAPES["train_4k"], 1) == 24
    assert loop_correction(cfg, SHAPES["train_4k"], 4) == 96
    assert loop_correction(cfg, SHAPES["decode_32k"], 4) == 24  # no accum
    z = configs.get("zamba2-7b")          # 13x6 + 3 tail: bodies 6+3
    assert loop_correction(z, SHAPES["train_4k"], 1) == pytest.approx(81 / 9)


def test_model_flops_dense_vs_moe():
    dense = configs.get("granite-3-8b")
    moe = configs.get("mixtral-8x7b")
    sh = SHAPES["train_4k"]
    # mixtral active ~13B > granite ~8B, but far below 8x7B total
    f_dense = model_flops(dense, sh)
    f_moe = model_flops(moe, sh)
    n_moe_total = moe.n_layers * 3 * moe.d_model * moe.d_ff * moe.moe.n_experts
    assert f_moe < 6 * n_moe_total * sh.global_batch * sh.seq_len
    assert f_dense > 0 and f_moe > 0


def test_head_flops_train_is_3x_forward():
    cfg = configs.get("qwen2-0.5b")
    assert head_flops(cfg, SHAPES["train_4k"]) == pytest.approx(
        3 * head_flops(cfg, SHAPES["prefill_32k"]) *
        (SHAPES["train_4k"].global_batch * SHAPES["train_4k"].seq_len) /
        (SHAPES["prefill_32k"].global_batch * SHAPES["prefill_32k"].seq_len))


def test_roofline_terms_pick_dominant():
    rec = {"cost": {"flops": 1e12, "bytes": 1e9},
           "collectives": {"total": 1e12}}
    t = roofline_terms(rec)
    assert t["bottleneck"] == "collective"
    rec = {"cost": {"flops": 1e15, "bytes": 1e9}, "collectives": {"total": 1e6}}
    assert roofline_terms(rec)["bottleneck"] == "compute"


def test_active_params_scales():
    small = active_params(configs.get("qwen2-0.5b"))
    big = active_params(configs.get("granite-3-8b"))
    assert 3e8 < small < 9e8
    assert 5e9 < big < 1.2e10
