"""Minimal stand-in for when `hypothesis` isn't installed.

conftest.py registers this module as ``sys.modules["hypothesis"]``, so
``from hypothesis import given, settings, strategies`` works everywhere:
property tests decorated with @given SKIP cleanly instead of killing the
whole module at collection; every plain pytest test in the same file keeps
running. Install the real thing with `pip install -e .[test]`.
"""
import pytest


class _Strategy:
    """Chainable dummy: any call or attribute yields another strategy, so
    idiomatic compositions (st.integers(0, 8).filter(...).map(...)) still
    import cleanly and the @given test skips at run time."""

    def __call__(self, *a, **k):
        return self

    def __getattr__(self, name):
        return self


class _Strategies:
    def __getattr__(self, name):
        return _Strategy()


st = _Strategies()
strategies = st


def given(*a, **k):
    return lambda f: pytest.mark.skip(reason="hypothesis not installed")(f)


def settings(*a, **k):
    return lambda f: f
