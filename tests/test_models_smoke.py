"""Per-architecture smoke tests (assignment requirement: reduced config of
the same family, one forward/train step on CPU, output shapes + no NaNs),
plus a decode step per arch with a decoder."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
from repro.models.lm import (
    init_lm,
    init_lm_cache,
    init_lm_states,
    lm_decode_step,
    lm_forward,
    lm_loss,
)

LM_ARCHS = ["zamba2-7b", "gemma3-4b", "qwen2-0.5b", "granite-3-8b",
            "stablelm-3b", "internvl2-26b", "falcon-mamba-7b",
            "deepseek-moe-16b", "mixtral-8x7b", "tinyllama-1.1b"]

KEY = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg, arch):
    if arch == "internvl2-26b":
        toks = jax.random.normal(KEY, (B, S, cfg.d_model))
    else:
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks,
            "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    params = init_lm(KEY, cfg)
    states = init_lm_states(KEY, cfg, B, S)
    batch = _batch(cfg, arch)

    logits, _, _, _ = lm_forward(params, batch["tokens"], cfg, states=states)
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())

    (loss, (_, metrics)), grads = jax.value_and_grad(
        lm_loss, has_aux=True)(params, batch, cfg, states=states)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_decode_step(arch):
    cfg = configs.get_smoke(arch)
    if not cfg.has_decoder:
        pytest.skip("encoder-only")
    params = init_lm(KEY, cfg)
    caches = init_lm_cache(cfg, B, 32, dtype=jnp.float32)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    logits, nc = lm_decode_step(params, tok, caches, 3, cfg)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())
    assert jax.tree.structure(nc) == jax.tree.structure(caches)


def test_smoke_whisper():
    cfg = configs.get_smoke("whisper-tiny")
    from repro.models.encdec import (
        encdec_decode_step,
        encdec_loss,
        encode,
        init_encdec,
        init_encdec_cache,
        init_encdec_states,
    )

    params = init_encdec(KEY, cfg)
    states = init_encdec_states(KEY, cfg, B, S)
    batch = {"frames": jax.random.normal(KEY, (B, cfg.enc_seq, cfg.d_model)),
             "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}
    (loss, _), grads = jax.value_and_grad(encdec_loss, has_aux=True)(
        params, batch, cfg, states=states)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))
    mem, _ = encode(params, batch["frames"], cfg)
    caches = init_encdec_cache(cfg, B, 32, dtype=jnp.float32)
    logits, _ = encdec_decode_step(params, batch["tokens"][:, :1], mem,
                                   caches, 0, cfg)
    assert logits.shape == (B, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


def test_smoke_vit():
    cfg = configs.get_smoke("vit-base")
    from repro.models.vit import init_vit, init_vit_states, vit_loss

    n_patches, patch_dim, n_classes = 16, 48, 10
    params = init_vit(KEY, cfg, n_classes, patch_dim, n_patches)
    states = init_vit_states(KEY, cfg, B, n_patches)
    batch = {"patches": jax.random.normal(KEY, (B, n_patches, patch_dim)),
             "labels": jax.random.randint(KEY, (B,), 0, n_classes)}
    (loss, (_, m)), grads = jax.value_and_grad(vit_loss, has_aux=True)(
        params, batch, cfg, states=states)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


@pytest.mark.parametrize("arch", LM_ARCHS[:3])
def test_wasi_methods_all_run(arch):
    """Every WasiConfig.method lowers and differentiates on every family."""
    base = configs.get_smoke(arch)
    batch = _batch(base, arch)
    for method in ["none", "wsi", "asi", "wasi"]:
        cfg = base.replace(wasi=dataclasses.replace(base.wasi, method=method))
        params = init_lm(KEY, cfg)
        states = init_lm_states(KEY, cfg, B, S) if cfg.wasi.compress_acts else None
        (loss, _), grads = jax.value_and_grad(lm_loss, has_aux=True)(
            params, batch, cfg, states=states)
        assert bool(jnp.isfinite(loss)), method
        assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads)), method


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    checks = {
        "zamba2-7b": dict(n_layers=81, d_model=3584, n_heads=32, d_ff=14336,
                          vocab_size=32000),
        "gemma3-4b": dict(n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
                          d_ff=10240, vocab_size=262144),
        "qwen2-0.5b": dict(n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
                           d_ff=4864, vocab_size=151936, qkv_bias=True),
        "granite-3-8b": dict(n_layers=40, d_model=4096, n_heads=32,
                             n_kv_heads=8, d_ff=12800, vocab_size=49155),
        "stablelm-3b": dict(n_layers=32, d_model=2560, n_heads=32,
                            n_kv_heads=32, d_ff=6912, vocab_size=50304),
        "internvl2-26b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab_size=92553),
        "falcon-mamba-7b": dict(n_layers=64, d_model=4096, vocab_size=65024),
        "whisper-tiny": dict(n_layers=4, d_model=384, n_heads=6, d_ff=1536,
                             vocab_size=51865),
        "tinyllama-1.1b": dict(n_layers=22, d_model=2048, n_heads=32,
                               n_kv_heads=4, d_ff=5632, vocab_size=32000),
    }
    for arch, fields in checks.items():
        cfg = configs.get(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert configs.get("deepseek-moe-16b").moe.n_experts == 64
    assert configs.get("deepseek-moe-16b").moe.top_k == 6
    assert configs.get("deepseek-moe-16b").moe.n_shared == 2
    assert configs.get("deepseek-moe-16b").moe.expert_d_ff == 1408
    assert configs.get("mixtral-8x7b").moe.n_experts == 8
    assert configs.get("mixtral-8x7b").moe.top_k == 2
    assert configs.get("zamba2-7b").ssm.d_state == 64
    assert configs.get("falcon-mamba-7b").ssm.d_state == 16
    # layer-pattern sums match the assigned depths
    for arch in LM_ARCHS:
        cfg = configs.get(arch)
        assert cfg.total_pattern_layers == cfg.n_layers, arch
