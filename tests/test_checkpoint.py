"""Checkpoint atomicity, roundtrip, retention, restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
    sweep_stale_tmp,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": [jnp.arange(3), {"c": jnp.float32(7.0)}]}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    back = restore_checkpoint(str(tmp_path), 5, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_tmp(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    os.makedirs(tmp_path / "step_9.tmp0", exist_ok=True)
    assert latest_step(str(tmp_path)) == 3


def test_latest_step_ignores_tmp_with_manifest(tmp_path):
    """A crash AFTER the manifest write but BEFORE the atomic rename leaves
    a manifest-bearing .tmp dir — it is unpublished and must not count."""
    save_checkpoint(str(tmp_path), 3, _tree())
    crashed = tmp_path / "step_9.tmp0"
    os.makedirs(crashed, exist_ok=True)
    (crashed / "manifest.json").write_text('{"step": 9}')
    assert latest_step(str(tmp_path)) == 3


def test_manager_sweeps_own_stale_tmp_on_startup(tmp_path):
    """Startup sweeps THIS process's crashed tmp dirs; a multi-host peer's
    tmp dir (possibly a live in-flight save) is left alone."""
    save_checkpoint(str(tmp_path), 2, _tree())
    for name in ("step_5.tmp0", "step_7.tmp0", "step_7.tmp1"):
        os.makedirs(tmp_path / name, exist_ok=True)
        (tmp_path / name / "manifest.json").write_text("{}")
    mgr = CheckpointManager(str(tmp_path), keep=2, process_index=0)
    left = sorted(os.listdir(tmp_path))
    assert left == ["step_2", "step_7.tmp1"]   # own tmp swept, peer's kept
    step, back = mgr.restore_latest(jax.tree.map(jnp.zeros_like, _tree()))
    assert step == 2 and back is not None


def test_sweep_stale_tmp_standalone(tmp_path):
    os.makedirs(tmp_path / "step_4.tmp0")
    os.makedirs(tmp_path / "step_4.tmp1")
    os.makedirs(tmp_path / "step_4")
    removed = sweep_stale_tmp(str(tmp_path))   # janitor mode: all processes
    assert sorted(os.path.basename(r) for r in removed) == \
        ["step_4.tmp0", "step_4.tmp1"]
    assert os.path.isdir(tmp_path / "step_4")   # published dirs untouched


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((3, 3))})


def test_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    mgr._gc()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_manager_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(42)
    mgr.save(7, t)
    step, back = mgr.restore_latest(jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(t["a"]))


def test_restore_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    step, back = mgr.restore_latest({"a": jnp.zeros(2)})
    assert step is None and back is None


def test_multiprocess_saves_merge_not_clobber(tmp_path):
    """Two processes publishing the same step must both end up restorable —
    the second publish merges its shards instead of rmtree'ing the first
    process's files away."""
    t0, t1 = _tree(0), _tree(1)
    save_checkpoint(str(tmp_path), 1, t0, process_index=0)
    save_checkpoint(str(tmp_path), 1, t1, process_index=1)
    names = sorted(os.listdir(tmp_path / "step_1"))
    assert any(n.startswith("proc0_") for n in names)
    assert any(n.startswith("proc1_") for n in names)
    back0 = restore_checkpoint(str(tmp_path), 1,
                               jax.tree.map(jnp.zeros_like, t0),
                               process_index=0)
    back1 = restore_checkpoint(str(tmp_path), 1,
                               jax.tree.map(jnp.zeros_like, t1),
                               process_index=1)
    np.testing.assert_array_equal(np.asarray(back0["a"]), np.asarray(t0["a"]))
    np.testing.assert_array_equal(np.asarray(back1["a"]), np.asarray(t1["a"]))
