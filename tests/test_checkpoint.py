"""Checkpoint atomicity, roundtrip, retention, restart."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointManager,
    latest_step,
    restore_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": [jnp.arange(3), {"c": jnp.float32(7.0)}]}


def test_roundtrip(tmp_path):
    t = _tree()
    save_checkpoint(str(tmp_path), 5, t)
    back = restore_checkpoint(str(tmp_path), 5, jax.tree.map(jnp.zeros_like, t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_ignores_tmp(tmp_path):
    save_checkpoint(str(tmp_path), 3, _tree())
    os.makedirs(tmp_path / "step_9.tmp0", exist_ok=True)
    assert latest_step(str(tmp_path)) == 3


def test_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((3, 3))})


def test_manager_async_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    mgr._gc()
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [3, 4]


def test_manager_restore_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    t = _tree(42)
    mgr.save(7, t)
    step, back = mgr.restore_latest(jax.tree.map(jnp.zeros_like, t))
    assert step == 7
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(t["a"]))


def test_restore_empty_dir(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    step, back = mgr.restore_latest({"a": jnp.zeros(2)})
    assert step is None and back is None
