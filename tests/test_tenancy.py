"""Tenancy subsystem: adapter plans, freeze-base fine-tuning, the
content-addressed store, and mixed-tenant serving.

The acceptance bars live here as tests:

* a mixed-tenant batch is BITWISE equal to per-tenant solo engines —
  with f32-stored AND int8-stored adapters (the store format is a disk
  format; both paths serve the same dequantized banks);
* tenant churn past the LRU bank capacity swaps bank CONTENTS only —
  the decode executable count stays at one (no re-jit, no re-upload of
  the base) while adapter EVICTED events fire;
* a rank-K smoke adapter through the store is < 1 MiB f32 and STRICTLY
  smaller int8;
* fine-tuning on a tenant's skewed stream beats the frozen base's CE,
  and the base cannot receive gradients by construction.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_lm, init_lm_states, lm_loss
from repro.serve import ServeEngine
from repro.serve.session import EventKind
from repro.tenancy import (AdapterStore, adapter_loss_fn, eval_ce,
                           finetune_adapters, init_adapters, merge_adapters,
                           plan_sha)
from repro.tenancy.resident import ResidentAdapters
from repro.utils.memprof import adapter_bytes, model_weight_bytes

MAX_NEW = 6
TENANTS = ["alice", "bob", "carol"]


@pytest.fixture(scope="module")
def tw(tmp_path_factory):
    """Adapter-stamped plan + briefly-trained base + a store holding each
    tenant's adapter in BOTH formats (int8 copies under '<t>.i8')."""
    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    plan = api.install(api.resolve(cfg))
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg, jnp.dtype(cfg.dtype))
    # briefly trained (the serve-fuzz precedent): random-init greedy paths
    # sit on near-ties, and the bitwise bars here decode greedily
    states = init_lm_states(key, cfg, 8, 32)
    tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9,
                       checkpoint_every=0)
    from repro.train.step import make_train_state, make_train_step
    state = make_train_state(key, params, cfg, tcfg, asi_states=states)
    jstep = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32,
                      global_batch=8, seed=1)
    for i in range(30):
        state, _ = jstep(state, data.batch(i))
    params = state.params

    aplan = plan.with_adapter(0.25)
    store = AdapterStore(str(tmp_path_factory.mktemp("adapters")))
    trees = {}
    for i, t in enumerate(TENANTS):
        ad = init_adapters(jax.random.PRNGKey(10 + i), params, aplan)
        # constant offset puts mass in La too => a real, nonzero delta
        trees[t] = jax.tree.map(lambda x: x + 0.01 * (i + 1), ad)
        store.save(t, trees[t], aplan)
        store.save(f"{t}.i8", trees[t], aplan, fmt="int8")

    rng = np.random.default_rng(7)
    prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (5, 9, 12, 7)]
    yield {"cfg": cfg, "plan": plan, "aplan": aplan, "params": params,
           "store": store, "trees": trees, "prompts": prompts}
    api.uninstall(cfg)


# -- plan stamps ------------------------------------------------------------

def test_adapter_plan_json_roundtrip(tw):
    aplan = tw["aplan"]
    assert aplan.has_adapters and not tw["plan"].has_adapters
    back = type(aplan).from_json(aplan.to_json())
    assert back.has_adapters
    assert [s.adapter for s in back.specs] == [s.adapter for s in aplan.specs]
    assert plan_sha(back) == plan_sha(aplan)
    assert plan_sha(aplan) != plan_sha(tw["plan"])


def test_zero_init_adapter_is_identity(tw):
    """Freshly initialized adapters (La = 0) leave the forward EXACTLY at
    the base model: the delta contributes x Ra^T 0^T = 0."""
    cfg, params = tw["cfg"], tw["params"]
    ad0 = init_adapters(jax.random.PRNGKey(99), params, tw["aplan"])
    assert all(not np.asarray(p["La"]).any()
               for _, p in _adapter_sites(ad0))
    batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                       global_batch=2, seed=3).batch(0)
    loss = jax.jit(lambda p, b: lm_loss(p, b, cfg)[0])
    assert float(loss(params, batch)) == \
        float(loss(merge_adapters(params, ad0), batch))


def _adapter_sites(tree):
    from repro.api.bind import iter_adapter_dicts
    return list(iter_adapter_dicts(tree))


# -- store ------------------------------------------------------------------

def test_store_roundtrip_f32_bitwise(tw):
    store, trees = tw["store"], tw["trees"]
    back, meta = store.load("alice")
    for a, b in zip(jax.tree.leaves(trees["alice"]), jax.tree.leaves(back)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    assert meta["format"] == "f32"
    assert meta["bytes"] < 2**20, "smoke adapter must be < 1 MiB f32"
    assert meta["bytes"] == sum(
        np.asarray(x).nbytes for x in jax.tree.leaves(trees["alice"]))


def test_store_content_dedupe(tw):
    """Identical trees under different tenants share ONE object."""
    store = tw["store"]
    before = store.total_object_bytes()
    m = store.save("alice-twin", tw["trees"]["alice"], tw["aplan"])
    assert m["object"] == store.meta("alice")["object"]
    assert store.total_object_bytes() == before
    assert "alice-twin" in store.tenants()
    assert store.bytes_by_tenant()["alice-twin"] == m["bytes"]


def test_store_int8_strictly_smaller_and_close(tw):
    store = tw["store"]
    f32_b = store.meta("bob")["bytes"]
    m8 = store.meta("bob.i8")
    assert m8["format"] == "int8"
    assert m8["bytes"] < f32_b, "int8 packing must beat f32 strictly"
    ref, _ = store.load("bob")
    deq, _ = store.load("bob.i8")        # load always hands back f32
    for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(deq)):
        a, b = np.asarray(a), np.asarray(b)
        assert b.dtype == np.float32
        assert np.abs(a - b).max() < 1e-2


def test_store_refuses_unstamped_plan(tw):
    with pytest.raises(ValueError, match="no adapter stamps"):
        tw["store"].save("zed", tw["trees"]["alice"], tw["plan"])


def test_store_plan_sha_guard(tw):
    with pytest.raises(ValueError, match="refusing"):
        tw["store"].load("alice", expect_plan_sha="0" * 64)


# -- freeze-base fine-tuning ------------------------------------------------

def test_finetune_beats_frozen_base_on_tenant_stream(tw):
    """The per-tenant acceptance bar: adapters trained on a tenant's
    topic-skewed stream must beat the frozen base's CE on held-out batches
    of the SAME stream."""
    cfg, params = tw["cfg"], tw["params"]
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32,
                      global_batch=8, seed=5).for_tenant("alice")
    ad, metrics = finetune_adapters(params, tw["aplan"], data, steps=30)
    assert np.isfinite(metrics["ce"])
    assert eval_ce(merge_adapters(params, ad), cfg, data) \
        < eval_ce(params, cfg, data)
    # and it actually trained: La left its zero init
    assert any(np.asarray(p["La"]).any() for _, p in _adapter_sites(ad))


def test_freeze_base_gradient_masking(tw):
    """The base cannot receive gradients by construction: the grad pytree
    IS the adapter tree (La/Ra leaves only), and every La grad is live at
    zero init (dLa = dy (x Ra^T) with Ra random-normal)."""
    cfg, params = tw["cfg"], tw["params"]
    ad0 = init_adapters(jax.random.PRNGKey(4), params, tw["aplan"])
    batch = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=16,
                       global_batch=2, seed=6).batch(0)
    lf = adapter_loss_fn(params)
    grads = jax.grad(lambda a: lf(a, batch, cfg)[0])(ad0)
    assert jax.tree.structure(grads) == jax.tree.structure(ad0)
    sites = _adapter_sites(grads)
    assert sites
    for _, g in sites:
        assert set(g) == {"La", "Ra"}
        assert np.isfinite(np.asarray(g["La"])).all()
        assert np.asarray(g["La"]).any()


def test_finetune_refuses_quantized_plan(tw):
    data = SyntheticLM(vocab_size=tw["cfg"].vocab_size, seq_len=16,
                      global_batch=2, seed=0)
    with pytest.raises(ValueError, match="f32 master"):
        finetune_adapters(tw["params"], tw["aplan"].quantized(), data,
                          steps=1)


# -- memprof accounting -----------------------------------------------------

def test_adapter_bytes_accounting(tw):
    ad = tw["trees"]["alice"]
    rep = adapter_bytes(ad, tw["aplan"])
    assert rep["n_sites"] == len(_adapter_sites(ad)) > 0
    assert rep["adapter_bytes"] == tw["store"].meta("alice")["bytes"]
    merged = merge_adapters(tw["params"], ad)
    mw = model_weight_bytes(merged)
    assert mw["adapter_bytes"] == rep["adapter_bytes"]
    base = model_weight_bytes(tw["params"])
    assert base["adapter_bytes"] == 0
    assert mw["total_bytes"] == base["total_bytes"] + rep["adapter_bytes"]
    with pytest.raises(ValueError, match="accounting"):
        adapter_bytes(tw["params"], tw["aplan"])


# -- mixed-tenant serving ---------------------------------------------------

def _engine(tw, **kw):
    base = dict(max_slots=4, max_cache=32, buckets=(4, 8, 16))
    base.update(kw)
    return ServeEngine(tw["params"], tw["cfg"], **base)


@pytest.mark.parametrize("suffix", ["", ".i8"])
def test_mixed_batch_bitwise_equals_solo_engines(tw, suffix):
    """THE tenancy bar: one engine serving [alice, bob, carol, base] in a
    single batch emits, per slot, exactly what a solo engine serving only
    that tenant emits — for f32-stored and int8-stored adapters alike."""
    prompts = tw["prompts"]
    assign = [t + suffix for t in TENANTS] + [None]
    mixed = _engine(tw, adapters=ResidentAdapters(tw["store"], capacity=3))
    hs = [mixed.submit(prompts[i], max_new=MAX_NEW, tenant=t)
          for i, t in enumerate(assign)]
    mixed.run()
    assert mixed.adapters.resident()          # banks actually populated
    for i, t in enumerate(assign):
        solo = _engine(tw,
                       adapters=ResidentAdapters(tw["store"], capacity=3))
        h = solo.submit(prompts[i], max_new=MAX_NEW, tenant=t)
        solo.run()
        assert hs[i].result() == h.result(), (t, i)
    # the no-adapter slot also matches an engine with NO tenancy at all:
    # identity row 0 is bitwise inert
    bare = _engine(tw)
    h = bare.submit(prompts[3], max_new=MAX_NEW)
    bare.run()
    assert hs[3].result() == h.result()


def test_churn_past_capacity_never_rejits(tw):
    """Four tenants through a TWO-row bank: every swap past capacity
    evicts (EVICTED adapter events fire, stats count them) yet the decode
    executable compiled for the first request serves every later one —
    churn changes bank CONTENTS, never shapes."""
    eng = _engine(tw, max_slots=1,
                  adapters=ResidentAdapters(tw["store"], capacity=2))
    prompt = tw["prompts"][0]
    rotation = [None] + TENANTS + [TENANTS[0] + ".i8", None, TENANTS[2]]
    outs = {}
    for t in rotation:
        h = eng.submit(prompt, max_new=MAX_NEW, tenant=t)
        eng.run()
        outs.setdefault(t, h.result())
        assert outs[t] == h.result(), f"revisit of {t} diverged after churn"
    assert eng._decode._cache_size() == 1, \
        "adapter churn must reuse ONE decode executable"
    assert eng.stats["adapter_evictions"] > 0
    assert eng.adapters.evictions > 0 and eng.adapters.swaps >= 4
    kinds = {e.kind for e in eng.adapter_events}
    assert kinds == {EventKind.EVICTED}
    assert all("adapter lru" in e.reason for e in eng.adapter_events)


def test_submit_unknown_tenant_raises(tw):
    eng = _engine(tw, adapters=ResidentAdapters(tw["store"], capacity=2))
    with pytest.raises((KeyError, ValueError, FileNotFoundError)):
        eng.submit(tw["prompts"][0], max_new=2, tenant="nobody")


def test_tenant_without_adapters_raises(tw):
    eng = _engine(tw)
    with pytest.raises(ValueError):
        eng.submit(tw["prompts"][0], max_new=2, tenant="alice")


def test_spec_decode_plus_adapters_rejected(tw):
    with pytest.raises(ValueError):
        _engine(tw, spec_k=2,
                adapters=ResidentAdapters(tw["store"], capacity=2))


def test_summary_reports_tenancy(tw):
    eng = _engine(tw, adapters=ResidentAdapters(tw["store"], capacity=2))
    h = eng.submit(tw["prompts"][1], max_new=2, tenant="bob")
    eng.run()
    assert h.finished
    s = eng.summary()
    t = s["tenancy"]
    assert t["resident"] == ["bob"]
    assert t["capacity"] == 2 and t["swaps"] >= 1
    assert s["adapter_bank_bytes"] == t["bank_bytes"] > 0
    assert t["bytes_by_tenant"]["bob"] == tw["store"].meta("bob")["bytes"]
