# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device; only launch/dryrun.py forces 512.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# When `hypothesis` isn't installed, register the stub under its name so a
# plain `from hypothesis import given, ...` works in every test file and
# property tests skip instead of killing collection (the seed-state failure
# mode). New property-test files need no boilerplate.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub


# Every compiled XLA executable pins several memory maps (LLVM JIT code
# pages), and a process is capped at vm.max_map_count (~65k) of them. The
# full suite compiles enough executables that the count brushes the cap,
# at which point a failed mmap inside LLVM surfaces as a SEGFAULT in
# backend_compile — in whatever unlucky test compiles next. Dropping dead
# executables at module boundaries keeps the count flat; modules compile
# their own executables anyway, so cross-module recompiles are noise
# against the suite's wall clock.
import gc

import pytest


@pytest.fixture(autouse=True, scope="module")
def _reclaim_jit_memory_maps():
    yield
    import jax

    jax.clear_caches()
    gc.collect()


# -- simulated multi-device tests -------------------------------------------
# `@pytest.mark.multidevice` tests need the forced host-device env (set
# BEFORE jax initializes, so it cannot come from this conftest):
#   XLA_FLAGS=--xla_force_host_platform_device_count=8 pytest tests/test_mesh_parity.py
# Tier-1 runs without the flag and skips them; the CI multidevice job sets
# it and runs only this subset (.github/workflows/ci.yml).

def _multidevice_env() -> bool:
    return ("xla_force_host_platform_device_count"
            in os.environ.get("XLA_FLAGS", ""))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "multidevice: needs XLA_FLAGS=--xla_force_host_platform_device_count"
        "=N set before jax init; skipped when absent")


def pytest_collection_modifyitems(config, items):
    if _multidevice_env():
        return
    skip = pytest.mark.skip(
        reason="multidevice: set XLA_FLAGS="
               "--xla_force_host_platform_device_count=8")
    for item in items:
        if "multidevice" in item.keywords:
            item.add_marker(skip)
