# NOTE: do NOT set --xla_force_host_platform_device_count here — smoke
# tests and benches must see 1 device; only launch/dryrun.py forces 512.
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# When `hypothesis` isn't installed, register the stub under its name so a
# plain `from hypothesis import given, ...` works in every test file and
# property tests skip instead of killing collection (the seed-state failure
# mode). New property-test files need no boilerplate.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub


# Every compiled XLA executable pins several memory maps (LLVM JIT code
# pages), and a process is capped at vm.max_map_count (~65k) of them. The
# full suite compiles enough executables that the count brushes the cap,
# at which point a failed mmap inside LLVM surfaces as a SEGFAULT in
# backend_compile — in whatever unlucky test compiles next. Dropping dead
# executables at module boundaries keeps the count flat; modules compile
# their own executables anyway, so cross-module recompiles are noise
# against the suite's wall clock.
import gc

import pytest


@pytest.fixture(autouse=True, scope="module")
def _reclaim_jit_memory_maps():
    yield
    import jax

    jax.clear_caches()
    gc.collect()
