"""Seeded stress fuzz of the serve engine: randomized interleavings of
submit / cancel / deadline-expiry / step across all three schedulers,
checking on every drain that

* no slot or page leaks (slots empty, page refcounts match holders),
* every request gets exactly ONE terminal event,
* paged generations are token-for-token the dense-slot oracle's: a
  FINISHED greedy request equals the oracle prefix of its length; a
  CANCELLED / EVICTED one is a proper prefix of it.

The oracle is computed ONCE per prompt with the dense engine (greedy
decode depends only on the prompt prefix, so any max_new is an oracle
prefix and the comparison is interleaving-invariant). Params are BRIEFLY
TRAINED (the tab2_latency.py precedent): random-init logits have
near-tied top-2 gaps below cross-shape reassociation noise, so greedy
matching on them would measure tie-breaking, not cache correctness.

Engines are built once per scheduler and reused across scenarios —
executables stay warm, so the ~200 interleavings the acceptance bar asks
for run in seconds, and the radix prefix cache carries state BETWEEN
scenarios (long-lived-server aging the per-scenario tests can't see).
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_lm, init_lm_states, lm_loss
from repro.serve import SamplingParams, ServeEngine
from repro.serve.session import TERMINAL
from repro.train.step import make_train_state, make_train_step

MAX_CACHE = 32
MAX_NEW_CAP = 6
N_SEEDS_PAGED = 70       # x3 schedulers = 210 interleavings (bar: >= 200)
N_SEEDS_DENSE = 10
TICK_LIMIT = 400


@pytest.fixture(scope="module")
def world():
    """Config + briefly-trained params + prompt pool + dense-oracle map +
    one warm engine per (mode, scheduler)."""
    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg, jnp.dtype(cfg.dtype))
    states = init_lm_states(key, cfg, 8, 32)
    tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9,
                       checkpoint_every=0)
    state = make_train_state(key, params, cfg, tcfg, asi_states=states)
    jstep = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                       seed=1)
    for i in range(40):
        state, _ = jstep(state, data.batch(i))
    params = state.params

    # prompt pool: three families sharing an 8-token prefix (page-aligned
    # for page_size=8 => radix hits) plus unshared strays, lengths chosen
    # to need 1..3 prefill chunks
    rng = np.random.default_rng(42)
    prefix = list(map(int, rng.integers(0, cfg.vocab_size, 8)))
    prompts = [prefix + list(map(int, rng.integers(0, cfg.vocab_size, n)))
               for n in (2, 5, 12)]
    prompts += [list(map(int, rng.integers(0, cfg.vocab_size, n)))
                for n in (3, 4, 7, 11, 16, 20)]

    def build(mode, sched):
        kw = dict(max_slots=2, max_cache=MAX_CACHE, buckets=(4, 8, 16),
                  scheduler=sched)
        if "paged" in mode:
            kw.update(paged=True, page_size=8, prefill_chunk=8)
        if mode.startswith("spec"):
            kw.update(spec_k=4, draft="int8")
        return ServeEngine(params, cfg, **kw)

    oracle_eng = build("dense", "fcfs")
    handles = [oracle_eng.submit(p, max_new=MAX_NEW_CAP) for p in prompts]
    oracle_eng.run()
    oracle = [h.generated for h in handles]
    assert all(len(o) == MAX_NEW_CAP for o in oracle)

    engines = {(m, s): build(m, s)
               for m in ("paged", "dense", "spec-dense", "spec-paged")
               for s in ("fcfs", "spf", "priority")}
    return {"cfg": cfg, "params": params, "prompts": prompts,
            "oracle": oracle, "engines": engines}


def _run_scenario(world, eng, sched, seed, n_requests=4):
    """One seeded interleaving: tick-scripted submits and cancels, driven
    to drain, then the full invariant audit."""
    rng = np.random.default_rng(seed)
    prompts, oracle = world["prompts"], world["oracle"]
    live = []          # (handle, prompt_idx, max_new, eos_id)
    submitted = 0
    ticks = 0
    while submitted < n_requests or eng.busy:
        if submitted < n_requests and rng.random() < 0.6:
            i = int(rng.integers(len(prompts)))
            max_new = int(rng.integers(1, MAX_NEW_CAP + 1))
            eos_id = None
            if rng.random() < 0.25:     # eos drawn from the oracle path =>
                j = int(rng.integers(max_new))      # guaranteed early stop
                eos_id = oracle[i][j]
            sp = SamplingParams(max_new=max_new, eos_id=eos_id)
            if sched == "priority":
                sp = SamplingParams(
                    max_new=max_new, eos_id=eos_id,
                    priority=int(rng.integers(0, 3)),
                    # ~1/8 requests expire instantly => EVICTED path
                    deadline_s=1e-6 if rng.random() < 0.125 else None)
            live.append((eng.submit(prompts[i], sampling=sp), i,
                         max_new, eos_id))
            submitted += 1
        if live and rng.random() < 0.12:
            h = live[int(rng.integers(len(live)))][0]
            if not h.done:
                eng.cancel(h.rid)
        eng.step()
        ticks += 1
        assert ticks < TICK_LIMIT, "engine failed to drain"
        if ticks % 7 == 0:
            eng.check_invariants()

    # -- drained: audit ----------------------------------------------------
    assert not eng.busy and all(s is None for s in eng.slots)
    eng.check_invariants()
    for h, i, max_new, eos_id in live:
        events = h.events
        assert sum(1 for e in events if e.kind in TERMINAL) == 1, h.rid
        assert events[-1].kind in TERMINAL     # nothing after the terminal
        gen = h.generated
        assert len(gen) <= max_new
        # greedy decode: ANY emitted tokens must be the oracle prefix —
        # this is the paged-vs-dense token-for-token acceptance bar, and
        # for cancelled/evicted requests it pins the partial output too
        assert gen == oracle[i][:len(gen)], (h.rid, gen, oracle[i])
        if h.finished:
            if eos_id is None:
                assert len(gen) == max_new
            else:
                assert gen[-1] == eos_id or len(gen) == max_new
                assert eos_id not in gen[:-1]


@pytest.mark.parametrize("sched", ["fcfs", "spf", "priority"])
def test_fuzz_paged_interleavings(world, sched):
    eng = world["engines"][("paged", sched)]
    base = {"fcfs": 0, "spf": 1000, "priority": 2000}[sched]
    for seed in range(N_SEEDS_PAGED):
        _run_scenario(world, eng, sched, base + seed)
    # end of life: drop the radix cache => every page refcount is zero
    eng.release_prefix_cache()
    eng.check_invariants()
    assert eng.pool.pages_in_use == 0
    assert eng.stats["completed"] + eng.stats["cancelled"] \
        + eng.stats["evicted"] == N_SEEDS_PAGED * 4


@pytest.mark.parametrize("sched", ["fcfs", "spf", "priority"])
def test_fuzz_dense_interleavings(world, sched):
    """Same harness over the dense oracle engine itself: the invariants
    (single terminal event, slot recycling, oracle-prefix outputs) hold
    for the path the paged comparisons lean on."""
    eng = world["engines"][("dense", sched)]
    for seed in range(N_SEEDS_DENSE):
        _run_scenario(world, eng, sched, 100_000 + seed)


@pytest.mark.parametrize("mode", ["spec-dense", "spec-paged"])
@pytest.mark.parametrize("sched", ["fcfs", "spf", "priority"])
def test_fuzz_spec_interleavings(world, mode, sched):
    """The spec-decode engines against the NON-SPEC dense oracle: greedy
    speculative decoding is lossless, so every interleaving invariant —
    oracle-prefix outputs, one terminal event, cancel/evict mid-draft
    freeing slots and pages — must hold unchanged. The paged variant's
    pool (9 pages, two 4-page slots + trash) leaves ZERO free pages for
    draft overrun, so the shrink-on-exhaustion path runs constantly and
    the every-7-ticks `check_invariants` would catch any page the draft
    path allocated and failed to release."""
    eng = world["engines"][(mode, sched)]
    base = {"fcfs": 0, "spf": 1000, "priority": 2000}[sched]
    base += 10_000 if mode == "spec-dense" else 20_000
    for seed in range(12):
        _run_scenario(world, eng, sched, base + seed)
    assert eng.stats["spec_steps"] > 0
    if "paged" in mode:
        eng.release_prefix_cache()
        eng.check_invariants()
        assert eng.pool.pages_in_use == 0


def test_spec_kv_rollback_matches_never_drafted(world):
    """After a full generation, the spec engine's dense KV cache is
    BITWISE equal to a never-drafted engine's over every position the
    final state says is valid (0..pos-1): the verify pass overwrites each
    accepted draft position with exact f32 KV, and rejected positions lie
    at >= pos where the next tick's writes land before any read."""
    import jax

    cfg, params = world["cfg"], world["params"]
    prompt = world["prompts"][2]

    def run(spec_k):
        kw = dict(max_slots=1, max_cache=MAX_CACHE, buckets=(4, 8, 16))
        if spec_k:
            kw.update(spec_k=spec_k, draft="int8")
        eng = ServeEngine(params, cfg, **kw)
        h = eng.submit(prompt, max_new=MAX_NEW_CAP)
        eng.run()
        return eng, h

    ref_eng, ref_h = run(0)
    spec_eng, spec_h = run(3)      # 3 does not divide 6: partial last block
    assert spec_h.generated == ref_h.generated
    valid = int(ref_eng.pos[0])
    assert valid == int(spec_eng.pos[0])
    for a, b in zip(jax.tree.leaves(ref_eng.caches),
                    jax.tree.leaves(spec_eng.caches)):
        # engine cache leaves are (repeat, slot, position, ...); compare
        # slot 0's valid region only — beyond pos is scratch by contract
        assert a.shape == b.shape
        av = np.asarray(a[:, 0, :valid])
        bv = np.asarray(b[:, 0, :valid])
        assert (av == bv).all(), "KV rollback left divergent cache state"


def test_fuzz_paged_starved_pool(world):
    """A pool with barely more than one request's pages: admissions defer
    and radix pages are evicted under pressure, yet every interleaving
    still drains with oracle-exact outputs."""
    cfg = world["cfg"]
    eng = ServeEngine(
        world["params"], cfg,
        max_slots=2, max_cache=MAX_CACHE, buckets=(4, 8, 16),
        paged=True, page_size=8, prefill_chunk=8,
        total_pages=5)               # 4 usable; the longest request needs 4
    for seed in range(10):
        _run_scenario(world, eng, "fcfs", 200_000 + seed, n_requests=3)
    assert eng.stats["deferred"] > 0, "pool never under pressure"
    eng.release_prefix_cache()
    eng.check_invariants()
    assert eng.pool.pages_in_use == 0


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["fcfs", "spf",
                                                     "priority"]))
def test_fuzz_property_random_seeds(world, seed, sched):
    """Hypothesis sweep over the same harness: shrinking turns a failing
    interleaving into a minimal seed instead of a 200-case haystack."""
    _run_scenario(world, world["engines"][("paged", sched)], sched, seed,
                  n_requests=3)


# -- multi-tenant interleavings --------------------------------------------

TENANT_POOL = [None, "fz-a", "fz-b", "fz-c"]   # 3 adapters + the bare base


@pytest.fixture(scope="module")
def tenancy(world, tmp_path_factory):
    """Adapter store (3 tenants, nonzero deltas) + per-tenant solo-engine
    oracles over the shared prompt pool. The None oracle is the WORLD's —
    tenant=None rides identity row 0 and must equal a no-adapter engine."""
    from repro.tenancy import AdapterStore, init_adapters

    cfg, params = world["cfg"], world["params"]
    aplan = api.plan_of(cfg).with_adapter(0.25)
    store = AdapterStore(str(tmp_path_factory.mktemp("fuzz_adapters")))
    for i, t in enumerate(TENANT_POOL[1:]):
        ad = init_adapters(jax.random.PRNGKey(40 + i), params, aplan)
        store.save(t, jax.tree.map(lambda x: x + 0.02 * (i + 1), ad), aplan)

    oracle = {None: world["oracle"]}
    for t in TENANT_POOL[1:]:
        solo = ServeEngine(params, cfg, max_slots=2, max_cache=MAX_CACHE,
                           buckets=(4, 8, 16), adapters=str(store.root),
                           adapter_slots=2)
        hs = [solo.submit(p, max_new=MAX_NEW_CAP, tenant=t)
              for p in world["prompts"]]
        solo.run()
        oracle[t] = [h.generated for h in hs]
        assert all(len(o) == MAX_NEW_CAP for o in oracle[t])
    # the adapters are not inert: each tenant's greedy path must diverge
    # from the base somewhere, or the interleaving checks test nothing
    for t in TENANT_POOL[1:]:
        assert oracle[t] != oracle[None], f"{t} adapter changed no output"
    return {"store": store, "oracle": oracle}


def _run_tenant_scenario(world, tz, eng, seed, n_requests=4):
    """The fuzz loop with a tenant axis: every submit draws a tenant from
    a pool LARGER than the LRU bank (churn + evict-under-pin + defers),
    cancels land mid-swap, and every emitted token must be the prefix of
    THAT tenant's solo-engine oracle."""
    rng = np.random.default_rng(seed)
    prompts, oracle = world["prompts"], tz["oracle"]
    live = []          # (handle, tenant, prompt_idx, max_new)
    submitted = 0
    ticks = 0
    while submitted < n_requests or eng.busy:
        if submitted < n_requests and rng.random() < 0.6:
            i = int(rng.integers(len(prompts)))
            t = TENANT_POOL[int(rng.integers(len(TENANT_POOL)))]
            max_new = int(rng.integers(1, MAX_NEW_CAP + 1))
            h = eng.submit(prompts[i], max_new=max_new, tenant=t)
            live.append((h, t, i, max_new))
            submitted += 1
        if live and rng.random() < 0.12:
            h = live[int(rng.integers(len(live)))][0]
            if not h.done:
                eng.cancel(h.rid)
        eng.step()
        ticks += 1
        assert ticks < TICK_LIMIT, "engine failed to drain"
        if ticks % 7 == 0:
            eng.check_invariants()

    assert not eng.busy and all(s is None for s in eng.slots)
    eng.check_invariants()
    assert all(ix == 0 for ix in eng.adapter_ix), "drained engine pins rows"
    for h, t, i, max_new in live:
        events = h.events
        assert sum(1 for e in events if e.kind in TERMINAL) == 1, h.rid
        gen = h.generated
        assert len(gen) <= max_new
        assert gen == oracle[t][i][:len(gen)], (h.rid, t, gen, oracle[t][i])


# -- sharded-engine interleavings ------------------------------------------

@pytest.mark.multidevice
@pytest.mark.parametrize("sched", ["fcfs", "spf", "priority"])
def test_fuzz_sharded_interleavings(world, sched):
    """The mesh engine — KV slots sharded over a 2-device sub-mesh of the
    forced 8 — through the same seeded cancel / deadline-evict / admission
    interleavings, judged against the single-device dense oracle. Every
    drain additionally audits per-shard state: each device holds exactly
    max_slots/2 cache rows and the sharding survived the scenario churn
    (a dropped with_sharding_constraint would silently gather the cache
    onto one device and pass the token checks)."""
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(2)
    eng = ServeEngine(world["params"], world["cfg"], max_slots=2,
                      max_cache=MAX_CACHE, buckets=(4, 8, 16),
                      scheduler=sched, mesh=mesh)
    base = {"fcfs": 0, "spf": 1000, "priority": 2000}[sched]
    for seed in range(12):
        _run_scenario(world, eng, sched, 400_000 + base + seed)
        # drained-state audit, every shard, every scenario
        eng.check_invariants()
        for leaf in jax.tree.leaves(eng.caches):
            shards = leaf.addressable_shards
            assert len(shards) == mesh.devices.size
            assert all(s.data.shape[1] == eng.max_slots // mesh.devices.size
                       for s in shards)
    assert eng.stats["completed"] + eng.stats["cancelled"] \
        + eng.stats["evicted"] == 12 * 4


@pytest.mark.parametrize("mode", ["dense", "paged"])
def test_fuzz_tenant_interleavings(world, tenancy, mode):
    """Mixed adapter-vs-no-adapter batches under churn: a 2-row bank
    serves a 4-way tenant pool, so interleavings constantly evict and
    re-load adapters between (and under) live requests — outputs must stay
    per-tenant-oracle-exact through every swap."""
    cfg = world["cfg"]
    kw = dict(max_slots=2, max_cache=MAX_CACHE, buckets=(4, 8, 16),
              adapters=str(tenancy["store"].root), adapter_slots=2)
    if mode == "paged":
        kw.update(paged=True, page_size=8, prefill_chunk=8)
    eng = ServeEngine(world["params"], cfg, **kw)
    for seed in range(10):
        _run_tenant_scenario(world, tenancy, eng, 300_000 + seed)
    assert eng.adapters.swaps > 0
    assert eng.adapters.evictions > 0, "pool never churned past capacity"
    if mode == "paged":
        eng.release_prefix_cache()
        eng.check_invariants()
        assert eng.pool.pages_in_use == 0
