"""Simulated multi-device parity: the mesh train/serve hot paths against
the single-device oracles.

Runs only under XLA_FLAGS=--xla_force_host_platform_device_count=8 (the
conftest `multidevice` marker skips otherwise — tier-1 stays on 1 device).
Pins the ISSUE's acceptance criteria: DP loss trajectory within tolerance,
factor-only gradient collectives measurably below dense, PowerSGD
error-feedback parity, and bitwise-equal mesh serving (f32 and int8).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

import repro.configs as configs
from repro import api
from repro.config import TrainConfig

pytestmark = pytest.mark.multidevice

KEY = jax.random.PRNGKey(0)
B, S = 8, 32
N_DEV = 8


@pytest.fixture(scope="module")
def mesh8():
    if len(jax.devices()) < N_DEV:
        pytest.skip(f"{len(jax.devices())} devices < {N_DEV}")
    from repro.launch.mesh import make_host_mesh

    return make_host_mesh(N_DEV)


def _train_world(method=None, powersgd_rank=0):
    from repro.data.synthetic import SyntheticLM
    from repro.models.lm import init_lm, init_lm_states, lm_loss

    cfg = configs.get_smoke("qwen2-0.5b")
    if method is not None:
        cfg = cfg.replace(wasi=dataclasses.replace(cfg.wasi, method=method))
    tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9, steps=8,
                       clip_norm=2.0, checkpoint_every=0,
                       powersgd_rank=powersgd_rank)
    params = init_lm(KEY, cfg)
    asi = init_lm_states(KEY, cfg, B, S) if cfg.wasi.compress_acts else None
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                       seed=1)
    return cfg, tcfg, params, asi, lm_loss, data


def _dp_state_and_step(mesh, cfg, tcfg, params, asi, loss_fn):
    from repro.train.step import (
        dp_batch_sharding,
        dp_state_shardings,
        make_train_state,
        make_train_step,
    )

    state = make_train_state(KEY, params, cfg, tcfg, asi_states=asi,
                             dp_degree=N_DEV)
    state = jax.device_put(state, dp_state_shardings(state, mesh))
    step = make_train_step(loss_fn, cfg, tcfg, mesh=mesh)
    return state, step, dp_batch_sharding(mesh)


# ---------------------------------------------------------------------------
# (a) DP train step vs single-device loss trajectory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,psgd", [("wasi", 0), ("none", 8)])
def test_dp_loss_trajectory_matches_single_device(mesh8, method, psgd):
    """6+ steps of the shard_map DP step track the single-device oracle.

    Not bitwise: pmean of 8 per-shard gradient blocks reassociates the f32
    sums the single-device batch reduction performs in one pass, and ASI
    warm-starts evolve per-replica. The trajectories must still agree to
    ~1e-2 at every step — divergence (e.g. a desynced replica) shows up
    orders of magnitude above that within a step or two."""
    from repro.train.step import make_train_state, make_train_step

    cfg, tcfg, params, asi, loss_fn, data = _train_world(method, psgd)
    s1 = make_train_state(KEY, params, cfg, tcfg, asi_states=asi)
    step1 = jax.jit(make_train_step(loss_fn, cfg, tcfg))
    ref = []
    for i in range(6):
        s1, m = step1(s1, data.batch(i))
        ref.append(float(m["loss"]))

    s8, dstep, bsh = _dp_state_and_step(mesh8, cfg, tcfg, params, asi,
                                        loss_fn)
    dstep = jax.jit(dstep)
    got = []
    for i in range(6):
        s8, m = dstep(s8, jax.device_put(data.batch(i), bsh))
        got.append(float(m["loss"]))
    np.testing.assert_allclose(got, ref, rtol=0, atol=0.05)
    assert ref[-1] < ref[0], "oracle did not learn — world broken"
    assert got[-1] < got[0], "DP step did not learn"


def test_dp_factor_collective_bytes_below_dense(mesh8):
    """Acceptance criterion: MEASURED per-step gradient-collective bytes of
    the factored smoke LM strictly below the dense all-reduce bytes — read
    from the compiled post-SPMD HLO, not computed from shapes."""
    from repro.distributed.collectives import measured_collective_bytes

    def bytes_for(method, psgd=0):
        cfg, tcfg, params, asi, loss_fn, data = _train_world(method, psgd)
        state, step, bsh = _dp_state_and_step(mesh8, cfg, tcfg, params, asi,
                                              loss_fn)
        return measured_collective_bytes(
            step, state, jax.device_put(data.batch(0), bsh))

    factor = bytes_for("wasi")
    dense = bytes_for("none")
    psgd = bytes_for("none", psgd=8)
    assert factor["all-reduce"] > 0, "factored step emitted no collectives"
    assert dense["all-reduce"] > 0
    assert factor["total"] < dense["total"], (factor, dense)
    assert psgd["total"] < dense["total"], (psgd, dense)


# ---------------------------------------------------------------------------
# (b) factor-only all-reduce == dense-grad all-reduce for factored sites
# ---------------------------------------------------------------------------

def test_factor_allreduce_equals_dense_allreduce(mesh8):
    """For a factored site the DP mean commutes with the factor->dense
    expansion dW = dL @ R + L @ dR: all-reducing rank-K dL/dR (K(O+I)
    bytes) then expanding equals expanding per-replica and all-reducing
    the O*I dense grad. The reduced factors themselves equal the
    arithmetic mean exactly — it IS the same mean, just smaller."""
    from repro.distributed.collectives import shard_map

    O, K, I = 48, 8, 40
    rng = np.random.default_rng(0)
    dL = jnp.asarray(rng.standard_normal((N_DEV, O, K)), jnp.float32)
    dR = jnp.asarray(rng.standard_normal((N_DEV, K, I)), jnp.float32)
    L = jnp.asarray(rng.standard_normal((O, K)), jnp.float32)
    R = jnp.asarray(rng.standard_normal((K, I)), jnp.float32)

    def factors(dl, dr):
        return (jax.lax.pmean(dl[0], "data"), jax.lax.pmean(dr[0], "data"))

    def dense(dl, dr):
        return jax.lax.pmean(dl[0] @ R + L @ dr[0], "data")

    sm = dict(mesh=mesh8, in_specs=(P("data"), P("data")), out_specs=P(),
              check_rep=False)
    dl_m, dr_m = shard_map(factors, **sm)(dL, dR)
    dw_dense = shard_map(dense, **sm)(dL, dR)

    # the all-reduced factors are EXACTLY the arithmetic mean
    np.testing.assert_array_equal(np.asarray(dl_m),
                                  np.mean(np.asarray(dL), axis=0))
    np.testing.assert_array_equal(np.asarray(dr_m),
                                  np.mean(np.asarray(dR), axis=0))
    # expansion commutes with the mean (bitwise up to f32 reassociation of
    # the K-dim contraction with the 8-way sum)
    dw_factor = np.asarray(dl_m @ R + L @ dr_m)
    np.testing.assert_allclose(dw_factor, np.asarray(dw_dense),
                               rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# (c) PowerSGD per-replica error feedback vs single-device oracle
# ---------------------------------------------------------------------------

def test_powersgd_dp_matches_single_device_oracle(mesh8):
    """The DP PowerSGD round (pmean'd P/Q factors, per-replica error)
    transmits the same decompressed sequence as the single-device
    compress_decompress oracle fed the mean gradient, and the mean of the
    per-replica errors tracks the oracle's error accumulator — over
    multiple steps, so error feedback itself is what's being compared."""
    from repro.core.powersgd import (
        PowerSGDState,
        compress_decompress,
        powersgd_init,
    )
    from repro.distributed.collectives import shard_map

    O, I, rank, steps = 72, 64, 4, 5
    rng = np.random.default_rng(1)
    grads = jnp.asarray(rng.standard_normal((steps, N_DEV, O, I)),
                        jnp.float32)
    key = jax.random.PRNGKey(3)
    oracle = powersgd_init(key, (O, I), rank)
    dp = powersgd_init(key, (O, I), rank, local_copies=N_DEV)

    def local(g, q, err):
        st = PowerSGDState(q=q, error=err[0])
        dec, ns = compress_decompress(
            g[0], st, lambda x: jax.lax.pmean(x, "data"))
        return dec, ns.q, ns.error[None]

    round_fn = shard_map(
        local, mesh=mesh8,
        in_specs=(P("data"), P(), P("data")),
        out_specs=(P(), P(), P("data")), check_rep=False)

    q, err = dp.q, dp.error
    for t in range(steps):
        dec, q, err = round_fn(grads[t], q, err)
        odec, oracle = compress_decompress(grads[t].mean(axis=0), oracle)
        np.testing.assert_allclose(np.asarray(dec), np.asarray(odec),
                                   rtol=0, atol=1e-4, err_msg=f"step {t}")
    np.testing.assert_allclose(np.asarray(err).mean(axis=0),
                               np.asarray(oracle.error), rtol=0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(q), np.asarray(oracle.q),
                               rtol=0, atol=1e-4)


# ---------------------------------------------------------------------------
# (d) mesh ServeEngine bitwise vs single-device dense engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quant", [False, True], ids=["f32", "int8"])
def test_mesh_engine_greedy_bitwise_equals_single_device(mesh8, quant):
    from repro.models.lm import init_lm
    from repro.serve import ServeEngine

    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    plan = api.resolve(cfg)
    params = init_lm(KEY, cfg, jnp.dtype(cfg.dtype))
    if quant:
        plan = plan.quantized("int8")
        params = api.convert.quantize(params, plan)
    try:
        kw = dict(plan=plan, max_slots=N_DEV, max_cache=32,
                  buckets=(4, 8, 16))
        rng = np.random.default_rng(2)
        prompts = [list(map(int, rng.integers(0, cfg.vocab_size, n)))
                   for n in (3, 7, 5, 11, 4, 9)]

        dense = ServeEngine(params, cfg, **kw)
        hd = [dense.submit(p, max_new=6) for p in prompts]
        dense.run()

        meshed = ServeEngine(params, cfg, mesh=mesh8, **kw)
        hm = [meshed.submit(p, max_new=6) for p in prompts]
        meshed.run()
        meshed.check_invariants()  # cache still sharded over all 8 devices

        for a, b, p in zip(hd, hm, prompts):
            assert a.tokens == b.tokens, (p, a.tokens, b.tokens)
        s = meshed.summary()
        assert s["mesh_devices"] == N_DEV
        assert s["slots_per_device"] == N_DEV // N_DEV
    finally:
        api.uninstall(cfg)


def test_mesh_engine_rejects_unshardable_modes(mesh8):
    from repro.models.lm import init_lm
    from repro.serve import ServeEngine

    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    params = init_lm(KEY, cfg, jnp.dtype(cfg.dtype))
    try:
        with pytest.raises(ValueError, match="paged"):
            ServeEngine(params, cfg, max_slots=8, max_cache=32, paged=True,
                        mesh=mesh8)
        with pytest.raises(ValueError, match="divide evenly"):
            ServeEngine(params, cfg, max_slots=6, max_cache=32, mesh=mesh8)
        with pytest.raises(ValueError, match="speculative"):
            ServeEngine(params, cfg, max_slots=8, max_cache=32, spec_k=2,
                        draft="rank:0.5", mesh=mesh8)
    finally:
        api.uninstall(cfg)
