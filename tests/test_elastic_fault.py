"""Elastic re-mesh planning + fault/straggler control-plane policies."""
import pytest

from repro.distributed.elastic import ElasticPlan, plan_mesh
from repro.distributed.fault import (
    HeartbeatMonitor,
    RestartPolicy,
    StragglerDetector,
)


def test_plan_keeps_tp_drops_dp():
    plan = plan_mesh(n_devices=480, model_parallel=16, old_global_batch=256,
                     old_data=16)
    assert plan.model == 16
    assert plan.data == 30
    assert plan.devices_used == 480
    assert plan.global_batch == 256 * 30 // 16


def test_plan_batch_policies():
    shrink = plan_mesh(128, 16, 256, 16, batch_policy="shrink")
    keep = plan_mesh(128, 16, 256, 16, batch_policy="keep")
    assert shrink.global_batch == 128
    assert keep.global_batch == 256


def test_plan_raises_when_tp_impossible():
    with pytest.raises(ValueError):
        plan_mesh(8, 16, 256, 16)


def test_straggler_detector_needs_persistence():
    det = StragglerDetector(threshold=1.5, patience=3)
    times_bad = {0: 1.0, 1: 1.0, 2: 1.0, 3: 5.0}
    assert det.observe(times_bad) == []
    assert det.observe(times_bad) == []
    assert det.observe(times_bad) == [3]
    # recovery resets strikes
    assert det.observe({0: 1.0, 1: 1.0, 2: 1.0, 3: 1.0}) == []
    assert det.observe(times_bad) == []


def test_heartbeat_monitor():
    mon = HeartbeatMonitor(timeout=10.0)
    mon.beat(0, now=100.0)
    mon.beat(1, now=100.0)
    mon.beat(1, now=109.0)
    assert mon.dead(now=111.0) == [0]


def test_restart_policy_backoff_and_budget():
    rp = RestartPolicy(max_restarts=3, backoff_base=2.0)
    delays = [rp.next_delay() for _ in range(4)]
    assert delays[:3] == [1.0, 2.0, 4.0]
    assert delays[3] is None
    rp.reset()
    assert rp.next_delay() == 1.0
