"""Rank policies: static ranks, mode caps, App. A.2 perplexity DP."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.rank_policy import (
    align_up,
    asi_mode_ranks,
    gradient_perplexity,
    perplexity_dp,
    static_rank,
)


def test_static_rank_alignment_and_bounds():
    assert static_rank(896, 4864, 0.25, align=128) == 256
    assert static_rank(64, 64, 0.25, align=128) == 64  # capped at full
    assert static_rank(64, 64, 0.5, align=1, min_rank=4) == 32
    assert static_rank(8, 8, 0.01, align=1, min_rank=4) == 4


@given(d=st.integers(2, 64), n=st.integers(2, 64), i=st.integers(2, 64),
       f=st.floats(0.05, 1.0))
@settings(max_examples=30, deadline=None)
def test_mode_ranks_never_exceed_unfold_rank(d, n, i, f):
    ranks = asi_mode_ranks((d, n, i), (f, f, f), skip_batch=True, align=1)
    total = d * n * i
    for m, (dim, r) in enumerate(zip((d, n, i), ranks)):
        assert 1 <= r <= min(dim, total // dim), (m, dim, r)


def test_skip_batch_gives_full_rank_mode0():
    ranks = asi_mode_ranks((8, 64, 32), (0.5, 0.5, 0.5), skip_batch=True)
    assert ranks[0] == 8


def test_perplexity_dp_respects_budget_and_beats_greedy():
    rng = np.random.RandomState(0)
    P = rng.rand(5, 4)
    M = rng.rand(5, 4) * 0.5 + 0.1
    budget = 1.8
    res = perplexity_dp(P, M, budget, bins=2048)
    assert res.total_memory <= budget + 1e-6
    # brute force over 4^5 = 1024 combos
    best = None
    import itertools

    for combo in itertools.product(range(4), repeat=5):
        mem = sum(M[i, j] for i, j in enumerate(combo))
        if mem > budget:
            continue
        ppl = sum(P[i, j] for i, j in enumerate(combo))
        if best is None or ppl < best:
            best = ppl
    # DP on a discretized budget is near-optimal (quantization slack)
    assert res.total_perplexity <= best * 1.05 + 1e-6


def test_perplexity_dp_infeasible_raises():
    P = np.ones((3, 2))
    M = np.ones((3, 2)) * 10
    with pytest.raises(ValueError):
        perplexity_dp(P, M, budget=1.0)


def test_gradient_perplexity_is_frobenius():
    import jax.numpy as jnp

    a = jnp.ones((3, 4))
    b = jnp.zeros((3, 4))
    assert gradient_perplexity(a, b) == pytest.approx(np.sqrt(12.0))


def test_align_up():
    assert align_up(1, 128) == 128
    assert align_up(129, 128) == 256
    assert align_up(256, 128) == 256
