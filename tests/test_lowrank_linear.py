"""Custom-VJP WASI/ASI matmuls (paper Eq. 8-11) vs autodiff oracles."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.asi import asi_init, asi_step, tucker_reconstruct
from repro.core.lowrank_linear import (
    asi_matmul,
    wasi_matmul,
    wasi_matmul_project,
    wsi_matmul_project_exact,
)


def _setup(key, b=4, n=16, i=48, o=24, k=8, ranks=(4, 8, 16)):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, n, i))
    L = jax.random.normal(ks[1], (o, k)) / k ** 0.5
    R = jax.random.normal(ks[2], (k, i)) / i ** 0.5
    st = asi_init(ks[3], x.shape, ranks)
    xt, _ = asi_step(x, st)
    return x, L, R, xt


def test_forward_exact():
    """Forward is EXACT (compression only affects residuals) — Eq. 8."""
    x, L, R, xt = _setup(jax.random.PRNGKey(0))
    y = wasi_matmul(x, L, R, xt)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(x @ R.T @ L.T), rtol=1e-4, atol=1e-4)


def test_dx_uses_exact_factors():
    """Eq. 10: dL/dx = dy L R — exact, independent of compression."""
    x, L, R, xt = _setup(jax.random.PRNGKey(1))

    def f(x_):
        return jnp.sum(jnp.sin(wasi_matmul(x_, L, R, xt)))

    dx = jax.grad(f)(x)
    dy = jnp.cos(x @ R.T @ L.T)
    dx_exact = jnp.einsum("bno,ok,ki->bni", dy, L, R)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_exact),
                               rtol=1e-4, atol=1e-4)


def test_dL_dR_match_compressed_oracle():
    """dL/dR computed from factors == dense grads with x REPLACED by its
    Tucker reconstruction — the defining property of f_LR."""
    x, L, R, xt = _setup(jax.random.PRNGKey(2))
    xr = tucker_reconstruct(xt)

    def f(L_, R_):
        return jnp.sum(wasi_matmul(x, L_, R_, xt) ** 2)

    gL, gR = jax.grad(f, argnums=(0, 1))(L, R)
    dy = 2 * (x @ R.T @ L.T)
    # oracle: dL = dy^T (x~ R^T); dR = (dy L)^T x~
    gL_or = jnp.einsum("bno,bnk->ok", dy, xr @ R.T)
    gR_or = jnp.einsum("bnk,bni->ki", jnp.einsum("bno,ok->bnk", dy, L), xr)
    np.testing.assert_allclose(np.asarray(gL), np.asarray(gL_or), rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(gR), np.asarray(gR_or), rtol=1e-3,
                               atol=1e-3)


def test_full_rank_compression_equals_autodiff():
    """All modes identity => custom VJP must equal plain autodiff exactly."""
    key = jax.random.PRNGKey(3)
    x, L, R, _ = _setup(key)
    st = asi_init(key, x.shape, x.shape)  # identity everywhere
    xt, _ = asi_step(x, st)

    def f_custom(x_, L_, R_):
        return jnp.sum(wasi_matmul(x_, L_, R_, xt) ** 2)

    def f_plain(x_, L_, R_):
        return jnp.sum((x_ @ R_.T @ L_.T) ** 2)

    g1 = jax.grad(f_custom, argnums=(0, 1, 2))(x, L, R)
    g2 = jax.grad(f_plain, argnums=(0, 1, 2))(x, L, R)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3,
                                   atol=1e-3)


def test_asi_matmul_dense_weight():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (4, 16, 48))
    w = jax.random.normal(jax.random.fold_in(key, 1), (24, 48)) / 48 ** 0.5
    st = asi_init(key, x.shape, (4, 16, 48))  # identity: exact
    xt, _ = asi_step(x, st)

    g1 = jax.grad(lambda w_: jnp.sum(asi_matmul(x, w_, xt) ** 2))(w)
    g2 = jax.grad(lambda w_: jnp.sum((x @ w_.T) ** 2))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-3)


def test_project_mode_grad_lands_on_w():
    """Eq. 9-11: gradient delivered to the FULL W, zero on (L, R)."""
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 8, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 32)) / 32 ** 0.5
    from repro.core.wsi import wsi_init

    stw = wsi_init(w, 6)
    st = asi_init(key, x.shape, (2, 8, 32))
    xt, _ = asi_step(x, st)

    def f(w_, L_, R_):
        return jnp.sum(wasi_matmul_project(x, w_, L_, R_, xt) ** 2)

    gw, gL, gR = jax.grad(f, argnums=(0, 1, 2))(w, stw.L, stw.R)
    assert float(jnp.abs(gL).max()) == 0.0
    assert float(jnp.abs(gR).max()) == 0.0
    # gw == dy^T x with dy from the FACTORED forward
    dy = 2 * (x @ stw.R.T @ stw.L.T)
    gw_or = jnp.einsum("bno,bni->oi", dy, x)
    np.testing.assert_allclose(np.asarray(gw), np.asarray(gw_or), rtol=1e-3,
                               atol=1e-3)


def test_project_exact_matches_project_with_identity_asi():
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (2, 8, 32))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 32)) / 32 ** 0.5
    from repro.core.wsi import wsi_init

    stw = wsi_init(w, 6)
    st = asi_init(key, x.shape, (2, 8, 32))
    xt, _ = asi_step(x, st)
    g1 = jax.grad(lambda w_: jnp.sum(
        wasi_matmul_project(x, w_, stw.L, stw.R, xt) ** 2))(w)
    g2 = jax.grad(lambda w_: jnp.sum(
        wsi_matmul_project_exact(x, w_, stw.L, stw.R) ** 2))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-3,
                               atol=1e-3)
