"""Conversion + plan-bearing checkpoints: densify(factorize(p)) at eps
tolerance, project-mode conversion trains, checkpoint -> serve engine with
no config in hand."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro import api
from repro.api import convert
from repro.api.plan import collect_linear_weights
from repro.checkpoint import CheckpointManager, restore_untyped, save_checkpoint
from repro.config import TrainConfig
from repro.models.lm import init_lm, init_lm_states, lm_loss
from repro.serve import ServeEngine
from repro.train.step import make_train_state, make_train_step


def _dense_cfg():
    cfg = configs.get_smoke("qwen2-0.5b")
    return cfg.replace(wasi=dataclasses.replace(cfg.wasi, method="none"))


def _dense_params(seed=1):
    return init_lm(jax.random.PRNGKey(seed), _dense_cfg())


def _with_wasi(cfg, **kw):
    return cfg.replace(wasi=dataclasses.replace(cfg.wasi, **kw))


def test_factorize_densify_within_eps_tolerance():
    """densify(factorize(p, plan), plan) ~= p: for every factored site the
    per-slice relative Frobenius error is bounded by sqrt(1 - eps) — the
    explained-variance guarantee of the calibrated rank choice."""
    dp = _dense_params()
    cfg = _with_wasi(_dense_cfg(), method="wsi", epsilon=0.8, rank_align=8)
    plan = api.resolve(cfg, calibration=dp)
    assert plan.calibrated
    back = convert.densify(convert.factorize(dp, plan), plan)
    bound = math.sqrt(1 - cfg.wasi.epsilon) + 1e-4
    orig, rec = collect_linear_weights(dp), collect_linear_weights(back)
    assert set(orig) == set(rec) and orig
    for name in orig:
        w0 = np.asarray(orig[name][0], np.float32).reshape(
            (-1,) + np.asarray(orig[name][0]).shape[-2:])
        w1 = np.asarray(rec[name][0], np.float32).reshape(w0.shape)
        for j in range(w0.shape[0]):
            rel = np.linalg.norm(w0[j] - w1[j]) / np.linalg.norm(w0[j])
            assert rel <= bound, (name, j, rel)


def test_densify_is_exact_for_project_and_dense():
    dp = _dense_params()
    proj = _with_wasi(_dense_cfg(), method="wasi", update_mode="project",
                      rank_align=8)
    plan = api.resolve(proj)
    fp = convert.factorize(dp, plan)
    node = fp["groups"][0][0]["mlp"]["up"]
    assert {"w", "L", "R"} <= set(node)    # project carries BOTH
    back = convert.densify(fp, plan)
    for a, b in zip(jax.tree.leaves(dp), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_factorize_rejects_already_factored():
    cfg = _with_wasi(_dense_cfg(), method="wsi", rank_align=8)
    plan = api.resolve(cfg)
    fp = convert.factorize(_dense_params(), plan)
    with pytest.raises(ValueError):
        convert.factorize(fp, plan)


def test_project_conversion_trains_with_warm_subspace():
    """The paper's project mode on a converted pretrained checkpoint: the
    carried (L, R) must strip into warm WSI states and the step must run."""
    dp = _dense_params()
    cfg = _with_wasi(_dense_cfg(), method="wasi", update_mode="project",
                     rank_align=8)
    plan = api.resolve(cfg)
    fp = convert.factorize(dp, plan)
    key = jax.random.PRNGKey(0)
    tcfg = TrainConfig(steps=1, checkpoint_every=0)
    st = make_train_state(key, fp, cfg, tcfg,
                          asi_states=init_lm_states(key, cfg, 2, 8))
    # params went back to dense; the converted factors seed the WSI states
    assert "L" not in st.params["groups"][0][0]["mlp"]["up"]
    path = next(p for p in st.wsi if p.endswith("mlp/up/w"))
    want_l = np.asarray(fp["groups"][0][0]["mlp"]["up"]["L"])
    np.testing.assert_array_equal(np.asarray(st.wsi[path].L), want_l)
    step = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    b = {"tokens": jnp.zeros((2, 8), jnp.int32),
         "labels": jnp.ones((2, 8), jnp.int32)}
    st, m = step(st, b)
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# plan-bearing checkpoints
# ---------------------------------------------------------------------------

def test_untyped_restore_matches_template_restore(tmp_path):
    params = _dense_params()
    save_checkpoint(str(tmp_path), 3, params, plan=api.resolve(_dense_cfg()))
    back = restore_untyped(str(tmp_path), 3)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    plan = convert.load_plan(str(tmp_path))
    assert plan is not None and plan.model == _dense_cfg()


def test_checkpoint_to_serve_engine_identical_logits(tmp_path):
    """A plan-bearing checkpoint saved from the train template restores into
    the serve engine (no config in hand) and generates identically."""
    cfg = _with_wasi(_dense_cfg(), method="wsi", rank_align=8)
    plan = api.install(api.resolve(cfg, batch=2, seq=8))
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg)
    tcfg = TrainConfig(steps=1, checkpoint_every=0)
    state = make_train_state(key, params, cfg, tcfg)
    mgr = CheckpointManager(str(tmp_path), plan=plan, label="train_state")
    mgr.save(5, state)

    prompts = [[3, 1, 4, 1, 5], [9, 2, 6]]
    def drive(engine):
        reqs = [engine.submit(p, max_new=4) for p in prompts]
        engine.run()
        return [r.tokens for r in reqs]

    direct = drive(ServeEngine(state.params, cfg, max_slots=2, max_cache=16))
    restored = ServeEngine.from_checkpoint(str(tmp_path), max_slots=2,
                                           max_cache=16)
    assert restored.cfg == cfg             # config round-tripped via plan
    assert drive(restored) == direct


def test_export_dense_from_checkpoint(tmp_path):
    cfg = _with_wasi(_dense_cfg(), method="wsi", rank_align=8)
    plan = api.resolve(cfg)
    fp = convert.factorize(_dense_params(), plan)
    save_checkpoint(str(tmp_path), 1, fp, plan=plan, label="params")
    dense, got_plan, step = convert.export_dense(str(tmp_path))
    assert step == 1 and got_plan.model == cfg
    node = dense["groups"][0][0]["mlp"]["up"]
    assert set(node) == {"w"}
    assert node["w"].shape[-2:] == (cfg.d_ff, cfg.d_model)
