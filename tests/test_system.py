"""End-to-end behaviour tests: the paper's claims on this system.

These are the CPU-scale versions of the paper's experiments; the full-size
configs are exercised by launch/dryrun.py (see EXPERIMENTS.md).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticLM, SyntheticVision
from repro.models.lm import init_lm, init_lm_states, lm_loss
from repro.train.step import make_train_state, make_train_step

KEY = jax.random.PRNGKey(233)  # paper §B.2 seed


def _train(cfg, steps=60, seed=1, b=8, s=32, lr=0.3):
    tcfg = TrainConfig(optimizer="sgd", lr=lr, momentum=0.9, steps=steps,
                       clip_norm=2.0, checkpoint_every=0)
    params = init_lm(KEY, cfg)
    asi = init_lm_states(KEY, cfg, b, s) if cfg.wasi.compress_acts else None
    state = make_train_state(KEY, params, cfg, tcfg, asi_states=asi)
    jstep = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=s, global_batch=b,
                       seed=seed)
    losses = []
    for i in range(steps):
        state, m = jstep(state, data.batch(i))
        losses.append(float(m["loss"]))
    return losses, state


def test_wasi_accuracy_tracks_vanilla():
    """Paper claim (Fig. 5/6): WASI at high eps ~ vanilla accuracy.
    On synthetic LM data: final CE within a modest gap of vanilla's."""
    base = configs.get_smoke("qwen2-0.5b")
    l_wasi, _ = _train(base)
    vanilla = base.replace(wasi=dataclasses.replace(base.wasi, method="none"))
    l_van, _ = _train(vanilla)
    # both learn
    assert l_wasi[-1] < l_wasi[0] - 0.3
    assert l_van[-1] < l_van[0] - 0.3
    # WASI within a modest fraction of vanilla's improvement
    gain_w = l_wasi[0] - l_wasi[-1]
    gain_v = l_van[0] - l_van[-1]
    assert gain_w > 0.6 * gain_v, (gain_w, gain_v)


def test_memory_accounting_matches_paper_formulas():
    """Eq. 41-44: weight/activation memory of WASI vs vanilla."""
    from repro.core.asi import tucker_storage
    from repro.core.rank_policy import asi_mode_ranks, static_rank

    o, i, b, n = 512, 512, 8, 64
    k = static_rank(i, o, 0.25, align=1)
    m_w_vanilla = i * o
    m_w_wasi = k * (i + o)
    assert m_w_wasi < m_w_vanilla
    assert m_w_vanilla / m_w_wasi == pytest.approx(o * i / (k * (i + o)))
    ranks = asi_mode_ranks((b, n, i), (1.0, 0.25, 0.25), skip_batch=True,
                           align=1)
    m_a_wasi = tucker_storage((b, n, i), ranks)
    assert m_a_wasi < b * n * i


def test_decode_after_training_generates():
    cfg = configs.get_smoke("qwen2-0.5b")
    _, state = _train(cfg, steps=30)
    from repro.launch.serve import generate

    prompt = jnp.zeros((2, 4), jnp.int32)
    out = generate(state.params, cfg, prompt, max_cache=16, n_new=8)
    assert out.shape == (2, 12)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())


def test_vit_learns_synthetic_classes():
    """ViT + WASI fine-tuning learns a separable synthetic task (the
    CIFAR-10 stand-in for paper Fig. 5)."""
    from repro.models.vit import init_vit, init_vit_states, vit_loss

    cfg = configs.get_smoke("vit-base")
    n_classes, n_patches, patch_dim = 4, 16, 24
    params = init_vit(KEY, cfg, n_classes, patch_dim, n_patches)
    states = init_vit_states(KEY, cfg, 16, n_patches)
    tcfg = TrainConfig(optimizer="sgd", lr=0.05, momentum=0.9, steps=60,
                       clip_norm=2.0, checkpoint_every=0)
    state = make_train_state(KEY, params, cfg, tcfg, asi_states=states)
    jstep = jax.jit(make_train_step(vit_loss, cfg, tcfg))
    data = SyntheticVision(n_classes=n_classes, n_patches=n_patches,
                           patch_dim=patch_dim, global_batch=16, seed=0,
                           noise=0.5)
    accs = []
    for i in range(60):
        state, m = jstep(state, data.batch(i))
        accs.append(float(m["acc"]))
    assert np.mean(accs[-10:]) > 0.8, np.mean(accs[-10:])


def test_elastic_restart_with_smaller_mesh_plan(tmp_path):
    """Failure-path integration: checkpoint -> lose devices -> plan new mesh
    -> resume from checkpoint with adjusted batch."""
    from repro.checkpoint import CheckpointManager
    from repro.distributed.elastic import plan_mesh

    cfg = configs.get_smoke("qwen2-0.5b")
    _, state = _train(cfg, steps=10)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(10, state)
    plan = plan_mesh(n_devices=224, model_parallel=16, old_global_batch=256,
                     old_data=16)
    assert plan.data == 14 and plan.global_batch == 224
    step, restored = mgr.restore_latest(jax.tree.map(jnp.zeros_like, state))
    assert step == 10
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(restored.params)[0]),
        np.asarray(jax.tree.leaves(state.params)[0]))
