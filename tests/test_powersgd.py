"""PowerSGD gradient compression with error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.powersgd import (
    compress_decompress,
    compression_factor,
    powersgd_init,
)

KEY = jax.random.PRNGKey(0)


def test_error_feedback_makes_cumulative_unbiased():
    """Sum of decompressed grads tracks sum of true grads (EF property)."""
    g = jax.random.normal(KEY, (48, 64))
    st = powersgd_init(KEY, g.shape, 4)
    total = jnp.zeros_like(g)
    n = 50
    rels = []
    for i in range(n):
        dec, st = compress_decompress(g, st)
        total = total + dec
        rels.append(float(jnp.linalg.norm(total / (i + 1) - g)
                          / jnp.linalg.norm(g)))
    # EF bound: |mean - g| = |e_n| / n -> O(1/n) once |e| plateaus;
    # check both the level and the decay rate
    assert rels[-1] < 0.2, rels[-1]
    assert rels[-1] < rels[9] / 2.5, (rels[9], rels[-1])


def test_warm_start_converges_on_lowrank_grad():
    """A truly rank-r gradient is transmitted exactly after warmup."""
    u = jax.random.normal(KEY, (48, 3))
    v = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 64))
    g = u @ v
    st = powersgd_init(KEY, g.shape, 4)
    for _ in range(4):
        dec, st = compress_decompress(g, st)
    rel = float(jnp.linalg.norm(dec - g) / jnp.linalg.norm(g))
    assert rel < 0.02, rel


def test_compression_factor():
    assert compression_factor((1024, 1024), 8) == 1024 * 1024 / (8 * 2048)


def test_mean_fn_applied_to_factors_only():
    calls = []

    def mean_fn(x):
        calls.append(x.shape)
        return x

    g = jax.random.normal(KEY, (16, 24))
    st = powersgd_init(KEY, g.shape, 2)
    compress_decompress(g, st, mean_fn)
    # two factor all-reduces: (O, r) and (I, r)
    assert calls == [(16, 2), (24, 2)]


def test_grad_compress_wrapper_skips_factored_params():
    from repro.distributed.grad_compress import collective_savings, init_compression

    params = {"dense": {"w": jnp.zeros((128, 128))},
              "fact": {"L": jnp.zeros((128, 64)), "R": jnp.zeros((64, 128))},
              "tiny": {"scale": jnp.zeros((128,))}}
    states = init_compression(KEY, params, 4)
    assert any("dense/w" in k for k in states)
    assert not any("/L" in k or "/R" in k for k in states)
    sav = collective_savings(params, states)
    assert sav["ratio"] > 1.0


def test_grad_compress_skips_int8_and_scale_leaves():
    """int8-packed weights carry no dense gradient and per-channel scale
    leaves are metadata: neither may get a PowerSGD state even when 2-D."""
    from repro.distributed.grad_compress import init_compression

    params = {"q": {"Lq": jnp.zeros((128, 96), jnp.int8),
                    "Rq": jnp.zeros((96, 128), jnp.int8),
                    "sL": jnp.zeros((128, 64)),     # clears the size floor
                    "sR": jnp.zeros((96, 64)),
                    "sW": jnp.zeros((128, 128)),
                    "w": jnp.zeros((128, 128))}}
    assert list(init_compression(KEY, params, 4)) == ["q/w"]


def test_grad_compress_skips_adapter_leaves_on_full_plan_tree():
    """Regression for the compressibility filter: on a FULL-config
    adapter-stamped plan the per-tenant adapter rank (~224 for
    qwen2-0.5b's large sites) clears the min-dim >= 64 size floor, so a
    size-only filter handed 2-D La/Ra delta factors to PowerSGD — double
    compression, and DP all-reduces their rank-r factors redundantly. The
    smoke configs (adapter rank ~16) never trip this, hence the full
    config under eval_shape (no large allocations)."""
    import repro.configs as configs
    from repro import api
    from repro.distributed.grad_compress import init_compression
    from repro.models.lm import init_lm
    from repro.tenancy import init_adapters, merge_adapters

    cfg = configs.get("qwen2-0.5b")
    api.uninstall(cfg)
    plan = api.install(api.resolve(cfg).with_adapter(0.25))
    try:
        ka = max(s.adapter for s in plan.specs if s.adapter is not None)
        assert ka >= 64, f"adapter rank {ka} would not trip the size floor"
        params = jax.eval_shape(lambda k: init_lm(k, cfg), KEY)
        ads = jax.eval_shape(lambda k: init_adapters(k, params, plan), KEY)
        merged = merge_adapters(params, ads)
        paths = list(init_compression(KEY, merged, 4))
        assert paths, "full tree has dense 2-D sites; filter went blind"
        bad = [p for p in paths
               if p.endswith(("/L", "/R", "/La", "/Ra", "/sLa", "/sRa"))]
        assert not bad, f"factor/adapter leaves got PowerSGD states: {bad}"
    finally:
        api.uninstall(cfg)
