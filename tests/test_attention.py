"""Attention module invariants: chunked==dense, rolling cache correctness,
decode==prefill consistency, flash-decode partials."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.attention import (
    KVCache,
    cache_update,
    chunked_attention,
    decode_attention,
    dense_attention,
)

KEY = jax.random.PRNGKey(0)


def _qkv(b=2, s=64, h=4, kvh=2, dh=16, key=KEY):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (b, s, h, dh)),
            jax.random.normal(ks[1], (b, s, kvh, dh)),
            jax.random.normal(ks[2], (b, s, kvh, dh)))


def test_chunked_equals_dense():
    q, k, v = _qkv(s=100)
    for causal, window in [(True, 0), (True, 24), (False, 0)]:
        d = dense_attention(q, k, v, causal=causal, window=window)
        c = chunked_attention(q, k, v, causal=causal, window=window, chunk=32)
        np.testing.assert_allclose(np.asarray(c), np.asarray(d), rtol=1e-4,
                                   atol=1e-4)


def test_decode_matches_full_attention():
    """Token-by-token decode over a full cache == row i of dense attention."""
    b, s, h, kvh, dh = 1, 16, 4, 2, 8
    q, k, v = _qkv(b, s, h, kvh, dh)
    full = dense_attention(q, k, v, causal=True)
    cache = KVCache(k=jnp.zeros((b, s, kvh, dh)), v=jnp.zeros((b, s, kvh, dh)))
    for t in range(s):
        cache = cache_update(cache, k[:, t:t + 1], v[:, t:t + 1], t)
        o = decode_attention(q[:, t:t + 1], cache, t)
        np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(full[:, t]),
                                   rtol=1e-4, atol=1e-4)


def test_rolling_cache_equals_full_cache_with_window():
    """Rolling W-slot cache == full cache + window mask (the long_500k
    memory trick must not change results)."""
    b, s, h, kvh, dh, w = 1, 40, 2, 2, 8, 8
    q, k, v = _qkv(b, s, h, kvh, dh)
    full = KVCache(k=jnp.zeros((b, s, kvh, dh)), v=jnp.zeros((b, s, kvh, dh)))
    roll = KVCache(k=jnp.zeros((b, w, kvh, dh)), v=jnp.zeros((b, w, kvh, dh)))
    for t in range(s):
        full = cache_update(full, k[:, t:t + 1], v[:, t:t + 1], t)
        roll = cache_update(roll, k[:, t:t + 1], v[:, t:t + 1], t, window=w)
        o_full = decode_attention(q[:, t:t + 1], full, t, window=w)
        o_roll = decode_attention(q[:, t:t + 1], roll, t, window=w)
        np.testing.assert_allclose(np.asarray(o_roll), np.asarray(o_full),
                                   rtol=1e-4, atol=1e-4)


def test_window_masks_out_distant_tokens():
    """With window=1 each token attends only to itself."""
    q, k, v = _qkv(s=8)
    out = dense_attention(q, k, v, causal=True, window=1)
    # manual self-attention value: softmax over single element = v itself
    g = q.shape[2] // k.shape[2]
    vr = v[:, :, jnp.arange(q.shape[2]) // g, :]
    np.testing.assert_allclose(np.asarray(out), np.asarray(vr), rtol=1e-4,
                               atol=1e-4)


def test_flash_decode_partials_match_dense():
    """distributed/collectives._local_partials combined across two manual
    shards == full softmax attention (the psum algebra)."""
    from repro.distributed.collectives import _local_partials

    b, s, h, dh = 1, 32, 4, 8
    q = jax.random.normal(KEY, (b, h, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, h, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, h, dh))
    valid = jnp.arange(s) <= 20

    # full reference
    m, l, acc = _local_partials(q, k, v, valid)
    want = acc / l[..., None]

    # two shards combined with the flash-decode algebra
    m1, l1, a1 = _local_partials(q, k[:, :16], v[:, :16], valid[:16])
    m2, l2, a2 = _local_partials(q, k[:, 16:], v[:, 16:], valid[16:])
    mg = jnp.maximum(m1, m2)
    s1, s2 = jnp.exp(m1 - mg), jnp.exp(m2 - mg)
    lg = l1 * s1 + l2 * s2
    ag = a1 * s1[..., None] + a2 * s2[..., None]
    got = ag / lg[..., None]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5,
                               atol=1e-5)
