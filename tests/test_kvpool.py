"""Paged KV bookkeeping: PagePool refcounts/free-list, RadixCache prefix
sharing, and the property layer over random alloc/free and insert/match
sequences (hypothesis runs in CI via the `dev` extra; locally the stub in
conftest.py makes @given tests skip cleanly)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serve import PagePool, RadixCache, pages_needed
from repro.serve.kvpool import TRASH_PAGE


def test_pages_needed():
    assert pages_needed(1, 8) == 1
    assert pages_needed(8, 8) == 1
    assert pages_needed(9, 8) == 2
    assert pages_needed(0, 8) == 0


def test_pool_alloc_unref_cycle():
    pool = PagePool(6, 8)
    assert pool.usable_pages == 5 and pool.free_pages == 5
    a = pool.alloc(3)
    assert a is not None and len(set(a)) == 3 and TRASH_PAGE not in a
    assert pool.pages_in_use == 3
    assert (pool.refs[a] == 1).all()
    pool.check()
    for p in a:
        pool.unref(p)
    assert pool.free_pages == 5 and pool.pages_in_use == 0
    pool.check()


def test_pool_alloc_shortage_returns_none():
    pool = PagePool(4, 8)
    assert pool.alloc(4) is None          # only 3 usable (page 0 is trash)
    assert pool.free_pages == 3           # failed alloc takes nothing
    got = pool.alloc(3)
    assert got is not None
    assert pool.alloc(1) is None
    pool.check()


def test_pool_refcount_sharing():
    pool = PagePool(4, 8)
    (p,) = pool.alloc(1)
    pool.ref(p)                           # second holder
    pool.unref(p)
    assert pool.free_pages == 2           # still held by the first
    pool.unref(p)
    assert pool.free_pages == 3
    pool.check()


def test_pool_guards():
    pool = PagePool(4, 8)
    with pytest.raises(ValueError):
        pool.ref(TRASH_PAGE)
    with pytest.raises(ValueError):
        pool.unref(TRASH_PAGE)
    (p,) = pool.alloc(1)
    pool.unref(p)
    with pytest.raises(ValueError):
        pool.unref(p)                     # already free
    with pytest.raises(ValueError):
        PagePool(1, 8)                    # no room for the trash page


def test_radix_match_is_page_granular():
    pool = PagePool(10, 4)
    radix = RadixCache(pool)
    prompt = list(range(10))              # 2 full pages + 2-token tail
    pages = pool.alloc(3)
    assert radix.insert(prompt, pages) == 2       # tail page NOT published
    assert radix.match(prompt) == pages[:2]
    assert radix.match(prompt[:7]) == pages[:1]   # only 1 full page covered
    assert radix.match(prompt[:3]) == []
    assert radix.match([99] + prompt[1:]) == []   # first page differs
    # tree holds its own ref on published pages; caller refs survive
    assert pool.refs[pages[0]] == 2 and pool.refs[pages[2]] == 1


def test_radix_first_writer_wins():
    pool = PagePool(10, 4)
    radix = RadixCache(pool)
    a = pool.alloc(1)
    b = pool.alloc(1)
    radix.insert(list(range(4)), a)
    assert radix.insert(list(range(4)), b) == 0   # span already published
    assert radix.match(list(range(4))) == a       # keeps the first page
    assert pool.refs[b[0]] == 1                   # b holds only caller's ref


def test_radix_evict_lru_unreferenced_only():
    pool = PagePool(10, 2)
    radix = RadixCache(pool)
    p1 = pool.alloc(2)
    radix.insert([0, 1, 2, 3], p1)
    p2 = pool.alloc(1)
    radix.insert([9, 8], p2)
    for p in p1 + p2:                     # hand the caller refs back
        pool.unref(p)
    radix.match([9, 8])                   # freshen the second chain
    # p1's leaf [2,3] is older LRU; evicting it exposes [0,1] (cascade)
    assert radix.evict(2) == 2
    assert radix.match([0, 1, 2, 3]) == []
    assert radix.match([9, 8]) == p2      # survivor
    # a slot still referencing a page pins it against eviction
    pool.ref(p2[0])
    assert radix.evict(1) == 0
    pool.unref(p2[0])
    assert radix.evict(1) == 1
    assert pool.pages_in_use == 0
    pool.check()


def test_radix_clear_releases_everything():
    pool = PagePool(10, 2)
    radix = RadixCache(pool)
    pages = pool.alloc(3)
    radix.insert([1, 2, 3, 4, 5, 6], pages)
    for p in pages:
        pool.unref(p)
    assert sorted(radix.held_pages()) == sorted(pages)
    assert radix.clear() == 3
    assert radix.held_pages() == [] and radix.n_nodes == 0
    assert pool.pages_in_use == 0
    pool.check()


# -- property layer ---------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 4)), max_size=60))
def test_pool_refcounts_match_reference_model(ops):
    """Random alloc/unref sequences against a plain-dict reference: the
    pool's refcounts, free count, and check() must agree at every step."""
    pool = PagePool(9, 4)
    held: list[int] = []
    for is_alloc, n in ops:
        if is_alloc:
            got = pool.alloc(n)
            if got is None:
                assert pool.free_pages < n
            else:
                held.extend(got)
        elif held:
            pool.unref(held.pop(n % len(held)))
        pool.check()
        assert pool.pages_in_use == len(set(held))
    counts = {p: held.count(p) for p in held}
    assert all(pool.refs[p] == c for p, c in counts.items())


@settings(max_examples=60, deadline=None)
@given(st.lists(st.lists(st.integers(0, 3), min_size=1, max_size=12),
                min_size=1, max_size=8),
       st.integers(1, 3))
def test_radix_match_returns_longest_published_prefix(prompts, page_size):
    """After inserting any set of prompts, match(p) must return exactly one
    page per full page-span of p that some inserted prompt shares as a
    prefix — and pool.check() must hold throughout."""
    pool = PagePool(64, page_size)
    radix = RadixCache(pool)
    published: list[tuple] = []
    for prompt in prompts:
        n = len(prompt) // page_size
        pages = pool.alloc(pages_needed(len(prompt), page_size))
        if pages is None:
            break
        radix.insert(prompt, pages)
        published.append(tuple(prompt[:n * page_size]))
        for p in pages:
            pool.unref(p)          # tree refs alone keep published pages
        pool.check()
    for prompt in prompts:
        got = radix.match(prompt)
        want = 0
        for pub in published:
            share = 0
            for i in range(min(len(pub), len(prompt)) // page_size):
                if tuple(prompt[i * page_size:(i + 1) * page_size]) \
                        != pub[i * page_size:(i + 1) * page_size]:
                    break
                share = i + 1
            want = max(want, share)
        assert len(got) == want, (prompt, published)
    radix.clear()
    assert pool.pages_in_use == 0
    pool.check()


# -- speculative-draft transient pages --------------------------------------

@pytest.fixture(scope="module")
def spec_world():
    """A tiny UNTRAINED model: these tests pin page accounting, not token
    outputs (greedy equivalence on trained params lives in
    test_spec_decode.py / test_serve_fuzz.py)."""
    import jax
    import jax.numpy as jnp

    import repro.configs as configs
    from repro import api
    from repro.models.lm import init_lm

    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    params = init_lm(jax.random.PRNGKey(0), cfg, jnp.dtype(cfg.dtype))
    yield cfg, params
    api.uninstall(cfg)


def _spec_engine(cfg, params, **kw):
    from repro.serve import ServeEngine

    base = dict(max_slots=1, max_cache=32, buckets=(4, 8, 16),
                paged=True, page_size=4, prefill_chunk=8,
                spec_k=8, draft="int8")
    base.update(kw)
    return ServeEngine(params, cfg, **base)


def test_spec_draft_straddles_page_boundary_and_releases(spec_world):
    """A draft near the end of the request budget writes KV past the pages
    reserved at admission: prompt 6 + max_new 3 reserves 3 pages (cover
    positions 0..11) but the k=8 draft's verify block reaches position 14
    — a 4th page is allocated mid-tick and MUST come back to the pool the
    same tick, whether the request survives it or finishes."""
    cfg, params = spec_world
    eng = _spec_engine(cfg, params)
    h = eng.submit(list(range(10, 16)), max_new=3)
    eng.step()                        # prefill + the straddling spec tick
    # the draft ran at FULL length 8 — positions 6..14, whose verify block
    # needs a 4th page beyond the 3 reserved — and did not shrink: the
    # transient page was really allocated
    assert eng.stats["spec_draft_tokens"] == 8
    assert eng.stats["spec_page_shrinks"] == 0
    while eng.busy:
        eng.step()
        eng.check_invariants()
        # transient pages never outlive their tick
        if eng.slots[0] is not None:
            assert len(eng.slot_pages[0]) == eng._prealloc[0] == 3
    eng.check_invariants()
    assert len(h.generated) == 3
    eng.release_prefix_cache()
    assert eng.pool.pages_in_use == 0
    eng.check_invariants()


def test_spec_draft_pool_exhaustion_shrinks_not_leaks(spec_world):
    """With ZERO free pages (total = trash + exactly the reservation) the
    overrunning draft cannot get its transient page: the draft shrinks to
    the covered region (stats the shrink), generation still completes,
    and no page leaks. The slot's own radix-published page is pinned by
    the slot's reference, so eviction cannot save the draft either."""
    cfg, params = spec_world
    eng = _spec_engine(cfg, params, total_pages=4)
    h = eng.submit(list(range(20, 26)), max_new=3)
    eng.step()                        # draft wants page 4 of 3: shrink
    assert eng.stats["spec_page_shrinks"] >= 1
    # shrunk to what 3 pages cover: positions <= 11, so dl = 11 - 6 = 5
    assert eng.stats["spec_draft_tokens"] == 5
    eng.check_invariants()
    eng.run()
    assert len(h.generated) == 3
    eng.release_prefix_cache()
    assert eng.pool.pages_in_use == 0
    eng.check_invariants()


def test_reference_np_gather_matches_pool_layout():
    """The device-side contract in miniature: writing token t of slot s to
    page table[s][t // pg] at offset t % pg and gathering pool[table[s]]
    reconstructs the slot's logical KV stream in order."""
    pg, pages_per_slot = 4, 3
    pool_arr = np.zeros((8, pg), np.int64)
    table = np.array([[3, 5, 1], [2, 6, 4]])
    streams = [np.arange(100, 110), np.arange(200, 207)]
    for s, stream in enumerate(streams):
        for t, tok in enumerate(stream):
            pool_arr[table[s][t // pg], t % pg] = tok
    for s, stream in enumerate(streams):
        logical = pool_arr[table[s]].reshape(-1)
        assert (logical[:len(stream)] == stream).all()
