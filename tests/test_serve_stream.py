"""Streaming serve API: device-side sampling, handles/events, cancellation,
pluggable scheduling.

The acceptance contract (ISSUE 5): temperature-0 through the new streaming
API reproduces the pre-redesign greedy engine token-for-token (f32 AND
int8) with sampling executed device-side; cancel() frees the slot for the
next queued request; fixed-seed sampling is deterministic across step()-
and run()-driven execution; the priority scheduler admits out of FCFS
order and deadline eviction emits EVICTED.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro import api
from repro.launch.serve import generate
from repro.models.lm import init_lm
from repro.serve import (
    EventKind,
    GenerationHandle,
    SamplingParams,
    ServeEngine,
    make_scheduler,
)

KEY = jax.random.PRNGKey(0)


def _engine(arch="qwen2-0.5b", **kw):
    cfg = configs.get_smoke(arch)
    params = init_lm(KEY, cfg, jnp.dtype(cfg.dtype))
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_cache", 64)
    kw.setdefault("buckets", (4, 8, 16))
    return ServeEngine(params, cfg, **kw), cfg, params


# ---------------------------------------------------------------------------
# temperature=0 == the pre-redesign greedy engine, f32 and int8
# ---------------------------------------------------------------------------

def test_greedy_stream_matches_legacy_f32():
    """Tokens consumed through the streaming iterator (which DRIVES the
    engine) must be bitwise those of the legacy lockstep greedy path."""
    eng, cfg, params = _engine()
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (3, 7, 5, 11)]
    handles = [eng.submit(p, max_new=6) for p in prompts]
    streamed = []
    for h in handles:
        toks = [ev.token for ev in h.stream() if ev.kind is EventKind.TOKEN]
        assert toks == h.generated          # stream saw every token
        streamed.append(h.tokens)
    for p, got in zip(prompts, streamed):
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_cache=64, n_new=6)
        assert got == [int(t) for t in ref[0]], p


def test_greedy_stream_matches_legacy_int8():
    """Same contract on an int8 deployment: the new engine's temperature-0
    rows and the pre-redesign greedy path, both serving the SAME quantized
    params, agree token-for-token (identical logits -> identical argmax,
    so this holds even at random init)."""
    from repro.api import convert

    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    try:
        qplan = api.install(api.resolve(cfg).quantized("int8"))
        params = convert.quantize(init_lm(KEY, cfg, jnp.dtype(cfg.dtype)),
                                  qplan)
        eng = ServeEngine(params, plan=qplan, max_slots=2, max_cache=64,
                          buckets=(4, 8, 16))
        assert eng.quantized
        rng = np.random.default_rng(2)
        prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (3, 9)]
        handles = [eng.submit(p, max_new=5) for p in prompts]
        eng.run()
        for p, h in zip(prompts, handles):
            ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                           max_cache=64, n_new=5)
            assert h.tokens == [int(t) for t in ref[0]], p
    finally:
        api.uninstall(cfg)


# ---------------------------------------------------------------------------
# handles, events, metrics
# ---------------------------------------------------------------------------

def test_handle_events_and_latency_metrics():
    eng, cfg, _ = _engine()
    h = eng.submit([1, 2, 3], max_new=4)
    assert isinstance(h, GenerationHandle)
    assert h.status is None and h.ttft_s is None and h.tpot_s is None
    eng.run()
    kinds = [ev.kind for ev in h.events]
    assert kinds == [EventKind.TOKEN] * 4 + [EventKind.FINISHED]
    assert h.finished and h.events[-1].reason == "max_new"
    assert h.ttft_s is not None and h.ttft_s > 0
    assert h.tpot_s is not None and h.tpot_s > 0
    # event timestamps are monotone and bracket the metrics
    ts = [ev.t for ev in h.events]
    assert ts == sorted(ts)


def test_stream_non_driving_and_result():
    eng, cfg, _ = _engine()
    h = eng.submit([1, 2, 3], max_new=3)
    assert list(h.stream(drive=False)) == []     # nothing buffered, no tick
    out = h.result()
    assert out == h.tokens and len(h.generated) == 3
    # a fresh stream() over a finished request replays the full event log
    assert [ev.kind for ev in h.stream()][-1] is EventKind.FINISHED


def test_eos_reason_on_finish():
    eng, cfg, _ = _engine()
    h = eng.submit([1, 2, 3], max_new=50)
    eng.run()
    eos = h.generated[0]
    eng2, _, _ = _engine()
    h2 = eng2.submit([1, 2, 3], max_new=50, eos_id=eos)
    eng2.run()
    assert h2.events[-1].reason == "eos"
    assert len(h2.generated) < 50


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_cancel_frees_slot_and_admits_queued():
    eng, cfg, _ = _engine(max_slots=1)
    a = eng.submit([1, 2, 3], max_new=50)
    eng.step()                                   # a admitted + 1 token
    b = eng.submit([4, 5, 6], max_new=3)         # queued behind a
    assert eng.slots[0] is not None and len(eng.queue) == 1
    assert a.cancel()
    assert eng.slots[0] is None                  # freed IMMEDIATELY
    assert a.status is EventKind.CANCELLED
    assert a.events[-1].kind is EventKind.CANCELLED
    assert not a.cancel()                        # already terminal
    eng.step()                                   # next tick admits b
    assert len(b.generated) >= 1
    eng.run()
    assert b.finished
    assert eng.stats["cancelled"] == 1 and eng.stats["completed"] == 1


def test_cancel_queued_request():
    eng, cfg, _ = _engine(max_slots=1)
    a = eng.submit([1, 2, 3], max_new=4)
    b = eng.submit([4, 5, 6], max_new=4)
    assert eng.cancel(b.rid)
    assert b.status is EventKind.CANCELLED and not b.generated
    eng.run()
    assert a.finished and eng.stats["completed"] == 1
    assert not eng.cancel(999)                   # unknown rid


# ---------------------------------------------------------------------------
# device-side sampling: determinism, parameter validation
# ---------------------------------------------------------------------------

def test_fixed_seed_topk_deterministic_step_vs_run():
    """Fixed-seed sampling depends only on (seed, token index) — never on
    which tick or slot produced the token — so run()-driven and manual
    step()-driven execution generate identical sequences."""
    cfg = configs.get_smoke("qwen2-0.5b")
    params = init_lm(KEY, cfg, jnp.dtype(cfg.dtype))
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (3, 7, 5)]
    sp = SamplingParams(temperature=1.0, top_k=4, seed=42)

    def drive(how):
        eng = ServeEngine(params, cfg, max_slots=2, max_cache=64,
                          buckets=(4, 8, 16))
        hs = [eng.submit(p, max_new=6, sampling=sp) for p in prompts]
        if how == "run":
            eng.run()
        else:
            while eng.busy:
                eng.step()
        return [h.generated for h in hs]

    a, b = drive("run"), drive("step")
    assert a == b
    # and it actually sampled (temperature 1 differs from greedy here)
    eng, _, _ = _engine()
    greedy = [eng.submit(p, max_new=6) for p in prompts]
    eng.run()
    assert a != [h.generated for h in greedy]


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(temperature=-0.1)
    with pytest.raises(ValueError):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=1.5)
    with pytest.raises(ValueError):
        SamplingParams(max_new=0)
    with pytest.raises(ValueError):
        SamplingParams(deadline_s=0.0)
    # resolved() re-validates submit()-level overrides
    with pytest.raises(ValueError):
        SamplingParams().resolved(0, max_new=0)
    # missing seed becomes the rid (stable replay)
    assert SamplingParams(temperature=1.0).resolved(7).seed == 7
    assert SamplingParams(seed=3).resolved(7).seed == 3


def test_topp_renormalizes_after_topk():
    """Nucleus cut applies to the top-k-RENORMALIZED distribution (the
    sequential-warper convention): with a near-flat distribution, top_k=8
    + top_p=0.5 keeps ~half the top-k mass, never all 8 survivors."""
    from repro.serve.sampling import sample_tokens

    v = 64
    logits = jnp.zeros((1, v)) + 1e-4 * jnp.arange(v)[None, ::-1]
    kw = dict(temperature=jnp.ones(1), seeds=jnp.zeros(1, jnp.uint32))
    draws = {int(sample_tokens(logits, top_k=jnp.array([8]),
                               top_p=jnp.array([0.5]),
                               counts=jnp.array([c]), **kw)[0])
             for c in range(200)}
    # renormalized: ceil(0.5 * 8) = 4 survivors; unrenormalized full-vocab
    # mass would never reach 0.5 inside the top-8 and keep all 8
    assert draws <= {0, 1, 2, 3} and len(draws) > 1


def test_slot_sampling_state_reset_on_free():
    """A finished/cancelled sampled request must not leave temperature > 0
    on its freed slot (it would defeat the all-greedy lax.cond fast path)."""
    eng, cfg, _ = _engine(max_slots=1)
    h = eng.submit([1, 2, 3], max_new=2,
                   sampling=SamplingParams(temperature=0.9, top_k=4, seed=1))
    eng.run()
    assert h.finished
    assert float(eng.temp[0]) == 0.0 and int(eng.top_k[0]) == 0
    assert float(eng.top_p[0]) == 1.0
    h2 = eng.submit([4, 5, 6], max_new=20,
                    sampling=SamplingParams(temperature=0.9, seed=2))
    eng.step()
    assert eng.cancel(h2.rid)
    assert float(eng.temp[0]) == 0.0


def test_greedy_rows_ignore_seed():
    """temperature=0 must be seed-independent (it is pure argmax)."""
    eng, cfg, params = _engine()
    p = [5, 6, 7]
    h1 = eng.submit(p, max_new=4, sampling=SamplingParams(seed=1))
    h2 = eng.submit(p, max_new=4, sampling=SamplingParams(seed=999))
    eng.run()
    assert h1.generated == h2.generated


# ---------------------------------------------------------------------------
# schedulers
# ---------------------------------------------------------------------------

def test_priority_preempts_fcfs_order():
    """With one slot occupied, a later-submitted high-priority request is
    admitted before earlier FCFS-order requests."""
    eng, cfg, _ = _engine(max_slots=1, scheduler="priority")
    a = eng.submit([1, 2, 3], max_new=3)
    eng.step()                                                 # a occupies
    b = eng.submit([4, 5, 6], max_new=3)                       # prio 0
    c = eng.submit([7, 8, 9], max_new=3,
                   sampling=SamplingParams(priority=5))        # jumps b
    eng.run()
    assert all(h.finished for h in (a, b, c))
    assert a._req.first_token_at < c._req.first_token_at
    assert c._req.first_token_at < b._req.first_token_at


def test_deadline_eviction_emits_evicted():
    eng, cfg, _ = _engine(max_slots=1, scheduler="priority")
    a = eng.submit([1, 2, 3],
                   sampling=SamplingParams(max_new=50, deadline_s=1e-4))
    eng.step()                          # admitted: prefill + 1 decode token
    assert eng.slots[0] is not None and len(a.generated) >= 1
    time.sleep(0.005)                            # let the deadline expire
    b = eng.submit([4, 5, 6], max_new=2)
    eng.step()                                   # evict a, admit b
    assert a.status is EventKind.EVICTED
    assert a.events[-1].kind is EventKind.EVICTED
    assert a.events[-1].reason == "deadline"
    assert len(a.generated) >= 1                 # partial tokens retained
    eng.run()
    assert b.finished
    assert eng.stats["evicted"] == 1 and eng.stats["completed"] == 1


def test_deadline_expired_in_queue_never_admitted():
    eng, cfg, _ = _engine(max_slots=1, scheduler="priority")
    a = eng.submit([1, 2, 3], max_new=3)
    q = eng.submit([4, 5], sampling=SamplingParams(max_new=3,
                                                   deadline_s=1e-5))
    time.sleep(0.005)
    eng.run()
    assert a.finished
    assert q.status is EventKind.EVICTED and not q.generated


def test_shortest_prompt_first_order():
    eng, cfg, _ = _engine(max_slots=1, scheduler="spf")
    long = eng.submit([1] * 12, max_new=2)
    short = eng.submit([2] * 3, max_new=2)
    mid = eng.submit([3] * 6, max_new=2)
    eng.run()
    t = {h: h._req.first_token_at for h in (long, short, mid)}
    assert t[short] < t[mid] < t[long]


def test_make_scheduler_registry():
    assert make_scheduler("fcfs").name == "fcfs"
    assert make_scheduler("spf").name == "spf"
    assert make_scheduler("priority").name == "priority"
    with pytest.raises(ValueError):
        make_scheduler("round-robin")


def test_summary_reports_scheduler_and_new_counters():
    eng, cfg, _ = _engine(scheduler="spf")
    eng.submit([1, 2, 3], max_new=2)
    eng.run()
    s = eng.summary()
    assert s["scheduler"] == "spf"
    assert {"cancelled", "evicted", "completed"} <= set(s)
