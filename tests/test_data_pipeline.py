"""Streaming tokenized input pipeline: tokenizers, corpus writer, sharded
sources, packing, checkpointable reader state, the prefetcher, the
registry, and end-to-end resume determinism through train_loop."""
import glob
import os

import jax
import numpy as np
import pytest

from repro import api
from repro.checkpoint import CheckpointManager, restore_extra, save_checkpoint
from repro.config import AsiConfig, LayerGroup, ModelConfig, TrainConfig, WasiConfig
from repro.data.pipeline import DataIterator, DeviceIterator, PackedStream
from repro.data.registry import TextDataset, make_dataset
from repro.data.source import ShardedTextSource, doc_topic, write_corpus
from repro.data.tokenizer import (BpeTokenizer, ByteTokenizer, EOS_ID,
                                  get_tokenizer)

B, S = 2, 24


@pytest.fixture(scope="module")
def corpus(tmp_path_factory):
    root = tmp_path_factory.mktemp("corpus")
    write_corpus(str(root), n_shards=4, docs_per_shard=24, seed=0)
    return str(root)


def _dataset(corpus, **kw):
    kw.setdefault("seq_len", S)
    kw.setdefault("global_batch", B)
    kw.setdefault("seed", 0)
    return TextDataset(os.path.join(corpus, "*.txt"), **kw)


# -- tokenizers --------------------------------------------------------------

def test_byte_tokenizer_roundtrip_unicode():
    tok = ByteTokenizer()
    s = "héllo wörld — 分词 ok"
    ids = tok.encode(s)
    assert max(ids) < 256 and tok.vocab_size == 257 and tok.eos_id == EOS_ID
    assert tok.decode(ids) == s
    assert tok.decode(ids + [EOS_ID]) == s  # EOS never decodes to text


def test_bpe_train_compresses_roundtrips_and_persists(tmp_path, corpus):
    texts = [ln for p in glob.glob(os.path.join(corpus, "*.txt"))
             for ln in open(p)]
    bpe = BpeTokenizer.train(texts, vocab_size=320)
    assert bpe.vocab_size == 320
    enc = bpe.encode(texts[0].strip())
    assert bpe.decode(enc) == texts[0].strip()
    assert len(enc) < len(texts[0].strip().encode("utf-8"))
    path = str(tmp_path / "vocab.json")
    bpe.save(path)
    again = get_tokenizer(f"bpe:{path}")
    assert again.key == bpe.key
    assert again.encode(texts[1].strip()) == bpe.encode(texts[1].strip())
    # retraining on the same corpus is bit-identical
    assert BpeTokenizer.train(texts, vocab_size=320).merges == bpe.merges


def test_tokenizer_spec_errors():
    with pytest.raises(ValueError, match="unknown tokenizer"):
        get_tokenizer("sentencepiece")
    with pytest.raises(ValueError, match="byte floor"):
        BpeTokenizer.train(["abc"], vocab_size=100)


# -- corpus writer + sharded source ------------------------------------------

def test_write_corpus_reproducible(tmp_path):
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    pa = write_corpus(a, n_shards=2, docs_per_shard=8, seed=3)
    pb = write_corpus(b, n_shards=2, docs_per_shard=8, seed=3)
    for x, y in zip(pa, pb):
        assert open(x).read() == open(y).read()
    pc = write_corpus(str(tmp_path / "c"), n_shards=2, docs_per_shard=8,
                      seed=4)
    assert open(pa[0]).read() != open(pc[0]).read()
    assert all(doc_topic(ln) < 8 for ln in open(pa[0]))


def test_source_round_robin_by_process_index(corpus):
    shards = sorted(glob.glob(os.path.join(corpus, "*.txt")))
    owned = [ShardedTextSource(shards, i, 2).owned for i in range(2)]
    assert owned[0] == shards[0::2] and owned[1] == shards[1::2]
    assert sorted(owned[0] + owned[1]) == shards
    with pytest.raises(ValueError, match="cannot feed"):
        ShardedTextSource(shards[:1], 0, 2)
    with pytest.raises(ValueError, match="process_index"):
        ShardedTextSource(shards, 5, 2)
    with pytest.raises(FileNotFoundError):
        ShardedTextSource.from_glob(os.path.join(corpus, "*.nope"))


# -- packing -----------------------------------------------------------------

class _ListProvider:
    """Token docs straight from lists — isolates PackedStream logic."""

    def __init__(self, shards):
        self._shards = [[np.asarray(d, np.int32) for d in s] for s in shards]

    @property
    def n_owned(self):
        return len(self._shards)

    def token_docs(self, i):
        return self._shards[i]


def test_packing_is_dense_interleaved_concatenation():
    # two shards, docs tagged by value; EOS = 9; no shuffle -> the window
    # stream must be the round-robin doc concatenation, no pad, no drop
    sh0 = [[1, 1, 9], [2, 2, 2, 9]]
    sh1 = [[5, 9], [6, 6, 9]]
    ps = PackedStream(_ListProvider([sh0, sh1]), seq_len=4, batch_size=1,
                      shuffle=0, seed=0)
    flat = []
    for _ in range(5):
        flat.extend(ps.next_row())
    expect = [1, 1, 9, 5, 9, 2, 2, 2, 9, 6, 6, 9]   # epoch 0, interleaved
    assert flat[:len(expect)] == expect
    assert flat[len(expect):len(expect) * 2] == expect  # epoch 1 replays
    assert int(ps.state()["epoch"]) >= 1


def test_batch_labels_are_next_tokens(corpus):
    ds = _dataset(corpus)
    it = ds.stream()
    b = it.next_batch()
    assert b["tokens"].shape == (B, S) and b["tokens"].dtype == np.int32
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert int(b["tokens"].max()) < ds.vocab_size
    # EOS boundaries actually appear in packed windows (documents are
    # packed dense across boundaries, EOS is the separator)
    st = ds.stream()
    assert any((st.next_batch()["tokens"] == EOS_ID).any()
               for _ in range(10))


def test_stream_state_resume_elementwise(corpus):
    ds = _dataset(corpus, shuffle=8)
    a = ds.stream()
    for _ in range(3):
        a.next_batch()
    snap = a.state()
    want = [a.next_batch() for _ in range(4)]
    b = ds.stream()
    b.load_state(snap)
    for w in want:
        got = b.next_batch()
        np.testing.assert_array_equal(got["tokens"], w["tokens"])
        np.testing.assert_array_equal(got["labels"], w["labels"])


def test_stream_resume_across_epoch_boundary(tmp_path):
    root = str(tmp_path / "tiny")
    write_corpus(root, n_shards=1, docs_per_shard=2, seed=1,
                 words_per_doc=(2, 4))
    ds = TextDataset(os.path.join(root, "*.txt"), seq_len=8, global_batch=1,
                     seed=0, shuffle=4)
    a = ds.stream()
    for _ in range(12):
        a.next_batch()
    assert int(a.state()["epoch"]) >= 1   # tiny corpus wraps
    snap = a.state()
    want = [a.next_batch()["tokens"] for _ in range(3)]
    b = ds.stream()
    b.load_state(snap)
    for w in want:
        np.testing.assert_array_equal(b.next_batch()["tokens"], w)


def test_load_state_rejects_foreign_shapes(corpus):
    ds = _dataset(corpus)
    other = TextDataset(os.path.join(corpus, "*.txt"), seq_len=S + 8,
                        global_batch=B, seed=0)
    with pytest.raises(ValueError, match="different corpus"):
        ds.stream().load_state(other.stream().state())


# -- prefetcher --------------------------------------------------------------

def test_device_iterator_preserves_order_and_satisfies_protocol(corpus):
    ds = _dataset(corpus)
    sync = ds.stream()
    want = [sync.next_batch() for _ in range(5)]
    it = ds.iterator()
    assert isinstance(it, DataIterator)
    try:
        for w in want:
            got = it.next_batch()
            np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                          w["tokens"])
        s = it.stats()
        assert s["batches"] == 5 and s["tok_s"] > 0
        assert 0.0 <= s["stall_frac"] <= 1.0
    finally:
        it.close()


def test_device_iterator_restore_midstream(corpus):
    ds = _dataset(corpus, shuffle=8)
    it = ds.iterator(prefetch=3)
    try:
        for _ in range(2):
            it.next_batch()
        snap = it.state()   # state of last CONSUMED batch, not producer's
        want = [np.asarray(it.next_batch()["tokens"]) for _ in range(4)]
    finally:
        it.close()
    it2 = ds.iterator(prefetch=3)
    try:
        it2.restore(snap)
        for w in want:
            np.testing.assert_array_equal(
                np.asarray(it2.next_batch()["tokens"]), w)
    finally:
        it2.close()


def test_device_iterator_rejects_bad_depth(corpus):
    with pytest.raises(ValueError, match="prefetch depth"):
        _dataset(corpus).iterator(prefetch=0)


# -- checkpoint extras -------------------------------------------------------

def test_reader_state_roundtrips_through_checkpoint(tmp_path, corpus):
    ds = _dataset(corpus)
    st = ds.stream()
    for _ in range(2):
        st.next_batch()
    reader = st.state()
    save_checkpoint(str(tmp_path), 7, {"w": np.arange(3.0)},
                    extra={"reader": reader})
    got = restore_extra(str(tmp_path), 7, "reader")
    assert sorted(got) == sorted(reader)
    for k in reader:
        np.testing.assert_array_equal(got[k], reader[k])
    # absent extra -> None (old checkpoints stay loadable)
    assert restore_extra(str(tmp_path), 7, "nope") is None
    save_checkpoint(str(tmp_path), 8, {"w": np.arange(3.0)})
    assert restore_extra(str(tmp_path), 8, "reader") is None


def test_checkpoint_manager_extra_async(tmp_path, corpus):
    ds = _dataset(corpus)
    st = ds.stream()
    st.next_batch()
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save_async(3, {"w": np.zeros(2)}, extra={"reader": st.state()})
    mgr.wait()
    got = mgr.restore_extra(3, "reader")
    np.testing.assert_array_equal(got["doc_cursor"],
                                  st.state()["doc_cursor"])


# -- registry ----------------------------------------------------------------

def test_registry_dispatch(corpus):
    import repro.configs as configs
    from repro.data.synthetic import SyntheticAudio, SyntheticLM
    lm = configs.get_smoke("qwen2-0.5b")
    assert isinstance(make_dataset("synthetic", lm, batch=2, seq=8),
                      SyntheticLM)
    enc = configs.get_smoke("whisper-tiny")
    assert isinstance(make_dataset("synthetic", enc, batch=2, seq=8),
                      SyntheticAudio)
    txt = make_dataset(f"text:{corpus}/*.txt", lm, batch=2, seq=8)
    assert isinstance(txt, TextDataset)
    with pytest.raises(ValueError, match="unknown dataset"):
        make_dataset("imagenet", lm, batch=2, seq=8)
    with pytest.raises(ValueError, match="shard glob"):
        make_dataset("text:", lm, batch=2, seq=8)
    with pytest.raises(ValueError, match="LM families"):
        make_dataset(f"text:{corpus}/*.txt", enc, batch=2, seq=8)


def test_random_access_batch_is_pure_in_seed_step(corpus):
    ds = _dataset(corpus)
    a, b = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(ds.batch(6)["tokens"], a["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    assert ds.batch(5, batch_size=1)["tokens"].shape == (1, S)


# -- per-tenant corpus filter ------------------------------------------------

def test_tenant_filter_is_deterministic_and_distinct(corpus):
    ds = _dataset(corpus)
    alice, bob = ds.for_tenant("alice"), ds.for_tenant("bob")
    n_all = sum(len(ds.token_docs(i)) for i in range(ds.n_owned))
    n_a = sum(len(alice.token_docs(i)) for i in range(alice.n_owned))
    n_b = sum(len(bob.token_docs(i)) for i in range(bob.n_owned))
    assert 0 < n_a < n_all and 0 < n_b < n_all
    # same tenant twice -> identical sub-corpus
    again = ds.for_tenant("alice")
    for i in range(ds.n_owned):
        da, dg = alice.token_docs(i), again.token_docs(i)
        assert len(da) == len(dg)
        for x, y in zip(da, dg):
            np.testing.assert_array_equal(x, y)
    # different tenants -> different doc mixes
    assert any(len(alice.token_docs(i)) != len(bob.token_docs(i))
               for i in range(ds.n_owned)) or n_a != n_b
    # tenant streams keep the resume property
    st = alice.stream()
    st.next_batch()
    snap = st.state()
    want = st.next_batch()["tokens"]
    st2 = alice.for_tenant("alice").stream()  # fresh clone, shared cache
    st2.load_state(snap)
    np.testing.assert_array_equal(st2.next_batch()["tokens"], want)


# -- end to end: train_loop resume replays the stream ------------------------

def _lm_world(vocab: int, seed: int = 0):
    cfg = ModelConfig(
        name="data-lm", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=vocab, head_dim=8,
        groups=(LayerGroup(("dense",), 2),),
        wasi=WasiConfig(method="wasi", scope="all", rank_frac=0.5,
                        rank_align=4, min_rank=4,
                        asi=AsiConfig(token_frac=0.5, feature_frac=0.5)),
        dtype="float32", remat="none")
    from repro.models.lm import init_lm, init_lm_states, lm_loss
    tcfg = TrainConfig(optimizer="sgd", lr=0.1, steps=8, checkpoint_every=4,
                       schedule="constant", seed=seed)
    api.install(api.resolve(cfg, batch=B, seq=S))
    key = jax.random.PRNGKey(seed)
    params = init_lm(key, cfg)
    states = init_lm_states(key, cfg, B, S)
    from repro.train.step import make_train_state, make_train_step
    state = make_train_state(key, params, cfg, tcfg, asi_states=states)
    return cfg, tcfg, state, make_train_step(lm_loss, cfg, tcfg)


class _Recording:
    """DataIterator wrapper that records consumed tokens and can 'die'."""

    def __init__(self, it, die_at: int | None = None):
        self.it, self.seen, self.die_at = it, [], die_at

    def next_batch(self, step=None):
        if self.die_at is not None and len(self.seen) >= self.die_at:
            raise RuntimeError("simulated mid-stream kill")
        b = self.it.next_batch(step)
        self.seen.append(np.asarray(b["tokens"]).copy())
        return b

    def state(self):
        return self.it.state()

    def restore(self, s):
        self.it.restore(s)

    def close(self):
        self.it.close()


def test_train_loop_text_resume_replays_stream(tmp_path, corpus):
    from repro.train.loop import train_loop
    ds = _dataset(corpus)
    cfg, tcfg, state0, step_fn = _lm_world(ds.vocab_size)

    # uninterrupted reference run: 8 steps, record every consumed batch
    ref = _Recording(ds.iterator())
    try:
        _, ref_hist = train_loop(state0, step_fn, ref, tcfg, log_every=1)
    finally:
        ref.close()
    assert len(ref.seen) == 8

    # interrupted run: checkpoint at 4, die mid-step-6, resume, finish
    cfg, tcfg, state0, step_fn = _lm_world(ds.vocab_size)
    ckpt = CheckpointManager(str(tmp_path / "ck"), keep=2)
    killed = _Recording(ds.iterator(), die_at=6)
    with pytest.raises(RuntimeError, match="simulated"):
        train_loop(state0, step_fn, killed, tcfg, ckpt=ckpt, log_every=1)
    killed.close()
    ckpt.wait()

    cfg, tcfg, state1, step_fn = _lm_world(ds.vocab_size)
    resumed = _Recording(ds.iterator())
    logs = []
    try:
        _, hist = train_loop(state1, step_fn, resumed, tcfg, ckpt=ckpt,
                             log_every=1, log_fn=logs.append)
    finally:
        resumed.close()
    assert any("reader state restored" in ln for ln in logs)
    # the continued stream is elementwise identical to the uninterrupted one
    assert len(resumed.seen) == 4            # steps 4..7
    for got, want in zip(resumed.seen, ref.seen[4:]):
        np.testing.assert_array_equal(got, want)
    # and the training curve rejoins the reference exactly
    ref_loss = {h["step"]: h["loss"] for h in ref_hist}
    for h in hist:
        np.testing.assert_allclose(h["loss"], ref_loss[h["step"]],
                                   rtol=1e-6)


@pytest.mark.multidevice
def test_train_loop_text_resume_under_mesh(tmp_path, corpus):
    """The same replay property with the DP mesh: iterator places batches
    onto dp_batch_sharding, reader state rides the sharded train state's
    checkpoint."""
    from repro.launch.mesh import make_host_mesh
    from repro.train.loop import train_loop
    from repro.train.step import (dp_batch_sharding, dp_state_shardings,
                                  make_train_state, make_train_step)
    from repro.models.lm import init_lm, init_lm_states, lm_loss

    mesh = make_host_mesh(2)
    ds = _dataset(corpus)
    cfg = ModelConfig(
        name="data-lm-dp", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=ds.vocab_size, head_dim=8,
        groups=(LayerGroup(("dense",), 2),),
        wasi=WasiConfig(method="wasi", scope="all", rank_frac=0.5,
                        rank_align=4, min_rank=4,
                        asi=AsiConfig(token_frac=0.5, feature_frac=0.5)),
        dtype="float32", remat="none")
    tcfg = TrainConfig(optimizer="sgd", lr=0.1, steps=6, checkpoint_every=3,
                       schedule="constant", seed=0)

    def world():
        plan = api.install(api.resolve(cfg, batch=B, seq=S).with_sharding())
        key = jax.random.PRNGKey(0)
        params = init_lm(key, cfg)
        states = init_lm_states(key, cfg, B, S)
        state = make_train_state(key, params, cfg, tcfg, asi_states=states,
                                 dp_degree=mesh.devices.size)
        state = jax.device_put(state, dp_state_shardings(state, mesh))
        return state, make_train_step(lm_loss, cfg, tcfg, mesh=mesh)

    sharding = dp_batch_sharding(mesh)
    state, step_fn = world()
    ref = _Recording(ds.iterator(sharding=sharding))
    try:
        train_loop(state, step_fn, ref, tcfg, log_every=1)
    finally:
        ref.close()

    state, step_fn = world()
    ckpt = CheckpointManager(str(tmp_path / "ck_dp"), keep=2)
    killed = _Recording(ds.iterator(sharding=sharding), die_at=4)
    with pytest.raises(RuntimeError, match="simulated"):
        train_loop(state, step_fn, killed, tcfg, ckpt=ckpt, log_every=1)
    killed.close()
    ckpt.wait()

    state, step_fn = world()
    resumed = _Recording(ds.iterator(sharding=sharding))
    try:
        train_loop(state, step_fn, resumed, tcfg, ckpt=ckpt, log_every=1)
    finally:
        resumed.close()
    assert len(resumed.seen) == 3            # steps 3..5
    for got, want in zip(resumed.seen, ref.seen[3:]):
        np.testing.assert_array_equal(got, want)
