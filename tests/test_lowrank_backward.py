"""Gradient equivalence for the sketch-saving backward path.

Three layers of oracle:
  1. the fused Pallas backward kernel vs its pure-jnp reference;
  2. the kernel-backed custom VJP (ops.lowrank_matmul_fused) vs jax.grad of
     the dense einsum pair, across shapes/dtypes/batch dims;
  3. the Tucker-residual custom VJPs (core/lowrank_linear) vs the dense
     jax.grad oracle — EXACT (1e-5, f32) when every ASI mode is identity,
     and vs the compressed oracle across batch-rank combos otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.asi import asi_init, asi_step, tucker_reconstruct
from repro.core.lowrank_linear import asi_matmul, wasi_matmul
from repro.kernels import lowrank_bwd_fused, lowrank_matmul_fused
from repro.kernels import ref
from repro.kernels.lowrank import lowrank_fused_tiled

KEY = jax.random.PRNGKey(7)


def _factors(key, m, i, k, o, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (m, i)).astype(dtype)
    R = (jax.random.normal(ks[1], (k, i)) / i ** 0.5).astype(dtype)
    L = (jax.random.normal(ks[2], (o, k)) / k ** 0.5).astype(dtype)
    dy = jax.random.normal(ks[3], (m, o)).astype(dtype)
    return x, R, L, dy


# -- 1. kernel vs reference -------------------------------------------------

@pytest.mark.parametrize("m,i,k,o", [(128, 96, 16, 64), (64, 48, 8, 24),
                                     # ragged: nothing 8/128-aligned
                                     (37, 70, 5, 33), (100, 130, 100, 7),
                                     (1, 9, 3, 513)])
def test_bwd_kernel_matches_ref(m, i, k, o):
    x, R, L, dy = _factors(KEY, m, i, k, o)
    h = (x @ R.T).astype(jnp.float32)
    got = lowrank_bwd_fused(dy, x, h, L, R)
    want = ref.lowrank_bwd_ref(dy, x, h, L, R)
    for g, w, name in zip(got, want, ("dx", "dL", "dR")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_save_sketch_forward_consistent():
    """save_sketch=True must not change y, and h must be exactly x R^T."""
    x, R, L, _ = _factors(KEY, 100, 70, 12, 40)
    y_plain = lowrank_fused_tiled(x, R.T, L.T)
    y, h = lowrank_fused_tiled(x, R.T, L.T, save_sketch=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_plain),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(h), np.asarray(x @ R.T),
                               rtol=1e-5, atol=1e-5)


# -- 2. kernel-backed custom VJP vs dense autodiff --------------------------

@pytest.mark.parametrize("shape,kdim,odim", [((2, 16, 48), 8, 24),
                                             ((4, 1, 9), 3, 17),
                                             ((1, 257, 130), 31, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_vjp_matches_dense_grad(shape, kdim, odim, dtype):
    x = jax.random.normal(KEY, shape).astype(dtype)
    R = (jax.random.normal(jax.random.fold_in(KEY, 1), (kdim, shape[-1]))
         / shape[-1] ** 0.5).astype(dtype)
    L = (jax.random.normal(jax.random.fold_in(KEY, 2), (odim, kdim))
         / kdim ** 0.5).astype(dtype)

    def fused(x, R, L):
        return (lowrank_matmul_fused(x, R, L).astype(jnp.float32) ** 2).sum()

    def dense(x, R, L):
        h = jnp.einsum("...i,ki->...k", x, R)
        y = jnp.einsum("...k,ok->...o", h, L)
        return (y.astype(jnp.float32) ** 2).sum()

    got = jax.grad(fused, argnums=(0, 1, 2))(x, R, L)
    want = jax.grad(dense, argnums=(0, 1, 2))(x, R, L)
    # bf16: rounding error is relative to the TENSOR scale (bf16 operands,
    # f32 accumulation), so atol scales with max|grad| — same convention as
    # test_kernels.py's dtype sweeps
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    for g, w, name in zip(got, want, ("dx", "dR", "dL")):
        w32 = np.asarray(w, np.float32)
        np.testing.assert_allclose(np.asarray(g, np.float32), w32,
                                   rtol=tol,
                                   atol=tol * max(1.0, np.abs(w32).max()),
                                   err_msg=name)


# -- 3. Tucker-residual custom VJPs vs the dense jax.grad oracle ------------

@pytest.mark.parametrize("b,n,i,o,k", [(2, 8, 24, 16, 6), (1, 5, 16, 32, 4),
                                       (3, 17, 20, 12, 8)])
def test_wasi_identity_asi_matches_dense_grad_1e5(b, n, i, o, k):
    """Acceptance bar: with every ASI mode at identity the compression is
    exact, so the sketch-saving custom VJP must reproduce the dense
    jax.grad oracle to 1e-5 in f32 — for dx, dL AND dR."""
    ks = jax.random.split(jax.random.fold_in(KEY, b * n), 3)
    x = jax.random.normal(ks[0], (b, n, i))
    L = jax.random.normal(ks[1], (o, k)) / k ** 0.5
    R = jax.random.normal(ks[2], (k, i)) / i ** 0.5
    st = asi_init(ks[0], x.shape, x.shape)      # identity everywhere
    xt, _ = asi_step(x, st)

    def custom(x_, L_, R_):
        return jnp.sum(jnp.tanh(wasi_matmul(x_, L_, R_, xt)))

    def dense(x_, L_, R_):
        return jnp.sum(jnp.tanh(x_ @ R_.T @ L_.T))

    got = jax.grad(custom, argnums=(0, 1, 2))(x, L, R)
    want = jax.grad(dense, argnums=(0, 1, 2))(x, L, R)
    for g, w, name in zip(got, want, ("dx", "dL", "dR")):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5, err_msg=name)


def test_asi_identity_matches_dense_grad_1e5():
    key = jax.random.fold_in(KEY, 11)
    x = jax.random.normal(key, (2, 12, 20))
    w = jax.random.normal(jax.random.fold_in(key, 1), (16, 20)) / 20 ** 0.5
    st = asi_init(key, x.shape, x.shape)
    xt, _ = asi_step(x, st)
    g1 = jax.grad(lambda w_: jnp.sum(jnp.tanh(asi_matmul(x, w_, xt))))(w)
    g2 = jax.grad(lambda w_: jnp.sum(jnp.tanh(x @ w_.T)))(w)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("ranks", [(4, 8, 16), (2, 16, 24), (1, 4, 4),
                                   # identity batch mode (skip_batch path)
                                   (999, 8, 16), (999, 16, 24)])
def test_wasi_batch_rank_combos_match_compressed_oracle(ranks):
    """Across batch-rank combos the factor grads must equal the dense grads
    with x replaced by its Tucker reconstruction (the defining property of
    f_LR); dx stays exact regardless of compression."""
    b, n, i, o, k = 4, 16, 24, 12, 6
    ks = jax.random.split(jax.random.fold_in(KEY, sum(ranks)), 4)
    x = jax.random.normal(ks[0], (b, n, i))
    L = jax.random.normal(ks[1], (o, k)) / k ** 0.5
    R = jax.random.normal(ks[2], (k, i)) / i ** 0.5
    st = asi_init(ks[3], x.shape, ranks)
    xt, _ = asi_step(x, st)
    xr = tucker_reconstruct(xt)

    def f(x_, L_, R_):
        return jnp.sum(wasi_matmul(x_, L_, R_, xt) ** 2)

    dx, gL, gR = jax.grad(f, argnums=(0, 1, 2))(x, L, R)
    dy = 2 * (x @ R.T @ L.T)
    np.testing.assert_allclose(
        np.asarray(dx), np.asarray(jnp.einsum("bno,ok,ki->bni", dy, L, R)),
        rtol=1e-4, atol=1e-4)
    gL_or = jnp.einsum("bno,bnk->ok", dy, xr @ R.T)
    gR_or = jnp.einsum("bnk,bni->ki", jnp.einsum("bno,ok->bnk", dy, L), xr)
    np.testing.assert_allclose(np.asarray(gL), np.asarray(gL_or),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(gR), np.asarray(gR_or),
                               rtol=1e-3, atol=1e-3)


def test_wasi_4d_activation_grads():
    """4D (Swin-like) path: identity modes -> dense oracle at 1e-5."""
    ks = jax.random.split(jax.random.fold_in(KEY, 44), 3)
    x = jax.random.normal(ks[0], (2, 4, 5, 12))
    L = jax.random.normal(ks[1], (8, 4)) / 2.0
    R = jax.random.normal(ks[2], (4, 12)) / 12 ** 0.5
    st = asi_init(ks[0], x.shape, x.shape)
    xt, _ = asi_step(x, st)
    got = jax.grad(lambda x_, L_, R_: jnp.sum(wasi_matmul(x_, L_, R_, xt) ** 2),
                   argnums=(0, 1, 2))(x, L, R)
    want = jax.grad(lambda x_, L_, R_: jnp.sum((x_ @ R_.T @ L_.T) ** 2),
                    argnums=(0, 1, 2))(x, L, R)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=1e-5, atol=1e-5)


def test_wasi_residuals_are_sketch_not_activation():
    """The custom-VJP boundary must NOT keep the dense activation alive:
    the vjp closure's residual bytes stay below the dense x footprint when
    the Tucker ranks compress (the sketch-saving property, probed
    directly)."""
    from repro.utils.memprof import measured_residual_bytes

    b, n, i, o, k = 8, 32, 64, 48, 8
    ks = jax.random.split(jax.random.fold_in(KEY, 99), 4)
    x = jax.random.normal(ks[0], (b, n, i))
    L = jax.random.normal(ks[1], (o, k)) / k ** 0.5
    R = jax.random.normal(ks[2], (k, i)) / i ** 0.5
    st = asi_init(ks[3], x.shape, (b, 8, 16))   # compressing token/feature
    xt, _ = asi_step(x, st)

    # x rides as an explicit vjp argument (as in training, where it is a
    # traced value): the closure-constant form would let partial-eval bake
    # unrelated constants into the backward jaxpr and muddy the measurement
    wasi = measured_residual_bytes(
        lambda x_, L_, R_: wasi_matmul(x_, L_, R_, xt).sum(), x, L, R)
    dense = measured_residual_bytes(
        lambda x_, w_: (x_ @ w_.T).sum(), x, L @ R)
    assert wasi.total_bytes < dense.total_bytes, (wasi, dense)
    assert wasi.total_bytes < x.size * 4  # never the dense activation
