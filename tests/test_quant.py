"""Int8 deployment quantization: round-trip error bounds, the fused q8
kernel vs the dequant-einsum oracle, plan stamping + JSON round trip,
model-tree conversion, bind dispatch, and the end-to-end acceptance —
a quantized ServeEngine.from_checkpoint generating token-for-token
identically to f32 on a greedy smoke decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro import api
from repro.api import bind, convert
from repro.api.plan import resolve_linear_spec
from repro.config import WasiConfig
from repro.kernels import lowrank_matmul_q8, lowrank_matmul_q8_fused
from repro.quant import (
    dequantize_linear,
    dequantize_tensor,
    error_report,
    quantize_linear,
    quantize_tensor,
)
from repro.utils.memprof import model_weight_bytes


def _wasi(**kw):
    kw.setdefault("method", "wsi")
    kw.setdefault("rank_align", 8)
    return WasiConfig(**kw)


# ---------------------------------------------------------------------------
# tensor round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(24, 16), (3, 24, 16), (2, 2, 8, 40)])
def test_quantize_tensor_roundtrip_bounded(shape):
    """Per-channel absmax: elementwise error <= scale/2 = absmax/254 per
    channel, exactly the int8 resolution bound — for every stacked dim."""
    w = jax.random.normal(jax.random.PRNGKey(0), shape)
    q, s = quantize_tensor(w)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert q.shape == shape and s.shape == shape[:-1]
    back = np.asarray(dequantize_tensor(q, s))
    w = np.asarray(w)
    bound = np.max(np.abs(w), axis=-1, keepdims=True) / 254.0 + 1e-7
    assert np.all(np.abs(w - back) <= bound)
    rel = np.linalg.norm(w - back) / np.linalg.norm(w)
    assert rel < 0.01


def test_quantize_tensor_zero_channel_exact():
    w = jnp.zeros((4, 8)).at[1].set(jnp.arange(8.0))
    q, s = quantize_tensor(w)
    np.testing.assert_array_equal(np.asarray(q[0]), 0)
    assert float(s[0]) == 1.0  # guard scale: dequant of zeros stays exact
    back = dequantize_tensor(q, s)
    np.testing.assert_allclose(np.asarray(back[0]), 0.0)


def test_quantize_linear_layouts_and_double_quant_raises():
    key = jax.random.PRNGKey(1)
    spec = resolve_linear_spec(_wasi(), "mlp/up", "mlp", 16, 24, bias=True)
    p = bind.init_params(key, spec, bias=True)
    qspec = dataclasses.replace(spec, quant="int8")
    qp = quantize_linear(p, qspec)
    assert set(qp) == {"L", "sL", "R", "sR", "b"}
    assert qp["L"].dtype == jnp.int8 and qp["sR"].shape == (spec.rank,)
    assert qp["b"] is p["b"]                     # bias stays f32, untouched
    assert bind.is_quantized(qp) and not bind.is_quantized(p)
    with pytest.raises(ValueError):
        quantize_linear(qp, qspec)
    back = dequantize_linear(qp, qspec)
    assert set(back) == {"L", "R", "b"}
    rel = (np.linalg.norm(np.asarray(back["L"]) - np.asarray(p["L"]))
           / np.linalg.norm(np.asarray(p["L"])))
    assert rel < 0.01
    # passthroughs: no quant stamp, or project layout
    assert quantize_linear(p, spec) is p
    proj = {"w": jnp.ones((8, 4)), "L": jnp.ones((8, 2)), "R": jnp.ones((2, 4))}
    assert quantize_linear(proj, dataclasses.replace(
        qspec, mode="project")) is proj


# ---------------------------------------------------------------------------
# kernel vs dequant-einsum oracle
# ---------------------------------------------------------------------------

def _q8_oracle(x, rq, rs, lq, ls):
    rf = np.asarray(dequantize_tensor(rq, rs), np.float32)
    lf = np.asarray(dequantize_tensor(lq, ls), np.float32)
    h = np.asarray(x, np.float32) @ rf.T
    return h @ lf.T


@pytest.mark.parametrize("m,i,k,o", [(4, 16, 4, 24), (7, 33, 5, 17),
                                     (130, 257, 40, 129), (128, 128, 32, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_q8_kernel_matches_oracle(m, i, k, o, dtype):
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (m, i)).astype(dtype)
    lq, ls = quantize_tensor(jax.random.normal(jax.random.PRNGKey(3), (o, k)))
    rq, rs = quantize_tensor(jax.random.normal(jax.random.PRNGKey(4), (k, i)))
    ref = _q8_oracle(x, rq, rs, lq, ls)
    tol = 2e-5 * i if dtype == jnp.float32 else 0.1
    got = np.asarray(lowrank_matmul_q8_fused(x, rq, rs, lq, ls), np.float32)
    np.testing.assert_allclose(got, ref, atol=tol, rtol=1e-2)
    # the dispatching entry (einsum fallback off-TPU) agrees too, and
    # handles leading batch dims
    got2 = np.asarray(lowrank_matmul_q8(x.reshape(1, m, i), rq, rs, lq, ls),
                      np.float32)
    np.testing.assert_allclose(got2.reshape(m, o), ref, atol=tol, rtol=1e-2)


# ---------------------------------------------------------------------------
# plan stamping + serialization
# ---------------------------------------------------------------------------

def test_plan_quantized_stamps_and_roundtrips():
    cfg = configs.get_smoke("qwen2-0.5b")
    plan = api.resolve(cfg)
    assert not plan.is_quantized
    qplan = plan.quantized("int8")
    assert qplan.is_quantized and qplan != plan
    for s in qplan.specs:
        want = "int8" if s.mode in ("factored", "dense") else None
        assert s.quant == want, s.name
    back = type(qplan).loads(qplan.dumps())
    assert back == qplan                       # quant survives JSON
    assert "quant=int8" in qplan.summary()
    # project sites stay f32: they carry the dense W by definition
    proj = cfg.replace(wasi=dataclasses.replace(cfg.wasi,
                                                update_mode="project"))
    qproj = api.resolve(proj).quantized("int8")
    assert all(s.quant is None for s in qproj.specs if s.mode == "project")


# ---------------------------------------------------------------------------
# model-tree conversion + accounting
# ---------------------------------------------------------------------------

def _factored_lm(seed=0):
    from repro.models.lm import init_lm

    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    plan = api.install(api.resolve(cfg))
    params = init_lm(jax.random.PRNGKey(seed), cfg)
    return cfg, plan, params


def test_convert_quantize_model_tree():
    cfg, plan, params = _factored_lm()
    try:
        qplan = plan.quantized("int8")
        qp = convert.quantize(params, qplan)
        site = qp["groups"][0][0]["mlp"]["up"]
        assert site["L"].dtype == jnp.int8
        assert site["sL"].shape == site["L"].shape[:-1]
        # untreated leaves (tied embedding) pass through untouched
        assert qp["embed"]["w"].dtype == params["embed"]["w"].dtype
        # packed bytes strictly below f32, scales accounted separately
        wb32, wb8 = model_weight_bytes(params), model_weight_bytes(qp)
        assert wb8["weights_bytes"] < wb32["weights_bytes"]
        assert wb8["total_bytes"] < wb32["total_bytes"]
        assert wb8["scales_bytes"] > 0 == wb32["scales_bytes"]
        # densify dequantizes: matches the f32 densify within quant error
        d32 = convert.densify(params, plan)
        d8 = convert.densify(qp, qplan)
        w32 = np.asarray(d32["groups"][0][0]["mlp"]["up"]["w"], np.float32)
        w8 = np.asarray(d8["groups"][0][0]["mlp"]["up"]["w"], np.float32)
        assert np.linalg.norm(w32 - w8) / np.linalg.norm(w32) < 0.02
        # dequantize is the explicit inverse, and factorize refuses packed
        back = convert.dequantize(qp, qplan)
        assert not bind.is_quantized(back["groups"][0][0]["mlp"]["up"])
        with pytest.raises(ValueError):
            convert.factorize(qp, qplan)
        # error report covers every packed tensor with bounded error
        recs = error_report(params, qplan)
        assert recs and all(r["rel_err"] < 0.02 for r in recs)
        assert all(r["q8_bytes"] < r["f32_bytes"] for r in recs)
    finally:
        api.uninstall(cfg)


def test_bind_apply_q8_dispatch():
    w = _wasi()
    spec = resolve_linear_spec(w, "mlp/up", "mlp", 16, 24)
    qspec = dataclasses.replace(spec, quant="int8")
    key = jax.random.PRNGKey(5)
    p = bind.init_params(key, spec)
    qp = quantize_linear(p, qspec)
    x = jax.random.normal(key, (2, 5, 16))
    y, ns = bind.apply(qspec, qp, x, w)
    assert ns is None
    ref = _q8_oracle(x.reshape(-1, 16), qp["R"], qp["sR"], qp["L"], qp["sL"])
    np.testing.assert_allclose(np.asarray(y).reshape(-1, 24), ref,
                               atol=1e-4, rtol=1e-3)
    # close to the f32 forward (quantization error only)
    y32, _ = bind.apply(spec, p, x, w)
    assert float(jnp.max(jnp.abs(y - y32))) < 0.05
    # quantized sites are serve-only / mismatches are loud
    with pytest.raises(ValueError):
        bind.apply(qspec, qp, x, w, state=object())
    with pytest.raises(ValueError):
        bind.apply(qspec, p, x, w)     # stamped spec, unpacked params
    with pytest.raises(ValueError):
        bind.apply(spec, qp, x, w)     # packed params, unstamped spec
    # infer_spec recovers the quant stamp from the layout
    assert bind.infer_spec(qp, w).quant == "int8"
    assert bind.infer_spec(p, w).quant is None


def test_moe_bank_q8_matches_dequant():
    from repro.nn.moe import _bank_matmul

    w = _wasi()
    spec = resolve_linear_spec(w, "moe/w_up", "moe", 16, 24)
    qspec = dataclasses.replace(spec, quant="int8")
    key = jax.random.PRNGKey(6)
    bank = {"L": jax.random.normal(key, (3, 24, spec.rank)),
            "R": jax.random.normal(key, (3, spec.rank, 16))}
    qbank = quantize_linear(bank, qspec)
    x = jax.random.normal(key, (3, 4, 16))
    got = np.asarray(_bank_matmul(qspec, qbank, x))
    for e in range(3):
        ref = _q8_oracle(x[e], qbank["R"][e], qbank["sR"][e],
                         qbank["L"][e], qbank["sL"][e])
        np.testing.assert_allclose(got[e], ref, atol=1e-4, rtol=1e-3)
    # DENSE banks (untreated moe role) pack to {w, sW} and must route too
    dspec = dataclasses.replace(resolve_linear_spec(
        WasiConfig(method="none"), "moe/w_up", "moe", 16, 24), quant="int8")
    dbank = quantize_linear({"w": jax.random.normal(key, (3, 24, 16))}, dspec)
    assert set(dbank) == {"w", "sW"}
    dgot = np.asarray(_bank_matmul(dspec, dbank, x))
    for e in range(3):
        wf = np.asarray(dequantize_tensor(dbank["w"][e], dbank["sW"][e]))
        np.testing.assert_allclose(dgot[e], np.asarray(x[e]) @ wf.T,
                                   atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# end-to-end: quantized checkpoint serves token-for-token identical
# ---------------------------------------------------------------------------

def test_quantized_serve_from_checkpoint_matches_f32(tmp_path):
    """The acceptance path (docs/deployment.md): briefly-trained factored
    LM -> plan-stamped int8 checkpoint -> ServeEngine.from_checkpoint with
    nothing else in hand -> greedy generations match f32 token-for-token
    and linear-weight bytes drop strictly. (Trained, not random-init:
    random init has top-2 logit gaps below the quantization noise, so
    token matching there measures tie-breaking, not fidelity.)"""
    from repro.checkpoint import save_checkpoint
    from repro.config import TrainConfig
    from repro.data.synthetic import SyntheticLM
    from repro.models.lm import init_lm, init_lm_states, lm_loss
    from repro.serve import ServeEngine
    from repro.train.step import make_train_state, make_train_step

    cfg = configs.get_smoke("qwen2-0.5b")
    api.uninstall(cfg)
    B, S = 8, 16
    plan = api.install(api.resolve(cfg, batch=B, seq=S))
    key = jax.random.PRNGKey(0)
    tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9,
                       checkpoint_every=0)
    state = make_train_state(key, init_lm(key, cfg), cfg, tcfg,
                             asi_states=init_lm_states(key, cfg, B, S))
    step = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=S,
                       global_batch=B, seed=1)
    try:
        for i in range(30):
            state, _ = step(state, data.batch(i))

        prompts = [[3, 1, 4, 1, 5], [9, 2, 6]]

        def drive(engine):
            reqs = [engine.submit(p, max_new=8) for p in prompts]
            engine.run()
            return [r.tokens for r in reqs]

        f32 = ServeEngine(state.params, plan=plan, max_slots=2, max_cache=16)
        toks32 = drive(f32)
        api.uninstall(cfg)

        qplan = plan.quantized("int8")
        qparams = convert.quantize(state.params, qplan)
        save_checkpoint(str(tmp_path), 30, qparams, plan=qplan,
                        label="params")
        q8 = ServeEngine.from_checkpoint(str(tmp_path), max_slots=2,
                                         max_cache=16)
        assert q8.quantized and q8.plan == qplan    # stamp round-tripped
        assert drive(q8) == toks32                  # token-for-token
        assert q8.summary()["weight_bytes"] < f32.summary()["weight_bytes"]
    finally:
        api.uninstall(cfg)
