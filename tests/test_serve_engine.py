"""Continuous-batching serve engine: admission, bucketing, recycling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.launch.serve import generate
from repro.models.lm import init_lm
from repro.serve import ServeEngine, bucket_for

KEY = jax.random.PRNGKey(0)


def _engine(arch="qwen2-0.5b", **kw):
    cfg = configs.get_smoke(arch)
    params = init_lm(KEY, cfg, jnp.dtype(cfg.dtype))
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_cache", 64)
    kw.setdefault("buckets", (4, 8, 16))
    return ServeEngine(params, cfg, **kw), cfg, params


def test_bucket_for():
    assert bucket_for(3, (4, 8)) == 4
    assert bucket_for(4, (4, 8)) == 4
    # beyond the largest bucket: round UP to multiples of it (bounded jit
    # cache under adversarial prompt lengths), capped at max_cache
    assert bucket_for(9, (4, 8)) == 16
    assert bucket_for(16, (4, 8)) == 16
    assert bucket_for(17, (4, 8)) == 24
    assert bucket_for(9, (4, 8), max_cache=12) == 12
    assert bucket_for(9, (4, 16), max_cache=12) == 12   # in-bucket capped too


def test_overlong_prompts_share_prefill_executables():
    """Adversarial prompt lengths beyond the largest bucket must map to a
    SMALL set of padded lengths (multiples of the largest bucket), not one
    exact-length compile each."""
    buckets = (4, 8)
    lengths = range(9, 33)
    padded = {bucket_for(n, buckets, max_cache=64) for n in lengths}
    assert padded == {16, 24, 32}
    assert all(b % buckets[-1] == 0 for b in padded)


def test_more_requests_than_slots_recycles():
    eng, cfg, _ = _engine()
    rng = np.random.default_rng(0)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 3 + 2 * i)),
                       max_new=4) for i in range(5)]
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.generated) == 4 for r in reqs)
    assert eng.stats["completed"] == 5
    assert eng.queue == type(eng.queue)()          # drained
    assert all(s is None for s in eng.slots)       # all recycled
    # prefill counted true prompt tokens, not bucket padding
    assert eng.stats["prefill_tokens"] == sum(3 + 2 * i for i in range(5))


def test_engine_matches_lockstep_generate():
    """Greedy tokens from the continuous-batching path (bucketed ragged
    prefill + per-slot-position decode alongside unrelated requests) must
    equal the lockstep single-prompt path."""
    eng, cfg, params = _engine()
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (3, 7, 5, 11)]
    reqs = [eng.submit(p, max_new=6) for p in prompts]
    eng.run()
    for p, r in zip(prompts, reqs):
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_cache=64, n_new=6)
        assert r.tokens == [int(t) for t in ref[0]], p


def test_eos_frees_slot_early():
    eng, cfg, _ = _engine()
    r = eng.submit([1, 2, 3], max_new=50, eos_id=None)
    eng.run()
    first = r.generated[0]
    # replay with that token as EOS: must stop at the first occurrence
    eng2, _, _ = _engine()
    r2 = eng2.submit([1, 2, 3], max_new=50, eos_id=first)
    eng2.run()
    assert r2.generated[-1] == first
    assert len(r2.generated) < 50


def test_submit_validates_capacity():
    eng, cfg, _ = _engine(max_cache=16)
    with pytest.raises(ValueError):
        eng.submit(list(range(10)), max_new=10)
    with pytest.raises(ValueError):
        eng.submit([], max_new=1)


def test_bucket_capped_at_max_cache():
    """A prompt whose bucket would exceed max_cache must still admit (the
    bucket is clamped; the prompt itself fits by submit() validation)."""
    eng, cfg, _ = _engine(max_cache=12, buckets=(4, 16))
    r = eng.submit([1] * 9, max_new=2)   # bucket_for(9) = 16 > max_cache
    eng.run()
    assert r.done and len(r.generated) == 2


def test_submit_rejects_zero_max_new():
    eng, cfg, _ = _engine()
    with pytest.raises(ValueError):
        eng.submit([1, 2], max_new=0)


def test_recycled_slot_short_prompt_mamba():
    """A recycled slot's stale conv buffer must not leak into a new request
    whose prompt is shorter than d_conv-1 (prefill pre-history is zeros by
    construction)."""
    eng, cfg, params = _engine(arch="falcon-mamba-7b", max_slots=1)
    eng.submit(list(range(1, 9)), max_new=6)   # occupy + dirty the slot
    eng.run()
    short = [3, 4]                              # len 2 < d_conv-1 = 3
    r = eng.submit(short, max_new=4)
    eng.run()
    ref = generate(params, cfg, jnp.asarray([short], jnp.int32),
                   max_cache=64, n_new=4)
    assert r.tokens == [int(t) for t in ref[0]]


def test_mamba_arch_through_engine():
    eng, cfg, params = _engine(arch="falcon-mamba-7b")
    rng = np.random.default_rng(2)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (3, 9, 6)]
    reqs = [eng.submit(p, max_new=5) for p in prompts]
    eng.run()
    for p, r in zip(prompts, reqs):
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_cache=64, n_new=5)
        assert r.tokens == [int(t) for t in ref[0]], p


# -- admission edge cases ----------------------------------------------------

def test_prompt_plus_max_new_exactly_at_cap():
    """prompt + max_new == max_cache is the last admissible request; one
    token more must be rejected at submit, not die inside prefill."""
    eng, cfg, _ = _engine(max_cache=16)
    r = eng.submit(list(range(1, 13)), max_new=4)     # 12 + 4 == 16
    eng.run()
    assert r.done and len(r.generated) == 4
    with pytest.raises(ValueError):
        eng.submit(list(range(1, 14)), max_new=4)     # 13 + 4 > 16


def test_prompt_exactly_max_cache_rejected():
    """A prompt of max_cache tokens leaves no KV slot for even one
    generated token (max_new >= 1 always)."""
    eng, cfg, _ = _engine(max_cache=16)
    with pytest.raises(ValueError):
        eng.submit(list(range(1, 17)), max_new=1)


def test_single_bucket_config():
    """One bucket serves every length: shorter prompts pad to it, longer
    ones round to its multiples (capped), all through one executable."""
    eng, cfg, params = _engine(buckets=(8,), max_cache=32)
    rng = np.random.default_rng(3)
    prompts = [list(rng.integers(0, cfg.vocab_size, n)) for n in (2, 8, 11)]
    reqs = [eng.submit(p, max_new=4) for p in prompts]
    eng.run()
    for p, r in zip(prompts, reqs):
        ref = generate(params, cfg, jnp.asarray([p], jnp.int32),
                       max_cache=32, n_new=4)
        assert r.tokens == [int(t) for t in ref[0]], p
    assert bucket_for(11, (8,)) == 16                 # overlong rounding
    assert bucket_for(17, (8,), max_cache=20) == 20   # rounded AND capped


# -- paged mode --------------------------------------------------------------

def _paged(**kw):
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    return _engine(**kw)


def test_paged_matches_dense_oracle():
    """Paged decode gathers into the same logical shape the dense cache
    has, so greedy generations must match the dense engine token for
    token — including a prompt long enough to need several prefill
    chunks."""
    dense, cfg, params = _engine()
    paged, _, _ = _paged()
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab_size, n))
               for n in (3, 7, 12, 37)]
    hd = [dense.submit(p, max_new=6) for p in prompts]
    dense.run()
    hp = [paged.submit(p, max_new=6) for p in prompts]
    paged.run()
    assert paged.stats["prefill_chunks"] >= len(prompts) + 2  # 37 => 3 chunks
    for d, p in zip(hd, hp):
        assert d.generated == p.generated
    paged.check_invariants()
    paged.release_prefix_cache()
    assert paged.pool.pages_in_use == 0


def test_shared_prefix_prefills_once_and_matches_cold():
    """Requests sharing a 16-token prefix: the radix cache must attach the
    shared pages by reference (prefill_tokens counts only the suffixes)
    and generations must be bitwise identical to a cold engine that
    prefills every prompt in full."""
    warm, cfg, params = _paged()
    cold, _, _ = _paged(prefix_cache=False)
    rng = np.random.default_rng(5)
    shared = list(rng.integers(0, cfg.vocab_size, 16))
    sufs = [list(rng.integers(0, cfg.vocab_size, 5)) for _ in range(3)]
    outs = {}
    for eng in (warm, cold):
        outs[eng] = []
        for s in sufs:
            h = eng.submit(shared + s, max_new=5)
            eng.run()                 # sequential: prefix published first
            outs[eng].append(h.generated)
    assert outs[warm] == outs[cold]
    # cold pays 21 tokens per request; warm pays the suffix after the first
    assert cold.stats["prefill_tokens"] == 3 * 21
    assert warm.stats["prefill_tokens"] == 21 + 5 + 5
    assert warm.stats["prefix_hit_tokens"] == 32
    assert cold.stats["prefix_hit_tokens"] == 0


def test_paged_pool_shortage_defers_admission():
    """A pool too small for two concurrent requests must serialize them
    (deferred admission), not fail — and both must still complete."""
    # 5 usable pages of 8; each request needs ceil((8+8)/8) = 2 pages, the
    # radix keeps 1 page of each finished prompt, so the third admission
    # forces both deferral and LRU eviction of radix pages.
    eng, cfg, _ = _paged(max_cache=16, total_pages=6, page_size=8,
                         max_slots=2)
    rng = np.random.default_rng(6)
    reqs = [eng.submit(list(rng.integers(0, cfg.vocab_size, 8)), max_new=8)
            for _ in range(4)]
    eng.run()
    assert all(r.done and len(r.generated) == 8 for r in reqs)
    assert eng.stats["completed"] == 4
    eng.check_invariants()


def test_paged_submit_validates_pool_capacity():
    eng, cfg, _ = _paged(max_cache=64, total_pages=3, page_size=8)
    with pytest.raises(ValueError):            # needs 4 pages, 2 usable
        eng.submit(list(range(1, 17)), max_new=16)


def test_paged_rejects_unsupported_arch_and_auto_falls_back():
    with pytest.raises(ValueError):
        _engine(arch="falcon-mamba-7b", paged=True)
    eng, cfg, _ = _engine(arch="falcon-mamba-7b", paged="auto")
    assert eng.paged is False                  # SSM state: dense fallback
    r = eng.submit([1, 2, 3], max_new=3)
    eng.run()
    assert r.done and len(r.generated) == 3


def test_paged_cancel_recycles_pages_mid_prefill():
    """Cancelling a request still inside chunked prefill must release its
    pages; a fresh request admitted into the recycled slot must match the
    dense oracle (its pages are clean-by-masking, and the dead row's
    writes went to the trash page)."""
    eng, cfg, params = _paged(max_slots=1, prefill_chunk=8)
    long_prompt = list(range(1, 30))           # 29 tokens => 4 chunks
    h1 = eng.submit(long_prompt, max_new=4)
    eng.step()                                 # admit + first chunk only
    assert eng.stats["prefill_chunks"] == 1 and h1.generated == []
    assert eng.cancel(h1.rid)
    short = [3, 1, 4, 1, 5]
    h2 = eng.submit(short, max_new=4)
    eng.run()
    ref = generate(params, cfg, jnp.asarray([short], jnp.int32),
                   max_cache=64, n_new=4)
    assert h2.tokens == [int(t) for t in ref[0]]
    eng.check_invariants()
