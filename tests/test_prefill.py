"""Token-parallel prefill vs the scanned single-token-decode oracle.

The contract (models/lm.py::lm_prefill): ONE forward over the prompt leaves
every layer's decode caches — full KV, rolling-window KV, Mamba conv
buffers and recurrent states — in the same state a scan of lm_decode_step
would have. Pure-attention stacks match BITWISE (identical op sequences per
row); Mamba recurrences and rolling-window softmax run through parallel
scans whose float reassociation shifts low-order bits, so those compare at
tight f32 tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models.lm import (
    init_lm,
    init_lm_cache,
    lm_decode_step,
    lm_prefill,
)

KEY = jax.random.PRNGKey(0)
B, P, CACHE = 2, 12, 24

# arch -> exact: bitwise cache equality expected (pure causal attention);
# others allow parallel-scan reassociation tolerance
CASES = [("qwen2-0.5b", True),     # dense causal
         ("gemma3-4b", False),     # sliding-window (rolling caches, W=8 < P)
         ("falcon-mamba-7b", False),   # mamba1 selective scan
         ("zamba2-7b", False)]     # mamba2 SSD + shared attention


def _scanned_oracle(params, cfg, prompt):
    caches = init_lm_cache(cfg, B, CACHE, dtype=jnp.float32)
    step = jax.jit(lambda pr, t, c, pos: lm_decode_step(pr, t, c, pos, cfg))
    logits = None
    for i in range(prompt.shape[1]):
        logits, caches = step(params, prompt[:, i:i + 1], caches, i)
    return logits, caches


@pytest.mark.parametrize("arch,exact", CASES)
def test_prefill_matches_scanned_decode(arch, exact):
    cfg = configs.get_smoke(arch)
    params = init_lm(KEY, cfg)
    prompt = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)

    logits_o, caches_o = _scanned_oracle(params, cfg, prompt)

    caches = init_lm_cache(cfg, B, CACHE, dtype=jnp.float32)
    logits_p, caches_p = jax.jit(
        lambda pr, t, c: lm_prefill(pr, t, cfg, caches=c))(
        params, prompt, caches)

    np.testing.assert_allclose(np.asarray(logits_p[:, -1]),
                               np.asarray(logits_o), rtol=1e-5, atol=1e-5)
    for o, p_ in zip(jax.tree.leaves(caches_o), jax.tree.leaves(caches_p)):
        if exact:
            np.testing.assert_array_equal(np.asarray(o), np.asarray(p_))
        else:
            np.testing.assert_allclose(np.asarray(o, np.float32),
                                       np.asarray(p_, np.float32),
                                       rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", [a for a, _ in CASES])
def test_padded_prefill_matches_exact_length(arch):
    """Right-padded bucketed prefill with valid_len must leave caches (and
    last-valid-token logits) identical to an exact-length prefill — the
    invariant serve admission relies on."""
    cfg = configs.get_smoke(arch)
    params = init_lm(KEY, cfg)
    lens = jnp.array([7, P], jnp.int32)          # ragged rows, bucket = P
    prompt = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)

    caches = init_lm_cache(cfg, B, CACHE, dtype=jnp.float32)
    logits_pad, caches_pad = jax.jit(
        lambda pr, t, c, vl: lm_prefill(pr, t, cfg, caches=c, valid_len=vl))(
        params, prompt, caches, lens)

    for row, true_len in enumerate(map(int, lens)):
        caches1 = init_lm_cache(cfg, 1, CACHE, dtype=jnp.float32)
        logits1, caches1 = jax.jit(
            lambda pr, t, c: lm_prefill(pr, t, cfg, caches=c))(
            params, prompt[row:row + 1, :true_len], caches1)
        np.testing.assert_allclose(
            np.asarray(logits_pad[row, true_len - 1]),
            np.asarray(logits1[0, -1]), rtol=1e-5, atol=1e-5)
        for pad_leaf, one_leaf in zip(jax.tree.leaves(caches_pad),
                                      jax.tree.leaves(caches1)):
            # cache leaves are (repeat, B, ...): compare this row only
            np.testing.assert_allclose(
                np.asarray(pad_leaf[:, row:row + 1], np.float32),
                np.asarray(one_leaf, np.float32), rtol=1e-4, atol=1e-4)


def test_last_only_prefill_matches_full():
    """last_only=True (the serving path: one vocab projection per prompt)
    must return exactly logits[b, valid_len[b]-1] of the full projection,
    with identical caches."""
    cfg = configs.get_smoke("qwen2-0.5b")
    params = init_lm(KEY, cfg)
    lens = jnp.array([5, P], jnp.int32)
    prompt = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    full, caches_f = lm_prefill(
        params, prompt, cfg,
        caches=init_lm_cache(cfg, B, CACHE, dtype=jnp.float32),
        valid_len=lens)
    last, caches_l = lm_prefill(
        params, prompt, cfg,
        caches=init_lm_cache(cfg, B, CACHE, dtype=jnp.float32),
        valid_len=lens, last_only=True)
    want = jnp.take_along_axis(full, (lens - 1)[:, None, None], axis=1)
    np.testing.assert_array_equal(np.asarray(last), np.asarray(want))
    for a, b in zip(jax.tree.leaves(caches_f), jax.tree.leaves(caches_l)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_decode_continues_from_prefill():
    """Greedy decode after batched prefill == greedy decode after scanned
    prefill, several tokens deep (caches truly interchangeable)."""
    cfg = configs.get_smoke("qwen2-0.5b")
    params = init_lm(KEY, cfg)
    prompt = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    step = jax.jit(lambda pr, t, c, pos: lm_decode_step(pr, t, c, pos, cfg))

    def roll(logits, caches):
        toks = []
        for j in range(5):
            nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            toks.append(nxt)
            logits, caches = step(params, nxt, caches, P + j)
        return jnp.concatenate(toks, axis=1)

    logits_o, caches_o = _scanned_oracle(params, cfg, prompt)
    caches = init_lm_cache(cfg, B, CACHE, dtype=jnp.float32)
    logits_p, caches_p = jax.jit(
        lambda pr, t, c: lm_prefill(pr, t, cfg, caches=c))(
        params, prompt, caches)
    np.testing.assert_array_equal(np.asarray(roll(logits_o, caches_o)),
                                  np.asarray(roll(logits_p[:, -1], caches_p)))


def test_vector_pos_decode_matches_scalar():
    """A (B,) per-slot position vector with equal entries must reproduce the
    scalar-pos decode step exactly (continuous-batching decode path)."""
    cfg = configs.get_smoke("gemma3-4b")   # rolling-window slot arithmetic
    params = init_lm(KEY, cfg)
    prompt = jax.random.randint(KEY, (B, P), 0, cfg.vocab_size)
    _, caches = _scanned_oracle(params, cfg, prompt)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)

    logits_s, caches_s = lm_decode_step(params, tok, caches, P, cfg)
    logits_v, caches_v = lm_decode_step(params, tok, caches,
                                        jnp.full((B,), P, jnp.int32), cfg)
    np.testing.assert_array_equal(np.asarray(logits_s), np.asarray(logits_v))
    for a, b in zip(jax.tree.leaves(caches_s), jax.tree.leaves(caches_v)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
