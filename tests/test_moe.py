"""MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.nn.moe import apply_moe, init_moe, moe_capacity

KEY = jax.random.PRNGKey(0)


def _cfg():
    return configs.get_smoke("mixtral-8x7b")


def test_output_shape_and_finite():
    cfg = _cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 0.99  # Switch aux loss >= 1 at balance


def test_capacity_is_respected():
    cfg = _cfg()
    cap = moe_capacity(16, cfg)
    assert cap >= 16 * cfg.moe.top_k / cfg.moe.n_experts
    assert cap % 8 == 0


def test_moe_matches_dense_routing_oracle():
    """With capacity high enough that nothing drops, MoE output must equal
    the explicit per-token sum over its top-k experts."""
    import dataclasses

    cfg = _cfg()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_moe(KEY, cfg)
    b, s, d = 2, 8, cfg.d_model
    x = jax.random.normal(KEY, (b, s, d))
    y, _ = apply_moe(p, x, cfg)

    # oracle: route each token individually
    logits = jnp.einsum("bsd,ed->bse", x, p["router"]["w"])
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    def expert_ffn(e, t):
        g = t @ p["experts"]["w_gate"]["w"][e].T if "w" in p["experts"]["w_gate"] \
            else (t @ p["experts"]["w_gate"]["R"][e].T) @ p["experts"]["w_gate"]["L"][e].T
        u = t @ p["experts"]["w_up"]["w"][e].T if "w" in p["experts"]["w_up"] \
            else (t @ p["experts"]["w_up"]["R"][e].T) @ p["experts"]["w_up"]["L"][e].T
        h = jax.nn.silu(g) * u
        return h @ p["experts"]["w_down"]["w"][e].T if "w" in p["experts"]["w_down"] \
            else (h @ p["experts"]["w_down"]["R"][e].T) @ p["experts"]["w_down"]["L"][e].T

    want = jnp.zeros_like(x)
    for bi in range(b):
        for si in range(s):
            acc = jnp.zeros((d,))
            for kk in range(cfg.moe.top_k):
                e = int(top_e[bi, si, kk])
                acc += float(top_p[bi, si, kk]) * expert_ffn(e, x[bi, si])
            want = want.at[bi, si].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-2,
                               atol=2e-2)


def test_shared_experts_always_on():
    """deepseek-style shared experts contribute for every token."""
    cfg = configs.get_smoke("deepseek-moe-16b")
    p = init_moe(KEY, cfg)
    x = jnp.zeros((1, 4, cfg.d_model))
    # zero input -> routed experts emit ~0 but so do shared; use nonzero
    x = jnp.ones((1, 4, cfg.d_model)) * 0.1
    y_with, _ = apply_moe(p, x, cfg)
    p_no_shared = dict(p)
    p_no_shared["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y_without, _ = apply_moe(p_no_shared, x, cfg)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-6


def test_project_mode_bank_runs_factored_forward_with_dense_grad():
    """Project-mode WSI injection leaves (L, R) next to each expert bank's
    dense w: the forward must be the factored product (paper Eq. 9) and the
    gradient must land on W, not on the injected factors."""
    from repro.api.plan import resolve_linear_spec
    from repro.config import WasiConfig
    from repro.nn.moe import _bank_matmul

    w_cfg = WasiConfig(method="wsi", update_mode="project", rank_align=8)
    spec = resolve_linear_spec(w_cfg, "moe/w_up", "moe", 16, 24)
    assert spec.mode == "project"
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    e, c, k = 3, 5, 8
    p = {"w": jax.random.normal(k1, (e, 24, 16)),
         "L": jax.random.normal(k2, (e, 24, k)),
         "R": jax.random.normal(k3, (e, k, 16))}
    x = jax.random.normal(k4, (e, c, 16))
    y = _bank_matmul(spec, p, x)
    ref = jnp.einsum("eck,eok->eco",
                     jnp.einsum("eci,eki->eck", x, p["R"]), p["L"])
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    grads = jax.grad(lambda p_: _bank_matmul(spec, p_, x).sum())(p)
    assert float(jnp.abs(grads["w"]).max()) > 0
    assert float(jnp.abs(grads["L"]).max()) == 0  # factors: derived state
    assert float(jnp.abs(grads["R"]).max()) == 0


def test_project_mode_moe_trains_end_to_end():
    """Full project-mode train step on an MoE arch: WSI states exist for
    the expert banks (stacked (repeat, E) leading dims) and the update
    step runs. Regression: _batched previously could not flatten WSIState
    factors over two leading stack dims."""
    import dataclasses

    from repro.config import TrainConfig
    from repro.models.lm import init_lm, lm_loss
    from repro.train.step import make_train_state, make_train_step

    cfg = _cfg().replace(wasi=dataclasses.replace(
        _cfg().wasi, method="wsi", update_mode="project", rank_align=8))
    params = init_lm(KEY, cfg)
    tcfg = TrainConfig(steps=1, checkpoint_every=0)
    st = make_train_state(KEY, params, cfg, tcfg)
    assert any("experts" in k for k in st.wsi)
    step = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    b = {"tokens": jnp.zeros((2, 8), jnp.int32),
         "labels": jnp.ones((2, 8), jnp.int32)}
    st2, m = step(st, b)
    assert np.isfinite(float(m["loss"]))
    moved = any(not np.array_equal(np.asarray(a), np.asarray(c))
                for a, c in zip(jax.tree.leaves(st.params),
                                jax.tree.leaves(st2.params)))
    assert moved                           # gradient landed on dense W
