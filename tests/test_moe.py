"""MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.nn.moe import apply_moe, init_moe, moe_capacity

KEY = jax.random.PRNGKey(0)


def _cfg():
    return configs.get_smoke("mixtral-8x7b")


def test_output_shape_and_finite():
    cfg = _cfg()
    p = init_moe(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) >= 0.99  # Switch aux loss >= 1 at balance


def test_capacity_is_respected():
    cfg = _cfg()
    cap = moe_capacity(16, cfg)
    assert cap >= 16 * cfg.moe.top_k / cfg.moe.n_experts
    assert cap % 8 == 0


def test_moe_matches_dense_routing_oracle():
    """With capacity high enough that nothing drops, MoE output must equal
    the explicit per-token sum over its top-k experts."""
    import dataclasses

    cfg = _cfg()
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    p = init_moe(KEY, cfg)
    b, s, d = 2, 8, cfg.d_model
    x = jax.random.normal(KEY, (b, s, d))
    y, _ = apply_moe(p, x, cfg)

    # oracle: route each token individually
    logits = jnp.einsum("bsd,ed->bse", x, p["router"]["w"])
    probs = jax.nn.softmax(logits, -1)
    top_p, top_e = jax.lax.top_k(probs, cfg.moe.top_k)
    top_p = top_p / top_p.sum(-1, keepdims=True)

    def expert_ffn(e, t):
        g = t @ p["experts"]["w_gate"]["w"][e].T if "w" in p["experts"]["w_gate"] \
            else (t @ p["experts"]["w_gate"]["R"][e].T) @ p["experts"]["w_gate"]["L"][e].T
        u = t @ p["experts"]["w_up"]["w"][e].T if "w" in p["experts"]["w_up"] \
            else (t @ p["experts"]["w_up"]["R"][e].T) @ p["experts"]["w_up"]["L"][e].T
        h = jax.nn.silu(g) * u
        return h @ p["experts"]["w_down"]["w"][e].T if "w" in p["experts"]["w_down"] \
            else (h @ p["experts"]["w_down"]["R"][e].T) @ p["experts"]["w_down"]["L"][e].T

    want = jnp.zeros_like(x)
    for bi in range(b):
        for si in range(s):
            acc = jnp.zeros((d,))
            for kk in range(cfg.moe.top_k):
                e = int(top_e[bi, si, kk])
                acc += float(top_p[bi, si, kk]) * expert_ffn(e, x[bi, si])
            want = want.at[bi, si].set(acc)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-2,
                               atol=2e-2)


def test_shared_experts_always_on():
    """deepseek-style shared experts contribute for every token."""
    cfg = configs.get_smoke("deepseek-moe-16b")
    p = init_moe(KEY, cfg)
    x = jnp.zeros((1, 4, cfg.d_model))
    # zero input -> routed experts emit ~0 but so do shared; use nonzero
    x = jnp.ones((1, 4, cfg.d_model)) * 0.1
    y_with, _ = apply_moe(p, x, cfg)
    p_no_shared = dict(p)
    p_no_shared["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    y_without, _ = apply_moe(p_no_shared, x, cfg)
    assert float(jnp.abs(y_with - y_without).max()) > 1e-6
