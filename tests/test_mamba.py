"""Mamba1 / Mamba2(SSD): decode-vs-prefill consistency and chunking
invariance — the recurrent state math must match the parallel scan."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.nn.mamba import (
    apply_mamba1,
    apply_mamba2,
    init_mamba1,
    init_mamba1_cache,
    init_mamba2,
    init_mamba2_cache,
)

KEY = jax.random.PRNGKey(0)


def test_mamba1_decode_matches_prefill():
    cfg = configs.get_smoke("falcon-mamba-7b")
    p = init_mamba1(KEY, cfg)
    b, s = 2, 12
    x = jax.random.normal(KEY, (b, s, cfg.d_model)) * 0.5
    y_par, _, _ = apply_mamba1(p, x, cfg)
    state = init_mamba1_cache(cfg, b)
    outs = []
    for t in range(s):
        y_t, state, _ = apply_mamba1(p, x[:, t:t + 1], cfg, state=state)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=2e-3, atol=2e-3)


def test_mamba1_chunking_invariance():
    """Same output whatever the chunk size (state carried across chunks)."""
    from repro.nn.mamba import _selective_scan

    b, s, di, n = 2, 32, 8, 4
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (b, s, di))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)))
    A = -jnp.exp(jax.random.normal(ks[2], (di, n)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((di,))
    y_full = _selective_scan(u, dt, A, B, C, D, chunk=32)
    y_8 = _selective_scan(u, dt, A, B, C, D, chunk=8)
    y_4 = _selective_scan(u, dt, A, B, C, D, chunk=4)
    np.testing.assert_allclose(np.asarray(y_8), np.asarray(y_full), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_4), np.asarray(y_full), rtol=1e-4,
                               atol=1e-4)


def test_mamba2_decode_matches_prefill():
    cfg = configs.get_smoke("zamba2-7b")
    p = init_mamba2(KEY, cfg)
    b, s = 2, 8  # == ssd chunk of smoke config
    x = jax.random.normal(KEY, (b, s, cfg.d_model)) * 0.5
    y_par, _, _ = apply_mamba2(p, x, cfg)
    state = init_mamba2_cache(cfg, b)
    outs = []
    for t in range(s):
        y_t, state, _ = apply_mamba2(p, x[:, t:t + 1], cfg, state=state)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_seq), np.asarray(y_par),
                               rtol=5e-3, atol=5e-3)


def test_ssd_chunking_invariance():
    from repro.nn.mamba import _ssd_chunked

    b, s, h, dh, n = 2, 16, 4, 8, 4
    ks = jax.random.split(KEY, 5)
    u = jax.random.normal(ks[0], (b, s, h, dh))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)))
    B = jax.random.normal(ks[3], (b, s, n))
    C = jax.random.normal(ks[4], (b, s, n))
    D = jnp.ones((h,))
    y16 = _ssd_chunked(u, dt, A, B, C, D, 16)
    y4 = _ssd_chunked(u, dt, A, B, C, D, 4)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16), rtol=1e-3,
                               atol=1e-3)
