"""Training integration: convergence per update mode, checkpoint/restart
determinism, WSI refresh continuity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_lm, init_lm_states, lm_loss
from repro.train.loop import train_loop
from repro.train.step import make_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 8, 32


def _setup(method="wasi", update_mode="factored", steps=40, refresh=8):
    cfg = configs.get_smoke("qwen2-0.5b")
    cfg = cfg.replace(wasi=dataclasses.replace(
        cfg.wasi, method=method, update_mode=update_mode,
        refresh_every=refresh))
    tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9, steps=steps,
                       clip_norm=2.0, checkpoint_every=0)
    params = init_lm(KEY, cfg)
    asi = init_lm_states(KEY, cfg, B, S) if cfg.wasi.compress_acts else None
    state = make_train_state(KEY, params, cfg, tcfg, asi_states=asi)
    step = make_train_step(lm_loss, cfg, tcfg)
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                       seed=1)
    return cfg, tcfg, state, step, data


@pytest.mark.parametrize("method,mode", [("wasi", "factored"),
                                         ("wasi", "project"),
                                         ("none", "factored")])
def test_loss_decreases(method, mode):
    cfg, tcfg, state, step, data = _setup(method, mode, steps=40)
    jstep = jax.jit(step)
    first = last = None
    for i in range(40):
        state, m = jstep(state, data.batch(i))
        if i == 0:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.3, (method, mode, first, last)


def test_wsi_refresh_does_not_disrupt_loss():
    """wsi_refresh_factored preserves L@R -> the loss stream must not jump
    at refresh steps."""
    cfg, tcfg, state, step, data = _setup("wsi", "factored", steps=24,
                                          refresh=4)
    jstep = jax.jit(step)
    losses = []
    for i in range(24):
        state, m = jstep(state, data.batch(i))
        losses.append(float(m["loss"]))
    diffs = np.abs(np.diff(losses))
    refresh_diffs = diffs[3::4]  # steps where refresh fired
    assert np.median(refresh_diffs) < np.median(diffs) * 5 + 0.5


def test_checkpoint_restart_is_bitexact(tmp_path):
    """Kill-and-restart must replay to the identical state (data is a pure
    function of step; checkpoint stores the full TrainState)."""
    from repro.checkpoint import CheckpointManager

    # NOTE: train_loop donates its input state to the jitted step, so every
    # run gets a freshly-built initial state.
    cfg, tcfg, state0, step, data = _setup("wasi", "factored", steps=12)
    tcfg = dataclasses.replace(tcfg, checkpoint_every=5, steps=12)

    # run A: straight through
    ckpt_a = CheckpointManager(str(tmp_path / "a"), keep=5)
    state_a, _ = train_loop(state0, step, lambda s: data.batch(s), tcfg,
                            ckpt=ckpt_a, log_fn=lambda *_: None)

    # run B: crash after the step-10 checkpoint, then resume
    _, _, state0b, _, _ = _setup("wasi", "factored", steps=12)
    ckpt_b = CheckpointManager(str(tmp_path / "b"), keep=5)
    state_b, _ = train_loop(state0b, step, lambda s: data.batch(s),
                            dataclasses.replace(tcfg, steps=10),
                            ckpt=ckpt_b, log_fn=lambda *_: None)
    del state_b
    _, _, state0c, _, _ = _setup("wasi", "factored", steps=12)
    state_b2, _ = train_loop(state0c, step, lambda s: data.batch(s), tcfg,
                             ckpt=CheckpointManager(str(tmp_path / "b"), keep=5),
                             log_fn=lambda *_: None)

    for a, b in zip(jax.tree.leaves(state_a.params),
                    jax.tree.leaves(state_b2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_powersgd_enabled_training_still_learns():
    cfg = configs.get_smoke("qwen2-0.5b")
    cfg = cfg.replace(wasi=dataclasses.replace(cfg.wasi, method="none"))
    tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9, steps=40,
                       clip_norm=2.0, powersgd_rank=8, checkpoint_every=0)
    params = init_lm(KEY, cfg)
    state = make_train_state(KEY, params, cfg, tcfg)
    assert state.psgd  # compression states exist for dense 2D params
    jstep = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                       seed=1)
    first = last = None
    for i in range(40):
        state, m = jstep(state, data.batch(i))
        first = float(m["loss"]) if i == 0 else first
        last = float(m["loss"])
    assert last < first - 0.2
