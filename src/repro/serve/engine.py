"""Continuous-batching serve engine: streaming request lifecycle over a
fixed slot pool, with device-side sampling and pluggable scheduling.

Design (the TrainDeeploy lesson: kernel and serving loop co-designed):

* The engine owns ONE set of batched decode caches (`init_lm_cache` with
  batch = max_slots). A *slot* is a batch row; admitting a request means
  prefilling its prompt into that row, finishing (or cancelling, or
  evicting) means freeing the row for the next queued request. Model code
  never sees the queue.

* Prefill is token-parallel (`lm_prefill`): one forward over the whole
  prompt writes every layer's KV slots / conv buffers / SSM states. To keep
  jit recompiles bounded, admitted prompts are right-padded to a small set
  of bucket lengths (overlong prompts round up to multiples of the largest
  bucket, capped at `max_cache`) and the per-row true length rides in as
  `valid_len`. Same-bucket admissions prefill together as one batch.

* Decode runs ALL slots in lockstep shapes but at per-slot positions
  (`pos` is a (B,) vector): every active request decodes one token per
  engine tick regardless of when it was admitted — that is the continuous
  batching. Free slots ride along as dead rows (their writes land at stale
  positions that the causal/rolling masks provably never read back).

* Sampling is DEVICE-SIDE (`serve/sampling.py`): per-slot temperature /
  top-k / top-p / RNG key arrays ride into the jitted prefill and decode
  steps, which return sampled int32 tokens — the host never round-trips
  logits, and temperature-0 rows lower to the exact argmax the greedy
  engine ran (token-for-token identical, f32 and int8).

* The request lifecycle is event-driven (`serve/session.py`): `submit()`
  returns a `GenerationHandle` streaming TOKEN / FINISHED / CANCELLED /
  EVICTED events with TTFT/TPOT on the handle; admission order and
  deadline eviction are a pluggable `Scheduler` (`serve/scheduler.py`).

The jit cache ends up with exactly one decode executable plus one prefill
executable per (bucket, group-size) pair actually seen.
"""
from __future__ import annotations

import collections
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import SubspacePlan, install, installed, plan_of
from repro.config import ModelConfig
from repro.models.lm import init_lm_cache, lm_decode_step, lm_prefill
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import Scheduler, make_scheduler
from repro.serve.session import Event, EventKind, GenerationHandle, Request

DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256)


def bucket_for(length: int, buckets: Sequence[int],
               max_cache: int | None = None) -> int:
    """Smallest bucket >= length. Prompts beyond the largest bucket round
    UP to the next multiple of it — a handful of shared executables instead
    of one exact-length compile per adversarial prompt length — and every
    result is capped at ``max_cache`` (admission validated the prompt
    itself fits)."""
    cap = max_cache if max_cache is not None else float("inf")
    for b in buckets:
        if b >= length:
            return int(min(b, cap))
    big = buckets[-1]
    return int(min(-(-length // big) * big, cap))


class ServeEngine:
    """Streaming continuous-batching engine over a fixed slot pool."""

    def __init__(self, params, cfg: ModelConfig | None = None, *,
                 plan: SubspacePlan | None = None, max_slots: int = 4,
                 max_cache: int = 512,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 scheduler: Scheduler | str = "fcfs"):
        if cfg is None:
            if plan is None:
                raise ValueError("ServeEngine needs a ModelConfig or a "
                                 "SubspacePlan (which carries one)")
            cfg = plan.model
        # the engine serves under ONE resolved plan: every linear in the
        # jitted prefill/decode must read the same subspace decision the
        # params were built (or converted) with. Install it only if the
        # slot is free — silently overriding another live plan for an
        # equal config would retrace someone else's model at wrong ranks.
        if plan is None:
            self.plan = plan_of(cfg)
        else:
            current = installed(cfg)
            if current is None:
                self.plan = install(plan)
            elif current == plan:
                self.plan = current
            else:
                raise ValueError(
                    "a different SubspacePlan is already installed for this "
                    "ModelConfig; api.uninstall(cfg) it first, or build the "
                    "engine with that plan")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_cache = max_cache
        self.sched: Scheduler = (make_scheduler(scheduler)
                                 if isinstance(scheduler, str) else scheduler)
        # weight-storage accounting: an int8 deployment (plan.quantized +
        # convert.quantize) serves through the same engine; summary() then
        # reports the packed linear-weight bytes next to throughput
        self.quantized = self.plan.is_quantized
        from repro.utils.memprof import model_weight_bytes
        self.weight_report = model_weight_bytes(params)
        self.buckets = tuple(sorted(buckets))
        self.caches = init_lm_cache(cfg, max_slots, max_cache,
                                    dtype=jnp.dtype(cfg.dtype))
        self.slots: list[Request | None] = [None] * max_slots
        # per-slot decode state, row-aligned with the cache batch axis:
        # position / next input token, plus the device-side sampling
        # arrays (temperature, top-k, top-p, RNG seed, sampled-token count)
        self.pos = np.zeros(max_slots, np.int32)
        self.next_tok = np.zeros(max_slots, np.int32)
        self.temp = np.zeros(max_slots, np.float32)
        self.top_k = np.zeros(max_slots, np.int32)
        self.top_p = np.ones(max_slots, np.float32)
        self.seed = np.zeros(max_slots, np.uint32)
        self.count = np.zeros(max_slots, np.int32)
        self._rid = 0
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_tokens": 0, "completed": 0, "cancelled": 0,
                      "evicted": 0, "wall_s": 0.0, "prefill_s": 0.0,
                      "decode_s": 0.0}

        def _decode(params_, toks, caches, pos, temp, tk, tp, seeds, counts):
            logits, caches = lm_decode_step(params_, toks, caches, pos, cfg)
            nxt = sample_tokens(logits, temp, tk, tp, seeds, counts)
            return nxt, caches

        def _prefill(params_, toks, caches, valid_len, rows,
                     temp, tk, tp, seeds):
            # gather the admitted rows, prefill them as one batch, scatter
            # back — cache leaves are (repeat, B, ...), batch on axis 1
            sub = jax.tree.map(lambda a: a[:, rows], caches)
            logits, sub = lm_prefill(params_, toks, cfg, caches=sub,
                                     valid_len=valid_len, last_only=True)
            new = jax.tree.map(lambda g, l: g.at[:, rows].set(l), caches, sub)
            first = sample_tokens(logits[:, 0], temp, tk, tp, seeds,
                                  jnp.zeros_like(seeds, jnp.int32))
            return first, new

        # donate the cache pytree: the engine rebinds self.caches on every
        # call and never touches the old buffers, so XLA can update KV/SSM
        # state in place instead of copying the whole cache per token.
        # (CPU ignores donation with a warning — skip it there.)
        donate = () if jax.default_backend() == "cpu" else (2,)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._prefill = jax.jit(_prefill, donate_argnums=donate)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, step: int | None = None,
                        **engine_kw) -> "ServeEngine":
        """Build an engine from a plan-bearing checkpoint — no config in
        hand. The manifest's SubspacePlan carries the ModelConfig and the
        per-site subspace layout the stored params use (api/convert.py) —
        including quant stamps, so an int8 checkpoint saved via
        ``convert.quantize`` serves quantized with zero extra flags."""
        from repro.api.convert import load_checkpoint

        params, plan, _ = load_checkpoint(ckpt_dir, step)
        if plan is None:
            raise ValueError(
                f"checkpoint at {ckpt_dir} carries no SubspacePlan; build "
                "the engine with ServeEngine(params, cfg) instead")
        return cls(params, plan=plan, **engine_kw)

    # -- submission / cancellation ------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int | None = None,
               eos_id: int | None = None, *,
               sampling: SamplingParams | None = None) -> GenerationHandle:
        """Queue a generation; returns its :class:`GenerationHandle`.

        ``sampling`` carries the full per-request contract (temperature /
        top-k / top-p / seed / max_new / eos / deadline / priority); the
        positional ``max_new`` / ``eos_id`` override it for the legacy
        call shape. Default is greedy decoding, token-for-token identical
        to the pre-redesign engine."""
        sp = (sampling or SamplingParams()).resolved(
            self._rid, max_new=max_new, eos_id=eos_id)
        if len(prompt) + sp.max_new > self.max_cache:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({sp.max_new}) exceeds "
                f"max_cache ({self.max_cache})")
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        req = Request(rid=self._rid, prompt=list(map(int, prompt)),
                      sampling=sp, submitted_at=time.perf_counter())
        self._rid += 1
        self.sched.add(req)
        return GenerationHandle(self, req)

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or running request. Running requests free their
        slot IMMEDIATELY (the next tick can admit into it; the dead row's
        stale cache writes are provably never read back). Returns False if
        the rid is unknown or already terminal."""
        queued = self.sched.remove(rid)
        if queued is not None:
            self._retire(queued, EventKind.CANCELLED, "user cancel")
            return True
        for slot, req in enumerate(self.slots):
            if req is not None and req.rid == rid:
                self._free_slot(slot)
                self._retire(req, EventKind.CANCELLED, "user cancel")
                return True
        return False

    @property
    def queue(self) -> collections.deque:
        """Queued requests in admission order (introspection only — the
        scheduler owns the real wait set)."""
        return collections.deque(self.sched.pending())

    @property
    def busy(self) -> bool:
        """True while any request is queued or occupying a slot — the
        ``step()``-until-done predicate ``run()`` (and any external
        driver) loops on."""
        return bool(len(self.sched)) or any(r is not None for r in self.slots)

    # -- internals ----------------------------------------------------------

    def _free_slot(self, slot: int) -> None:
        """Recycle a slot AND reset its sampling row to greedy defaults —
        a stale temperature on a dead row would keep ``jnp.any(temp > 0)``
        true and defeat the all-greedy ``lax.cond`` fast path."""
        self.slots[slot] = None
        self.temp[slot] = 0.0
        self.top_k[slot] = 0
        self.top_p[slot] = 1.0

    def _emit_token(self, req: Request, token: int, t: float) -> None:
        req.generated.append(token)
        if not req.first_token_at:
            req.first_token_at = t
        req.last_token_at = t
        req.events.append(Event(EventKind.TOKEN, req.rid, token=token, t=t))

    def _retire(self, req: Request, kind: EventKind, reason: str) -> None:
        t = time.perf_counter()
        req.events.append(Event(kind, req.rid, reason=reason, t=t))
        req.status = kind
        req.finished_at = t
        key = {EventKind.FINISHED: "completed",
               EventKind.CANCELLED: "cancelled",
               EventKind.EVICTED: "evicted"}[kind]
        self.stats[key] += 1

    def _finish_if_done(self, slot: int) -> None:
        req = self.slots[slot]
        if req is not None and req.hit_stop:
            self._free_slot(slot)         # recycle: next _admit reuses it
            s = req.sampling
            reason = ("eos" if s.eos_id is not None and req.generated
                      and req.generated[-1] == s.eos_id else "max_new")
            self._retire(req, EventKind.FINISHED, reason)

    def _evict(self, now: float) -> None:
        running = [r for r in self.slots if r is not None]
        for req in self.sched.victims(running, now):
            if req.terminal:      # defensive vs misbehaving schedulers:
                continue          # a request gets exactly ONE terminal event
            for slot, r in enumerate(self.slots):
                if r is req:
                    self._free_slot(slot)
                    break
            self._retire(req, EventKind.EVICTED, "deadline")

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not len(self.sched):
            return
        t0 = time.perf_counter()
        admitted: list[tuple[int, Request]] = []
        while free:
            req = self.sched.pop(t0)
            if req is None:
                break
            if req.terminal:      # e.g. evicted-from-queue by a scheduler
                continue          # that didn't also dequeue it
            admitted.append((free.pop(0), req))
        # group by bucket so same-shape prompts prefill as one batch
        groups: dict[int, list[tuple[int, Request]]] = collections.defaultdict(list)
        for slot, req in admitted:
            groups[bucket_for(len(req.prompt), self.buckets,
                              self.max_cache)].append((slot, req))
        for bucket, group in groups.items():
            rows = np.array([s for s, _ in group], np.int32)
            vlen = np.array([len(r.prompt) for _, r in group], np.int32)
            toks = np.zeros((len(group), bucket), np.int32)
            for i, (slot, req) in enumerate(group):
                toks[i, :len(req.prompt)] = req.prompt
                sp = req.sampling
                self.temp[slot] = sp.temperature
                self.top_k[slot] = sp.top_k
                self.top_p[slot] = sp.top_p
                self.seed[slot] = np.uint32(sp.seed & 0xFFFFFFFF)
            first, self.caches = self._prefill(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(vlen), jnp.asarray(rows),
                jnp.asarray(self.temp[rows]), jnp.asarray(self.top_k[rows]),
                jnp.asarray(self.top_p[rows]), jnp.asarray(self.seed[rows]))
            first = np.asarray(first)
            now = time.perf_counter()
            for i, (slot, req) in enumerate(group):
                self.slots[slot] = req
                self._emit_token(req, int(first[i]), now)
                self.pos[slot] = int(vlen[i])
                self.next_tok[slot] = int(first[i])
                self.count[slot] = 1
                self.stats["prefill_tokens"] += int(vlen[i])
                self._finish_if_done(slot)
        self.stats["prefill_s"] += time.perf_counter() - t0

    def _decode_all(self) -> None:
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return
        t0 = time.perf_counter()
        nxt, self.caches = self._decode(
            self.params, jnp.asarray(self.next_tok[:, None]),
            self.caches, jnp.asarray(self.pos),
            jnp.asarray(self.temp), jnp.asarray(self.top_k),
            jnp.asarray(self.top_p), jnp.asarray(self.seed),
            jnp.asarray(self.count))
        nxt = np.asarray(nxt, np.int32)
        self.stats["decode_steps"] += 1
        now = time.perf_counter()
        for slot in active:
            req = self.slots[slot]
            self._emit_token(req, int(nxt[slot]), now)
            self.pos[slot] += 1
            self.next_tok[slot] = int(nxt[slot])
            self.count[slot] += 1
            self.stats["decode_tokens"] += 1
            self._finish_if_done(slot)
        self.stats["decode_s"] += time.perf_counter() - t0

    # -- driving ------------------------------------------------------------

    def step(self) -> None:
        """One engine tick: enforce deadlines, admit whatever fits, then
        decode every active slot by one token. Accumulates wall_s so
        summary() rates are correct for callers driving step() directly,
        not just run()."""
        t0 = time.perf_counter()
        self._evict(t0)
        self._admit()
        self._decode_all()
        self.stats["wall_s"] += time.perf_counter() - t0

    def run(self) -> None:
        """Drain queue + slots to completion."""
        while self.busy:
            self.step()

    # -- reporting ----------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero all counters/timers (e.g. after warmup runs)."""
        for k in self.stats:
            self.stats[k] = type(self.stats[k])()

    def summary(self) -> dict:
        """Counters plus derived rates. Phase throughputs use each phase's
        own wall time (prefill_s / decode_s) so they measure the phase,
        not the mix; requests_s uses total engine time."""
        s = dict(self.stats)
        s["prefill_tok_s"] = s["prefill_tokens"] / max(s["prefill_s"], 1e-9)
        s["decode_tok_s"] = s["decode_tokens"] / max(s["decode_s"], 1e-9)
        s["requests_s"] = s["completed"] / max(s["wall_s"], 1e-9)
        s["weight_bytes"] = self.weight_report["total_bytes"]
        s["weight_mib"] = self.weight_report["total_bytes"] / 2**20
        s["quantized"] = self.quantized
        s["scheduler"] = getattr(self.sched, "name", type(self.sched).__name__)
        return s
