"""Continuous-batching serve engine: admission queue, per-slot KV caches,
prompt-length bucketing, slot recycling on EOS.

Design (the TrainDeeploy lesson: kernel and serving loop co-designed):

* The engine owns ONE set of batched decode caches (`init_lm_cache` with
  batch = max_slots). A *slot* is a batch row; admitting a request means
  prefilling its prompt into that row, finishing means freeing the row for
  the next queued request. Model code never sees the queue.

* Prefill is token-parallel (`lm_prefill`): one forward over the whole
  prompt writes every layer's KV slots / conv buffers / SSM states. To keep
  jit recompiles bounded, admitted prompts are right-padded to a small set
  of bucket lengths and the per-row true length rides in as `valid_len` —
  padded positions are masked out of cache writes and freeze recurrent
  state, so the caches are indistinguishable from exact-length prefill.
  Same-bucket admissions prefill together as one batch.

* Decode runs ALL slots in lockstep shapes but at per-slot positions
  (`pos` is a (B,) vector): every active request decodes one token per
  engine step regardless of when it was admitted — that is the continuous
  batching. Free slots ride along as dead rows (their writes land at stale
  positions that the causal/rolling masks provably never read back).

The jit cache ends up with exactly one decode executable plus one prefill
executable per (bucket, group-size) pair actually seen.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import SubspacePlan, install, installed, plan_of
from repro.config import ModelConfig
from repro.models.lm import init_lm_cache, lm_decode_step, lm_prefill

DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256)


def bucket_for(length: int, buckets: Sequence[int]) -> int:
    """Smallest bucket >= length; prompts beyond the largest bucket get an
    exact-length prefill (one extra compile, still a single forward)."""
    for b in buckets:
        if b >= length:
            return b
    return length


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int
    eos_id: int | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    finished_at: float = 0.0

    @property
    def done(self) -> bool:
        if self.generated and self.eos_id is not None \
                and self.generated[-1] == self.eos_id:
            return True
        return len(self.generated) >= self.max_new

    @property
    def tokens(self) -> list[int]:
        return list(self.prompt) + list(self.generated)


class ServeEngine:
    """Greedy-decoding continuous-batching engine over a fixed slot pool."""

    def __init__(self, params, cfg: ModelConfig | None = None, *,
                 plan: SubspacePlan | None = None, max_slots: int = 4,
                 max_cache: int = 512,
                 buckets: Sequence[int] = DEFAULT_BUCKETS):
        if cfg is None:
            if plan is None:
                raise ValueError("ServeEngine needs a ModelConfig or a "
                                 "SubspacePlan (which carries one)")
            cfg = plan.model
        # the engine serves under ONE resolved plan: every linear in the
        # jitted prefill/decode must read the same subspace decision the
        # params were built (or converted) with. Install it only if the
        # slot is free — silently overriding another live plan for an
        # equal config would retrace someone else's model at wrong ranks.
        if plan is None:
            self.plan = plan_of(cfg)
        else:
            current = installed(cfg)
            if current is None:
                self.plan = install(plan)
            elif current == plan:
                self.plan = current
            else:
                raise ValueError(
                    "a different SubspacePlan is already installed for this "
                    "ModelConfig; api.uninstall(cfg) it first, or build the "
                    "engine with that plan")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_cache = max_cache
        # weight-storage accounting: an int8 deployment (plan.quantized +
        # convert.quantize) serves through the same engine; summary() then
        # reports the packed linear-weight bytes next to throughput
        self.quantized = self.plan.is_quantized
        from repro.utils.memprof import model_weight_bytes
        self.weight_report = model_weight_bytes(params)
        self.buckets = tuple(sorted(buckets))
        self.caches = init_lm_cache(cfg, max_slots, max_cache,
                                    dtype=jnp.dtype(cfg.dtype))
        self.slots: list[Request | None] = [None] * max_slots
        # per-slot next decode position / next input token (row-aligned)
        self.pos = np.zeros(max_slots, np.int32)
        self.next_tok = np.zeros(max_slots, np.int32)
        self.queue: collections.deque[Request] = collections.deque()
        self._rid = 0
        self.stats = {"prefill_tokens": 0, "decode_steps": 0,
                      "decode_tokens": 0, "completed": 0, "wall_s": 0.0,
                      "prefill_s": 0.0, "decode_s": 0.0}

        def _decode(params_, toks, caches, pos):
            return lm_decode_step(params_, toks, caches, pos, cfg)

        def _prefill(params_, toks, caches, valid_len, rows):
            # gather the admitted rows, prefill them as one batch, scatter
            # back — cache leaves are (repeat, B, ...), batch on axis 1
            sub = jax.tree.map(lambda a: a[:, rows], caches)
            logits, sub = lm_prefill(params_, toks, cfg, caches=sub,
                                     valid_len=valid_len, last_only=True)
            new = jax.tree.map(lambda g, l: g.at[:, rows].set(l), caches, sub)
            return jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32), new

        # donate the cache pytree: the engine rebinds self.caches on every
        # call and never touches the old buffers, so XLA can update KV/SSM
        # state in place instead of copying the whole cache per token.
        # (CPU ignores donation with a warning — skip it there.)
        donate = () if jax.default_backend() == "cpu" else (2,)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._prefill = jax.jit(_prefill, donate_argnums=donate)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, step: int | None = None,
                        **engine_kw) -> "ServeEngine":
        """Build an engine from a plan-bearing checkpoint — no config in
        hand. The manifest's SubspacePlan carries the ModelConfig and the
        per-site subspace layout the stored params use (api/convert.py) —
        including quant stamps, so an int8 checkpoint saved via
        ``convert.quantize`` serves quantized with zero extra flags."""
        from repro.api.convert import load_checkpoint

        params, plan, _ = load_checkpoint(ckpt_dir, step)
        if plan is None:
            raise ValueError(
                f"checkpoint at {ckpt_dir} carries no SubspacePlan; build "
                "the engine with ServeEngine(params, cfg) instead")
        return cls(params, plan=plan, **engine_kw)

    # -- submission ---------------------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int,
               eos_id: int | None = None) -> Request:
        if len(prompt) + max_new > self.max_cache:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({max_new}) exceeds "
                f"max_cache ({self.max_cache})")
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if max_new < 1:
            raise ValueError("max_new must be >= 1 (prefill always emits "
                             "the first token)")
        req = Request(rid=self._rid, prompt=list(map(int, prompt)),
                      max_new=max_new, eos_id=eos_id,
                      submitted_at=time.perf_counter())
        self._rid += 1
        self.queue.append(req)
        return req

    # -- internals ----------------------------------------------------------

    def _finish_if_done(self, slot: int) -> None:
        req = self.slots[slot]
        if req is not None and req.done:
            req.finished_at = time.perf_counter()
            self.slots[slot] = None           # recycle: next _admit reuses it
            self.stats["completed"] += 1

    def _admit(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not self.queue:
            return
        t0 = time.perf_counter()
        admitted: list[tuple[int, Request]] = []
        while free and self.queue:
            admitted.append((free.pop(0), self.queue.popleft()))
        # group by bucket so same-shape prompts prefill as one batch; the
        # bucket is capped at max_cache (prompt itself always fits: submit()
        # validated len + max_new <= max_cache)
        groups: dict[int, list[tuple[int, Request]]] = collections.defaultdict(list)
        for slot, req in admitted:
            bucket = min(bucket_for(len(req.prompt), self.buckets),
                         self.max_cache)
            groups[bucket].append((slot, req))
        for bucket, group in groups.items():
            rows = np.array([s for s, _ in group], np.int32)
            vlen = np.array([len(r.prompt) for _, r in group], np.int32)
            toks = np.zeros((len(group), bucket), np.int32)
            for i, (_, r) in enumerate(group):
                toks[i, :len(r.prompt)] = r.prompt
            first, self.caches = self._prefill(
                self.params, jnp.asarray(toks), self.caches,
                jnp.asarray(vlen), jnp.asarray(rows))
            first = np.asarray(first)
            now = time.perf_counter()
            for i, (slot, req) in enumerate(group):
                self.slots[slot] = req
                req.generated.append(int(first[i]))
                req.first_token_at = now
                self.pos[slot] = int(vlen[i])
                self.next_tok[slot] = int(first[i])
                self.stats["prefill_tokens"] += int(vlen[i])
                self._finish_if_done(slot)
        self.stats["prefill_s"] += time.perf_counter() - t0

    def _decode_all(self) -> None:
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            return
        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.next_tok[:, None]),
            self.caches, jnp.asarray(self.pos))
        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        self.stats["decode_steps"] += 1
        for slot in active:
            req = self.slots[slot]
            req.generated.append(int(nxt[slot]))
            self.pos[slot] += 1
            self.next_tok[slot] = int(nxt[slot])
            self.stats["decode_tokens"] += 1
            self._finish_if_done(slot)
        self.stats["decode_s"] += time.perf_counter() - t0

    # -- driving ------------------------------------------------------------

    def step(self) -> None:
        """One engine tick: admit whatever fits, then decode every active
        slot by one token. Accumulates wall_s so summary() rates are
        correct for callers driving step() directly, not just run()."""
        t0 = time.perf_counter()
        self._admit()
        self._decode_all()
        self.stats["wall_s"] += time.perf_counter() - t0

    def run(self) -> None:
        """Drain queue + slots to completion."""
        while self.queue or any(r is not None for r in self.slots):
            self.step()

    # -- reporting ----------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero all counters/timers (e.g. after warmup runs)."""
        for k in self.stats:
            self.stats[k] = type(self.stats[k])()

    def summary(self) -> dict:
        """Counters plus derived rates. Phase throughputs use each phase's
        own wall time (prefill_s / decode_s) so they measure the phase,
        not the mix; requests_s uses total engine time."""
        s = dict(self.stats)
        s["prefill_tok_s"] = s["prefill_tokens"] / max(s["prefill_s"], 1e-9)
        s["decode_tok_s"] = s["decode_tokens"] / max(s["decode_s"], 1e-9)
        s["requests_s"] = s["completed"] / max(s["wall_s"], 1e-9)
        s["weight_bytes"] = self.weight_report["total_bytes"]
        s["weight_mib"] = self.weight_report["total_bytes"] / 2**20
        s["quantized"] = self.quantized
        return s
