"""Continuous-batching serve engine: streaming request lifecycle over a
fixed slot pool, with device-side sampling, pluggable scheduling, and an
optional paged KV pool with prefix sharing and chunked prefill.

Design (the TrainDeeploy lesson: kernel and serving loop co-designed):

* The engine owns ONE set of batched decode caches. A *slot* is a batch
  row; admitting a request means prefilling its prompt into that row,
  finishing (or cancelling, or evicting) means freeing the row for the
  next queued request. Model code never sees the queue.

* DENSE mode (`paged=False`, the oracle path): `init_lm_cache` with
  batch = max_slots — every slot reserves `max_cache` KV in every layer.
  Prefill is token-parallel (`lm_prefill`): admitted prompts are
  right-padded to a small set of bucket lengths and same-bucket
  admissions prefill together as one batch.

* PAGED mode (`paged=True` / `"auto"`): KV storage is a pool of
  fixed-size pages (`serve/kvpool.py` owns refcounts + free list;
  `nn/attention.py::PagedKVCache` is the device side). Each slot maps
  logical pages to physical ones through a per-slot page-table row, so
  live slot count decouples from `max_cache` — a 12-token prompt holds
  pages for 12+max_new tokens, not max_cache. A radix tree over prompt
  prefixes lets a shared system prompt prefill ONCE: later requests
  attach the shared pages by refcount and prefill only their suffix.
  Prefill is CHUNKED — at most `prefill_chunks_per_tick` fixed-size
  chunks advance per engine tick, interleaved with the decode tick, so
  one 8k prompt cannot spike every other request's TPOT. Paged mode
  needs causal full attention in every layer (`supports_paging`);
  sliding-window / Mamba configs serve dense.

* Decode runs ALL slots in lockstep shapes but at per-slot positions
  (`pos` is a (B,) vector): every active request decodes one token per
  engine tick regardless of when it was admitted — that is the continuous
  batching. Free (and still-prefilling) slots ride along as dead rows:
  dense dead rows write at stale positions the causal masks provably
  never read back; paged dead rows carry an all-zero page-table row, so
  their writes land on the reserved trash page.

* MESH mode (`mesh=...`): the dense engine sharded over a device mesh —
  weights (f32 or int8 factors) replicated on every device, the KV slot
  pool sharded across devices on the cache BATCH axis, so `max_slots`
  scales with the mesh while every executable stays
  one-per-bucket. Each slot's decode math is row-independent, so mesh
  generations are bitwise-identical to the single-device dense engine
  (tests/test_mesh_parity.py pins this, f32 and int8). Paged pools,
  speculative decoding and tenant adapters keep their single-device
  engines for now — mesh serves the dense oracle path.

* Sampling is DEVICE-SIDE (`serve/sampling.py`): per-slot temperature /
  top-k / top-p / RNG key arrays ride into the jitted prefill and decode
  steps, which return sampled int32 tokens — the host never round-trips
  logits, and temperature-0 rows lower to the exact argmax the greedy
  engine ran (token-for-token identical, f32 and int8).

* The request lifecycle is event-driven (`serve/session.py`): `submit()`
  returns a `GenerationHandle` streaming TOKEN / FINISHED / CANCELLED /
  EVICTED events with TTFT/TPOT on the handle; admission order and
  deadline eviction are a pluggable `Scheduler` (`serve/scheduler.py`).
  In paged mode a popped request that cannot get enough pages is pushed
  back to the scheduler and admission stops for the tick — pages free as
  running requests retire (or the prefix cache evicts LRU entries).

The jit cache ends up with one decode executable, plus (dense) one
prefill executable per (bucket, group-size) pair actually seen or
(paged) exactly ONE chunk-prefill executable regardless of prompt mix.
"""
from __future__ import annotations

import collections
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api.plan import SubspacePlan, install, installed, plan_of
from repro.config import ModelConfig
from repro.models.lm import (
    init_lm_cache,
    lm_decode_step,
    lm_prefill,
    supports_paging,
)
from repro.serve.kvpool import PagePool, RadixCache, pages_needed
from repro.serve.sampling import SamplingParams, sample_tokens
from repro.serve.scheduler import Scheduler, make_scheduler
from repro.serve.session import Event, EventKind, GenerationHandle, Request

DEFAULT_BUCKETS = (8, 16, 32, 64, 128, 256)
DEFAULT_PAGE_SIZE = 16


def bucket_for(length: int, buckets: Sequence[int],
               max_cache: int | None = None) -> int:
    """Smallest bucket >= length. Prompts beyond the largest bucket round
    UP to the next multiple of it — a handful of shared executables instead
    of one exact-length compile per adversarial prompt length — and every
    result is capped at ``max_cache`` (admission validated the prompt
    itself fits)."""
    cap = max_cache if max_cache is not None else float("inf")
    for b in buckets:
        if b >= length:
            return int(min(b, cap))
    big = buckets[-1]
    return int(min(-(-length // big) * big, cap))


class ServeEngine:
    """Streaming continuous-batching engine over a fixed slot pool."""

    def __init__(self, params, cfg: ModelConfig | None = None, *,
                 plan: SubspacePlan | None = None, max_slots: int = 4,
                 max_cache: int = 512,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 scheduler: Scheduler | str = "fcfs",
                 paged: bool | str = False,
                 page_size: int = DEFAULT_PAGE_SIZE,
                 total_pages: int | None = None,
                 prefix_cache: bool = True,
                 prefill_chunk: int | None = None,
                 prefill_chunks_per_tick: int = 1,
                 prefill_every: int = 1,
                 spec_k: int = 0,
                 draft: str = "int8",
                 adapters=None,
                 adapter_slots: int = 4,
                 mesh: Mesh | None = None):
        if cfg is None:
            if plan is None:
                raise ValueError("ServeEngine needs a ModelConfig or a "
                                 "SubspacePlan (which carries one)")
            cfg = plan.model
        # the engine serves under ONE resolved plan: every linear in the
        # jitted prefill/decode must read the same subspace decision the
        # params were built (or converted) with. Install it only if the
        # slot is free — silently overriding another live plan for an
        # equal config would retrace someone else's model at wrong ranks.
        if plan is None:
            self.plan = plan_of(cfg)
        else:
            current = installed(cfg)
            if current is None:
                self.plan = install(plan)
            elif current == plan:
                self.plan = current
            else:
                raise ValueError(
                    "a different SubspacePlan is already installed for this "
                    "ModelConfig; api.uninstall(cfg) it first, or build the "
                    "engine with that plan")
        self.params = params
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_cache = max_cache
        self.sched: Scheduler = (make_scheduler(scheduler)
                                 if isinstance(scheduler, str) else scheduler)
        # weight-storage accounting: an int8 deployment (plan.quantized +
        # convert.quantize) serves through the same engine; summary() then
        # reports the packed linear-weight bytes next to throughput
        self.quantized = self.plan.is_quantized
        from repro.utils.memprof import model_weight_bytes
        self.weight_report = model_weight_bytes(params)
        self.buckets = tuple(sorted(buckets))

        # -- self-speculative decoding setup ------------------------------
        # spec_k > 0: each decode tick drafts spec_k tokens ahead through a
        # cheap subspace view of the SAME weights (int8 factors, or a
        # rank-K' slice of the resident L/R), then verifies all of them in
        # one batched f32 forward with the standard rejection rule.
        self.spec_k = int(spec_k)
        self.draft_source = draft
        if self.spec_k:
            if self.spec_k > max_cache - 2:
                raise ValueError(f"spec_k ({spec_k}) leaves no room in "
                                 f"max_cache ({max_cache})")
            if not supports_paging(cfg):
                # rolling-window and recurrent (Mamba) caches update
                # destructively — a rejected draft could not be rolled back
                raise ValueError(
                    f"config {cfg.name!r} has sliding-window or recurrent "
                    "layers whose caches cannot roll back rejected drafts; "
                    "speculative decoding needs causal full attention")
            if draft == "int8" and self.quantized:
                raise ValueError(
                    "engine already serves int8 — an int8 draft would equal "
                    "the target; use draft='rank:<frac>' to slice the "
                    "resident int8 factors instead")
            # stamp the plan so bind.apply accepts the draft layouts (an
            # int8-packed draft under an f32 spec, or narrower factor
            # slices). Stamps never change f32 semantics, so overriding an
            # installed unstamped plan is safe for other consumers.
            stamped = self.plan.with_draft(draft)
            if stamped.draft_source is None:
                raise ValueError(
                    f"draft {draft!r} stamps no site of this plan (rank "
                    "drafts need factored sites — a dense-only plan has "
                    "nothing to slice)")
            if stamped != self.plan:
                install(stamped)
                self.plan = stamped
            from repro.api.convert import draft_view
            self.draft_params = draft_view(params, self.plan)
        else:
            self.draft_params = None

        # -- multi-tenant adapter banks -----------------------------------
        # `adapters` is a ResidentAdapters (or a store dir path): per-slot
        # int32 indices gather each request's tenant row out of the
        # device-resident banks INSIDE the jitted steps, so any tenant mix
        # — including adapter-less slots via identity row 0 — runs through
        # one executable, and tenant churn swaps bank contents only.
        if adapters is not None:
            if self.spec_k:
                raise ValueError(
                    "speculative decoding and tenant adapters are mutually "
                    "exclusive: the draft view does not carry per-tenant "
                    "deltas, so drafts would systematically diverge")
            from repro.tenancy.resident import ResidentAdapters
            if isinstance(adapters, str):
                adapters = ResidentAdapters(adapters, capacity=adapter_slots)
            self.adapters = adapters
            stamped = SubspacePlan.from_json(adapters.plan_json)
            if stamped.model != cfg:
                raise ValueError(
                    f"adapter store was trained for model "
                    f"{stamped.model.name!r} but the engine serves "
                    f"{cfg.name!r}")
            self.adapter_plan = stamped
            adapters.on_evict = self._adapter_evicted
        else:
            self.adapters = None
            self.adapter_plan = None
        self.adapter_events: list[Event] = []

        if paged == "auto":
            paged = supports_paging(cfg)
        elif paged and not supports_paging(cfg):
            raise ValueError(
                f"config {cfg.name!r} has layers a paged KV pool cannot hold "
                "(sliding-window or recurrent state); serve it dense or use "
                "paged='auto'")
        self.paged = bool(paged)

        # -- mesh mode: dense slots sharded across devices -----------------
        self.mesh = mesh
        if mesh is not None:
            n = mesh.devices.size
            if self.paged:
                raise ValueError(
                    "mesh serving shards the DENSE slot pool on the cache "
                    "batch axis; the paged pool's page tables are "
                    "single-device — serve paged without a mesh")
            if self.spec_k:
                raise ValueError("speculative decoding is single-device; "
                                 "drop spec_k or the mesh")
            if self.adapters is not None:
                raise ValueError("tenant adapter banks are single-device; "
                                 "drop adapters or the mesh")
            if max_slots % n:
                raise ValueError(
                    f"max_slots ({max_slots}) must divide evenly across the "
                    f"{n}-device mesh — every device holds max_slots/{n} "
                    "cache rows")
            # weights replicate; KV shards on the batch (slot) axis — cache
            # leaves are (repeat, B, ...), batch at axis 1 for every layout
            self._repl = NamedSharding(mesh, P())
            self._cache_shard = NamedSharding(
                mesh, P(None, tuple(mesh.axis_names)))
            self.params = params = jax.device_put(params, self._repl)
        dtype = jnp.dtype(cfg.dtype)
        if self.paged:
            self.page_size = int(page_size)
            self.pages_per_slot = pages_needed(max_cache, page_size)
            if total_pages is None:
                # dense-equivalent capacity by default; pass fewer pages to
                # oversubscribe slots, or more to grow the prefix cache
                total_pages = max_slots * self.pages_per_slot + 1
            self.pool = PagePool(total_pages, page_size)
            self.radix = RadixCache(self.pool) if prefix_cache else None
            self.prefill_chunk = int(prefill_chunk or self.buckets[-1])
            self.prefill_chunks_per_tick = int(prefill_chunks_per_tick)
            # stride: with decodes active, advance prefill only every Nth
            # tick — each chunk attends over the full gathered history, so
            # on long prompts a chunk can cost several decode ticks; the
            # stride bounds its TPOT tax at the price of long-request TTFT
            # (benchmarks/tab2_latency.py measures the trade)
            self.prefill_every = max(1, int(prefill_every))
            self._tick = 0
            self.caches = init_lm_cache(cfg, max_slots, max_cache,
                                        dtype=dtype, pages=total_pages,
                                        page_size=page_size)
            # host-side slot state: page-table rows, per-slot page lists,
            # and the prefill cursor (abs position of the next unprefilled
            # prompt token; None = not prefilling)
            self.tables = np.zeros((max_slots, self.pages_per_slot), np.int32)
            self.slot_pages: list[list[int]] = [[] for _ in range(max_slots)]
            self._cursor: list[int | None] = [None] * max_slots
            self._pf_rr = 0
            # pages reserved at admission (pages_needed(prompt + max_new));
            # spec decode may transiently allocate pages BEYOND this to hold
            # draft KV past the budget end, and releases them every tick
            self._prealloc = [0] * max_slots
        else:
            self.pool = self.radix = None
            self.caches = init_lm_cache(cfg, max_slots, max_cache,
                                        dtype=dtype)
            if mesh is not None:
                self.caches = jax.device_put(self.caches, self._cache_shard)
        self.slots: list[Request | None] = [None] * max_slots
        # per-slot decode state, row-aligned with the cache batch axis:
        # position / next input token, plus the device-side sampling
        # arrays (temperature, top-k, top-p, RNG seed, sampled-token count)
        self.pos = np.zeros(max_slots, np.int32)
        self.next_tok = np.zeros(max_slots, np.int32)
        # per-slot adapter bank row (0 = identity / no tenant)
        self.adapter_ix = np.zeros(max_slots, np.int32)
        self.temp = np.zeros(max_slots, np.float32)
        self.top_k = np.zeros(max_slots, np.int32)
        self.top_p = np.ones(max_slots, np.float32)
        self.seed = np.zeros(max_slots, np.uint32)
        self.count = np.zeros(max_slots, np.int32)
        self._rid = 0
        self.stats = {"prefill_tokens": 0, "prefill_chunks": 0,
                      "prefix_hit_tokens": 0, "decode_steps": 0,
                      "decode_tokens": 0, "completed": 0, "cancelled": 0,
                      "evicted": 0, "deferred": 0, "wall_s": 0.0,
                      "prefill_s": 0.0, "decode_s": 0.0,
                      "spec_steps": 0, "spec_draft_tokens": 0,
                      "spec_accepted_tokens": 0, "spec_page_shrinks": 0,
                      "adapter_evictions": 0}

        def _pin(caches):
            # mesh mode: keep the returned cache pytree sharded on the slot
            # axis — without the constraint the row gather/scatter in
            # prefill can make XLA fall back to a replicated layout
            if mesh is None:
                return caches
            return jax.tree.map(
                lambda c: jax.lax.with_sharding_constraint(
                    c, self._cache_shard), caches)

        def _merged(params_, banks, aix):
            # trace-time branch: a no-adapter engine passes banks=None and
            # compiles the EXACT pre-tenancy computation; an adapter
            # engine gathers each batch row's tenant factors from the
            # banks and merges them next to the base weights, so
            # bind.apply adds the delta. Tenant churn changes bank
            # CONTENTS only — one executable either way.
            if banks is None:
                return params_
            from repro.tenancy.adapter import gather_rows, merge_adapters
            return merge_adapters(params_, gather_rows(banks, aix))

        def _decode(params_, banks, aix, toks, caches, pos, table,
                    temp, tk, tp, seeds, counts):
            logits, caches = lm_decode_step(_merged(params_, banks, aix),
                                            toks, caches, pos, cfg,
                                            page_table=table)
            nxt = sample_tokens(logits, temp, tk, tp, seeds, counts)
            return nxt, _pin(caches)

        def _prefill(params_, banks, aix, toks, caches, valid_len, rows,
                     temp, tk, tp, seeds):
            # dense grouped prefill: gather the admitted rows, prefill them
            # as one batch, scatter back — cache leaves are (repeat, B, ...),
            # batch on axis 1. `aix` is already row-gathered on the host.
            sub = jax.tree.map(lambda a: a[:, rows], caches)
            logits, sub = lm_prefill(_merged(params_, banks, aix), toks,
                                     cfg, caches=sub,
                                     valid_len=valid_len, last_only=True)
            new = jax.tree.map(lambda g, l: g.at[:, rows].set(l), caches, sub)
            first = sample_tokens(logits[:, 0], temp, tk, tp, seeds,
                                  jnp.zeros_like(seeds, jnp.int32))
            return first, _pin(new)

        def _prefill_chunk(params_, banks, aix, toks, caches, offset,
                           valid_len, table, temp, tk, tp, seeds):
            # paged chunk prefill: one (1, chunk) executable for EVERY
            # prompt; the pool rides whole (pages are disjoint by
            # construction) and the chunk writes through this slot's table
            logits, caches = lm_prefill(_merged(params_, banks, aix), toks,
                                        cfg, caches=caches,
                                        pos=offset, valid_len=valid_len,
                                        last_only=True, page_table=table)
            first = sample_tokens(logits[:, 0], temp, tk, tp, seeds,
                                  jnp.zeros_like(seeds, jnp.int32))
            return first, caches

        def _draft_step(dparams, toks, caches, pos, table,
                        temp, tk, tp, seeds, counts):
            # one draft-model decode step: same shape as _decode but under
            # the cheap subspace view, sampling from the SALT_DRAFT stream
            # and returning the warped proposal distribution q for the
            # rejection test. Draft KV lands at the drafted positions and
            # is OVERWRITTEN by the verify pass's f32 KV (rows past their
            # capacity write at a sentinel position that scatter drops /
            # the padded trash table column routes to page 0).
            from repro.serve.sampling import sample_draft_tokens
            logits, caches = lm_decode_step(dparams, toks, caches, pos, cfg,
                                            page_table=table)
            nxt, q = sample_draft_tokens(logits, temp, tk, tp, seeds, counts)
            return nxt, q, caches

        def _verify(params_, toks, caches, offset, table, draft_toks,
                    draft_q, draft_len, temp, tk, tp, seeds, counts):
            # ONE token-parallel f32 forward over [cur, d_0..d_{k-1}] at
            # per-row offsets — the same machinery as chunked prefill
            # (paged) or the dense per-row verify branch — followed by the
            # device-side rejection rule. Only int32 tokens leave the jit.
            from repro.serve.sampling import spec_accept
            logits, caches = lm_prefill(params_, toks, cfg, caches=caches,
                                        pos=offset, valid_len=draft_len + 1,
                                        last_only=False, page_table=table)
            n_acc, out = spec_accept(logits.astype(jnp.float32), draft_toks,
                                     draft_q, draft_len, temp, tk, tp,
                                     seeds, counts)
            return n_acc, out, caches

        # donate the cache pytree: the engine rebinds self.caches on every
        # call and never touches the old buffers, so XLA can update KV/SSM
        # state in place instead of copying the whole cache per token.
        # (CPU ignores donation with a warning — skip it there.) The
        # adapter-aware steps carry caches at arg 4 (after banks + aix —
        # banks are NOT donated, they persist across calls); the spec
        # steps keep the original signature, caches at arg 2.
        cpu = jax.default_backend() == "cpu"
        donate = () if cpu else (4,)
        donate_spec = () if cpu else (2,)
        self._decode = jax.jit(_decode, donate_argnums=donate)
        self._prefill = jax.jit(_prefill, donate_argnums=donate)
        self._prefill_chunk = jax.jit(_prefill_chunk, donate_argnums=donate)
        self._draft_step = jax.jit(_draft_step, donate_argnums=donate_spec)
        self._verify = jax.jit(_verify, donate_argnums=donate_spec)

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str, step: int | None = None,
                        **engine_kw) -> "ServeEngine":
        """Build an engine from a plan-bearing checkpoint — no config in
        hand. The manifest's SubspacePlan carries the ModelConfig and the
        per-site subspace layout the stored params use (api/convert.py) —
        including quant stamps, so an int8 checkpoint saved via
        ``convert.quantize`` serves quantized with zero extra flags."""
        from repro.api.convert import load_checkpoint

        params, plan, _ = load_checkpoint(ckpt_dir, step)
        if plan is None:
            raise ValueError(
                f"checkpoint at {ckpt_dir} carries no SubspacePlan; build "
                "the engine with ServeEngine(params, cfg) instead")
        return cls(params, plan=plan, **engine_kw)

    # -- submission / cancellation ------------------------------------------

    def submit(self, prompt: Sequence[int], max_new: int | None = None,
               eos_id: int | None = None, *,
               sampling: SamplingParams | None = None,
               tenant: str | None = None) -> GenerationHandle:
        """Queue a generation; returns its :class:`GenerationHandle`.

        ``sampling`` carries the full per-request contract (temperature /
        top-k / top-p / seed / max_new / eos / deadline / priority); the
        positional ``max_new`` / ``eos_id`` override it for the legacy
        call shape. Default is greedy decoding, token-for-token identical
        to the pre-redesign engine. ``tenant`` routes the request through
        that tenant's adapter delta (engine built with ``adapters=``);
        ``None`` serves the bare base via the identity bank row."""
        sp = (sampling or SamplingParams()).resolved(
            self._rid, max_new=max_new, eos_id=eos_id)
        if tenant is not None:
            if self.adapters is None:
                raise ValueError(
                    "engine has no adapter banks; build it with "
                    "adapters=<ResidentAdapters or store dir>")
            if not self.adapters.store.has(tenant):
                raise ValueError(f"unknown tenant {tenant!r}: no adapter "
                                 f"in store {self.adapters.store.root!r}")
        if len(prompt) + sp.max_new > self.max_cache:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new ({sp.max_new}) exceeds "
                f"max_cache ({self.max_cache})")
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if self.paged:
            need = pages_needed(len(prompt) + sp.max_new, self.page_size)
            if need > self.pool.usable_pages:
                raise ValueError(
                    f"request needs {need} pages but the pool has "
                    f"{self.pool.usable_pages} usable (total_pages too "
                    "small for this prompt + max_new)")
        req = Request(rid=self._rid, prompt=list(map(int, prompt)),
                      sampling=sp, tenant=tenant,
                      submitted_at=time.perf_counter())
        self._rid += 1
        self.sched.add(req)
        return GenerationHandle(self, req)

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or running request. Running requests free their
        slot IMMEDIATELY (the next tick can admit into it; the dead row's
        stale cache writes are provably never read back). Returns False if
        the rid is unknown or already terminal."""
        queued = self.sched.remove(rid)
        if queued is not None:
            self._retire(queued, EventKind.CANCELLED, "user cancel")
            return True
        for slot, req in enumerate(self.slots):
            if req is not None and req.rid == rid:
                self._free_slot(slot)
                self._retire(req, EventKind.CANCELLED, "user cancel")
                return True
        return False

    @property
    def queue(self) -> collections.deque:
        """Queued requests in admission order (introspection only — the
        scheduler owns the real wait set)."""
        return collections.deque(self.sched.pending())

    @property
    def busy(self) -> bool:
        """True while any request is queued or occupying a slot — the
        ``step()``-until-done predicate ``run()`` (and any external
        driver) loops on."""
        return bool(len(self.sched)) or any(r is not None for r in self.slots)

    # -- paged-pool maintenance ---------------------------------------------

    def release_prefix_cache(self) -> int:
        """Drop every radix-held page (frees them back to the pool).
        Returns the number of pages released. After a full drain plus this
        call, every page refcount is zero — the invariant the fuzz harness
        pins."""
        return self.radix.clear() if self.radix is not None else 0

    def check_invariants(self) -> None:
        """Audit the paged bookkeeping (no-op in plain dense mode): pool
        structure is sound and every page's refcount equals its holder
        count (slots holding it in their table + radix nodes). Mesh
        engines additionally audit the cache placement: every leaf still
        sharded over the full mesh on the slot axis, one equal-size shard
        per device (a silent fallback to replicated layout would be a
        correctness-preserving but capacity-destroying regression)."""
        if self.mesh is not None:
            n = self.mesh.devices.size
            for leaf in jax.tree.leaves(self.caches):
                shards = leaf.addressable_shards
                if len(shards) != n:
                    raise AssertionError(
                        f"cache leaf lost mesh sharding: {len(shards)} "
                        f"shards for a {n}-device mesh")
                if shards[0].data.shape[1] * n != leaf.shape[1]:
                    raise AssertionError(
                        f"cache leaf not sharded on the slot axis: local "
                        f"{shards[0].data.shape} vs global {leaf.shape}")
        if not self.paged:
            return
        self.pool.check()
        expected = np.zeros(self.pool.total_pages, np.int64)
        for slot, pages in enumerate(self.slot_pages):
            if self.slots[slot] is not None:
                for p in pages:
                    expected[p] += 1
        if self.radix is not None:
            for p in self.radix.held_pages():
                expected[p] += 1
        actual = self.pool.refs.astype(np.int64)
        if not (expected == actual).all():
            bad = np.nonzero(expected != actual)[0]
            raise AssertionError(
                f"page refcount leak: pages {bad.tolist()} expected "
                f"{expected[bad].tolist()} got {actual[bad].tolist()}")

    # -- internals ----------------------------------------------------------

    def _adapter_evicted(self, tenant: str) -> None:
        """Resident-bank LRU displacement -> the existing EVICTED event
        machinery (rid -1: no single request owns a bank row)."""
        self.adapter_events.append(Event(
            EventKind.EVICTED, rid=-1,
            reason=f"adapter lru tenant={tenant}", t=time.perf_counter()))
        self.stats["adapter_evictions"] += 1

    def _acquire_adapter(self, req: Request,
                         admitted: list) -> int | None:
        """Bank row for this request's tenant (0 = identity). Rows held by
        slots still generating — and by requests admitted earlier this
        same round, whose slots aren't populated yet (dense prefills in
        one batch after the pop loop) — are pinned against eviction.
        ``None`` = every row pinned; caller defers the request."""
        if self.adapters is None or req.tenant is None:
            return 0
        pinned = {int(self.adapter_ix[s]) for s, r in enumerate(self.slots)
                  if r is not None}
        pinned.update(int(self.adapter_ix[s]) for s, _ in admitted)
        pinned.discard(0)
        return self.adapters.acquire(req.tenant, pinned)

    def _adapter_args(self, rows=None):
        """(banks, aix) for a jitted step — (None, None) on a no-adapter
        engine so it traces the exact pre-tenancy computation."""
        if self.adapters is None:
            return None, None
        ix = self.adapter_ix if rows is None else self.adapter_ix[rows]
        return self.adapters.banks, jnp.asarray(ix)

    def _free_slot(self, slot: int) -> None:
        """Recycle a slot AND reset its sampling row to greedy defaults —
        a stale temperature on a dead row would keep ``jnp.any(temp > 0)``
        true and defeat the all-greedy ``lax.cond`` fast path. In paged
        mode also release the slot's page references and point its table
        row at the trash page, so the dead row's lockstep writes can never
        land in a page the pool hands to someone else."""
        self.slots[slot] = None
        self.adapter_ix[slot] = 0     # unpin the tenant's bank row
        self.temp[slot] = 0.0
        self.top_k[slot] = 0
        self.top_p[slot] = 1.0
        if self.paged:
            for p in self.slot_pages[slot]:
                self.pool.unref(p)
            self.slot_pages[slot] = []
            self.tables[slot, :] = 0
            self._cursor[slot] = None
            self._prealloc[slot] = 0

    def _emit_token(self, req: Request, token: int, t: float) -> None:
        req.generated.append(token)
        if not req.first_token_at:
            req.first_token_at = t
        req.last_token_at = t
        req.events.append(Event(EventKind.TOKEN, req.rid, token=token, t=t))

    def _retire(self, req: Request, kind: EventKind, reason: str) -> None:
        t = time.perf_counter()
        req.events.append(Event(kind, req.rid, reason=reason, t=t))
        req.status = kind
        req.finished_at = t
        key = {EventKind.FINISHED: "completed",
               EventKind.CANCELLED: "cancelled",
               EventKind.EVICTED: "evicted"}[kind]
        self.stats[key] += 1

    def _finish_if_done(self, slot: int) -> None:
        req = self.slots[slot]
        if req is not None and req.hit_stop:
            self._free_slot(slot)         # recycle: next _admit reuses it
            s = req.sampling
            reason = ("eos" if s.eos_id is not None and req.generated
                      and req.generated[-1] == s.eos_id else "max_new")
            self._retire(req, EventKind.FINISHED, reason)

    def _evict(self, now: float) -> None:
        running = [r for r in self.slots if r is not None]
        for req in self.sched.victims(running, now):
            if req.terminal:      # defensive vs misbehaving schedulers:
                continue          # a request gets exactly ONE terminal event
            for slot, r in enumerate(self.slots):
                if r is req:
                    self._free_slot(slot)
                    break
            self._retire(req, EventKind.EVICTED, "deadline")

    def _set_sampling_row(self, slot: int, req: Request) -> None:
        sp = req.sampling
        self.temp[slot] = sp.temperature
        self.top_k[slot] = sp.top_k
        self.top_p[slot] = sp.top_p
        self.seed[slot] = np.uint32(sp.seed & 0xFFFFFFFF)

    def _admit(self) -> None:
        if self.paged:
            self._admit_paged()
        else:
            self._admit_dense()

    def _admit_dense(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not len(self.sched):
            return
        t0 = time.perf_counter()
        admitted: list[tuple[int, Request]] = []
        while free:
            req = self.sched.pop(t0)
            if req is None:
                break
            if req.terminal:      # e.g. evicted-from-queue by a scheduler
                continue          # that didn't also dequeue it
            row = self._acquire_adapter(req, admitted)
            if row is None:
                self.sched.add(req)       # every bank row pinned: wait
                self.stats["deferred"] += 1
                break
            slot = free.pop(0)
            self.adapter_ix[slot] = row
            admitted.append((slot, req))
        # group by bucket so same-shape prompts prefill as one batch
        groups: dict[int, list[tuple[int, Request]]] = collections.defaultdict(list)
        for slot, req in admitted:
            groups[bucket_for(len(req.prompt), self.buckets,
                              self.max_cache)].append((slot, req))
        for bucket, group in groups.items():
            rows = np.array([s for s, _ in group], np.int32)
            vlen = np.array([len(r.prompt) for _, r in group], np.int32)
            toks = np.zeros((len(group), bucket), np.int32)
            for i, (slot, req) in enumerate(group):
                toks[i, :len(req.prompt)] = req.prompt
                self._set_sampling_row(slot, req)
            banks, aix = self._adapter_args(rows)
            first, self.caches = self._prefill(
                self.params, banks, aix, jnp.asarray(toks), self.caches,
                jnp.asarray(vlen), jnp.asarray(rows),
                jnp.asarray(self.temp[rows]), jnp.asarray(self.top_k[rows]),
                jnp.asarray(self.top_p[rows]), jnp.asarray(self.seed[rows]))
            first = np.asarray(first)
            now = time.perf_counter()
            for i, (slot, req) in enumerate(group):
                self.slots[slot] = req
                self._emit_token(req, int(first[i]), now)
                self.pos[slot] = int(vlen[i])
                self.next_tok[slot] = int(first[i])
                self.count[slot] = 1
                self.stats["prefill_tokens"] += int(vlen[i])
                self._finish_if_done(slot)
        self.stats["prefill_s"] += time.perf_counter() - t0

    def _admit_paged(self) -> None:
        """Admit queued requests into free slots by RESERVING pages —
        prefill itself happens chunk-by-chunk in `_prefill_tick`. The
        radix cache is consulted first: matched full-page prefixes attach
        by reference (refcount bump, zero prefill) and the prefill cursor
        starts past them. A request the pool cannot satisfy (even after
        LRU eviction of unreferenced prefix-cache pages) goes back to the
        scheduler and admission stops for this tick — running requests
        will free pages as they retire."""
        free = [i for i, r in enumerate(self.slots) if r is None]
        if not free or not len(self.sched):
            return
        t0 = time.perf_counter()
        while free:
            req = self.sched.pop(t0)
            if req is None:
                break
            if req.terminal:
                continue
            # paged admission populates self.slots inside the loop, so
            # active-slot pinning already covers this round's admissions
            row = self._acquire_adapter(req, [])
            if row is None:
                self.sched.add(req)        # every bank row pinned: wait
                self.stats["deferred"] += 1
                break
            prompt = req.prompt
            pg = self.page_size
            need = pages_needed(len(prompt) + req.sampling.max_new, pg)
            shared: list[int] = []
            if self.radix is not None:
                # cap shared pages so at least ONE prompt token is left to
                # prefill — the final chunk must produce next-token logits
                # tenant-namespaced: a prefix prefilled under one adapter
                # is NOT the same KV under another (or under the bare base)
                shared = self.radix.match(
                    prompt, namespace=req.tenant)[:(len(prompt) - 1) // pg]
                for p in shared:       # protect from our own eviction below
                    self.pool.ref(p)
            fresh = need - len(shared)
            if self.pool.free_pages < fresh and self.radix is not None:
                self.radix.evict(fresh - self.pool.free_pages)
            alloc = self.pool.alloc(fresh)
            if alloc is None:
                for p in shared:
                    self.pool.unref(p)
                self.sched.add(req)        # not enough pages: wait
                self.stats["deferred"] += 1
                break
            slot = free.pop(0)
            self.adapter_ix[slot] = row
            pages = shared + alloc
            self.tables[slot, :] = 0
            self.tables[slot, :len(pages)] = pages
            self.slot_pages[slot] = pages
            self._prealloc[slot] = need
            self.slots[slot] = req
            self._set_sampling_row(slot, req)
            self._cursor[slot] = len(shared) * pg
            self.pos[slot] = 0
            self.count[slot] = 0
            self.stats["prefix_hit_tokens"] += len(shared) * pg
        self.stats["prefill_s"] += time.perf_counter() - t0

    def _prefill_tick(self) -> None:
        """Advance chunked prefill: up to `prefill_chunks_per_tick` chunks
        across the slots currently prefilling, round-robin so a long
        prompt cannot starve a short one. Each chunk is one fixed-shape
        (1, prefill_chunk) jitted call that writes K/V through the slot's
        page table; the final chunk samples the request's first token."""
        if not self.paged:
            return
        waiting = [s for s in range(self.max_slots)
                   if self.slots[s] is not None and self._cursor[s] is not None]
        if not waiting:
            return
        decoding = any(self.slots[s] is not None and self._cursor[s] is None
                       for s in range(self.max_slots))
        if decoding and self._tick % self.prefill_every:
            return      # stride only matters when there is someone to starve
        t0 = time.perf_counter()
        order = sorted(waiting, key=lambda s: (s - self._pf_rr) % self.max_slots)
        for slot in order[:self.prefill_chunks_per_tick]:
            self._pf_rr = (slot + 1) % self.max_slots
            req = self.slots[slot]
            cur = self._cursor[slot]
            end = min(cur + self.prefill_chunk, len(req.prompt))
            toks = np.zeros((1, self.prefill_chunk), np.int32)
            toks[0, :end - cur] = req.prompt[cur:end]
            # slice the table to a power-of-2 HISTORY bucket: the chunk
            # attends (and writes) only positions < end, every shape in the
            # paged attention flows from the table width, and masked
            # columns contribute exactly 0 — so early chunks of a long
            # prompt cost O(history so far), bitwise-identical to the
            # full-width gather, at one executable per bucket (log2 many)
            n_hist = min(self.pages_per_slot,
                         1 << (pages_needed(end, self.page_size) - 1)
                         .bit_length())
            banks, aix = self._adapter_args([slot])
            first, self.caches = self._prefill_chunk(
                self.params, banks, aix, jnp.asarray(toks), self.caches,
                jnp.asarray([cur], np.int32),
                jnp.asarray([end - cur], np.int32),
                jnp.asarray(self.tables[slot:slot + 1, :n_hist]),
                jnp.asarray(self.temp[slot:slot + 1]),
                jnp.asarray(self.top_k[slot:slot + 1]),
                jnp.asarray(self.top_p[slot:slot + 1]),
                jnp.asarray(self.seed[slot:slot + 1]))
            self.stats["prefill_tokens"] += end - cur
            self.stats["prefill_chunks"] += 1
            if end < len(req.prompt):
                self._cursor[slot] = end
                continue
            # prompt complete: publish full pages for prefix reuse, then
            # emit the sampled first token and hand the slot to decode
            self._cursor[slot] = None
            if self.radix is not None:
                n_full = len(req.prompt) // self.page_size
                self.radix.insert(req.prompt,
                                  self.slot_pages[slot][:n_full],
                                  namespace=req.tenant)
            now = time.perf_counter()
            self._emit_token(req, int(np.asarray(first)[0]), now)
            self.pos[slot] = len(req.prompt)
            self.next_tok[slot] = int(np.asarray(first)[0])
            self.count[slot] = 1
            self._finish_if_done(slot)
        self.stats["prefill_s"] += time.perf_counter() - t0

    def _decode_all(self) -> None:
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and (not self.paged
                                        or self._cursor[i] is None)]
        if not active:
            return
        t0 = time.perf_counter()
        if self.paged:
            # still-prefilling rows ride along dead: route their lockstep
            # writes to the trash page, not into pages their prefill owns
            tbl = self.tables.copy()
            for s in range(self.max_slots):
                if self._cursor[s] is not None:
                    tbl[s, :] = 0
            table = jnp.asarray(tbl)
        else:
            table = None
        banks, aix = self._adapter_args()
        nxt, self.caches = self._decode(
            self.params, banks, aix, jnp.asarray(self.next_tok[:, None]),
            self.caches, jnp.asarray(self.pos), table,
            jnp.asarray(self.temp), jnp.asarray(self.top_k),
            jnp.asarray(self.top_p), jnp.asarray(self.seed),
            jnp.asarray(self.count))
        nxt = np.asarray(nxt, np.int32)
        self.stats["decode_steps"] += 1
        now = time.perf_counter()
        for slot in active:
            req = self.slots[slot]
            self._emit_token(req, int(nxt[slot]), now)
            self.pos[slot] += 1
            self.next_tok[slot] = int(nxt[slot])
            self.count[slot] += 1
            self.stats["decode_tokens"] += 1
            self._finish_if_done(slot)
        self.stats["decode_s"] += time.perf_counter() - t0

    def _spec_pages(self, active: list[int]) -> np.ndarray:
        """Paged-mode draft coverage: per-slot draft length after making
        sure pages exist under every position the draft + verify will
        write (pos .. pos + draft_len). A draft near the end of its budget
        may need pages BEYOND the admission reservation (the verify block
        overruns `prompt + max_new` even though emission never does) —
        those are allocated here and released by ``_spec_release`` the
        same tick. Pool exhaustion shrinks the draft to the covered
        region instead of deferring the whole tick."""
        draft_len = np.zeros(self.max_slots, np.int32)
        pg = self.page_size
        for slot in active:
            pos = int(self.pos[slot])
            dl = min(self.spec_k, self.max_cache - 1 - pos)
            need = pages_needed(pos + dl + 1, pg)
            have = len(self.slot_pages[slot])
            if need > have:
                want = need - have
                if self.pool.free_pages < want and self.radix is not None:
                    self.radix.evict(want - self.pool.free_pages)
                grab = min(want, self.pool.free_pages)
                alloc = self.pool.alloc(grab) if grab else None
                if alloc:
                    self.tables[slot, have:have + len(alloc)] = alloc
                    self.slot_pages[slot].extend(alloc)
                    have += len(alloc)
                if have < need:
                    # shrink the draft to what the held pages cover
                    dl = min(dl, have * pg - 1 - pos)
                    self.stats["spec_page_shrinks"] += 1
            draft_len[slot] = max(dl, 0)
        return draft_len

    def _spec_release(self) -> None:
        """Return every page past a live slot's admission reservation to
        the pool and zero its table tail — the rollback half of the paged
        draft path. Emission is capped at max_new, so a slot never needs
        those pages again; finished slots already released everything via
        ``_free_slot``."""
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            keep = self._prealloc[slot]
            extra = self.slot_pages[slot][keep:]
            if not extra:
                continue
            for p in extra:
                self.pool.unref(p)
            self.slot_pages[slot] = self.slot_pages[slot][:keep]
            self.tables[slot, keep:] = 0

    def _spec_decode_all(self) -> None:
        """One speculative tick over every decoding slot: draft ``spec_k``
        tokens through the cheap subspace view, verify all of them (plus
        the current token) in ONE batched f32 forward, emit the accepted
        prefix + the corrected/bonus token. Per-row draft lengths are
        clamped by CACHE CAPACITY only (max_cache - 1 - pos), not by the
        request budget — the host stops emitting at max_new/EOS, and the
        overrun KV is never read (dense) or its pages are released
        (paged). Dead and still-prefilling rows ride along at draft
        length 0 exactly as they ride through ``_decode_all``."""
        active = [i for i, r in enumerate(self.slots)
                  if r is not None and (not self.paged
                                        or self._cursor[i] is None)]
        if not active:
            return
        t0 = time.perf_counter()
        k = self.spec_k
        if self.paged:
            draft_len = self._spec_pages(active)
            # dead/prefilling rows write to the trash page; ALL rows get
            # one extra trash column so a past-capacity position index
            # (which gather CLIPS, not drops) can never alias a live page
            tbl = self.tables.copy()
            for s in range(self.max_slots):
                if self.slots[s] is None or self._cursor[s] is not None:
                    tbl[s, :] = 0
            table = jnp.asarray(np.concatenate(
                [tbl, np.zeros((self.max_slots, 1), np.int32)], axis=1))
        else:
            draft_len = np.zeros(self.max_slots, np.int32)
            for slot in active:
                draft_len[slot] = max(
                    0, min(k, self.max_cache - 1 - int(self.pos[slot])))
            table = None

        temp = jnp.asarray(self.temp)
        tk = jnp.asarray(self.top_k)
        tp = jnp.asarray(self.top_p)
        seeds = jnp.asarray(self.seed)
        counts = jnp.asarray(self.count)
        dlen = jnp.asarray(draft_len)
        pos0 = jnp.asarray(self.pos)

        # -- draft: k cheap decode steps, all device-resident -------------
        cur = jnp.asarray(self.next_tok[:, None])
        toks_cols = [cur]
        q_cols = []
        for i in range(k):
            # rows done drafting park at the sentinel position max_cache:
            # dense scatter drops it, the padded trash column absorbs it
            p_i = jnp.where(i < dlen, pos0 + i, self.max_cache)
            nxt, q, self.caches = self._draft_step(
                self.draft_params, toks_cols[-1], self.caches, p_i, table,
                temp, tk, tp, seeds, counts + i)
            toks_cols.append(nxt[:, None])
            q_cols.append(q[:, None])
        draft_toks = jnp.concatenate(toks_cols[1:], axis=1)       # (B, k)
        draft_q = jnp.concatenate(q_cols, axis=1)                 # (B, k, V)

        # -- verify: one batched f32 forward + device-side rejection ------
        ver_toks = jnp.concatenate(toks_cols, axis=1)             # (B, k+1)
        n_acc, out, self.caches = self._verify(
            self.params, ver_toks, self.caches, pos0, table,
            draft_toks, draft_q, dlen, temp, tk, tp, seeds, counts)
        n_acc = np.asarray(n_acc, np.int32)
        out = np.asarray(out, np.int32)

        self.stats["decode_steps"] += 1
        self.stats["spec_steps"] += 1
        now = time.perf_counter()
        for slot in active:
            req = self.slots[slot]
            self.stats["spec_draft_tokens"] += int(draft_len[slot])
            self.stats["spec_accepted_tokens"] += int(n_acc[slot])
            req.accepted_counts.append(int(n_acc[slot]))
            emitted = 0
            for j in range(int(n_acc[slot]) + 1):
                self._emit_token(req, int(out[slot, j]), now)
                emitted += 1
                if req.hit_stop:
                    break
            self.pos[slot] += emitted
            self.next_tok[slot] = int(out[slot, emitted - 1])
            self.count[slot] += emitted
            self.stats["decode_tokens"] += emitted
            self._finish_if_done(slot)
        if self.paged:
            self._spec_release()
        self.stats["decode_s"] += time.perf_counter() - t0

    # -- driving ------------------------------------------------------------

    def step(self) -> None:
        """One engine tick: enforce deadlines, admit whatever fits, advance
        chunked prefill (paged mode), then decode every active slot by one
        token. Accumulates wall_s so summary() rates are correct for
        callers driving step() directly, not just run()."""
        t0 = time.perf_counter()
        if self.paged:
            self._tick += 1
        self._evict(t0)
        self._admit()
        self._prefill_tick()
        if self.spec_k:
            self._spec_decode_all()
        else:
            self._decode_all()
        self.stats["wall_s"] += time.perf_counter() - t0

    def run(self) -> None:
        """Drain queue + slots to completion."""
        while self.busy:
            self.step()

    # -- reporting ----------------------------------------------------------

    def reset_stats(self) -> None:
        """Zero all counters/timers (e.g. after warmup runs)."""
        for k in self.stats:
            self.stats[k] = type(self.stats[k])()

    def cache_bytes(self) -> int:
        """Device bytes of the decode caches: dense reserves
        slots x max_cache per layer, paged reserves total_pages x
        page_size per full-attention layer (the decoupling the paged pool
        buys — see utils/memprof.kv_cache_bytes for the formula)."""
        from repro.utils.memprof import array_bytes
        return int(sum(array_bytes(a) for a in jax.tree.leaves(self.caches)))

    def summary(self) -> dict:
        """Counters plus derived rates. Phase throughputs use each phase's
        own wall time (prefill_s / decode_s) so they measure the phase,
        not the mix; requests_s uses total engine time."""
        s = dict(self.stats)
        s["prefill_tok_s"] = s["prefill_tokens"] / max(s["prefill_s"], 1e-9)
        s["decode_tok_s"] = s["decode_tokens"] / max(s["decode_s"], 1e-9)
        s["requests_s"] = s["completed"] / max(s["wall_s"], 1e-9)
        s["weight_bytes"] = self.weight_report["total_bytes"]
        s["weight_mib"] = self.weight_report["total_bytes"] / 2**20
        s["quantized"] = self.quantized
        s["scheduler"] = getattr(self.sched, "name", type(self.sched).__name__)
        s["paged"] = self.paged
        s["cache_bytes"] = self.cache_bytes()
        if self.mesh is not None:
            s["mesh_devices"] = int(self.mesh.devices.size)
            s["slots_per_device"] = self.max_slots // int(
                self.mesh.devices.size)
            s["cache_bytes_per_device"] = (s["cache_bytes"]
                                           // int(self.mesh.devices.size))
        if self.paged:
            s["page_size"] = self.page_size
            s["total_pages"] = self.pool.total_pages
            s["pages_in_use"] = self.pool.pages_in_use
            s["prefix_cache_pages"] = (self.radix.n_nodes
                                       if self.radix is not None else 0)
        if self.adapters is not None:
            # base-vs-adapter accounting split (utils/memprof.py):
            # weight_bytes above is the RESIDENT BASE; the banks are the
            # only per-tenant device cost, store bytes the per-tenant
            # disk cost
            t = self.adapters.summary()
            t["bytes_by_tenant"] = self.adapters.store.bytes_by_tenant()
            s["tenancy"] = t
            s["adapter_bank_bytes"] = t["bank_bytes"]
        if self.spec_k:
            s["spec_k"] = self.spec_k
            s["draft_source"] = self.draft_source
            s["acceptance_rate"] = (s["spec_accepted_tokens"]
                                    / max(s["spec_draft_tokens"], 1))
            # mean emitted tokens per verify step (accepted + corrected /
            # bonus), the speedup numerator the paper's Tab. 2 reports
            s["tokens_per_verify"] = (s["decode_tokens"]
                                      / max(s["spec_steps"], 1))
        return s
