"""Request-level serving subsystem (continuous batching over WASI models).

The engine owns the decode caches and the slot <-> request mapping; model
code stays purely functional (models/lm.py). Sampling runs device-side
inside the jitted decode (sampling.py), the request lifecycle streams
typed events through GenerationHandle (session.py), and admission policy
is a pluggable Scheduler (scheduler.py). KV storage is either dense
per-slot (the oracle path) or a paged pool with refcounted prefix
sharing and chunked prefill (kvpool.py). Self-speculative decoding
(spec_k > 0) drafts ahead through a cheap subspace view of the same
weights and verifies in one batched forward with the device-side
rejection rule (sampling.py::spec_accept). See docs/serving.md for the
request lifecycle and docs/architecture.md for the slot/caches design.
"""

from repro.serve.engine import (
    DEFAULT_BUCKETS,
    DEFAULT_PAGE_SIZE,
    ServeEngine,
    bucket_for,
)
from repro.serve.kvpool import PagePool, RadixCache, pages_needed
from repro.serve.sampling import (
    SamplingParams,
    sample_draft_tokens,
    sample_tokens,
    spec_accept,
    warped_probs,
)
from repro.serve.scheduler import (
    FCFS,
    SCHEDULERS,
    PriorityDeadline,
    Scheduler,
    ShortestPromptFirst,
    make_scheduler,
)
from repro.serve.session import Event, EventKind, GenerationHandle, Request

__all__ = [
    "DEFAULT_BUCKETS",
    "DEFAULT_PAGE_SIZE",
    "Event",
    "EventKind",
    "FCFS",
    "GenerationHandle",
    "PagePool",
    "PriorityDeadline",
    "RadixCache",
    "Request",
    "SCHEDULERS",
    "SamplingParams",
    "Scheduler",
    "ServeEngine",
    "ShortestPromptFirst",
    "bucket_for",
    "make_scheduler",
    "pages_needed",
    "sample_draft_tokens",
    "sample_tokens",
    "spec_accept",
    "warped_probs",
]
