"""Request-level serving subsystem (continuous batching over WASI models).

The engine owns the decode caches and the slot <-> request mapping; model
code stays purely functional (models/lm.py). See docs/architecture.md for
the request lifecycle diagram.
"""

from repro.serve.engine import Request, ServeEngine, bucket_for
