"""Pluggable admission policy for the serve engine.

PR 1's engine hardcoded FIFO admission inside ``_admit``. Admission is now
a :class:`Scheduler` the engine consults each tick:

* ``add``     — a submitted request enters the wait set;
* ``pop``     — hand the engine the next request for a free slot (the
                policy decision: arrival order, prompt length, priority);
* ``remove``  — a queued request is cancelled;
* ``victims`` — which RUNNING requests to evict this tick (deadline
                enforcement; the engine frees their slots and emits
                EVICTED events).

The engine owns everything device-side (slots, caches, sampling arrays);
schedulers are pure host-side policy over ``Request`` objects and never
touch jax. That keeps a custom policy a ~20-line class: implement the
four methods (or subclass :class:`FCFS`) and pass an instance — or a
registered name — as ``ServeEngine(..., scheduler=...)``.

Built-ins (``make_scheduler``): ``fcfs`` (arrival order), ``spf``
(shortest prompt first — minimizes mean TTFT under mixed lengths),
``priority`` (highest ``SamplingParams.priority`` first, FIFO within a
level, plus deadline eviction of expired requests — queued OR running).
"""
from __future__ import annotations

import collections
from typing import Protocol, Sequence, runtime_checkable

from repro.serve.session import Request


@runtime_checkable
class Scheduler(Protocol):
    """Host-side admission policy. All methods are O(queue) or better and
    called once per engine tick; ``now`` is ``time.perf_counter()``."""

    def add(self, req: Request) -> None:
        """A submitted request enters the wait set."""

    def pop(self, now: float) -> Request | None:
        """Next request to admit into a free slot (None = nothing ready)."""

    def remove(self, rid: int) -> Request | None:
        """Withdraw a queued request (cancellation); None if unknown."""

    def pending(self) -> list[Request]:
        """Queued requests in current admission order (for introspection)."""

    def victims(self, running: Sequence[Request], now: float) -> list[Request]:
        """Requests this policy evicts this tick — running ones, plus any
        QUEUED ones the policy drops (which it must also remove from its
        own wait set before returning them; the engine retires every
        victim with a terminal EVICTED event)."""

    def __len__(self) -> int: ...


class FCFS:
    """Arrival order; never evicts. The PR 1 behaviour, now swappable."""

    name = "fcfs"

    def __init__(self):
        self._q: collections.deque[Request] = collections.deque()

    def add(self, req: Request) -> None:
        self._q.append(req)

    def pop(self, now: float) -> Request | None:
        return self._q.popleft() if self._q else None

    def remove(self, rid: int) -> Request | None:
        for req in self._q:
            if req.rid == rid:
                self._q.remove(req)
                return req
        return None

    def pending(self) -> list[Request]:
        return list(self._q)

    def victims(self, running: Sequence[Request], now: float) -> list[Request]:
        return []

    def __len__(self) -> int:
        return len(self._q)


class ShortestPromptFirst(FCFS):
    """Admit the shortest queued prompt first (ties: arrival order).
    Short prompts prefill cheapest, so under mixed lengths this minimizes
    mean TTFT; never evicts."""

    name = "spf"

    def pop(self, now: float) -> Request | None:
        if not self._q:
            return None
        best = min(self._q, key=lambda r: (len(r.prompt), r.rid))
        self._q.remove(best)
        return best


class PriorityDeadline(FCFS):
    """Highest ``SamplingParams.priority`` first (FIFO within a level),
    with deadline enforcement: a request whose ``deadline_s`` budget has
    expired is never admitted (``pop`` skips it; the engine sees it via
    ``victims``) and is evicted from its slot if already running. Eviction
    is terminal — partial tokens stay on the handle, the slot frees this
    tick, and the handle's last event is EVICTED(reason="deadline")."""

    name = "priority"

    def pop(self, now: float) -> Request | None:
        live = [r for r in self._q
                if r.deadline_at is None or r.deadline_at > now]
        if not live:
            return None
        best = max(live, key=lambda r: (r.sampling.priority, -r.rid))
        self._q.remove(best)
        return best

    def victims(self, running: Sequence[Request], now: float) -> list[Request]:
        expired = [r for r in self._q
                   if r.deadline_at is not None and r.deadline_at <= now]
        for r in expired:                  # queued past-deadline: drop too
            self._q.remove(r)
        expired += [r for r in running
                    if r.deadline_at is not None and r.deadline_at <= now]
        return expired


SCHEDULERS: dict[str, type] = {c.name: c for c in
                               (FCFS, ShortestPromptFirst, PriorityDeadline)}


def make_scheduler(name: str) -> Scheduler:
    try:
        return SCHEDULERS[name]()
    except KeyError:
        raise ValueError(f"unknown scheduler {name!r}; "
                         f"registered: {sorted(SCHEDULERS)}") from None
