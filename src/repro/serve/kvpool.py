"""Paged KV-cache management: a fixed pool of pages with refcounts and a
free list, plus a page-granular radix tree for cross-request prefix reuse.

Dense per-slot caches weld slot count to ``max_cache``: every admitted
request reserves ``max_cache`` worth of KV for every layer, whether its
prompt is 4 tokens or 4000. The serving workload the roadmap names
(millions of users sharing system prompts / few-shot templates) breaks
both assumptions at once — most requests are short-tailed AND most
prompts share long prefixes. This module is the host-side bookkeeping
that fixes both:

* :class:`PagePool` — physical pages. A page is ``page_size`` KV slots in
  every layer's pool array (the device arrays live in the engine's cache
  pytree; the pool tracks ids only). Pages carry refcounts so several
  slots can map the same physical page; page 0 is reserved as the TRASH
  page — freed slots point their whole table at it, so the dead rows that
  ride along in the lockstep decode batch scatter their garbage writes
  into a page nothing ever reads, never into a page another request may
  have been handed.

* :class:`RadixCache` — a radix tree over prompt-token prefixes at page
  granularity: each edge is exactly one page worth of tokens (a tuple,
  the dict key), each node owns the physical page holding that span's KV.
  ``match`` walks the longest shared prefix and hands back pages to
  attach BY REFERENCE (refcount bump, zero prefill work); ``insert``
  publishes a freshly prefilled prompt's full pages for the next request.
  Sharing is copy-on-write at page granularity *by construction*: shared
  pages are only ever read (a request's first write lands at its first
  non-shared position, which starts a fresh page because matches are
  whole pages), so the "divergence page" is always privately allocated
  and no page is ever physically copied. Eviction is LRU over
  unreferenced leaves, run only when an allocation would otherwise fail.

The engine (serve/engine.py) owns the mapping slot -> page-table row; the
model (models/lm.py / nn/attention.py) gathers and scatters through that
table and never sees this module.
"""
from __future__ import annotations

import itertools
from typing import Sequence

import numpy as np

TRASH_PAGE = 0


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages covering ``n_tokens`` KV positions."""
    return -(-n_tokens // page_size)


class PagePool:
    """Refcounted free-list allocator over ``total_pages`` physical pages.

    Page ids are plain ints in ``[0, total_pages)``; id 0 is the reserved
    trash page and is never allocated. The pool never touches device
    memory — the engine sizes its device-side pool arrays from
    ``total_pages`` and indexes them with the ids handed out here.
    """

    def __init__(self, total_pages: int, page_size: int):
        if total_pages < 2:
            raise ValueError("PagePool needs >= 2 pages (page 0 is the "
                             f"reserved trash page), got {total_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.total_pages = int(total_pages)
        self.page_size = int(page_size)
        self.refs = np.zeros(total_pages, np.int32)
        # LIFO free list: recently freed pages are reused first (their old
        # contents are provably masked — see nn/attention.py paged reads)
        self._free = list(range(total_pages - 1, 0, -1))

    @property
    def usable_pages(self) -> int:
        return self.total_pages - 1          # minus the trash page

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.usable_pages - len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        """``n`` fresh pages at refcount 1, or None if the pool is short
        (caller decides: evict prefix-cache pages, or defer admission)."""
        if n < 0:
            raise ValueError(f"alloc({n})")
        if len(self._free) < n:
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.refs[pages] = 1
        return pages

    def ref(self, page: int) -> None:
        """Attach one more holder to an allocated page."""
        if page == TRASH_PAGE or self.refs[page] <= 0:
            raise ValueError(f"ref of unallocated page {page}")
        self.refs[page] += 1

    def unref(self, page: int) -> None:
        """Detach one holder; the page returns to the free list at zero."""
        if page == TRASH_PAGE or self.refs[page] <= 0:
            raise ValueError(f"unref of unallocated page {page}")
        self.refs[page] -= 1
        if self.refs[page] == 0:
            self._free.append(page)

    def check(self) -> None:
        """Structural invariants (the fuzz harness calls this every tick):
        refcounts non-negative, trash never allocated, and the free list
        exactly complements the referenced pages."""
        if self.refs[TRASH_PAGE] != 0:
            raise AssertionError("trash page acquired a refcount")
        if (self.refs < 0).any():
            raise AssertionError("negative page refcount")
        free = set(self._free)
        if len(free) != len(self._free):
            raise AssertionError("duplicate page on the free list")
        for p in free:
            if self.refs[p] != 0:
                raise AssertionError(f"page {p} free but refcount "
                                     f"{self.refs[p]}")
        referenced = {int(p) for p in np.nonzero(self.refs)[0]}
        if free | referenced != set(range(1, self.total_pages)):
            raise AssertionError("free list + referenced pages != pool")


class _Node:
    __slots__ = ("children", "page", "last_used")

    def __init__(self, page: int = TRASH_PAGE):
        self.children: dict[tuple, _Node] = {}
        self.page = page
        self.last_used = 0


class RadixCache:
    """Page-granular radix tree over prompt token prefixes.

    Each node below the root holds exactly one page: the KV of one
    ``page_size``-token span, keyed by that span's token tuple. The tree
    holds its OWN refcount on every published page, so pages survive the
    request that prefilled them and later requests attach by reference;
    eviction (LRU over unreferenced leaves) is the only way the tree lets
    go of a page, which keeps "who owns this page" a pure refcount
    question the fuzz harness can audit.

    ``namespace`` partitions the tree: the KV of a token span is only
    reusable under the SAME model weights, and tenant adapters
    (repro/tenancy/) make weights per-request state — a prefix prefilled
    under tenant A's adapter must never attach to tenant B's request.
    Namespace nodes are pageless interior markers (page = TRASH_PAGE):
    never ref'd, never evicted, invisible to ``held_pages``.
    """

    def __init__(self, pool: PagePool):
        self.pool = pool
        self.page_size = pool.page_size
        self.root = _Node()
        self._clock = itertools.count(1)
        self.n_nodes = 0

    def _spans(self, tokens: Sequence[int]):
        pg = self.page_size
        for i in range(len(tokens) // pg):
            yield tuple(tokens[i * pg:(i + 1) * pg])

    def _ns_root(self, namespace) -> _Node:
        if namespace is None:
            return self.root
        # key shape can't collide with a span (a tuple of ints)
        key = ("\x00ns", namespace)
        child = self.root.children.get(key)
        if child is None:                   # pageless marker, not counted
            child = self.root.children[key] = _Node()
        return child

    def match(self, tokens: Sequence[int], *,
              namespace=None) -> list[int]:
        """Pages of the longest cached full-page prefix of ``tokens``
        within ``namespace``. Touches every matched node (LRU freshness).
        The caller must ``pool.ref`` each page it actually attaches."""
        node, pages = self._ns_root(namespace), []
        now = next(self._clock)
        for span in self._spans(tokens):
            child = node.children.get(span)
            if child is None:
                break
            child.last_used = now
            pages.append(child.page)
            node = child
        return pages

    def insert(self, tokens: Sequence[int], pages: Sequence[int], *,
               namespace=None) -> int:
        """Publish a prefilled prompt's full pages; ``pages[i]`` holds the
        KV of tokens ``[i*pg, (i+1)*pg)``. Spans already in the tree keep
        their existing page (first writer wins — both copies hold bitwise
        identical KV, and the caller's copy dies with its request); new
        nodes take a tree-owned reference on the caller's page. Returns
        the number of pages newly published."""
        node, created = self._ns_root(namespace), 0
        now = next(self._clock)
        for span, page in zip(self._spans(tokens), pages):
            child = node.children.get(span)
            if child is None:
                child = _Node(int(page))
                self.pool.ref(int(page))
                node.children[span] = child
                self.n_nodes += 1
                created += 1
            child.last_used = now
            node = child
        return created

    def _leaves(self):
        out = []

        def walk(node, parent, key):
            for k, c in node.children.items():
                walk(c, node, k)
            if parent is not None and not node.children:
                out.append((node, parent, key))

        walk(self.root, None, None)
        return out

    def evict(self, n_pages: int) -> int:
        """Drop up to ``n_pages`` LRU leaf nodes whose page has no holder
        besides the tree (refcount 1); evicting a leaf may expose its
        parent, so eviction cascades. Returns pages actually freed."""
        freed = 0
        while freed < n_pages:
            evictable = [(n.last_used, n, p, k) for n, p, k in self._leaves()
                         if n.page != TRASH_PAGE        # namespace markers
                         and self.pool.refs[n.page] == 1]
            if not evictable:
                break
            # one eviction per pass: dropping a leaf exposes its parent,
            # which may be older LRU than the next leaf in this snapshot
            _, node, parent, key = min(evictable, key=lambda t: t[0])
            del parent.children[key]
            self.pool.unref(node.page)
            self.n_nodes -= 1
            freed += 1
        return freed

    def clear(self) -> int:
        """Release every tree-held page (drain/shutdown); returns count."""
        released = 0

        def walk(node):
            nonlocal released
            for c in node.children.values():
                walk(c)
            if node is not self.root and node.page != TRASH_PAGE:
                self.pool.unref(node.page)
                released += 1

        walk(self.root)
        self.root = _Node()
        self.n_nodes = 0
        return released

    def held_pages(self) -> list[int]:
        """All tree-held page ids (invariant audits)."""
        out = []

        def walk(node):
            for c in node.children.values():
                if c.page != TRASH_PAGE:
                    out.append(c.page)
                walk(c)

        walk(self.root)
        return out
