"""Per-request sampling, executed DEVICE-SIDE inside the jitted decode.

The contract that makes streaming serving fast: the host never sees
logits. ``sample_tokens`` runs inside the engine's jitted prefill/decode
step and returns one int32 token per slot; the only device->host transfer
per engine tick is that (B,) token vector (which the greedy engine already
paid for its argmax result).

Per-slot parameters ride in as (B,) arrays so ONE executable serves any
mix of requests — greedy next to temperature-0.8/top-k next to nucleus:

* ``temperature <= 0`` lowers to ``jnp.argmax`` over the raw logits —
  the same op on the same array the pre-redesign greedy engine ran, so
  temperature-0 rows are token-for-token identical to it (f32 and int8).
* ``top_k = 0`` / ``top_p = 1.0`` disable those filters; free slots ride
  along as greedy rows whose sampled token is never read.

Determinism: the per-row PRNG key is ``fold_in(PRNGKey(seed), n)`` where
``n`` counts that REQUEST's sampled tokens (prefill token = 0). It depends
only on (seed, token index) — never on the slot, the engine tick, or which
other requests share the batch — so fixed-seed generations are identical
under ``run()``, manual ``step()`` loops, or any admission interleaving.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

GREEDY_TEMPERATURE = 0.0


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Everything the engine needs to know about one request.

    temperature: 0 => greedy argmax (the default, bitwise-compatible with
        the legacy engine); > 0 scales logits before sampling.
    top_k: keep only the k highest logits (0 = off).
    top_p: nucleus sampling — keep the smallest prefix of the sorted
        distribution with cumulative probability >= top_p (1.0 = off).
    seed: per-request RNG seed; None derives a stable one from the rid at
        submit time, so sampled requests are reproducible by default.
    max_new: generation budget (prefill always emits the first token).
    eos_id: stop token (None = run to max_new).
    deadline_s: wall-clock budget from submit(); a deadline-aware
        scheduler evicts the request once it expires (EVICTED event).
    priority: higher admits first under the priority scheduler (FIFO
        within a priority level); ignored by FCFS/shortest-prompt.
    """

    temperature: float = GREEDY_TEMPERATURE
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None
    max_new: int = 16
    eos_id: int | None = None
    deadline_s: float | None = None
    priority: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1 (prefill always emits "
                             "the first token)")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")

    def resolved(self, rid: int, max_new: int | None = None,
                 eos_id: int | None = None) -> "SamplingParams":
        """Fill per-request defaults: explicit submit() overrides win, and
        a missing seed becomes a stable function of the rid (so replaying
        the same submission order reproduces the same generations)."""
        return dataclasses.replace(
            self,
            max_new=self.max_new if max_new is None else max_new,
            eos_id=self.eos_id if eos_id is None else eos_id,
            seed=self.seed if self.seed is not None else rid)

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= GREEDY_TEMPERATURE


def sample_tokens(logits, temperature, top_k, top_p, seeds, counts):
    """Device-side batched sampling: (B, V) logits -> (B,) int32 tokens.

    temperature/top_p (B,) f32, top_k/counts (B,) int32, seeds (B,) uint32.
    Jit-traceable; rows with temperature <= 0 return the exact
    ``jnp.argmax(logits, -1)`` the greedy engine computed (the sampled
    branch is evaluated but discarded by ``where``).

    Filter order matches the common serving convention (sequential
    warpers): temperature scale, then top-k, then top-p over the
    RENORMALIZED top-k-filtered distribution, then categorical. One
    descending sort per row serves both filters (O(V log V) jnp — on
    smoke vocabs this is noise; a fused TPU kernel is future work). An
    ALL-greedy batch never pays for it: ``lax.cond`` skips the sampling
    branch entirely, so the default engine path stays at the legacy
    argmax-only decode cost.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _sampled(_):
        lg = logits.astype(jnp.float32)
        v = lg.shape[-1]
        safe_t = jnp.where(temperature <= 0, 1.0, temperature)[:, None]
        order = jnp.argsort(-lg, axis=-1)                   # descending
        scaled = jnp.take_along_axis(lg, order, axis=-1) / safe_t
        ranks = jnp.arange(v, dtype=jnp.int32)[None, :]
        k = jnp.where(top_k <= 0, v, top_k).astype(jnp.int32)[:, None]
        keep = ranks < k
        # nucleus cut over the top-k-RENORMALIZED distribution (softmax of
        # the filtered logits): keep a token while the renormalized mass
        # BEFORE it is < top_p (rank 0 always kept)
        probs = jax.nn.softmax(jnp.where(keep, scaled, -jnp.inf), axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep &= (cum - probs) < top_p[:, None]
        keep = keep.at[:, 0].set(True)
        masked = jnp.where(keep, scaled, -jnp.inf)

        def one(seed, count, row):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
            return jax.random.categorical(key, row)

        drawn = jax.vmap(one)(seeds, counts, masked)        # sorted index
        sampled = jnp.take_along_axis(
            order, drawn[:, None], axis=-1)[:, 0].astype(jnp.int32)
        return jnp.where(temperature <= 0, greedy, sampled)

    return jax.lax.cond(jnp.any(temperature > 0), _sampled,
                        lambda _: greedy, None)
