"""Per-request sampling, executed DEVICE-SIDE inside the jitted decode.

The contract that makes streaming serving fast: the host never sees
logits. ``sample_tokens`` runs inside the engine's jitted prefill/decode
step and returns one int32 token per slot; the only device->host transfer
per engine tick is that (B,) token vector (which the greedy engine already
paid for its argmax result).

Per-slot parameters ride in as (B,) arrays so ONE executable serves any
mix of requests — greedy next to temperature-0.8/top-k next to nucleus:

* ``temperature <= 0`` lowers to ``jnp.argmax`` over the raw logits —
  the same op on the same array the pre-redesign greedy engine ran, so
  temperature-0 rows are token-for-token identical to it (f32 and int8).
* ``top_k = 0`` / ``top_p = 1.0`` disable those filters; free slots ride
  along as greedy rows whose sampled token is never read.

Determinism: the per-row PRNG key is ``fold_in(PRNGKey(seed), n)`` where
``n`` counts that REQUEST's sampled tokens (prefill token = 0). It depends
only on (seed, token index) — never on the slot, the engine tick, or which
other requests share the batch — so fixed-seed generations are identical
under ``run()``, manual ``step()`` loops, or any admission interleaving.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

GREEDY_TEMPERATURE = 0.0

# Speculative-decoding RNG salts. The non-spec decode draws with
# ``fold_in(PRNGKey(seed), n)``; spec decode needs THREE independent
# streams per token index n (draft proposal, accept uniform, corrected
# resample/bonus), so each folds a distinct salt on top:
# ``fold_in(fold_in(PRNGKey(seed), n), salt)``. Distinct from the
# non-spec stream and from each other; still a pure function of
# (seed, token index), so spec generations are interleaving-invariant.
SALT_DRAFT, SALT_ACCEPT, SALT_FIX = 1, 2, 3


def _spec_key(seed, index, salt: int):
    return jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), index), salt)


def _safe_log(p):
    """log(p) with exact -inf on zero-probability entries (so categorical
    can never draw a filtered-out token)."""
    return jnp.where(p > 0, jnp.log(jnp.maximum(p, 1e-38)), -jnp.inf)


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Everything the engine needs to know about one request.

    temperature: 0 => greedy argmax (the default, bitwise-compatible with
        the legacy engine); > 0 scales logits before sampling.
    top_k: keep only the k highest logits (0 = off).
    top_p: nucleus sampling — keep the smallest prefix of the sorted
        distribution with cumulative probability >= top_p (1.0 = off).
    seed: per-request RNG seed; None derives a stable one from the rid at
        submit time, so sampled requests are reproducible by default.
    max_new: generation budget (prefill always emits the first token).
    eos_id: stop token (None = run to max_new).
    deadline_s: wall-clock budget from submit(); a deadline-aware
        scheduler evicts the request once it expires (EVICTED event).
    priority: higher admits first under the priority scheduler (FIFO
        within a priority level); ignored by FCFS/shortest-prompt.
    """

    temperature: float = GREEDY_TEMPERATURE
    top_k: int = 0
    top_p: float = 1.0
    seed: int | None = None
    max_new: int = 16
    eos_id: int | None = None
    deadline_s: float | None = None
    priority: int = 0

    def __post_init__(self):
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0 (0 = off), got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.max_new < 1:
            raise ValueError("max_new must be >= 1 (prefill always emits "
                             "the first token)")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got {self.deadline_s}")

    def resolved(self, rid: int, max_new: int | None = None,
                 eos_id: int | None = None) -> "SamplingParams":
        """Fill per-request defaults: explicit submit() overrides win, and
        a missing seed becomes a stable function of the rid (so replaying
        the same submission order reproduces the same generations)."""
        return dataclasses.replace(
            self,
            max_new=self.max_new if max_new is None else max_new,
            eos_id=self.eos_id if eos_id is None else eos_id,
            seed=self.seed if self.seed is not None else rid)

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= GREEDY_TEMPERATURE


def sample_tokens(logits, temperature, top_k, top_p, seeds, counts):
    """Device-side batched sampling: (B, V) logits -> (B,) int32 tokens.

    temperature/top_p (B,) f32, top_k/counts (B,) int32, seeds (B,) uint32.
    Jit-traceable; rows with temperature <= 0 return the exact
    ``jnp.argmax(logits, -1)`` the greedy engine computed (the sampled
    branch is evaluated but discarded by ``where``).

    Filter order matches the common serving convention (sequential
    warpers): temperature scale, then top-k, then top-p over the
    RENORMALIZED top-k-filtered distribution, then categorical. One
    descending sort per row serves both filters (O(V log V) jnp — on
    smoke vocabs this is noise; a fused TPU kernel is future work). An
    ALL-greedy batch never pays for it: ``lax.cond`` skips the sampling
    branch entirely, so the default engine path stays at the legacy
    argmax-only decode cost.
    """
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _sampled(_):
        lg = logits.astype(jnp.float32)
        v = lg.shape[-1]
        safe_t = jnp.where(temperature <= 0, 1.0, temperature)[:, None]
        order = jnp.argsort(-lg, axis=-1)                   # descending
        scaled = jnp.take_along_axis(lg, order, axis=-1) / safe_t
        ranks = jnp.arange(v, dtype=jnp.int32)[None, :]
        k = jnp.where(top_k <= 0, v, top_k).astype(jnp.int32)[:, None]
        keep = ranks < k
        # nucleus cut over the top-k-RENORMALIZED distribution (softmax of
        # the filtered logits): keep a token while the renormalized mass
        # BEFORE it is < top_p (rank 0 always kept)
        probs = jax.nn.softmax(jnp.where(keep, scaled, -jnp.inf), axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        keep &= (cum - probs) < top_p[:, None]
        keep = keep.at[:, 0].set(True)
        masked = jnp.where(keep, scaled, -jnp.inf)

        def one(seed, count, row):
            key = jax.random.fold_in(jax.random.PRNGKey(seed), count)
            return jax.random.categorical(key, row)

        drawn = jax.vmap(one)(seeds, counts, masked)        # sorted index
        sampled = jnp.take_along_axis(
            order, drawn[:, None], axis=-1)[:, 0].astype(jnp.int32)
        return jnp.where(temperature <= 0, greedy, sampled)

    return jax.lax.cond(jnp.any(temperature > 0), _sampled,
                        lambda _: greedy, None)


def warped_probs(logits, temperature, top_k, top_p):
    """The post-filter next-token distribution, in VOCAB order: (B, V)
    logits -> (B, V) probabilities after the same temperature -> top-k ->
    top-p warp ``sample_tokens`` draws from. This is the q (draft) and p
    (target) of the speculative rejection rule — ``sample_tokens``'s
    categorical over the masked sorted logits samples EXACTLY this
    distribution, which is what makes the spec-decode acceptance test a
    distribution-identity statement rather than an approximation.

    Rows with temperature <= 0 are warped at temperature 1 (their value is
    never read: greedy rows accept by argmax match, not by ratio)."""
    lg = logits.astype(jnp.float32)
    v = lg.shape[-1]
    safe_t = jnp.where(temperature <= 0, 1.0, temperature)[:, None]
    order = jnp.argsort(-lg, axis=-1)
    scaled = jnp.take_along_axis(lg, order, axis=-1) / safe_t
    ranks = jnp.arange(v, dtype=jnp.int32)[None, :]
    k = jnp.where(top_k <= 0, v, top_k).astype(jnp.int32)[:, None]
    keep = ranks < k
    probs = jax.nn.softmax(jnp.where(keep, scaled, -jnp.inf), axis=-1)
    cum = jnp.cumsum(probs, axis=-1)
    keep &= (cum - probs) < top_p[:, None]
    keep = keep.at[:, 0].set(True)
    probs = jax.nn.softmax(jnp.where(keep, scaled, -jnp.inf), axis=-1)
    inv = jnp.argsort(order, axis=-1)           # sorted order -> vocab order
    return jnp.take_along_axis(probs, inv, axis=-1)


def sample_draft_tokens(logits, temperature, top_k, top_p, seeds, counts):
    """One draft-step proposal: (B, V) draft logits -> ((B,) int32 tokens,
    (B, V) f32 q). ``counts`` is the ABSOLUTE index of the token being
    proposed (request count at spec-step start + draft position), so draft
    randomness is interleaving-invariant like everything else. q is the
    warped draft distribution the proposal was drawn from — ``spec_accept``
    needs it for the p/q ratio. Greedy rows propose argmax (the lossless
    deterministic draft); an all-greedy batch skips the warp entirely."""
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def _sampled(_):
        q = warped_probs(logits, temperature, top_k, top_p)

        def one(seed, count, row):
            return jax.random.categorical(
                _spec_key(seed, count, SALT_DRAFT), _safe_log(row))

        drawn = jax.vmap(one)(seeds, counts, q).astype(jnp.int32)
        return jnp.where(temperature <= 0, greedy, drawn), q

    def _greedy(_):
        # value never read on the all-greedy path; one-hot keeps q a valid
        # distribution for shape/dtype parity across the cond branches
        return greedy, jax.nn.one_hot(greedy, logits.shape[-1],
                                      dtype=jnp.float32)

    return jax.lax.cond(jnp.any(temperature > 0), _sampled, _greedy, None)


def spec_accept(target_logits, draft_toks, draft_q, draft_len,
                temperature, top_k, top_p, seeds, counts):
    """The standard speculative rejection rule, device-side over all k
    positions at once.

    target_logits (B, k+1, V): f32 verify logits — position i is the
        target's next-token distribution GIVEN the first i draft tokens
        (position 0 conditions on the pre-draft context only).
    draft_toks (B, k) int32, draft_q (B, k, V) f32: the proposals and the
        warped draft distributions they were drawn from.
    draft_len (B,) int32: how many proposals are live per row (rows near
        their cache capacity draft fewer than k; dead rows draft 0).
    temperature/top_k/top_p/seeds (B,): the per-request sampling state.
    counts (B,) int32: each request's sampled-token count at spec-step
        start — position i corresponds to absolute token index counts + i.

    Returns (n_acc (B,) int32, out (B, k+1) int32): row b emits
    ``out[b, : n_acc[b] + 1]`` — the accepted prefix plus ONE more token
    (the corrected resample from normalize(max(p - q, 0)) on rejection, or
    the free bonus token from p_k when every proposal is accepted).

    Greedy rows (temperature <= 0) use the deterministic rule — accept
    while the proposal equals the target argmax — whose output is
    token-for-token the non-spec greedy generation by construction.
    Sampled rows accept proposal i iff u_i < p_i(d_i) / q_i(d_i); the
    emitted sequence is then distributed EXACTLY as k+1 sequential draws
    from p (the lossless guarantee tests/test_spec_decode.py checks at the
    distribution level). All of it runs inside the jit: only the accepted
    int32 tokens cross to host.
    """
    bsz, kk = draft_toks.shape
    rows = jnp.arange(bsz)
    pos = jnp.arange(kk, dtype=jnp.int32)[None, :]
    live = pos < draft_len[:, None]

    tgt = jnp.argmax(target_logits, axis=-1).astype(jnp.int32)   # (B, k+1)
    match = (draft_toks == tgt[:, :kk]) & live
    m_greedy = jnp.sum(jnp.cumprod(match.astype(jnp.int32), axis=1),
                       axis=1).astype(jnp.int32)

    def _sampled(_):
        p = jax.vmap(
            lambda l: warped_probs(l, temperature, top_k, top_p),
            in_axes=1, out_axes=1)(target_logits)                # (B,k+1,V)
        q_d = jnp.take_along_axis(
            draft_q, draft_toks[..., None], axis=-1)[..., 0]     # (B, k)
        p_d = jnp.take_along_axis(
            p[:, :kk], draft_toks[..., None], axis=-1)[..., 0]   # (B, k)

        def uniforms(seed, count):
            return jax.vmap(lambda i: jax.random.uniform(
                _spec_key(seed, count + i, SALT_ACCEPT)))(
                    jnp.arange(kk, dtype=jnp.int32))

        u = jax.vmap(uniforms)(seeds, counts)                    # (B, k)
        accept = (u * jnp.maximum(q_d, 1e-38) < p_d) & live
        m = jnp.sum(jnp.cumprod(accept.astype(jnp.int32), axis=1),
                    axis=1).astype(jnp.int32)

        # corrected resample per candidate position: normalize(max(p-q,0)),
        # falling back to p when the residual is identically zero (q >= p
        # everywhere => every proposal was accepted with probability 1, but
        # guard the degenerate numerics anyway)
        res = jnp.maximum(p[:, :kk] - draft_q, 0.0)
        res = jnp.where(jnp.sum(res, axis=-1, keepdims=True) > 0,
                        res, p[:, :kk])

        def fix_row(seed, count, rws):
            def one(i, row):
                return jax.random.categorical(
                    _spec_key(seed, count + i, SALT_FIX), _safe_log(row))
            return jax.vmap(one)(jnp.arange(kk, dtype=jnp.int32), rws)

        r = jax.vmap(fix_row)(seeds, counts, res).astype(jnp.int32)

        # free bonus token when the whole draft survives: a fresh draw
        # from the target at position draft_len
        p_bonus = jnp.take_along_axis(
            p, draft_len[:, None, None], axis=1)[:, 0]           # (B, V)

        def bonus_one(seed, count, dl, row):
            return jax.random.categorical(
                _spec_key(seed, count + dl, SALT_FIX), _safe_log(row))

        b = jax.vmap(bonus_one)(seeds, counts, draft_len,
                                p_bonus).astype(jnp.int32)
        r_at_m = jnp.take_along_axis(
            r, jnp.minimum(m, kk - 1)[:, None], axis=1)[:, 0]
        fix = jnp.where(m < draft_len, r_at_m, b)
        out = jnp.concatenate(
            [draft_toks, jnp.zeros((bsz, 1), jnp.int32)], axis=1)
        out = out.at[rows, m].set(fix)

        g = temperature <= 0
        return (jnp.where(g, m_greedy, m),
                jnp.where(g[:, None], tgt, out))

    return jax.lax.cond(jnp.any(temperature > 0), _sampled,
                        lambda _: (m_greedy, tgt), None)
