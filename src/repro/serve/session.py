"""Request-side view of the serve engine: typed events and handles.

``ServeEngine.submit`` returns a :class:`GenerationHandle`. The engine
pushes :class:`Event` records onto the underlying :class:`Request` as it
ticks (TOKEN per sampled token, then exactly one terminal FINISHED /
CANCELLED / EVICTED); the handle exposes them as an incremental
``stream()`` iterator that DRIVES the engine when it runs dry — the
single-threaded analogue of an async generator — plus per-request latency
metrics (TTFT, TPOT) computed from the event timestamps.

The engine stays the only mutator; handles only read request state and
call back into ``engine.step()`` / ``engine.cancel()``.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Iterator

from repro.serve.sampling import SamplingParams


class EventKind(enum.Enum):
    TOKEN = "token"
    FINISHED = "finished"
    CANCELLED = "cancelled"
    EVICTED = "evicted"


TERMINAL = (EventKind.FINISHED, EventKind.CANCELLED, EventKind.EVICTED)


@dataclasses.dataclass(frozen=True)
class Event:
    kind: EventKind
    rid: int
    token: int | None = None          # TOKEN events only
    reason: str = ""                  # terminal events: why (eos, max_new,
                                      # deadline, user cancel, ...)
    t: float = 0.0                    # perf_counter timestamp


@dataclasses.dataclass
class Request:
    """Engine-internal per-request state (the handle is the public face)."""

    rid: int
    prompt: list[int]
    sampling: SamplingParams
    # tenant id routing this request through its adapter delta
    # (repro/tenancy/); None = bare base via the identity bank row
    tenant: str | None = None
    generated: list[int] = dataclasses.field(default_factory=list)
    events: list[Event] = dataclasses.field(default_factory=list)
    # speculative decoding: per-spec-step accepted draft-token counts
    # (one entry per verify step this request took part in; empty when the
    # engine decodes non-speculatively)
    accepted_counts: list[int] = dataclasses.field(default_factory=list)
    status: EventKind | None = None   # None = queued/running; else terminal
    submitted_at: float = 0.0
    first_token_at: float = 0.0
    last_token_at: float = 0.0
    finished_at: float = 0.0

    @property
    def terminal(self) -> bool:
        return self.status is not None

    @property
    def hit_stop(self) -> bool:
        """Natural completion: EOS emitted or max_new reached."""
        s = self.sampling
        if self.generated and s.eos_id is not None \
                and self.generated[-1] == s.eos_id:
            return True
        return len(self.generated) >= s.max_new

    @property
    def deadline_at(self) -> float | None:
        d = self.sampling.deadline_s
        return None if d is None else self.submitted_at + d

    @property
    def tokens(self) -> list[int]:
        return list(self.prompt) + list(self.generated)


class GenerationHandle:
    """What ``submit()`` returns: a live view of one generation.

    Readable any time: ``generated`` / ``tokens`` (prompt + generated),
    ``status``, ``events``, and the latency metrics ``ttft_s`` (submit ->
    first token) and ``tpot_s`` (mean inter-token time after the first).
    ``stream()`` yields events incrementally, stepping the engine whenever
    no buffered event remains; ``result()`` drains it and returns the full
    token list; ``cancel()`` frees the request's slot immediately.
    """

    def __init__(self, engine, req: Request):
        self._engine = engine
        self._req = req

    # -- identity / state ---------------------------------------------------

    @property
    def rid(self) -> int:
        return self._req.rid

    @property
    def sampling(self) -> SamplingParams:
        return self._req.sampling

    @property
    def prompt(self) -> list[int]:
        return list(self._req.prompt)

    @property
    def generated(self) -> list[int]:
        return list(self._req.generated)

    @property
    def tokens(self) -> list[int]:
        return self._req.tokens

    @property
    def status(self) -> EventKind | None:
        """None while queued/running; a terminal EventKind afterwards."""
        return self._req.status

    @property
    def done(self) -> bool:
        return self._req.terminal

    @property
    def finished(self) -> bool:
        return self._req.status is EventKind.FINISHED

    @property
    def events(self) -> tuple[Event, ...]:
        return tuple(self._req.events)

    # -- latency metrics ----------------------------------------------------

    @property
    def ttft_s(self) -> float | None:
        """Time to first token (submit -> prefill's sampled token)."""
        r = self._req
        if not r.first_token_at:
            return None
        return r.first_token_at - r.submitted_at

    @property
    def tpot_s(self) -> float | None:
        """Time per output token after the first (decode steady state)."""
        r = self._req
        if len(r.generated) < 2 or not r.first_token_at:
            return None
        return (r.last_token_at - r.first_token_at) / (len(r.generated) - 1)

    # -- speculative decoding -----------------------------------------------

    @property
    def accepted_counts(self) -> list[int]:
        """Accepted draft tokens per spec-decode verify step this request
        took part in (empty under a non-speculative engine). Each verify
        step also emits one corrected/bonus token, so a step contributes
        ``accepted + 1`` tokens (budget/EOS permitting)."""
        return list(self._req.accepted_counts)

    @property
    def acceptance_rate(self) -> float | None:
        """Mean accepted-draft fraction over this request's spec steps:
        sum(accepted) / (steps * k) for the engine's draft length k. None
        when the engine never spec-decoded this request."""
        c = self._req.accepted_counts
        k = getattr(self._engine, "spec_k", 0)
        if not c or not k:
            return None
        return sum(c) / (len(c) * k)

    # -- control ------------------------------------------------------------

    def cancel(self) -> bool:
        return self._engine.cancel(self.rid)

    def stream(self, *, drive: bool = True) -> Iterator[Event]:
        """Yield events in order, ending after the terminal one. With
        ``drive=True`` (default) a starved iterator ticks the engine —
        ``for ev in handle.stream()`` is a complete serving loop. With
        ``drive=False`` it yields only what is already buffered (use when
        something else is stepping the engine)."""
        i = 0
        while True:
            events = self._req.events
            while i < len(events):
                ev = events[i]
                i += 1
                yield ev
                if ev.kind in TERMINAL:
                    return
            if not drive:
                return
            self._engine.step()

    def result(self) -> list[int]:
        """Drive to completion; return prompt + generated tokens."""
        for _ in self.stream():
            pass
        return self.tokens

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        s = self._req.status
        return (f"GenerationHandle(rid={self.rid}, "
                f"status={s.value if s else 'active'}, "
                f"generated={len(self._req.generated)})")
