from repro.utils.memprof import (
    LiveWatermark,
    device_memory_stats,
    device_peak_bytes,
    live_bytes,
    measured_residual_bytes,
    role_residual_bytes,
)
from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_map_with_path_str,
    pretty_bytes,
)
