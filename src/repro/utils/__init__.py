from repro.utils.tree import (
    tree_bytes,
    tree_count,
    tree_map_with_path_str,
    pretty_bytes,
)
