"""Measured memory telemetry: live-bytes watermarks, backward-residual
probes, per-role residual accounting.

benchmarks/fig5_tab1_resources.py historically reported memory ONLY from
the paper's analytic formulas (Eq. 41-46). This module adds the measured
side, with three tiers that are explicit about what each can and cannot
see:

1. ``measured_residual_bytes`` — a ``jax.vjp`` probe: linearize a function
   at the given primals and count the bytes of the residual arrays the
   returned VJP closure actually holds (deduplicated by buffer, so shared
   Tucker factors are counted once). This is a TRUE measurement of
   saved-for-backward memory — the quantity the paper's C_training ratio
   compresses — independent of any formula. Run it eagerly (outside jit);
   under jit the residuals are traced values with the same shapes, but the
   probe here wants concrete buffers.
2. ``live_bytes`` / ``LiveWatermark`` — sum over ``jax.live_arrays()``:
   exact for persistent state (params, optimizer, ASI states, batches)
   sampled at step boundaries from the host loop. Transients INSIDE a
   jitted step are invisible to this tier.
3. ``device_peak_bytes`` — the XLA allocator's peak
   (``device.memory_stats()``): the real intra-step high-water mark, on
   backends that expose it (TPU/GPU). CPU returns None — benchmark output
   must say "n/a" there, never fake a number.

``role_residual_bytes`` complements the measured tiers with the per-linear
breakdown (which role saves what, dense vs compressed) that a single total
cannot show.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import numpy as np


def array_bytes(x) -> int:
    """Bytes of one array-like (works on jax.Array / ShapeDtypeStruct)."""
    return int(np.prod(x.shape)) * np.dtype(x.dtype).itemsize


def live_bytes() -> int:
    """Total bytes of all live jax arrays on the default backend."""
    return sum(array_bytes(a) for a in jax.live_arrays())


def device_memory_stats() -> dict | None:
    """Raw allocator stats of device 0, or None when the backend has no
    allocator instrumentation (CPU)."""
    dev = jax.devices()[0]
    stats = getattr(dev, "memory_stats", None)
    return stats() if stats is not None else None


def device_peak_bytes() -> int | None:
    """Allocator peak-bytes-in-use, or None when unavailable (CPU)."""
    stats = device_memory_stats()
    if not stats:
        return None
    return stats.get("peak_bytes_in_use")


class LiveWatermark:
    """Step-boundary live-bytes watermark for host-driven training loops.

    ``sample()`` after each step; ``peak`` is the highest boundary total
    seen, ``baseline`` the first. Pairs with ``device_peak_bytes`` (which
    sees intra-step transients) when the backend has allocator stats.
    """

    def __init__(self):
        self.baseline = live_bytes()
        self.peak = self.baseline
        self.last = self.baseline

    def sample(self) -> int:
        self.last = live_bytes()
        self.peak = max(self.peak, self.last)
        return self.last

    def metrics(self, prefix: str = "mem_") -> dict:
        """Host-side metrics dict merged into train-loop logging."""
        out = {f"{prefix}live_mib": self.last / 2**20,
               f"{prefix}live_peak_mib": self.peak / 2**20}
        dev = device_peak_bytes()
        if dev is not None:
            out[f"{prefix}dev_peak_mib"] = dev / 2**20
        return out


class ResidualReport(NamedTuple):
    total_bytes: int
    n_arrays: int


def measured_residual_bytes(fn: Callable, *args, has_aux: bool = False,
                            **kwargs) -> ResidualReport:
    """Measure the saved-for-backward bytes of ``fn`` at ``args``.

    Runs ``jax.vjp`` and walks the returned VJP closure's pytree: its array
    leaves ARE the residuals autodiff decided to keep (for custom-VJP ops,
    exactly what the fwd rule returned). Buffers are deduplicated by
    identity so a Tucker factor shared between the x~ and h~ residuals
    (core/lowrank_linear.py) counts once. Differentiated-argument buffers
    that appear as residuals are counted too — if autodiff keeps the dense
    activation alive, that is precisely what this probe must report.
    """
    f = (lambda *a: fn(*a, **kwargs)) if kwargs else fn
    if has_aux:
        _, vjp_fn, _ = jax.vjp(f, *args, has_aux=True)
    else:
        _, vjp_fn = jax.vjp(f, *args)
    seen: set[int] = set()
    total = 0
    count = 0
    for leaf in jax.tree.leaves(vjp_fn):
        if not hasattr(leaf, "shape") or not hasattr(leaf, "dtype"):
            continue
        key = id(leaf)
        try:  # same underlying buffer via different Array wrappers
            key = leaf.unsafe_buffer_pointer()
        except Exception:
            pass
        if key in seen:
            continue
        seen.add(key)
        total += array_bytes(leaf)
        count += 1
    return ResidualReport(total_bytes=total, n_arrays=count)


def model_weight_bytes(params) -> dict:
    """Linear-site weight storage of a param tree, split so a quantized
    deployment shows its packing win next to the f32 master:

    {"weights_bytes", "scales_bytes", "bias_bytes", "adapter_bytes",
     "total_bytes", "n_linears"} — weights are the w/L/R payloads (int8
    after ``convert.quantize``), scales the per-channel f32 vectors that
    ride with them, bias always f32; adapter_bytes counts any per-tenant
    La/Ra delta pairs (plus their int8 storage scales) riding next to the
    base weights in a merged tree — zero on a plain base tree, so every
    pre-tenancy caller sees unchanged numbers. The walk covers every
    linear-LAYOUT dict ({"w"}/{"L","R"}-keyed), which includes w-keyed
    leaves the plan does not treat (tied embeddings, an untied lm_head) —
    those stay f32 and dilute the aggregate packing ratio;
    norms/convs/router tables are excluded. This is the accounting
    ``benchmarks/tab2_latency.py`` reports as ``weight_mib`` and
    docs/deployment.md sizes devices by. The tree walk is ``api.bind``'s
    (the key monopoly)."""
    from repro.api.bind import iter_linear_dicts, linear_param_bytes

    out = {"weights_bytes": 0, "scales_bytes": 0, "bias_bytes": 0,
           "adapter_bytes": 0, "n_linears": 0}
    for _, p in iter_linear_dicts(params):
        b = linear_param_bytes(p)
        out["weights_bytes"] += b["weights"]
        out["scales_bytes"] += b["scales"]
        out["bias_bytes"] += b["bias"]
        out["adapter_bytes"] += b["adapter_weights"] + b["adapter_scales"]
        out["n_linears"] += 1
    out["total_bytes"] = (out["weights_bytes"] + out["scales_bytes"]
                          + out["bias_bytes"] + out["adapter_bytes"])
    return out


def adapter_bytes(params, plan=None) -> dict:
    """Per-tenant delta storage of an adapter tree (or a merged tree):
    {"adapter_bytes", "n_sites", "by_site"} over every La/Ra-keyed dict.
    This is the base-vs-adapter split ``ServeEngine.summary()`` and the
    tenancy bench rows report: ``model_weight_bytes`` sizes the resident
    base, this sizes what each additional tenant costs. ``plan`` (adapter-
    stamped) is optional cross-checking: when given, a site count mismatch
    against ``plan``'s stamps raises instead of under-reporting."""
    from repro.api.bind import iter_adapter_dicts

    by_site = {}
    for path, p in iter_adapter_dicts(params):
        by_site[path] = sum(
            array_bytes(v) for k, v in p.items()
            if k in ("La", "Ra", "sLa", "sRa"))
    out = {"adapter_bytes": sum(by_site.values()),
           "n_sites": len(by_site), "by_site": by_site}
    if plan is not None:
        stamped = sum(1 for s in plan.specs if s.adapter)
        if stamped and not by_site:
            raise ValueError(
                f"plan stamps {stamped} adapter sites but the tree carries "
                "none — accounting would silently report 0")
    return out


def kv_cache_bytes(cfg, batch: int, max_cache: int, *,
                   pages: int | None = None,
                   page_size: int | None = None) -> dict:
    """Decode-cache storage for a serve engine, dense or paged — computed
    with ``jax.eval_shape`` over the REAL ``init_lm_cache`` so the number
    is the allocator's, not a formula that can drift from the code.

    Dense reserves ``batch x max_cache`` KV per attention layer (every
    slot pays for the worst case). Paged reserves ``pages x page_size``
    rows per attention layer SHARED by all slots — total bytes scale with
    the pool, not with ``batch x max_cache``, which is exactly the
    decoupling the paged pool buys (docs/serving.md has the sizing
    formulas). Returns {"total_bytes", "per_layer_bytes", "n_arrays",
    "mode"}."""
    from repro.models.lm import init_lm_cache

    caches = jax.eval_shape(
        lambda: init_lm_cache(cfg, batch, max_cache,
                              dtype=np.dtype(cfg.dtype),
                              pages=pages, page_size=page_size))
    leaves = jax.tree.leaves(caches)
    total = sum(array_bytes(l) for l in leaves)
    return {"total_bytes": total,
            "per_layer_bytes": total // max(cfg.n_layers, 1),
            "n_arrays": len(leaves),
            "mode": "paged" if pages is not None else "dense"}


# ---------------------------------------------------------------------------
# Per-role residual accounting (analytic, from the config's own policies).
# ---------------------------------------------------------------------------

def tucker_residual_bytes(act_shape, ranks, itemsize: int = 4) -> int:
    """Bytes of one linear's Tucker residual (paper Eq. 31/44) plus the
    rank-K sketch's extra last-mode factor is charged by the caller."""
    from repro.core.asi import tucker_storage

    return tucker_storage(act_shape, ranks) * itemsize


def dense_residual_bytes(act_shape, itemsize: int = 4) -> int:
    n = 1
    for d in act_shape:
        n *= d
    return n * itemsize


def role_residual_bytes(cfg, batch: int, seq: int,
                        itemsize: int = 4) -> list[dict]:
    """Per-linear-role saved-activation bytes under ``cfg.wasi``, next to
    the dense baseline. Covers one transformer block's projections (the
    repeating cost); embedding/head stay dense by design (DESIGN.md §5).

    Returns records {role, in_dim, out_dim, dense_bytes, bytes, kind} where
    kind names what the backward actually saves for that linear:
    ``tucker`` (+ sketch factor) for compressed roles, ``x+sketch`` for the
    factored-no-ASI path (kernels/ops.py saves x and the M×K sketch), and
    ``dense`` otherwise.
    """
    from repro.api.plan import resolve_linear_spec

    w = cfg.wasi
    d, f = cfg.d_model, cfg.d_ff
    dh = cfg.resolved_head_dim
    roles = [
        ("mlp_up", "mlp", d, f),
        ("mlp_down", "mlp", f, d),
        ("attn_qkv", "attn", d, (cfg.n_heads + 2 * cfg.n_kv_heads) * dh),
        ("attn_out", "attn", cfg.n_heads * dh, d),
    ]
    out = []
    for name, role, i_dim, o_dim in roles:
        act = (batch, seq, i_dim)
        dense = dense_residual_bytes(act, itemsize)
        spec = resolve_linear_spec(w, f"memprof/{name}", role, i_dim, o_dim,
                                   act_shape=act)
        if spec.asi_ranks is not None:
            ranks = spec.asi_ranks
            bytes_ = tucker_residual_bytes(act, ranks, itemsize)
            if spec.mode == "factored":  # + h~ sketch's (K, r_feat) factor
                bytes_ += spec.rank * ranks[-1] * itemsize
            kind = "tucker"
        elif spec.mode == "factored":  # wsi: exact sketch-saving backward
            bytes_ = dense + batch * seq * spec.rank * 4  # x + h (f32)
            kind = "x+sketch"
        else:
            bytes_ = dense
            kind = "dense"
        out.append({"role": name, "in_dim": i_dim, "out_dim": o_dim,
                    "dense_bytes": dense, "bytes": bytes_, "kind": kind})
    return out


def summarize_roles(records: list[dict]) -> dict:
    """Totals over a role report: {dense_bytes, bytes, ratio}."""
    dense = sum(r["dense_bytes"] for r in records)
    got = sum(r["bytes"] for r in records)
    return {"dense_bytes": dense, "bytes": got,
            "ratio": dense / max(got, 1)}
