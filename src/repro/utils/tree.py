"""Small pytree utilities used across the framework."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def tree_count(tree) -> int:
    """Total number of elements across all leaves."""
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    """Total bytes across all leaves (works on arrays and ShapeDtypeStructs)."""
    total = 0
    for x in jax.tree.leaves(tree):
        dt = jnp.dtype(x.dtype)
        total += int(np.prod(x.shape)) * dt.itemsize
    return total


def pretty_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024.0 or unit == "TiB":
            return f"{n:.2f}{unit}"
        n /= 1024.0
    return f"{n}B"


def tree_map_with_path_str(fn, tree):
    """tree_map where fn receives ('a/b/c', leaf)."""

    def _fmt(path):
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        return "/".join(parts)

    return jax.tree_util.tree_map_with_path(lambda p, x: fn(_fmt(p), x), tree)
