"""Fault-tolerant training loop: checkpoint/restart, async saves, step
timing, straggler hooks, measured memory telemetry. The data pipeline is a
pure function of step, so restart = restore state + continue at state.step
(no reader state).
"""
from __future__ import annotations

import time
from typing import Callable

import jax

from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig
from repro.distributed.fault import RestartPolicy, StepTimer
from repro.train.step import TrainState
from repro.utils.memprof import LiveWatermark


def train_loop(state: TrainState, step_fn, batch_fn: Callable[[int], dict],
               tcfg: TrainConfig, *, log_every: int = 10,
               ckpt: CheckpointManager | None = None,
               max_steps: int | None = None, memprof: bool = False,
               batch_sharding=None,
               log_fn=print) -> tuple[TrainState, list[dict]]:
    """Runs up to ``max_steps or tcfg.steps``; resumes from the latest
    checkpoint if ``ckpt`` has one. Returns (final_state, metrics_history).

    ``batch_sharding`` (a NamedSharding from train.step.dp_batch_sharding)
    places each host batch across the DP mesh before the step — required
    when ``step_fn`` came from make_train_step(..., mesh=...).

    ``memprof`` adds MEASURED memory columns to every logged step: live
    jax-array bytes at the step boundary and the watermark across the run
    (utils/memprof.py tier 2), plus the device allocator's intra-step peak
    on backends that report one (tier 3; absent on CPU). Sampling is
    host-side between steps — it never perturbs the jitted hot path.
    """
    if ckpt is not None:
        restored_step, restored = ckpt.restore_latest(state)
        if restored is not None:
            state = restored
            log_fn(f"[train] resumed from checkpoint step {restored_step}")

    jit_step = jax.jit(step_fn, donate_argnums=0)
    total = max_steps or tcfg.steps
    timer = StepTimer()
    watermark = LiveWatermark() if memprof else None
    history = []
    start = int(state.step)
    for step in range(start, total):
        timer.start()
        batch = batch_fn(step)
        if batch_sharding is not None:
            batch = jax.device_put(batch, batch_sharding)
        state, metrics = jit_step(state, batch)
        if watermark is not None:
            jax.block_until_ready(metrics)
            watermark.sample()
        if step % log_every == 0 or step == total - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["sec"] = timer.stop()
            if watermark is not None:
                m.update(watermark.metrics())
            history.append(m)
            log_fn(f"[train] step {step}: " +
                   " ".join(f"{k}={v:.4g}" for k, v in m.items() if k != "step"))
        else:
            timer.stop()
        if ckpt is not None and tcfg.checkpoint_every > 0 and \
                (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save_async(step + 1, state)
    if ckpt is not None:
        ckpt.save(total, state)
    return state, history
