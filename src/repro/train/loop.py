"""Fault-tolerant training loop: checkpoint/restart, async saves, step
timing, straggler hooks, measured memory telemetry.

Two data contracts:

* a plain ``batch_fn(step) -> batch`` — a pure function of step
  (``data/synthetic.py``), so restart = restore state + continue at
  ``state.step`` with no reader state;
* a ``DataIterator`` (``data/pipeline.py``) — a stateful streaming reader
  (sharded text files, shuffle buffer, background host->device prefetch)
  whose explicit reader-state pytree is saved NEXT TO the train state in
  every checkpoint (``CheckpointManager`` ``extra={"reader": ...}``) and
  restored on resume, so restart-from-checkpoint replays the exact token
  stream the uninterrupted run would have seen.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig
from repro.distributed.fault import RestartPolicy, StepTimer
from repro.train.step import TrainState
from repro.utils.memprof import LiveWatermark

READER_EXTRA = "reader"   # manifest extras key for pipeline reader state


def _is_iterator(data) -> bool:
    return hasattr(data, "next_batch") and hasattr(data, "state")


def train_loop(state: TrainState, step_fn, data, tcfg: TrainConfig, *,
               log_every: int = 10, ckpt: CheckpointManager | None = None,
               max_steps: int | None = None, memprof: bool = False,
               batch_sharding=None,
               log_fn=print) -> tuple[TrainState, list[dict]]:
    """Runs up to ``max_steps or tcfg.steps``; resumes from the latest
    checkpoint if ``ckpt`` has one. Returns (final_state, metrics_history).

    ``data`` is either ``batch_fn(step) -> batch`` or a ``DataIterator``
    (has ``next_batch``/``state``/``restore``). With an iterator, the
    reader state rides in every checkpoint and is restored on resume; the
    iterator is expected to place batches on device itself (pass the mesh
    sharding at iterator construction), and its measured input telemetry
    (``stats()``: tokens/s, prefetch stall fraction) joins the logged
    metrics.

    ``batch_sharding`` (a NamedSharding from train.step.dp_batch_sharding)
    places each host batch across the DP mesh before the step — required
    when ``step_fn`` came from make_train_step(..., mesh=...) and ``data``
    is a plain batch_fn.

    ``memprof`` adds MEASURED memory columns to every logged step: live
    jax-array bytes at the step boundary and the watermark across the run
    (utils/memprof.py tier 2), plus the device allocator's intra-step peak
    on backends that report one (tier 3; absent on CPU). Sampling is
    host-side between steps — it never perturbs the jitted hot path.
    """
    streaming = _is_iterator(data)
    if ckpt is not None:
        restored_step, restored = ckpt.restore_latest(state)
        if restored is not None:
            state = restored
            log_fn(f"[train] resumed from checkpoint step {restored_step}")
            if streaming:
                reader = ckpt.restore_extra(restored_step, READER_EXTRA)
                if reader is not None:
                    data.restore(reader)
                    log_fn("[train] reader state restored: token stream "
                           "resumes exactly where the checkpoint left off")
                else:
                    log_fn("[train] WARNING: checkpoint carries no reader "
                           "state — the resumed stream restarts from the "
                           "head of the corpus, not from the save point")

    jit_step = jax.jit(step_fn, donate_argnums=0)
    total = max_steps or tcfg.steps
    timer = StepTimer()
    watermark = LiveWatermark() if memprof else None
    history = []
    start = int(state.step)
    for step in range(start, total):
        timer.start()
        if streaming:
            batch = data.next_batch(step)   # prefetched + pre-placed
        else:
            batch = data(step)
            if batch_sharding is not None:
                batch = jax.device_put(batch, batch_sharding)
        state, metrics = jit_step(state, batch)
        if watermark is not None:
            jax.block_until_ready(metrics)
            watermark.sample()
        if step % log_every == 0 or step == total - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = step
            m["sec"] = timer.stop()
            if watermark is not None:
                m.update(watermark.metrics())
            if streaming and hasattr(data, "stats"):
                s = data.stats()
                m["input_tok_s"] = s["tok_s"]
                m["input_stall_frac"] = s["stall_frac"]
            history.append(m)
            log_fn(f"[train] step {step}: " +
                   " ".join(f"{k}={v:.4g}" for k, v in m.items() if k != "step"))
        else:
            timer.stop()
        if ckpt is not None and tcfg.checkpoint_every > 0 and \
                (step + 1) % tcfg.checkpoint_every == 0:
            ckpt.save_async(step + 1, state, extra=_reader_extra(data))
    if ckpt is not None:
        ckpt.save(total, state, extra=_reader_extra(data))
    return state, history


def _reader_extra(data) -> dict | None:
    """The reader-state side tree for a checkpoint (None for batch_fn
    data). ``DeviceIterator.state()`` is the state as of the last CONSUMED
    batch, so a restore resumes at exactly the next training step's batch
    even though the prefetcher has run ahead."""
    return {READER_EXTRA: data.state()} if _is_iterator(data) else None
