from repro.train.step import TrainState, make_train_state, make_train_step
from repro.train.loop import train_loop
