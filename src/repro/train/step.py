"""Train-step assembly: model loss -> grads -> clip -> optimizer -> WASI
subspace maintenance -> (optional) PowerSGD-compressed DP all-reduce.

One jittable pure function over a single TrainState pytree, so the same
step lowers for the single-pod (16x16) and multi-pod (2x16x16) meshes in
launch/dryrun.py and runs eagerly in CPU tests.

``make_train_step(..., mesh=...)`` wraps that same function in shard_map
over the policy's DP axes with FACTOR-ONLY gradient communication:
WASI-factored sites all-reduce their rank-K dL/dR directly (the factors
ARE the compressor — K(O+I) bytes instead of O*I), and the remaining
dense 2D sites go through the distributed/grad_compress.py PowerSGD path
whose small P/Q factors are the only thing that crosses the mesh. Every
non-gradient collective is a scalar (loss/metric pmeans). State stays
replicated except the PER-REPLICA buffers — PowerSGD error feedback and
ASI activation-subspace warm-starts — which carry a leading device axis
sharded over DP (each worker tracks its own local statistics; no sync
collective, see core/powersgd.py).

WASI maintenance per update mode:
* factored — every ``refresh_every`` steps, re-orthogonalize each (L, R)
  pair (wsi_refresh_factored: one fused CholeskyQR per pair). The refresh
  sits under jax.lax.cond so the 1 - 1/refresh_every majority of steps pay
  nothing for it (the step is jitted at the top level, where cond executes
  only the taken branch — a where-select would run the QR every step).
* project  — insert (L, R) from WSIState for the forward; after the
  optimizer updates W, run one WSI subspace iteration (paper Alg. 1).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api.bind import extract_project_factors, map_factored
from repro.config import ModelConfig, TrainConfig
from repro.core.project import (
    init_project_states,
    project_forward_params,
    update_project_states,
)
from repro.core.powersgd import PowerSGDState
from repro.core.wsi import wsi_refresh_factored
from repro.distributed.grad_compress import compress_gradients, init_compression
from repro.distributed.sharding import MeshPolicy
from repro.optim import (
    clip_by_global_norm,
    init_optimizer,
    make_schedule,
    optimizer_update,
)


class TrainState(NamedTuple):
    params: Any
    opt: Any
    asi: Any            # ASI warm-start states (or None)
    wsi: Any            # project-mode WSIState dict (or None)
    psgd: Any           # PowerSGD compression states (or None)
    step: jax.Array


def make_train_state(key, params, cfg: ModelConfig, tcfg: TrainConfig, *,
                     asi_states=None, use_epsilon_ranks: bool = False,
                     dp_degree: int = 0) -> TrainState:
    """``dp_degree=D`` sizes the PowerSGD error buffers for a D-way DP mesh
    (per-replica error feedback, leading device axis); 0 = single device."""
    wsi = None
    if cfg.wasi.project:
        # converted checkpoints (api.convert.factorize, project mode) carry
        # {"w","L","R"}: strip the factors into warm WSI states so the
        # param tree stays dense and training resumes the stored subspace
        params, warm = extract_project_factors(params)
        wsi = init_project_states(params, cfg, use_epsilon=use_epsilon_ranks,
                                  warm=warm)
    psgd = None
    if tcfg.powersgd_rank > 0:
        psgd = init_compression(key, params, tcfg.powersgd_rank,
                                local_copies=dp_degree)
    if dp_degree and asi_states is not None:
        # ASI warm-starts are per-worker statistics (each replica tracks its
        # own local activation subspace — no sync collective): give every
        # leaf a leading device axis the DP step shards, like psgd.error.
        asi_states = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (dp_degree,) + x.shape),
            asi_states)
    return TrainState(params=params, opt=init_optimizer(params, tcfg),
                      asi=asi_states, wsi=wsi, psgd=psgd,
                      step=jnp.zeros((), jnp.int32))


def make_train_step(loss_fn, cfg: ModelConfig, tcfg: TrainConfig, *,
                    policy: MeshPolicy | None = None, mean_fn=None,
                    mesh: Mesh | None = None):
    """loss_fn(params, batch, cfg, states=..., policy=...) -> (loss, (ns, metrics)).

    Returns step(state, batch) -> (state, metrics).

    With ``mesh`` the step is shard_map'd data-parallel over the policy's
    batch axes (default ("data",)): the batch arrives sharded on its leading
    dim, cross-replica averaging is lax.pmean — rank-K dL/dR for factored
    sites, PowerSGD P/Q factors for dense sites when tcfg.powersgd_rank>0.
    The state must then come from ``make_train_state(..., dp_degree=D)``
    placed with :func:`dp_state_shardings`; batches with
    :func:`dp_batch_sharding`. ``mean_fn`` must be None when mesh is given.
    """
    schedule = make_schedule(tcfg)

    def build(mean_fn):
        return _build_step(loss_fn, cfg, tcfg, policy, mean_fn, schedule)

    if mesh is None:
        return build(mean_fn)

    if mean_fn is not None:
        raise ValueError("pass either mesh or mean_fn, not both")
    dp = _dp_axes(policy)
    for ax in dp:
        if ax not in mesh.axis_names:
            raise ValueError(f"policy batch axis {ax!r} not in mesh "
                             f"{mesh.axis_names}")

    def pmean(x):
        return jax.lax.pmean(x, dp)

    inner = build(pmean)

    def local_step(state: TrainState, batch):
        # per-replica state (PowerSGD error, ASI warm-starts) arrives as a
        # (1, ...) local shard of the (D, ...) buffer; the math runs on the
        # squeezed view and the device axis is restored on the way out.
        if state.psgd is not None:
            state = state._replace(psgd={
                k: s._replace(error=s.error[0])
                for k, s in state.psgd.items()})
        if state.asi is not None:
            state = state._replace(asi=jax.tree.map(lambda x: x[0],
                                                    state.asi))
        new_state, metrics = inner(state, batch)
        if new_state.psgd is not None:
            new_state = new_state._replace(psgd={
                k: s._replace(error=s.error[None])
                for k, s in new_state.psgd.items()})
        if new_state.asi is not None:
            new_state = new_state._replace(asi=jax.tree.map(
                lambda x: x[None], new_state.asi))
        # only the loss/metric scalars cross the mesh beyond the gradient
        # factors — pmean so every replica reports the global numbers
        metrics = jax.tree.map(pmean, metrics)
        return new_state, metrics

    from repro.distributed.collectives import shard_map

    def dp_step(state: TrainState, batch) -> tuple[TrainState, dict]:
        sspecs = dp_state_specs(state, policy)
        bspecs = jax.tree.map(lambda _: P(dp), batch)
        return shard_map(local_step, mesh=mesh,
                         in_specs=(sspecs, bspecs),
                         out_specs=(sspecs, P()),
                         check_rep=False)(state, batch)

    return dp_step


def _build_step(loss_fn, cfg: ModelConfig, tcfg: TrainConfig,
                policy, mean_fn, schedule):
    def step(state: TrainState, batch) -> tuple[TrainState, dict]:
        params = state.params
        fwd_params = params
        if state.wsi is not None:
            fwd_params = project_forward_params(params, state.wsi)

        if tcfg.microbatch > 1:
            # gradient accumulation: scan over microbatches slices the batch
            # leading dim; activations (the HBM peak) shrink by the factor,
            # grads are averaged, ASI warm-start states thread through.
            nm = tcfg.microbatch

            def slice_mb(x):
                b = x.shape[0]
                return x.reshape((nm, b // nm) + x.shape[1:])

            mbatches = jax.tree.map(slice_mb, batch)

            def mb_step(carry, mb):
                acc, asi = carry
                (l, (asi2, mets)), g = jax.value_and_grad(
                    lambda p: loss_fn(p, mb, cfg, states=asi, policy=policy),
                    has_aux=True)(fwd_params)
                acc = jax.tree.map(lambda a, b: a + b / nm, acc, g)
                return (acc, asi2), (l, mets)

            zero = jax.tree.map(
                lambda x: jnp.zeros(x.shape, jnp.float32), fwd_params)
            (grads, new_asi), (losses, metset) = jax.lax.scan(
                mb_step, (zero, state.asi), mbatches)
            loss = losses.mean()
            metrics = jax.tree.map(lambda m: m.mean(), metset)
        else:
            def lf(p):
                return loss_fn(p, batch, cfg, states=state.asi, policy=policy)

            (loss, (new_asi, metrics)), grads = jax.value_and_grad(
                lf, has_aux=True)(fwd_params)
        if state.wsi is not None:
            # strip gradient entries for the injected L/R (zeros by custom vjp)
            grads = jax.tree.map(lambda g: g, grads)
            grads = _strip_lr(grads, params)

        if state.psgd is not None:
            grads, new_psgd = compress_gradients(grads, state.psgd, mean_fn)
        else:
            new_psgd = None
            if mean_fn is not None:
                grads = jax.tree.map(mean_fn, grads)

        grads, gnorm = clip_by_global_norm(grads, tcfg.clip_norm)
        lr = schedule(state.step)
        new_params, new_opt = optimizer_update(params, grads, state.opt,
                                               tcfg, lr)

        new_wsi = state.wsi
        if state.wsi is not None:
            # paper Alg. 1: one subspace iteration against the updated W
            new_wsi = update_project_states(new_params, state.wsi)
        elif cfg.wasi.factored and cfg.wasi.refresh_every > 0:
            do = (state.step + 1) % cfg.wasi.refresh_every == 0
            new_params = jax.lax.cond(
                do,
                lambda p: map_factored(p, wsi_refresh_factored),
                lambda p: p,
                new_params)

        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm, "lr": lr})
        return TrainState(params=new_params, opt=new_opt, asi=new_asi,
                          wsi=new_wsi, psgd=new_psgd,
                          step=state.step + 1), metrics

    return step


def _dp_axes(policy: MeshPolicy | None) -> tuple[str, ...]:
    return tuple(policy.batch) if policy is not None else ("data",)


def dp_state_specs(state: TrainState, policy: MeshPolicy | None = None):
    """PartitionSpecs for a DP TrainState: everything replicated except the
    per-replica buffers — PowerSGD error feedback and ASI warm-starts —
    whose leading device axis shards over the DP mesh axes."""
    dp = _dp_axes(policy)
    rep = jax.tree.map(lambda _: P(), state)
    if state.psgd is not None:
        rep = rep._replace(psgd={
            k: PowerSGDState(q=P(), error=P(dp)) for k in state.psgd})
    if state.asi is not None:
        rep = rep._replace(asi=jax.tree.map(lambda _: P(dp), state.asi))
    return rep


def dp_state_shardings(state: TrainState, mesh: Mesh,
                       policy: MeshPolicy | None = None):
    """NamedShardings for jax.device_put of a DP TrainState."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        dp_state_specs(state, policy),
                        is_leaf=lambda t: isinstance(t, P))


def dp_batch_sharding(mesh: Mesh, policy: MeshPolicy | None = None):
    """NamedSharding placing a batch's leading dim across the DP axes."""
    return NamedSharding(mesh, P(_dp_axes(policy)))


def _strip_lr(grads, params_template):
    """Zero-out/removal of grads for injected L/R keys absent in the real
    param tree (project mode: params hold w, fwd tree held w+L+R)."""
    def walk(g, p):
        if isinstance(p, dict):
            return {k: walk(g[k], p[k]) for k in p}
        if isinstance(p, list):
            return [walk(a, b) for a, b in zip(g, p)]
        if isinstance(p, tuple) and not hasattr(p, "_fields"):
            return tuple(walk(a, b) for a, b in zip(g, p))
        return g

    return walk(grads, params_template)
