"""Dataset registry: ONE construction path for ``--data`` and programmatic
callers.

``make_dataset("synthetic", cfg, ...)`` builds the family-matched
procedural dataset (``data/synthetic.py``); ``make_dataset("text:<glob>",
cfg, ...)`` builds a :class:`TextDataset` streaming real shard files
through the tokenize/pack/prefetch pipeline (``data/pipeline.py``).
Launchers (``launch/train.py --data``, ``launch/finetune_user.py``) and
library callers share this table — adding a dataset means registering a
builder here, not editing every CLI.

:class:`TextDataset` is the text twin of ``SyntheticLM``: same
``.batch(step, batch_size)`` random-access surface (a pure function of
``(seed, step)`` — used by fine-tuning, eval, and the collective-bytes
probe) and the same ``.for_tenant(uid)`` seam (a deterministic per-tenant
CORPUS FILTER: the tenant's favorite topic bucket plus an ``offmix``
fraction of everything else), PLUS ``.iterator(...)`` — the streaming,
checkpointable, prefetching path ``train_loop`` consumes.
"""
from __future__ import annotations

import zlib
from typing import Callable

import numpy as np

from repro.data.pipeline import DeviceIterator, PackedStream
from repro.data.source import ShardedTextSource, doc_topic
from repro.data.tokenizer import get_tokenizer


class TextDataset:
    """Sharded text corpus -> tokenized/packed batches, two access modes.

    Tenant clones (``for_tenant``) share the host-side token cache with
    their parent — the corpus is tokenized once per (shard, tokenizer)
    regardless of how many tenants filter it.
    """

    def __init__(self, shards, *, seq_len: int, global_batch: int,
                 seed: int = 0, tokenizer="byte", shuffle: int = 64,
                 process_index: int = 0, process_count: int = 1,
                 tenant: str | None = None, tenant_offmix: float = 0.15,
                 tenant_topics: int = 8, _tok_cache: dict | None = None):
        if isinstance(shards, str):
            self.source = ShardedTextSource.from_glob(
                shards, process_index, process_count)
        else:
            self.source = ShardedTextSource(shards, process_index,
                                            process_count)
        self.tokenizer = get_tokenizer(tokenizer) \
            if isinstance(tokenizer, str) else tokenizer
        self.seq_len = int(seq_len)
        self.global_batch = int(global_batch)
        self.seed = int(seed)
        self.shuffle = int(shuffle)
        self.tenant = tenant
        self.tenant_offmix = float(tenant_offmix)
        self.tenant_topics = int(tenant_topics)
        # token cache: {owned_ix: [int32 doc tokens + EOS, ...]}, shared
        # across tenant clones (same shards, same tokenizer)
        self._tok_cache: dict[int, list[np.ndarray]] = \
            _tok_cache if _tok_cache is not None else {}
        self._filtered: dict[int, list[np.ndarray]] = {}

    @property
    def vocab_size(self) -> int:
        return self.tokenizer.vocab_size

    def for_tenant(self, uid: str | None) -> "TextDataset":
        """This corpus filtered to tenant ``uid``'s sub-corpus: documents
        in the tenant's favorite topic bucket, plus a deterministic
        ``tenant_offmix`` fraction of off-topic documents (pure function
        of (seed, uid, shard, doc) — no hidden RNG)."""
        ds = TextDataset.__new__(TextDataset)
        ds.__dict__.update(self.__dict__)
        ds.tenant = uid
        ds._filtered = {}
        return ds

    # -- tokenize + tenant filter (cached) -----------------------------------
    def _raw_docs(self, owned_ix: int) -> list[np.ndarray]:
        if owned_ix not in self._tok_cache:
            eos = self.tokenizer.eos_id
            self._tok_cache[owned_ix] = [
                np.asarray(self.tokenizer.encode(d) + [eos], np.int32)
                for d in self.source.docs(owned_ix)]
        return self._tok_cache[owned_ix]

    def _keep_doc(self, owned_ix: int, doc_ix: int, text: str) -> bool:
        if self.tenant is None:
            return True
        fav = zlib.crc32(self.tenant.encode()) % self.tenant_topics
        if doc_topic(text, self.tenant_topics) == fav:
            return True
        u = np.random.default_rng(
            (self.seed, 0x7E, zlib.crc32(self.tenant.encode()),
             owned_ix, doc_ix)).uniform()
        return bool(u < self.tenant_offmix)

    def token_docs(self, owned_ix: int) -> list[np.ndarray]:
        """This shard's (tenant-filtered) tokenized documents."""
        if owned_ix not in self._filtered:
            raw = self._raw_docs(owned_ix)
            texts = self.source.docs(owned_ix)
            self._filtered[owned_ix] = [
                t for i, (t, txt) in enumerate(zip(raw, texts))
                if self._keep_doc(owned_ix, i, txt)]
        return self._filtered[owned_ix]

    @property
    def n_owned(self) -> int:
        return self.source.n_owned

    # -- streaming path (train_loop) -----------------------------------------
    def stream(self, *, batch_size: int | None = None) -> PackedStream:
        return PackedStream(self, seq_len=self.seq_len,
                            batch_size=batch_size or self.global_batch,
                            shuffle=self.shuffle, seed=self.seed)

    def iterator(self, *, batch_size: int | None = None, prefetch: int = 2,
                 sharding=None, place: bool = True) -> DeviceIterator:
        """The checkpointable prefetching iterator ``train_loop`` consumes
        (``sharding``: a ``dp_batch_sharding`` when a mesh is live)."""
        return DeviceIterator(self.stream(batch_size=batch_size),
                              prefetch=prefetch, sharding=sharding,
                              place=place)

    # -- random-access path (finetune / eval / probes) -----------------------
    def batch(self, step: int, batch_size: int | None = None) -> dict:
        """A packed batch as a PURE function of ``(seed, step)`` — the
        ``SyntheticLM.batch`` contract, kept so fine-tuning, held-out eval
        (``tenancy.eval_ce``'s step-offset holdout) and one-shot probes
        work unchanged on text. Rows start at a step-keyed random document
        and pack forward (wrapping) exactly like the streaming path."""
        b = batch_size or self.global_batch
        docs = [d for i in range(self.n_owned) for d in self.token_docs(i)]
        if not docs:
            raise ValueError("tenant filter removed every document")
        W = self.seq_len + 1
        rng = np.random.default_rng((self.seed, 0xA7, step))
        starts = rng.integers(len(docs), size=b)
        rows = np.empty((b, W), np.int32)
        for r, s0 in enumerate(starts):
            parts, have, j = [], 0, int(s0)
            while have < W:
                parts.append(docs[j % len(docs)])
                have += len(parts[-1])
                j += 1
            rows[r] = np.concatenate(parts)[:W]
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}


# -- the registry ------------------------------------------------------------

DATA_REGISTRY: dict[str, Callable] = {}


def register(name: str):
    def deco(fn):
        DATA_REGISTRY[name] = fn
        return fn
    return deco


@register("synthetic")
def _build_synthetic(arg: str, cfg, *, batch: int, seq: int, seed: int = 0,
                     **kw):
    from repro.data.synthetic import (SyntheticAudio, SyntheticLM,
                                      SyntheticVision)
    if cfg.family == "encdec":
        return SyntheticAudio(vocab_size=cfg.vocab_size, enc_seq=cfg.enc_seq,
                              d_model=cfg.d_model, seq_len=seq,
                              global_batch=batch, seed=seed)
    if cfg.family == "vit":
        # vision data shapes are not in ModelConfig — drivers pass them
        return SyntheticVision(n_classes=kw["n_classes"],
                               n_patches=kw["n_patches"],
                               patch_dim=kw["patch_dim"], global_batch=batch,
                               seed=seed)
    return SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                       global_batch=batch, seed=seed)


@register("text")
def _build_text(arg: str, cfg, *, batch: int, seq: int, seed: int = 0,
                tokenizer="byte", shuffle: int = 64, process_index: int = 0,
                process_count: int = 1):
    if not arg:
        raise ValueError("text dataset needs a shard glob: --data "
                         "'text:/path/to/corpus/*.txt'")
    if cfg is not None and cfg.family != "lm":
        raise ValueError(f"text streaming drives LM families only, "
                         f"not {cfg.family!r}")
    return TextDataset(arg, seq_len=seq, global_batch=batch, seed=seed,
                       tokenizer=tokenizer, shuffle=shuffle,
                       process_index=process_index,
                       process_count=process_count)


def make_dataset(spec: str, cfg, *, batch: int, seq: int, seed: int = 0,
                 **kw):
    """Resolve a ``--data`` spec (``synthetic`` | ``text:<glob>``) through
    the registry. ``cfg`` is the ModelConfig (family/vocab hints); extra
    keyword args flow to the builder."""
    name, _, arg = spec.partition(":")
    if name not in DATA_REGISTRY:
        raise ValueError(f"unknown dataset {name!r}; registered: "
                         f"{sorted(DATA_REGISTRY)}")
    return DATA_REGISTRY[name](arg, cfg, batch=batch, seq=seq, seed=seed,
                               **kw)
