"""Streaming tokenized input pipeline: windowing + document packing,
shard interleaving, a seeded shuffle buffer, and a double-buffered
background host->device prefetcher.

Design contract (what the rest of the system relies on):

* **Reader state is an explicit, fixed-shape pytree** — integer cursors
  (per-shard doc index, interleave position, intra-doc token offset,
  epoch, RNG draw counter) plus the shuffle-buffer contents. Every array
  has the same shape at every step, so it checkpoints through the
  plan-bearing ``CheckpointManager`` (an "extras" tree next to the train
  state) and restores with shape validation.
* **Generation is a pure function of (static corpus, state)** — given the
  same shard files, tokenizer, and a restored state, the stream replays
  elementwise identically. RNG draws are counter-keyed
  (``default_rng((seed, draw_index))``), never hidden generator objects,
  which is what makes the shuffle buffer checkpointable at all. This is
  the property ``data/synthetic.py`` got for free from pure
  ``(seed, step)`` batches, preserved across the move to stateful file
  readers.
* **Packing** concatenates documents (each terminated by EOS) into
  ``seq_len + 1`` windows with NO padding — a window may span document
  boundaries; the EOS token is the boundary marker the LM learns.
  Windows interleave round-robin across this host's shards at document
  granularity.
* **The prefetcher overlaps host work with the device step**: a
  background thread tokenizes/packs the next batches and ``device_put``\\ s
  them (onto ``dp_batch_sharding`` when a mesh is live) while the device
  runs the current step; the train loop only ever blocks when the host
  falls behind, and that stall time is MEASURED (``stats()`` →
  ``stall_frac``), benchmarked (``benchmarks/bench_input.py``) and gated.

Resume correctness with prefetch: the producer runs AHEAD of the consumer,
so the producer's cursor is the wrong thing to checkpoint. Each prefetched
batch therefore carries the reader state valid for resuming AFTER it, and
``DeviceIterator.state()`` returns the state attached to the most recently
CONSUMED batch — save it at step N and the restored stream's first batch
is exactly batch N.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Protocol, runtime_checkable

import numpy as np


@runtime_checkable
class DataIterator(Protocol):
    """What ``train_loop`` accepts alongside a plain ``batch_fn``: a
    stateful stream with checkpointable reader state."""

    def next_batch(self, step: int | None = None) -> dict: ...
    def state(self) -> dict: ...
    def restore(self, state: dict) -> None: ...


class PackedStream:
    """Deterministic doc -> token -> packed-window -> batch stream.

    ``provider`` supplies this host's already-tokenized documents:
    ``provider.n_owned`` shards, ``provider.token_docs(i)`` -> list of
    int32 arrays (each INCLUDING its trailing EOS). Tokenization is the
    provider's concern (cached per shard) so the stream's hot loop is
    pure array slicing.
    """

    def __init__(self, provider, *, seq_len: int, batch_size: int,
                 shuffle: int = 64, seed: int = 0):
        if shuffle < 0:
            raise ValueError(f"shuffle buffer size must be >= 0, got {shuffle}")
        self.provider = provider
        self.seq_len = int(seq_len)
        self.batch_size = int(batch_size)
        self.shuffle = int(shuffle)
        self.seed = int(seed)
        self._W = self.seq_len + 1
        n = provider.n_owned
        if n == 0:
            raise ValueError("provider owns no shards")
        self._n_docs = [len(provider.token_docs(i)) for i in range(n)]
        if sum(self._n_docs) == 0:
            raise ValueError("no documents in any owned shard "
                             "(over-aggressive tenant filter?)")
        self._st = self._init_state(n)

    def _init_state(self, n_shards: int) -> dict:
        return {
            "doc_cursor": np.zeros((n_shards,), np.int64),
            "shard_pos": np.zeros((), np.int64),
            "tok_off": np.zeros((), np.int64),
            "epoch": np.zeros((), np.int64),
            "rng_calls": np.zeros((), np.int64),
            "buf": np.zeros((max(self.shuffle, 1), self._W), np.int32),
            "buf_fill": np.zeros((), np.int64),
        }

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        return {k: v.copy() for k, v in self._st.items()}

    def load_state(self, state: dict) -> None:
        for k, tmpl in self._st.items():
            v = np.asarray(state[k])
            if v.shape != tmpl.shape:
                raise ValueError(
                    f"reader state leaf {k!r}: shape {v.shape} != "
                    f"{tmpl.shape} — state from a different corpus/"
                    "shuffle/seq_len configuration")
            self._st[k] = v.astype(tmpl.dtype).copy()

    # -- deterministic generation -------------------------------------------
    def _draw(self, bound: int) -> int:
        """Counter-keyed RNG: the draw index IS the state."""
        i = int(self._st["rng_calls"])
        self._st["rng_calls"] += 1
        return int(np.random.default_rng((self.seed, 0x5B, i)).integers(bound))

    def _next_doc_run(self, need: int) -> np.ndarray:
        """Up to ``need`` tokens from the active document; advances the
        (shard_pos, doc_cursor, tok_off) cursor, wrapping epochs."""
        st = self._st
        n = self.provider.n_owned
        for _ in range(2 * n + 2):           # skip exhausted/empty shards
            s = int(st["shard_pos"])
            if int(st["doc_cursor"][s]) < self._n_docs[s]:
                break
            st["shard_pos"] = np.int64((s + 1) % n)
            st["tok_off"] = np.int64(0)
            if int(st["shard_pos"]) == 0 and \
                    all(int(c) >= m for c, m in zip(st["doc_cursor"],
                                                    self._n_docs)):
                st["epoch"] += 1
                st["doc_cursor"][:] = 0
        else:
            raise RuntimeError("no consumable document found — corpus empty?")
        s = int(st["shard_pos"])
        doc = self.provider.token_docs(s)[int(st["doc_cursor"][s])]
        off = int(st["tok_off"])
        run = doc[off:off + need]
        if off + len(run) >= len(doc):       # document exhausted
            st["doc_cursor"][s] += 1
            st["tok_off"] = np.int64(0)
            st["shard_pos"] = np.int64((s + 1) % n)   # interleave shards
        else:
            st["tok_off"] = np.int64(off + len(run))
        return run

    def _next_window(self) -> np.ndarray:
        parts, have = [], 0
        while have < self._W:
            run = self._next_doc_run(self._W - have)
            parts.append(run)
            have += len(run)
        return np.concatenate(parts).astype(np.int32)

    def next_row(self) -> np.ndarray:
        """One packed ``seq_len + 1`` row, through the shuffle buffer."""
        st = self._st
        if self.shuffle == 0:
            return self._next_window()
        while int(st["buf_fill"]) < self.shuffle:
            st["buf"][int(st["buf_fill"])] = self._next_window()
            st["buf_fill"] += 1
        j = self._draw(self.shuffle)
        out = st["buf"][j].copy()
        st["buf"][j] = self._next_window()
        return out

    def next_batch(self, step: int | None = None) -> dict:
        rows = np.stack([self.next_row() for _ in range(self.batch_size)])
        return {"tokens": rows[:, :-1].astype(np.int32),
                "labels": rows[:, 1:].astype(np.int32)}

    # PackedStream itself satisfies DataIterator (synchronous flavor)
    def restore(self, state: dict) -> None:
        self.load_state(state)


class DeviceIterator:
    """Double-buffered background prefetcher over a :class:`PackedStream`.

    A producer thread packs the next ``prefetch`` batches and places them
    on device (``jax.device_put``; onto ``sharding`` when given, so a DP
    mesh sees its batch pre-placed exactly like the synchronous
    ``dp_batch_sharding`` path). ``next_batch`` pops the queue and records
    how long it waited — ``stats()["stall_frac"]`` is the fraction of
    wall time the consumer spent blocked on the host pipeline.
    """

    def __init__(self, stream: PackedStream, *, prefetch: int = 2,
                 sharding=None, place: bool = True):
        if prefetch < 1:
            raise ValueError(f"prefetch depth must be >= 1, got {prefetch}")
        self.stream = stream
        self.prefetch = prefetch
        self.sharding = sharding
        self.place = place
        self._err: BaseException | None = None
        self.reset_stats()
        self._start()

    # -- producer ------------------------------------------------------------
    def _start(self) -> None:
        self._q: queue.Queue = queue.Queue(maxsize=self.prefetch)
        self._stop = threading.Event()
        self._resume_state = self.stream.state()
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _produce(self) -> None:
        try:
            while not self._stop.is_set():
                batch = self.stream.next_batch()
                after = self.stream.state()   # resume point AFTER this batch
                if self.place:
                    import jax
                    batch = jax.device_put(batch, self.sharding) \
                        if self.sharding is not None else \
                        jax.device_put(batch)
                while not self._stop.is_set():
                    try:
                        self._q.put((batch, after), timeout=0.05)
                        break
                    except queue.Full:
                        continue
        except BaseException as e:          # surfaced on the consumer side
            self._err = e

    # -- consumer ------------------------------------------------------------
    def next_batch(self, step: int | None = None) -> dict:
        t0 = time.perf_counter()
        while True:
            try:
                batch, after = self._q.get(timeout=0.1)
                break
            except queue.Empty:
                if self._err is not None:
                    raise RuntimeError("input pipeline producer died") \
                        from self._err
        now = time.perf_counter()
        if self._t0 is None:
            self._t0 = t0
        self._stall += now - t0
        self._t_last = now
        self._batches += 1
        self._tokens += int(np.prod(batch["tokens"].shape))
        self._resume_state = after
        return batch

    # -- checkpointable state ------------------------------------------------
    def state(self) -> dict:
        """Reader state as of the last CONSUMED batch — what to save."""
        return self._resume_state

    def restore(self, state: dict) -> None:
        self._halt()
        self.stream.load_state(state)
        self._start()

    def close(self) -> None:
        self._halt()

    def _halt(self) -> None:
        self._stop.set()
        while True:                          # unblock a producer mid-put
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        self._thread.join(timeout=5.0)

    # -- measured input telemetry --------------------------------------------
    def reset_stats(self) -> None:
        self._t0 = None
        self._t_last = 0.0
        self._stall = 0.0
        self._batches = 0
        self._tokens = 0

    def stats(self) -> dict:
        """``tok_s`` (tokens consumed / wall), ``stall_frac`` (fraction of
        wall the consumer waited on the host pipeline), over the window
        since construction or the last ``reset_stats``."""
        if self._t0 is None or self._t_last <= self._t0:
            return {"tok_s": 0.0, "stall_frac": 0.0, "batches": 0}
        wall = self._t_last - self._t0
        return {"tok_s": self._tokens / wall,
                "stall_frac": min(self._stall / wall, 1.0),
                "batches": self._batches}
