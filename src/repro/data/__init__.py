from repro.data.synthetic import SyntheticLM, SyntheticVision, SyntheticAudio
