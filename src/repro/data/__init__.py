from repro.data.synthetic import (SyntheticLM, SyntheticVision,
                                  SyntheticAudio, host_shard)
from repro.data.tokenizer import ByteTokenizer, BpeTokenizer, get_tokenizer
from repro.data.source import ShardedTextSource, write_corpus
from repro.data.pipeline import DataIterator, DeviceIterator, PackedStream
from repro.data.registry import TextDataset, make_dataset, DATA_REGISTRY
