"""Sharded text sources: local shard files + per-host shard assignment,
plus a corpus writer so the offline container and CI have REAL files to
stream (the container has no network, so "real text" means reproducibly
materialized text with learnable structure, not iid noise).

Layout convention: a corpus is a directory of ``shard_NNNNN.txt`` files,
ONE DOCUMENT PER LINE, written by :func:`write_corpus`. Documents are
topical — each line starts with its ``topic<t>`` tag and draws words from
a topic-skewed Zipfian vocabulary over a shared backbone, so a model's CE
measurably falls below log(V) when it learns the word structure, and the
tenancy layer can carve per-tenant sub-corpora by topic
(``data/registry.py::TextDataset.for_tenant``).

Multi-host: :class:`ShardedTextSource` assigns shard files round-robin by
``process_index`` (shard i belongs to host ``i % process_count``) — each
host streams only its own files, no distributed filesystem coordination
needed. Document iteration order inside a host is fully determined by
(assignment, file order, line order), which is what makes the reader
state in ``data/pipeline.py`` a handful of integer cursors.
"""
from __future__ import annotations

import glob as _glob
import os
import re

import numpy as np

_SHARD_FMT = "shard_{:05d}.txt"
_TOPIC_RE = re.compile(r"^topic(\d+)\b")

# deterministic syllable inventory for the procedural corpus
_ONSETS = ["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z"]
_NUCLEI = ["a", "e", "i", "o", "u", "ai", "ou"]


def _word(rng: np.random.Generator) -> str:
    return "".join(_ONSETS[rng.integers(len(_ONSETS))]
                   + _NUCLEI[rng.integers(len(_NUCLEI))]
                   for _ in range(int(rng.integers(1, 4))))


def write_corpus(root: str, *, n_shards: int = 4, docs_per_shard: int = 128,
                 seed: int = 0, n_topics: int = 8, vocab_words: int = 96,
                 words_per_doc: tuple[int, int] = (6, 32)) -> list[str]:
    """Materialize a reproducible multi-shard text corpus under ``root``.

    Same arguments => byte-identical files (the writer is a pure function
    of its parameters). Each document is ``topic<t> w1 w2 ...`` where the
    words are Zipf-sampled from a topic-rotated slice of a shared word
    list — enough bigram structure to learn, enough per-topic skew for
    per-tenant corpus filters to mean something. Returns the shard paths.
    """
    os.makedirs(root, exist_ok=True)
    base = np.random.default_rng((seed, 0xC0))
    words = sorted({_word(base) for _ in range(vocab_words * 2)})[:vocab_words]
    if len(words) < n_topics:
        raise ValueError(f"vocab_words={vocab_words} too small for "
                         f"{n_topics} topics")
    paths = []
    for s in range(n_shards):
        rng = np.random.default_rng((seed, 1, s))
        lines = []
        for _ in range(docs_per_shard):
            topic = int(rng.integers(n_topics))
            # topic-rotated slice: each topic favors its own word window
            lo = (topic * len(words)) // n_topics
            n_w = int(rng.integers(words_per_doc[0], words_per_doc[1] + 1))
            zipf = np.minimum(rng.zipf(1.6, size=n_w) - 1, len(words) - 1)
            doc = " ".join(words[(lo + int(z)) % len(words)] for z in zipf)
            lines.append(f"topic{topic} {doc}")
        path = os.path.join(root, _SHARD_FMT.format(s))
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        paths.append(path)
    return paths


def doc_topic(text: str, n_topics: int = 8) -> int:
    """Topic bucket of a document: its ``topic<t>`` tag when present,
    else a stable content hash — so arbitrary (non-generated) corpora
    still partition deterministically across tenants."""
    m = _TOPIC_RE.match(text)
    if m:
        return int(m.group(1)) % n_topics
    import zlib
    return zlib.crc32(text.encode("utf-8")) % n_topics


class ShardedTextSource:
    """Shard files + per-host round-robin assignment keyed by process_index.

    ``owned`` is this host's stable sub-list of the GLOBAL sorted shard
    list; :meth:`docs` reads (and caches) one shard's documents. All
    downstream cursor state indexes into ``owned``/``docs`` order, so a
    restart on the same (shards, process_index, process_count) resumes
    the identical stream.
    """

    def __init__(self, shards, process_index: int = 0, process_count: int = 1):
        shards = sorted(shards)
        if not shards:
            raise ValueError("no shard files given")
        if not 0 <= process_index < process_count:
            raise ValueError(f"process_index {process_index} outside "
                             f"process_count {process_count}")
        if len(shards) < process_count:
            raise ValueError(
                f"{len(shards)} shard file(s) cannot feed {process_count} "
                f"hosts round-robin — write at least one shard per host")
        self.all_shards = list(shards)
        self.process_index = process_index
        self.process_count = process_count
        self.owned = shards[process_index::process_count]
        self._docs: dict[int, list[str]] = {}

    @classmethod
    def from_glob(cls, pattern: str, process_index: int = 0,
                  process_count: int = 1) -> "ShardedTextSource":
        paths = _glob.glob(pattern)
        if not paths:
            raise FileNotFoundError(f"no shard files match {pattern!r}")
        return cls(paths, process_index, process_count)

    @property
    def n_owned(self) -> int:
        return len(self.owned)

    def docs(self, owned_ix: int) -> list[str]:
        """Documents (one per line, blanks dropped) of owned shard i."""
        if owned_ix not in self._docs:
            with open(self.owned[owned_ix]) as f:
                self._docs[owned_ix] = [ln.rstrip("\n") for ln in f
                                        if ln.strip()]
        return self._docs[owned_ix]
