"""Deterministic synthetic data pipelines.

The container is offline, so every dataset here is procedurally generated —
but with STRUCTURE, not iid noise, so models measurably learn:

* SyntheticLM  — a Markov token stream with per-sequence latent "topics":
  next-token distribution is a mixture of a global bigram table and a
  topic-specific unigram boost. CE should fall well below log(V) when the
  model learns the bigram structure (integration tests assert this).
* SyntheticVision — class-conditional patch prototypes + noise (the
  ViT fine-tuning stand-in for CIFAR-style tasks).
* SyntheticAudio — frame embeddings with class-dependent spectral envelope.

Determinism & fault tolerance: batches are a pure function of (seed, step),
so restart-from-checkpoint replays the exact stream with no reader state to
save; skip-ahead is O(1). Sharding: each host slices its batch rows by
process_index (multi-host data loading without a distributed filesystem).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class SyntheticLM:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_topics: int = 8
    # Tenant skew: when set, ~(1 - tenant_offmix) of rows sample the
    # tenant's favorite topic (a stable hash of the id) instead of uniform,
    # so a per-user adapter has a real distribution shift to learn
    # (repro/tenancy/finetune.py) while the bigram backbone — and hence
    # everything a GLOBAL model learns — is shared across tenants.
    tenant: str | None = None
    tenant_offmix: float = 0.15

    def for_tenant(self, uid: str) -> "SyntheticLM":
        """This stream, skewed toward tenant ``uid``'s topic. Deterministic
        in (seed, step, uid); ``uid=None``-equivalent is the base stream."""
        return replace(self, tenant=uid)

    def _tables(self):
        key = jax.random.PRNGKey(self.seed)
        k1, k2 = jax.random.split(key)
        # sparse-ish bigram logits
        bigram = jax.random.normal(k1, (self.vocab_size, self.vocab_size)) * 2.0
        topic = jax.random.normal(k2, (self.n_topics, self.vocab_size)) * 2.0
        return bigram, topic

    def batch(self, step: int, batch_size: int | None = None) -> dict:
        """Batch for a global step: {tokens (B,S), labels (B,S)}."""
        b = batch_size or self.global_batch
        bigram, topic = self._tables()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        kt, ks, kc = jax.random.split(key, 3)
        topics = jax.random.randint(kt, (b,), 0, self.n_topics)
        if self.tenant is not None:
            fav = zlib.crc32(self.tenant.encode()) % self.n_topics
            km = jax.random.fold_in(kt, 1)
            offmix = jax.random.uniform(km, (b,)) < self.tenant_offmix
            topics = jnp.where(offmix, topics, fav)
        start = jax.random.randint(ks, (b,), 0, self.vocab_size)

        def gen_row(carry, k):
            tok, tvec = carry
            logits = bigram[tok] + tvec
            nxt = jax.random.categorical(k, logits)
            return (nxt, tvec), nxt

        keys = jax.random.split(kc, self.seq_len * b).reshape(self.seq_len, b, 2)

        def gen_seq(s0, tvec, kk):
            (_, _), toks = jax.lax.scan(gen_row, (s0, tvec), kk)
            return toks

        toks = jax.vmap(gen_seq, in_axes=(0, 0, 1))(start, topic[topics], keys)
        tokens = jnp.concatenate([start[:, None], toks[:, :-1]], axis=1)
        return {"tokens": tokens.astype(jnp.int32),
                "labels": toks.astype(jnp.int32)}


@dataclass(frozen=True)
class SyntheticVision:
    n_classes: int
    n_patches: int
    patch_dim: int
    global_batch: int
    seed: int = 0
    noise: float = 1.0

    def _protos(self):
        key = jax.random.PRNGKey(self.seed)
        return jax.random.normal(key, (self.n_classes, self.n_patches, self.patch_dim))

    def batch(self, step: int, batch_size: int | None = None) -> dict:
        b = batch_size or self.global_batch
        protos = self._protos()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed + 1), step)
        kl, kn = jax.random.split(key)
        labels = jax.random.randint(kl, (b,), 0, self.n_classes)
        patches = protos[labels] + self.noise * jax.random.normal(
            kn, (b, self.n_patches, self.patch_dim))
        return {"patches": patches, "labels": labels}


@dataclass(frozen=True)
class SyntheticAudio:
    vocab_size: int
    enc_seq: int
    d_model: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int, batch_size: int | None = None) -> dict:
        b = batch_size or self.global_batch
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        kf, kt = jax.random.split(key)
        frames = jax.random.normal(kf, (b, self.enc_seq, self.d_model))
        toks = jax.random.randint(kt, (b, self.seq_len + 1), 0, self.vocab_size)
        return {"frames": frames,
                "tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}


def host_shard(batch: dict, process_index: int, process_count: int) -> dict:
    """Slice this host's rows (row-contiguous sharding over the batch dim).

    The batch dim must divide evenly: a silent floor-division here would
    DROP the remainder rows on every host — data loss that surfaces only
    as a mysteriously-smaller effective batch."""
    def slc(x):
        if x.shape[0] % process_count:
            raise ValueError(
                f"batch dim {x.shape[0]} (shape {tuple(x.shape)}) is not "
                f"divisible by process_count={process_count}: "
                f"{x.shape[0] % process_count} row(s) would be silently "
                "dropped — pick a global batch that divides across hosts")
        per = x.shape[0] // process_count
        return x[process_index * per:(process_index + 1) * per]
    return jax.tree.map(slc, batch)
