"""Deterministic, fully offline tokenizers for the streaming text pipeline.

Two implementations share one duck-typed surface (``encode`` / ``decode`` /
``vocab_size`` / ``eos_id`` / ``key``):

* ``ByteTokenizer`` — UTF-8 bytes as ids 0..255 plus EOS. Zero training,
  zero files, bijective on any text; the default for smoke/CI runs where
  the container has no pretrained vocab.
* ``BpeTokenizer`` — a BPE-lite vocab TRAINED offline on the corpus
  itself: greedy highest-count pair merges over the byte stream, ids
  appended after EOS. Deterministic (count then lexicographic tie-break),
  JSON round-trip via ``save``/``load``; ``train`` is the only entry that
  looks at data.

``key`` is a stable fingerprint (algorithm + vocab content hash) used to
key host-side token caches — two tokenizers with the same key MUST encode
identically.
"""
from __future__ import annotations

import collections
import hashlib
import json
import os

EOS_ID = 256          # first id past the 256 raw bytes
BYTE_VOCAB = 257      # bytes + EOS


class ByteTokenizer:
    """UTF-8 byte-level: id = byte value, EOS appended by the pipeline."""

    vocab_size = BYTE_VOCAB
    eos_id = EOS_ID
    key = f"byte:{BYTE_VOCAB}"

    def encode(self, text: str) -> list[int]:
        return list(text.encode("utf-8"))

    def decode(self, ids) -> str:
        return bytes(i for i in ids if 0 <= i < 256).decode(
            "utf-8", errors="replace")


class BpeTokenizer:
    """BPE-lite over bytes: ``merges[i] = (a, b)`` creates id 257 + i.

    Encoding applies merges in TRAINING order (rank order), which is the
    classic deterministic BPE inference rule — no regex pre-splitting, so
    the same code handles any byte stream.
    """

    def __init__(self, merges: list[tuple[int, int]]):
        self.merges = [tuple(m) for m in merges]
        self.vocab_size = BYTE_VOCAB + len(self.merges)
        self.eos_id = EOS_ID
        self._rank = {m: i for i, m in enumerate(self.merges)}
        h = hashlib.sha256(json.dumps(self.merges).encode()).hexdigest()[:12]
        self.key = f"bpe:{self.vocab_size}:{h}"
        # expansion table for decode: id -> byte string
        self._bytes: dict[int, bytes] = {i: bytes([i]) for i in range(256)}
        for i, (a, b) in enumerate(self.merges):
            self._bytes[BYTE_VOCAB + i] = self._bytes[a] + self._bytes[b]

    @classmethod
    def train(cls, texts, vocab_size: int = 512) -> "BpeTokenizer":
        """Greedy pair merges until ``vocab_size`` ids exist (or no pair
        repeats). Ties break on the lexicographically smallest pair so
        retraining on the same corpus is bit-identical."""
        if vocab_size < BYTE_VOCAB:
            raise ValueError(f"vocab_size {vocab_size} < byte floor {BYTE_VOCAB}")
        seqs = [list(t.encode("utf-8")) for t in texts if t]
        merges: list[tuple[int, int]] = []
        while BYTE_VOCAB + len(merges) < vocab_size:
            counts: collections.Counter = collections.Counter()
            for s in seqs:
                counts.update(zip(s, s[1:]))
            if not counts:
                break
            best_n = max(counts.values())
            if best_n < 2:
                break
            pair = min(p for p, n in counts.items() if n == best_n)
            new_id = BYTE_VOCAB + len(merges)
            merges.append(pair)
            seqs = [_apply_merge(s, pair, new_id) for s in seqs]
        return cls(merges)

    def encode(self, text: str) -> list[int]:
        ids = list(text.encode("utf-8"))
        for rank, pair in enumerate(self.merges):
            if len(ids) < 2:
                break
            ids = _apply_merge(ids, pair, BYTE_VOCAB + rank)
        return ids

    def decode(self, ids) -> str:
        out = b"".join(self._bytes.get(i, b"") for i in ids if i != EOS_ID)
        return out.decode("utf-8", errors="replace")

    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"format": "bpe-lite-v1", "merges": self.merges}, f)

    @classmethod
    def load(cls, path: str) -> "BpeTokenizer":
        with open(path) as f:
            d = json.load(f)
        if d.get("format") != "bpe-lite-v1":
            raise ValueError(f"{path}: not a bpe-lite-v1 vocab file")
        return cls([tuple(m) for m in d["merges"]])


def _apply_merge(ids: list[int], pair: tuple[int, int], new_id: int) -> list[int]:
    out, i, n = [], 0, len(ids)
    a, b = pair
    while i < n:
        if i + 1 < n and ids[i] == a and ids[i + 1] == b:
            out.append(new_id)
            i += 2
        else:
            out.append(ids[i])
            i += 1
    return out


def get_tokenizer(spec: str = "byte"):
    """``"byte"`` or ``"bpe:<vocab.json>"`` (a trained BpeTokenizer file)."""
    if spec == "byte":
        return ByteTokenizer()
    if spec.startswith("bpe:"):
        return BpeTokenizer.load(spec[len("bpe:"):])
    raise ValueError(f"unknown tokenizer spec {spec!r} (byte | bpe:<path>)")
