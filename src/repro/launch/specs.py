"""ShapeDtypeStruct stand-ins + PartitionSpecs for every dry-run cell.

Nothing here allocates: params/state/caches come from jax.eval_shape over
the real init functions, inputs are hand-built ShapeDtypeStructs, and the
sharding rules mirror distributed/sharding.py.

Cache sharding (DESIGN.md §4): decode caches shard batch on the DP axes and
the SEQUENCE dim on 'model' (plus the DP axes too for long_500k, where
batch=1 leaves them free) — decode attention over a sequence-sharded cache
is exactly the flash-decode communication pattern.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.config import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed.sharding import MeshPolicy, param_specs


def dp_axes(multi_pod: bool) -> tuple[str, ...]:
    return ("pod", "data") if multi_pod else ("data",)


# ---------------------------------------------------------------------------
# Input ShapeDtypeStructs
# ---------------------------------------------------------------------------

def train_inputs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.family == "encdec":
        return {"frames": sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
                "tokens": sds((b, s), jnp.int32),
                "labels": sds((b, s), jnp.int32)}
    if cfg.name.startswith("internvl"):
        # VLM backbone: frontend stub supplies patch embeddings directly
        return {"tokens": sds((b, s, cfg.d_model), jnp.bfloat16),
                "labels": sds((b, s), jnp.int32)}
    return {"tokens": sds((b, s), jnp.int32),
            "labels": sds((b, s), jnp.int32)}


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, multi_pod: bool):
    dp = dp_axes(multi_pod)
    if cfg.family == "encdec":
        return {"frames": P(dp, None, None), "tokens": P(dp, None),
                "labels": P(dp, None)}
    if cfg.name.startswith("internvl"):
        return {"tokens": P(dp, None, None), "labels": P(dp, None)}
    return {"tokens": P(dp, None), "labels": P(dp, None)}


def prefill_inputs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if cfg.family == "encdec":
        return {"frames": sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16),
                "tokens": sds((b, s), jnp.int32)}
    if cfg.name.startswith("internvl"):
        return {"tokens": sds((b, s, cfg.d_model), jnp.bfloat16)}
    return {"tokens": sds((b, s), jnp.int32)}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig, multi_pod: bool):
    dp = dp_axes(multi_pod)
    if cfg.family == "encdec":
        return {"frames": P(dp, None, None), "tokens": P(dp, None)}
    if cfg.name.startswith("internvl"):
        return {"tokens": P(dp, None, None)}
    return {"tokens": P(dp, None)}


def decode_inputs(cfg: ModelConfig, shape: ShapeConfig):
    """(token, pos) stand-ins; caches come from cache_shapes()."""
    b = shape.global_batch
    sds = jax.ShapeDtypeStruct
    tok = sds((b, 1), jnp.int32)
    pos = sds((), jnp.int32)
    if cfg.family == "encdec":
        memory = sds((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
        return tok, pos, memory
    return tok, pos, None


def cache_shapes(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStructs of the decode caches via eval_shape (no alloc)."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        from repro.models.encdec import init_encdec_cache
        return jax.eval_shape(
            lambda: init_encdec_cache(cfg, b, s, dtype=jnp.bfloat16))
    from repro.models.lm import init_lm_cache
    return jax.eval_shape(lambda: init_lm_cache(cfg, b, s, dtype=jnp.bfloat16))


def cache_specs(cfg: ModelConfig, shape: ShapeConfig, caches,
                multi_pod: bool):
    """Specs for cache leaves by shape pattern.

    KV leaves   (repeat, B, S, KVH, Dh): batch->dp, seq->model (+dp if B==1)
    SSM leaves  (repeat, B, ..., N):     batch->dp, first inner dim->model
    conv leaves (repeat, B, K-1, C):     batch->dp, channel->model
    """
    dp = dp_axes(multi_pod)
    batch_small = shape.global_batch == 1
    bspec = None if batch_small else dp
    # with batch=1 (long_500k) the DP axes are free: fold them into the
    # sequence sharding so the 500k cache spreads over ALL chips
    seq_axes = (dp + ("model",)) if batch_small else ("model",)

    def spec(x):
        nd = x.ndim
        is_f32 = jnp.dtype(x.dtype) == jnp.float32
        if nd == 5 and not is_f32:   # stacked KV (repeat, B, S, KVH, Dh)
            return P(None, bspec, seq_axes, None, None)
        if nd == 5 and is_f32:       # mamba2 state (repeat, B, H, dh, N)
            return P(None, bspec, "model", None, None)
        if nd == 4 and is_f32:       # mamba1 state (repeat, B, di, N)
            return P(None, bspec, "model", None)
        if nd == 4:                  # conv buffer (repeat, B, K-1, C)
            return P(None, bspec, None, "model")
        return P()

    return jax.tree.map(spec, caches)


# ---------------------------------------------------------------------------
# Train-state specs
# ---------------------------------------------------------------------------

def asi_state_specs(states, multi_pod: bool):
    """ASI warm-start factors (repeat, D_m, r_m): shard the mode dim D_m on
    the DP axes when divisible (ZeRO-style state sharding; the stacked-layer
    dim is often not divisible by the DP degree, D_m almost always is)."""
    dp = dp_axes(multi_pod)
    dp_total = 32 if multi_pod else 16

    def spec(x):
        if x.ndim >= 3 and x.shape[1] % dp_total == 0:
            return P(None, dp, *((None,) * (x.ndim - 2)))
        return P()

    return jax.tree.map(spec, states)


def opt_moment_specs(params, p_specs, multi_pod: bool):
    """ZeRO-style: optimizer moments additionally shard their leading stack
    dim over the DP axes when divisible (moments are elementwise — any
    sharding is valid; this cuts the fp32 mu/nu residency by the DP degree)."""
    dp = dp_axes(multi_pod)
    dp_total = 32 if multi_pod else 16

    def widen(leaf, spec):
        entries = tuple(spec)
        if (leaf.ndim >= 3 and len(entries) == leaf.ndim
                and entries[0] is None and leaf.shape[0] % dp_total == 0):
            return P(dp, *entries[1:])
        return spec

    return jax.tree.map(widen, params, p_specs,
                        is_leaf=lambda x: isinstance(x, P))


def train_state_specs(state, cfg: ModelConfig, policy: MeshPolicy,
                      multi_pod: bool):
    from repro.train.step import TrainState

    p_specs = param_specs(state.params, policy)
    m_specs = opt_moment_specs(state.params, p_specs, multi_pod)
    opt_mu = None if state.opt.mu is None else m_specs
    opt_nu = None if state.opt.nu is None else m_specs
    asi = None if state.asi is None else asi_state_specs(state.asi, multi_pod)
    wsi = None if state.wsi is None else jax.tree.map(lambda x: P(), state.wsi)
    psgd = None if state.psgd is None else jax.tree.map(lambda x: P(), state.psgd)
    from repro.optim.optimizers import OptState
    return TrainState(
        params=p_specs,
        opt=OptState(step=P(), mu=opt_mu, nu=opt_nu),
        asi=asi, wsi=wsi, psgd=psgd, step=P())
