"""Serving launcher: thin CLI over the continuous-batching engine.

``python -m repro.launch.serve --arch qwen2-0.5b --tokens 32 --batch 4``

Prefill is token-parallel — ONE forward over the whole prompt writes every
layer's decode caches (models/lm.py::lm_prefill); decode is a jit'd
single-token step over all serve slots at per-slot positions. WASI
inference benefit: every linear runs in the rank-K subspace through the
fused lowrank kernel (paper C_inference / S_inference — measured by
benchmarks/tab2_latency.py). The engine itself (admission queue, bucketing,
slot recycling) lives in repro/serve/engine.py.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.models.lm import init_lm, init_lm_cache, lm_decode_step, lm_prefill
from repro.serve import ServeEngine


@functools.lru_cache(maxsize=8)
def _jitted_steps(cfg):
    """Per-config jitted prefill/decode, cached so repeated generate()
    calls (warmup-then-time benchmarks, test reference loops) reuse the
    compiled executables instead of retracing fresh lambdas each call."""
    prefill = jax.jit(
        lambda pr, t, c: lm_prefill(pr, t, cfg, caches=c, last_only=True))
    step = jax.jit(
        lambda pr, tok, c, pos: lm_decode_step(pr, tok, c, pos, cfg))
    return prefill, step


def generate(params, cfg, prompt, max_cache: int, n_new: int, *, greedy=True,
             key=None):
    """prompt (B, P) -> (B, P + n_new). Lockstep batch: one token-parallel
    prefill (no per-token Python loop), then a jit'd decode step."""
    b, p = prompt.shape
    caches = init_lm_cache(cfg, b, max_cache, dtype=jnp.dtype(cfg.dtype))
    prefill, step = _jitted_steps(cfg)

    logits, caches = prefill(params, prompt, caches)
    logits = logits[:, 0]
    out = [prompt]
    for j in range(n_new):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(nxt)
        if j < n_new - 1:  # the last token needs no further forward
            logits, caches = step(params, nxt, caches, p + j)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests to submit")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--max-slots", type=int, default=0,
                    help="serve slots (0 => min(batch, 4)); fewer slots than "
                         "requests exercises queueing + slot recycling")
    ap.add_argument("--wasi", default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="",
                    help="serve from a plan-bearing checkpoint dir (the "
                         "manifest's SubspacePlan replaces --arch/--wasi)")
    ap.add_argument("--quant", default="", choices=["", "int8"],
                    help="deploy-quantize the weights before serving "
                         "(per-channel absmax int8 factors; a checkpoint "
                         "that is already quant-stamped needs no flag)")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    slots = args.max_slots or min(args.batch, 4)
    max_cache = args.prompt_len + args.tokens + 1
    if args.ckpt:
        params, plan, _ = api.convert.load_checkpoint(args.ckpt)
        if plan is None:
            raise SystemExit(f"checkpoint at {args.ckpt} carries no plan")
        if args.quant and not plan.is_quantized:
            plan = plan.quantized(args.quant)
            params = api.convert.quantize(params, plan)
        engine = ServeEngine(params, plan=plan, max_slots=slots,
                             max_cache=max_cache)
        cfg = engine.cfg
    else:
        cfg = configs.get(args.arch) if args.full \
            else configs.get_smoke(args.arch)
        if args.wasi is not None:
            cfg = cfg.replace(
                wasi=dataclasses.replace(cfg.wasi, method=args.wasi))
        plan = api.install(api.resolve(cfg))
        params = init_lm(key, cfg, jnp.dtype(cfg.dtype))
        if args.quant:
            api.uninstall(cfg)          # the engine installs the quant view
            plan = plan.quantized(args.quant)
            params = api.convert.quantize(params, plan)
        engine = ServeEngine(params, plan=plan, max_slots=slots,
                             max_cache=max_cache)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    reqs = [engine.submit(list(map(int, prompts[i])), max_new=args.tokens)
            for i in range(args.batch)]
    engine.run()
    dt = time.time() - t0
    s = engine.summary()
    qtag = " quant=int8" if engine.quantized else ""
    print(f"[serve] arch={cfg.name} wasi={cfg.wasi.method}{qtag} "
          f"slots={slots} requests={args.batch} wall={dt:.2f}s "
          f"weights={s['weight_mib']:.2f}MiB")
    print(f"[serve] prefill {s['prefill_tokens']} tok "
          f"({s['prefill_tok_s']:.1f} tok/s, one forward per admission "
          f"group) | decode {s['decode_tokens']} tok "
          f"({s['decode_tok_s']:.1f} tok/s) | "
          f"{s['requests_s']:.2f} req/s")
    print("[serve] sample:", reqs[0].tokens)


if __name__ == "__main__":
    main()
