"""Serving launcher: thin CLI over the streaming continuous-batching engine.

``python -m repro.launch.serve --arch qwen2-0.5b --tokens 32 --batch 4``
``python -m repro.launch.serve --ckpt /tmp/ckpt --stream --temperature 0.8
  --top-k 8 --seed 1 --sched priority``

Prefill is token-parallel — ONE forward over the whole prompt writes every
layer's decode caches (models/lm.py::lm_prefill); decode is a jit'd
single-token step over all serve slots at per-slot positions, with
per-request temperature/top-k/top-p sampling fused into the step so only
sampled int32 tokens ever leave the device (serve/sampling.py). WASI
inference benefit: every linear runs in the rank-K subspace through the
fused lowrank kernel (paper C_inference / S_inference — measured by
benchmarks/tab2_latency.py). The engine itself (pluggable scheduler,
bucketed prefill, slot recycling, streaming handles) lives in
repro/serve/; the request lifecycle is documented in docs/serving.md.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.models.lm import init_lm, init_lm_cache, lm_decode_step, lm_prefill
from repro.serve import SCHEDULERS, EventKind, SamplingParams, ServeEngine


@functools.lru_cache(maxsize=8)
def _jitted_steps(cfg):
    """Per-config jitted prefill/decode, cached so repeated generate()
    calls (warmup-then-time benchmarks, test reference loops) reuse the
    compiled executables instead of retracing fresh lambdas each call."""
    prefill = jax.jit(
        lambda pr, t, c: lm_prefill(pr, t, cfg, caches=c, last_only=True))
    step = jax.jit(
        lambda pr, tok, c, pos: lm_decode_step(pr, tok, c, pos, cfg))
    return prefill, step


def generate(params, cfg, prompt, max_cache: int, n_new: int, *, greedy=True,
             key=None):
    """prompt (B, P) -> (B, P + n_new). Lockstep batch: one token-parallel
    prefill (no per-token Python loop), then a jit'd decode step.

    This is the PRE-REDESIGN greedy path (host argmax over returned
    logits), kept as the bitwise oracle the streaming engine's
    temperature-0 rows are tested against."""
    b, p = prompt.shape
    caches = init_lm_cache(cfg, b, max_cache, dtype=jnp.dtype(cfg.dtype))
    prefill, step = _jitted_steps(cfg)

    logits, caches = prefill(params, prompt, caches)
    logits = logits[:, 0]
    out = [prompt]
    for j in range(n_new):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(nxt)
        if j < n_new - 1:  # the last token needs no further forward
            logits, caches = step(params, nxt, caches, p + j)
    return jnp.concatenate(out, axis=1)


def _stream(engine, handles) -> None:
    """Drive the engine to completion, printing tokens as they arrive
    (one line per engine tick batch) and a TTFT/TPOT line per request."""
    cursors = [0] * len(handles)
    while engine.busy:
        engine.step()
        for i, h in enumerate(handles):
            events = h.events
            for ev in events[cursors[i]:]:
                if ev.kind is EventKind.TOKEN:
                    print(f"[stream] rid={ev.rid} token={ev.token}",
                          flush=True)
                else:
                    print(f"[stream] rid={ev.rid} {ev.kind.value}"
                          + (f" ({ev.reason})" if ev.reason else ""))
            cursors[i] = len(events)
    bad = []
    for h in handles:
        ttft = h.ttft_s
        tpot = h.tpot_s
        print(f"[stream] rid={h.rid} status={h.status.value} "
              f"new={len(h.generated)} "
              f"ttft_ms={ttft * 1e3 if ttft else float('nan'):.2f} "
              f"tpot_ms={tpot * 1e3 if tpot else float('nan'):.3f}")
        if h.finished and not (ttft and ttft > 0):
            bad.append(h.rid)
    if bad:
        raise SystemExit(f"finished requests with no TTFT: {bad}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4,
                    help="number of requests to submit")
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--max-slots", type=int, default=0,
                    help="serve slots (0 => min(batch, 4)); fewer slots than "
                         "requests exercises queueing + slot recycling")
    ap.add_argument("--wasi", default=None)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default="",
                    help="serve from a plan-bearing checkpoint dir (the "
                         "manifest's SubspacePlan replaces --arch/--wasi)")
    ap.add_argument("--quant", default="", choices=["", "int8"],
                    help="deploy-quantize the weights before serving "
                         "(per-channel absmax int8 factors; a checkpoint "
                         "that is already quant-stamped needs no flag)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy (token-for-token the legacy engine); "
                         "> 0 samples device-side in the fused decode step")
    ap.add_argument("--top-k", type=int, default=0,
                    help="keep only the k highest logits (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling mass (1.0 = off)")
    ap.add_argument("--seed", type=int, default=None,
                    help="sampling seed (default: stable per-request rid)")
    ap.add_argument("--sched", default="fcfs", choices=sorted(SCHEDULERS),
                    help="admission policy (serve/scheduler.py)")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV pool with prefix sharing + chunked "
                         "prefill (serve/kvpool.py); needs full causal "
                         "attention in every layer")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV positions per page (paged mode)")
    ap.add_argument("--total-pages", type=int, default=0,
                    help="pool size (0 => dense-equivalent capacity)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="tokens per chunked-prefill tick (0 => largest "
                         "bucket)")
    ap.add_argument("--prefill-every", type=int, default=1,
                    help="run chunked prefill every Nth tick while decodes "
                         "are active (higher => lower decode TPOT tax, "
                         "slower long-prompt TTFT)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable cross-request radix prefix reuse")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-speculative decoding: draft this many "
                         "tokens per tick through a cheap subspace view "
                         "of the SAME weights, verify them in one "
                         "batched forward (0 = off; docs/serving.md)")
    ap.add_argument("--draft", default="int8",
                    help="draft source for --spec-k: 'int8' (packed "
                         "factors) or 'rank:<frac>' (leading slice of "
                         "each site's L/R, e.g. rank:0.5)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are generated plus "
                         "per-request TTFT/TPOT, instead of the batch "
                         "summary only")
    ap.add_argument("--adapters", default="",
                    help="AdapterStore directory (launch/finetune_user.py "
                         "writes it): requests carry per-tenant factored "
                         "deltas hot-swapped from this store")
    ap.add_argument("--tenants", default="",
                    help="comma-separated tenant ids cycled across the "
                         "submitted requests ('' entries = bare base); "
                         "requires --adapters")
    ap.add_argument("--adapter-slots", type=int, default=4,
                    help="device-resident adapter LRU capacity "
                         "(tenant churn past it swaps bank rows, never "
                         "re-jits)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="shard the dense slot pool across an N-device "
                         "mesh (weights replicate, KV slots shard on the "
                         "batch axis; 0 = single device). Excludes "
                         "--paged/--spec-k/--adapters")
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    slots = args.max_slots or min(args.batch, 4)
    max_cache = args.prompt_len + args.tokens + 1
    if args.tenants and not args.adapters:
        raise SystemExit("--tenants needs --adapters DIR")
    tenants = ([t or None for t in args.tenants.split(",")]
               if args.tenants else [None])
    paged_kw = {}
    if args.adapters:
        paged_kw.update(adapters=args.adapters,
                        adapter_slots=args.adapter_slots)
    if args.spec_k:
        paged_kw.update(spec_k=args.spec_k, draft=args.draft)
    if args.paged:
        paged_kw.update(paged=True, page_size=args.page_size,
                        total_pages=args.total_pages or None,
                        prefill_chunk=args.prefill_chunk or None,
                        prefill_every=args.prefill_every,
                        prefix_cache=not args.no_prefix_cache)
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        paged_kw.update(mesh=make_host_mesh(args.mesh))
    if args.ckpt:
        params, plan, _ = api.convert.load_checkpoint(args.ckpt)
        if plan is None:
            raise SystemExit(f"checkpoint at {args.ckpt} carries no plan")
        if args.quant and not plan.is_quantized:
            plan = plan.quantized(args.quant)
            params = api.convert.quantize(params, plan)
        engine = ServeEngine(params, plan=plan, max_slots=slots,
                             max_cache=max_cache, scheduler=args.sched,
                             **paged_kw)
        cfg = engine.cfg
    else:
        cfg = configs.get(args.arch) if args.full \
            else configs.get_smoke(args.arch)
        if args.wasi is not None:
            cfg = cfg.replace(
                wasi=dataclasses.replace(cfg.wasi, method=args.wasi))
        plan = api.install(api.resolve(cfg))
        params = init_lm(key, cfg, jnp.dtype(cfg.dtype))
        if args.quant:
            api.uninstall(cfg)          # the engine installs the quant view
            plan = plan.quantized(args.quant)
            params = api.convert.quantize(params, plan)
        engine = ServeEngine(params, plan=plan, max_slots=slots,
                             max_cache=max_cache, scheduler=args.sched,
                             **paged_kw)
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p, seed=args.seed)
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    t0 = time.time()
    handles = [engine.submit(list(map(int, prompts[i])), max_new=args.tokens,
                             sampling=sp, tenant=tenants[i % len(tenants)])
               for i in range(args.batch)]
    for i, h in enumerate(handles):
        print(f"[serve] rid={h.rid} tenant={tenants[i % len(tenants)]}")
    if args.stream:
        _stream(engine, handles)
    else:
        engine.run()
    dt = time.time() - t0
    s = engine.summary()
    qtag = " quant=int8" if engine.quantized else ""
    stag = "" if sp.is_greedy else (f" T={sp.temperature}"
                                    f" top_k={sp.top_k} top_p={sp.top_p}")
    ptag = ""
    if s["paged"]:
        ptag = (f" paged pg={s['page_size']} pages={s['total_pages']} "
                f"chunks={s['prefill_chunks']} "
                f"prefix_hits={s['prefix_hit_tokens']}")
    if args.mesh:
        ptag += (f" mesh={s['mesh_devices']}dev "
                 f"({s['slots_per_device']} slots, "
                 f"{s['cache_bytes_per_device'] / 2**20:.2f}MiB KV each)")
    print(f"[serve] arch={cfg.name} wasi={cfg.wasi.method}{qtag}{stag} "
          f"sched={s['scheduler']} slots={slots} requests={args.batch} "
          f"wall={dt:.2f}s weights={s['weight_mib']:.2f}MiB "
          f"kv={s['cache_bytes'] / 2**20:.2f}MiB{ptag}")
    print(f"[serve] prefill {s['prefill_tokens']} tok "
          f"({s['prefill_tok_s']:.1f} tok/s, one forward per admission "
          f"group) | decode {s['decode_tokens']} tok "
          f"({s['decode_tok_s']:.1f} tok/s) | "
          f"{s['requests_s']:.2f} req/s")
    if args.adapters:
        t = s["tenancy"]
        print(f"[serve] tenancy resident={','.join(t['resident']) or '-'} "
              f"capacity={t['capacity']} swaps={t['swaps']} "
              f"evictions={t['evictions']} hits={t['hits']} "
              f"bank={t['bank_bytes'] / 2**20:.2f}MiB "
              f"store_tenants={t['store_tenants']}")
    if args.spec_k:
        print(f"[serve] spec k={s['spec_k']} draft={s['draft_source']} "
              f"acceptance_rate={s['acceptance_rate']:.3f} "
              f"tokens_per_verify={s['tokens_per_verify']:.2f} "
              f"verify_steps={s['spec_steps']} "
              f"drafted={s['spec_draft_tokens']} "
              f"accepted={s['spec_accepted_tokens']}")
    print("[serve] sample:", handles[0].tokens)


if __name__ == "__main__":
    main()
