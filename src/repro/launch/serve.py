"""Serving launcher: batched prefill + decode with factored (WASI) weights.

``python -m repro.launch.serve --arch qwen2-0.5b --tokens 32 --batch 4``

Prefill is token-parallel (one forward over the prompt, caches built by a
scan of decode steps for exactness on rolling-window layers); decode is a
jit'd single-token step reused across the generation loop. WASI inference
benefit: every linear runs in the rank-K subspace (paper C_inference /
S_inference — measured by benchmarks/tab2_latency.py).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.models.lm import init_lm, init_lm_cache, lm_decode_step


def generate(params, cfg, prompt, max_cache: int, n_new: int, *, greedy=True,
             key=None):
    """prompt (B, P) -> (B, P + n_new). Warmup = scanned decode steps (exact
    for rolling caches); generation = the same jit'd step."""
    b, p = prompt.shape
    caches = init_lm_cache(cfg, b, max_cache, dtype=jnp.dtype(cfg.dtype))

    step = jax.jit(
        lambda pr, tok, c, pos: lm_decode_step(pr, tok, c, pos, cfg))

    toks = prompt
    logits = None
    for i in range(p):  # prefill via decode steps (small prompts)
        logits, caches = step(params, toks[:, i:i + 1], caches, i)
    out = [toks]
    cur = None
    for j in range(n_new):
        nxt = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(nxt)
        logits, caches = step(params, nxt, caches, p + j)
    return jnp.concatenate(out, axis=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=24)
    ap.add_argument("--wasi", default=None)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.full else configs.get_smoke(args.arch)
    if args.wasi is not None:
        cfg = cfg.replace(wasi=dataclasses.replace(cfg.wasi, method=args.wasi))
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg, jnp.dtype(cfg.dtype))
    prompt = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = generate(params, cfg, prompt,
                   max_cache=args.prompt_len + args.tokens + 1,
                   n_new=args.tokens)
    dt = time.time() - t0
    total_new = args.batch * args.tokens
    print(f"[serve] arch={cfg.name} wasi={cfg.wasi.method} "
          f"generated {total_new} tokens in {dt:.2f}s "
          f"({total_new / dt:.1f} tok/s)")
    print("[serve] sample:", out[0].tolist())


if __name__ == "__main__":
    main()
