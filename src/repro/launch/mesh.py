"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because dryrun.py must set
XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import MeshPolicy


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_policy(*, multi_pod: bool = False, seq_shard: bool = False,
                expert_mode: str = "expert") -> MeshPolicy:
    """Activation-sharding policy matching the production mesh.

    seq_shard=True moves the data axis from batch to sequence (SP) — used by
    prefill_32k (batch 32 < 2*16 data shards would starve) and long_500k
    (batch 1). expert_mode: cfg.moe.shard ("expert"=EP / "ffn"=TP experts).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    if seq_shard:
        return MeshPolicy(batch=(), seq=dp, model="model",
                          expert_mode=expert_mode)
    return MeshPolicy(batch=dp, seq=(), model="model",
                      expert_mode=expert_mode, seq_resid=("model",))
