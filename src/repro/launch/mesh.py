"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because dryrun.py must set
XLA_FLAGS before the first jax initialization.
"""
from __future__ import annotations

import jax

from repro.distributed.sharding import MeshPolicy


def make_host_mesh(n: int | None = None, axis: str = "data"):
    """1-D data-parallel mesh over the visible devices — the mesh the DP
    train step (train/step.py) and mesh ServeEngine actually run on. Under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` this is N
    simulated host devices (tests/test_mesh_parity.py, CI multidevice
    job); on a real slice it is the local accelerators. ``n`` takes the
    first n devices (default: all of them)."""
    count = len(jax.devices()) if n is None else n
    if count > len(jax.devices()):
        raise ValueError(f"asked for a {count}-device mesh but only "
                         f"{len(jax.devices())} devices are visible")
    return jax.sharding.Mesh(jax.devices()[:count], (axis,))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    auto = (jax.sharding.AxisType.Auto,) * len(axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def make_policy(*, multi_pod: bool = False, seq_shard: bool = False,
                expert_mode: str = "expert") -> MeshPolicy:
    """Activation-sharding policy matching the production mesh.

    seq_shard=True moves the data axis from batch to sequence (SP) — used by
    prefill_32k (batch 32 < 2*16 data shards would starve) and long_500k
    (batch 1). expert_mode: cfg.moe.shard ("expert"=EP / "ffn"=TP experts).
    """
    dp = ("pod", "data") if multi_pod else ("data",)
    if seq_shard:
        return MeshPolicy(batch=(), seq=dp, model="model",
                          expert_mode=expert_mode)
    return MeshPolicy(batch=dp, seq=(), model="model",
                      expert_mode=expert_mode, seq_resid=("model",))
