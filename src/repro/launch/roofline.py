"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell:
    compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory term     = HLO_bytes / (chips × HBM_bw)
    collective term = collective_bytes / (chips × link_bw)

Inputs come from launch/dryrun.py JSON records. Conventions VERIFIED on
this backend (see tests/test_roofline.py): cost_analysis() is PER-DEVICE,
counts 2 flops per MAC, and counts while-loop bodies ONCE — so all in-loop
work (the layer-group scans, gradient-accumulation scan) is scaled by its
statically-known trip count, with the vocab head (outside the loops)
estimated analytically and excluded from the scaling.

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (3 links/chip; we charge the busiest-link assumption: all collective
bytes cross one link).

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE): the "useful" lower bound
the compiled-FLOPs ratio is judged against (catches remat / redundancy).
"""
from __future__ import annotations

import argparse
import json

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
ICI_BW = 50e9             # bytes/s / link

MESH_CHIPS = {"16x16": 256, "2x16x16": 512}


def active_params(cfg) -> int:
    """Approximate N (dense) / N_active (MoE) parameter count."""
    d, v, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    dh = cfg.resolved_head_dim
    emb = v * d * (1 if cfg.tie_embeddings else 2)
    if cfg.family == "encdec":
        per = 4 * d * cfg.n_heads * dh + 2 * d * cfg.d_ff
        return emb + cfg.n_enc_layers * per + L * (per + 4 * d * cfg.n_heads * dh)
    att = d * (cfg.n_heads * dh) * 2 + d * (cfg.n_kv_heads * dh) * 2
    if cfg.moe.n_experts:
        f = cfg.moe.expert_d_ff or cfg.d_ff
        ffn_active = 3 * d * f * (cfg.moe.top_k + cfg.moe.n_shared)
    elif cfg.d_ff:
        n_gate = 3 if cfg.mlp_act == "swiglu" else 2
        ffn_active = n_gate * d * cfg.d_ff
    else:
        ffn_active = 0
    ssm = 0
    if any("mamba" in k for g in cfg.groups for k in g.pattern):
        di = cfg.ssm.expand * d
        ssm = 2 * d * di + d * di  # in/out projections (dominant)
        ffn_active = 0 if cfg.d_ff == 0 else ffn_active
    per_layer = att + ffn_active + ssm
    # crude: attention-free archs have no att term
    if all("mamba" in k or k == "mamba2_attn" for g in cfg.groups
           for k in g.pattern):
        per_layer = ssm
    return emb + L * per_layer


def model_flops(cfg, shape) -> float:
    """2-flops-per-MAC (matching cost_analysis): 6·N_active·tokens for
    train (fwd 2 + bwd 4), 2·N_active·tokens forward-only; remat excluded."""
    n = active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6 if shape.kind == "train" else 2
    return mult * n * tokens


def head_flops(cfg, shape) -> float:
    """lm_head matmuls (outside the layer scans): fwd 2·T·d·V; train adds
    dx + dW (3x total)."""
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    f = 2.0 * tokens * cfg.d_model * cfg.padded_vocab
    return 3 * f if shape.kind == "train" else f


def loop_correction(cfg, shape, microbatch: int) -> float:
    """XLA cost_analysis counts while-loop bodies ONCE (verified on this
    backend); scale FLOPs/bytes by the statically-known trip counts: the
    layer-group scans (dominant) and the gradient-accumulation scan."""
    if not cfg.groups:
        total = bodies = max(cfg.n_layers + cfg.n_enc_layers, 1)
        bodies = 2  # enc scan + dec scan compile one body each
    else:
        total = sum(len(g.pattern) * g.repeat for g in cfg.groups)
        bodies = sum(len(g.pattern) for g in cfg.groups)
    factor = total / max(bodies, 1)
    if shape.kind == "train" and microbatch > 1:
        factor *= microbatch
    return max(factor, 1.0)


def roofline_terms(rec: dict, correction: float = 1.0) -> dict:
    # cost_analysis is PER-DEVICE on this backend (verified: sharded matmul
    # reports 2*M*K*N/devices); no further chip division.
    flops = rec["cost"]["flops"] * correction
    bytes_ = rec["cost"]["bytes"] * correction
    coll = rec["collectives"]["total"] * correction
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_ / HBM_BW
    t_coll = coll / ICI_BW          # per-device bytes over one link
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll, "correction": correction}
    dom = max(("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k])
    terms["bottleneck"] = dom.replace("_s", "")
    return terms


def analyze(records: list[dict]) -> list[dict]:
    import repro.configs as configs
    from repro.config import SHAPES

    out = []
    for rec in records:
        if rec.get("status") != "ok":
            out.append(rec)
            continue
        cfg = configs.get(rec["arch"])
        shape = SHAPES[rec["shape"]]
        from repro.launch.dryrun import _train_cfg
        nm = _train_cfg(cfg).microbatch
        corr = loop_correction(cfg, shape, nm)
        chips = MESH_CHIPS[rec["mesh"]]
        # head-aware trip-count correction: the vocab head sits OUTSIDE the
        # layer scans — scale only the in-loop remainder
        hf = head_flops(cfg, shape) / chips
        raw = rec["cost"]["flops"]
        loop_part = max(raw - hf, 0.0)
        rec2 = dict(rec)
        rec2["cost"] = dict(rec["cost"])
        rec2["cost"]["flops"] = hf + loop_part * corr
        rec2["cost"]["bytes"] = rec["cost"]["bytes"] * corr  # loop-dominated
        rec2["collectives"] = dict(rec["collectives"])
        rec2["collectives"]["total"] = rec["collectives"]["total"] * corr
        terms = roofline_terms(rec2, 1.0)
        terms["correction"] = corr
        mf = model_flops(cfg, shape) / chips    # per-device useful FLOPs
        terms["model_flops_per_dev"] = mf
        terms["hlo_flops_per_dev_raw"] = raw
        terms["hlo_flops_per_dev"] = rec2["cost"]["flops"]
        terms["useful_ratio"] = mf / max(terms["hlo_flops_per_dev"], 1.0)
        # roofline fraction: useful work time / achievable step time
        t_star = max(terms["compute_s"], terms["memory_s"], terms["collective_s"])
        terms["roofline_frac"] = (mf / PEAK_FLOPS) / max(t_star, 1e-12)
        out.append({**rec, "roofline": terms})
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="dryrun JSON")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    records = json.load(open(args.results))
    out = analyze(records)
    for r in out:
        if r.get("status") != "ok":
            print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:8s} "
                  f"{r.get('status')}: {r.get('reason', r.get('error', ''))[:60]}")
            continue
        t = r["roofline"]
        print(f"{r['arch']:18s} {r['shape']:12s} {r['mesh']:8s} "
              f"comp={t['compute_s']*1e3:9.2f}ms mem={t['memory_s']*1e3:9.2f}ms "
              f"coll={t['collective_s']*1e3:9.2f}ms dom={t['bottleneck']:10s} "
              f"useful={t['useful_ratio']:.2f} roofline={t['roofline_frac']:.3f}")
    if args.out:
        json.dump(out, open(args.out, "w"), indent=1)


if __name__ == "__main__":
    main()
