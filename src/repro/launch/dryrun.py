import os

os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST precede any other import (jax locks the device
count at first init). Everything below is ordinary code.

Per cell this produces:
  - compiled.memory_analysis()  -> bytes per device (proves it fits)
  - compiled.cost_analysis()    -> HLO FLOPs / bytes for §Roofline
  - collective_bytes            -> summed result sizes of all-gather /
    all-reduce / reduce-scatter / all-to-all / collective-permute in the
    post-SPMD HLO (cost_analysis does not expose these)

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod --out dryrun.json
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.config import SHAPES, ModelConfig, ShapeConfig, TrainConfig
# the HLO collective-bytes parser moved to distributed/collectives.py once
# the real train/serve paths started consuming it too (fig_comm.py,
# grad_compress.measured_collective_savings); re-exported here for callers
# that still import it from the dryrun module
from repro.distributed.collectives import collective_bytes  # noqa: F401
from repro.launch.mesh import make_policy, make_production_mesh
from repro.launch import specs as S


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------

def _loss_fn(cfg: ModelConfig):
    if cfg.family == "encdec":
        from repro.models.encdec import encdec_loss
        return encdec_loss
    from repro.models.lm import lm_loss
    return lm_loss


def _train_cfg(cfg: ModelConfig | None = None) -> TrainConfig:
    # wide/deep/MoE archs take gradient-accumulation microbatches: the
    # per-device activation peak shrinks by the factor (§Perf iter. 8)
    nm = 1
    if cfg is not None:
        wide = cfg.d_model >= 2560 or cfg.moe.n_experts > 0
        deep = (cfg.d_model >= 3584 and cfg.n_layers >= 48) or \
               (cfg.moe.n_experts > 0 and cfg.d_model >= 4096)
        nm = 8 if deep else (4 if wide else 1)
    return TrainConfig(optimizer="adamw", lr=3e-4, steps=10000,
                       clip_norm=1.0, weight_decay=0.1, powersgd_rank=0,
                       microbatch=nm)


def build_train(cfg: ModelConfig, shape: ShapeConfig, mesh, multi_pod: bool):
    from repro.train.step import make_train_state, make_train_step

    policy = make_policy(multi_pod=multi_pod,
                         expert_mode=cfg.moe.shard)
    tcfg = _train_cfg(cfg)
    b, s = shape.global_batch, shape.seq_len
    key = jax.random.PRNGKey(0)
    dtype = jnp.dtype(cfg.dtype)

    def init_all():
        if cfg.family == "encdec":
            from repro.models.encdec import init_encdec, init_encdec_states
            params = init_encdec(key, cfg, dtype)
            asi = init_encdec_states(key, cfg, b, s, dtype) \
                if cfg.wasi.compress_acts else None
        else:
            from repro.models.lm import init_lm, init_lm_states
            params = init_lm(key, cfg, dtype)
            asi = init_lm_states(key, cfg, b, s, dtype) \
                if cfg.wasi.compress_acts else None
        return make_train_state(key, params, cfg, tcfg, asi_states=asi)

    state_sds = jax.eval_shape(init_all)
    state_specs = S.train_state_specs(state_sds, cfg, policy, multi_pod)
    batch_sds = S.train_inputs(cfg, shape)
    batch_specs = S.train_input_specs(cfg, shape, multi_pod)

    step = make_train_step(_loss_fn(cfg), cfg, tcfg, policy=policy)

    ns = lambda spec: NamedSharding(mesh, spec)
    in_sh = (jax.tree.map(ns, state_specs,
                          is_leaf=lambda x: isinstance(x, P)),
             jax.tree.map(ns, batch_specs,
                          is_leaf=lambda x: isinstance(x, P)))
    # state donation: params/opt/ASI buffers update in place (the train
    # loop discards the old state anyway). out_shardings pinned to the input
    # state shardings so every donated buffer actually aliases (auto output
    # shardings may differ -> donation silently skipped).
    jitted = jax.jit(step, in_shardings=in_sh,
                     out_shardings=(in_sh[0], None), donate_argnums=(0,))
    with mesh:
        lowered = jitted.lower(state_sds, batch_sds)
    return lowered


def build_serve(cfg: ModelConfig, shape: ShapeConfig, mesh, multi_pod: bool):
    policy = make_policy(multi_pod=multi_pod,
                         seq_shard=(shape.kind == "prefill"
                                    and shape.global_batch < 32),
                         expert_mode=cfg.moe.shard)
    dtype = jnp.bfloat16
    key = jax.random.PRNGKey(0)

    def init_params():
        if cfg.family == "encdec":
            from repro.models.encdec import init_encdec
            return init_encdec(key, cfg, dtype)
        from repro.models.lm import init_lm
        return init_lm(key, cfg, dtype)

    params_sds = jax.eval_shape(init_params)
    from repro.distributed.sharding import param_specs
    p_specs = param_specs(params_sds, policy)
    ns = lambda spec: NamedSharding(mesh, spec)
    p_sh = jax.tree.map(ns, p_specs, is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "prefill":
        batch_sds = S.prefill_inputs(cfg, shape)
        batch_specs = S.prefill_input_specs(cfg, shape, multi_pod)

        def prefill(params, batch):
            if cfg.family == "encdec":
                from repro.models.encdec import decode_train, encode
                memory, _ = encode(params, batch["frames"], cfg, policy=policy)
                logits, _ = decode_train(params, batch["tokens"], memory, cfg,
                                         policy=policy)
            else:
                from repro.models.lm import lm_forward
                logits, _, _, _ = lm_forward(params, batch["tokens"], cfg,
                                             policy=policy)
            # serving returns only the last position's token scores
            return jnp.argmax(logits[:, -1, :], axis=-1)

        jitted = jax.jit(prefill, in_shardings=(
            p_sh, jax.tree.map(ns, batch_specs,
                               is_leaf=lambda x: isinstance(x, P))))
        with mesh:
            return jitted.lower(params_sds, batch_sds)

    # decode
    caches_sds = S.cache_shapes(cfg, shape)
    c_specs = S.cache_specs(cfg, shape, caches_sds, multi_pod)
    tok_sds, pos_sds, mem_sds = S.decode_inputs(cfg, shape)
    dp = S.dp_axes(multi_pod)
    tok_spec = P() if shape.global_batch == 1 else P(dp, None)

    if cfg.family == "encdec":
        def decode(params, token, caches, pos, memory):
            from repro.models.encdec import encdec_decode_step
            logits, nc = encdec_decode_step(params, token, memory, caches,
                                            pos, cfg, policy=policy)
            return jnp.argmax(logits, axis=-1), nc

        in_sh = (p_sh, ns(tok_spec),
                 jax.tree.map(ns, c_specs, is_leaf=lambda x: isinstance(x, P)),
                 ns(P()), ns(P(dp, None, None) if shape.global_batch > 1 else P()))
        # serving donates the KV cache: in-place update, no double buffer
        jitted = jax.jit(decode, in_shardings=in_sh,
                         out_shardings=(None, in_sh[2]), donate_argnums=(2,))
        with mesh:
            return jitted.lower(params_sds, tok_sds, caches_sds, pos_sds,
                                mem_sds)

    def decode(params, token, caches, pos):
        from repro.models.lm import lm_decode_step
        logits, nc = lm_decode_step(params, token, caches, pos, cfg,
                                    policy=policy)
        return jnp.argmax(logits, axis=-1), nc

    in_sh = (p_sh, ns(tok_spec),
             jax.tree.map(ns, c_specs, is_leaf=lambda x: isinstance(x, P)),
             ns(P()))
    # serving donates the KV cache: in-place update, no double buffer
    jitted = jax.jit(decode, in_shardings=in_sh,
                     out_shardings=(None, in_sh[2]), donate_argnums=(2,))
    with mesh:
        return jitted.lower(params_sds, tok_sds, caches_sds, pos_sds)


# ---------------------------------------------------------------------------
# Cell matrix + runner
# ---------------------------------------------------------------------------

def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k skipped: full quadratic attention (DESIGN §5)"
    if shape.kind == "decode" and not cfg.has_decoder:
        return False, "decode skipped: encoder-only"
    return True, ""


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             wasi_method: str | None = None) -> dict:
    cfg = configs.get(arch)
    if wasi_method is not None:
        import dataclasses
        cfg = cfg.replace(wasi=dataclasses.replace(cfg.wasi, method=wasi_method))
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "wasi": cfg.wasi.method}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    try:
        if shape.kind == "train":
            lowered = build_train(cfg, shape, mesh, multi_pod)
        else:
            lowered = build_serve(cfg, shape, mesh, multi_pod)
        t_lower = time.time() - t0
        t0 = time.time()
        with mesh:
            compiled = lowered.compile()
        t_compile = time.time() - t0
        try:
            ma = compiled.memory_analysis()
            mem = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
            }
            mem["total_bytes_per_device"] = (mem["argument_bytes"]
                                             + mem["temp_bytes"]
                                             + mem["output_bytes"]
                                             - mem["alias_bytes"])
        except Exception as e:  # pragma: no cover
            mem = {"error": str(e)}
        try:
            ca = compiled.cost_analysis()
            cost = {"flops": float(ca.get("flops", 0.0)),
                    "bytes": float(ca.get("bytes accessed", 0.0))}
        except Exception as e:  # pragma: no cover
            cost = {"error": str(e)}
        coll = collective_bytes(compiled.as_text())
        rec.update(status="ok", lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem, cost=cost,
                   collectives=coll)
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}"[:500])
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--wasi", default=None,
                    help="override wasi method: none|wasi|asi|wsi")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    lm_archs = [a for a in configs.list_archs()
                if a not in ("vit-base", "tinyllama-1.1b")]
    if args.all:
        cells = [(a, s) for a in lm_archs for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, wasi_method=args.wasi)
            results.append(rec)
            line = {k: rec.get(k) for k in
                    ("arch", "shape", "mesh", "status", "compile_s")}
            if rec["status"] == "ok":
                line["flops"] = rec["cost"].get("flops")
                line["coll_MiB"] = round(rec["collectives"]["total"] / 2**20, 1)
                line["mem_GiB"] = round(
                    rec["memory"].get("total_bytes_per_device", 0) / 2**30, 2)
            print(json.dumps(line), flush=True)
            if rec["status"] == "error":
                print("  ERROR:", rec["error"], flush=True)
            if args.out:  # incremental write: a crash loses nothing
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    if args.out:
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
