"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU host it trains SMOKE configs end-to-end (the full configs are
exercised by dryrun.py); on a real TPU slice the same entry point runs the
full config — the launcher only switches mesh construction and config
resolution.

Demonstrates the full production loop: mesh + sharded state, checkpoint /
restart (kill it mid-run and relaunch), WASI maintenance, deterministic
data, straggler/heartbeat hooks.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.checkpoint import CheckpointManager
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticAudio, SyntheticLM
from repro.train.loop import train_loop
from repro.train.step import make_train_state, make_train_step


def build(arch: str, *, smoke: bool, batch: int, seq: int, wasi: str | None,
          tcfg: TrainConfig):
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    if wasi is not None:
        cfg = cfg.replace(wasi=dataclasses.replace(cfg.wasi, method=wasi))
    # resolve the subspace plan ONCE (with the training activation-shape
    # hint) and install it — every linear below reads this plan
    plan = api.install(api.resolve(cfg, batch=batch, seq=seq))
    key = jax.random.PRNGKey(tcfg.seed)
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        from repro.models.encdec import encdec_loss, init_encdec, init_encdec_states
        params = init_encdec(key, cfg, dtype)
        asi = init_encdec_states(key, cfg, batch, seq, dtype) \
            if cfg.wasi.compress_acts else None
        loss_fn = encdec_loss
        data = SyntheticAudio(vocab_size=cfg.vocab_size, enc_seq=cfg.enc_seq,
                              d_model=cfg.d_model, seq_len=seq,
                              global_batch=batch, seed=tcfg.seed)
    else:
        from repro.models.lm import init_lm, init_lm_states, lm_loss
        params = init_lm(key, cfg, dtype)
        asi = init_lm_states(key, cfg, batch, seq, dtype) \
            if cfg.wasi.compress_acts else None
        loss_fn = lm_loss
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=seq,
                           global_batch=batch, seed=tcfg.seed)
    state = make_train_state(key, params, cfg, tcfg, asi_states=asi)
    step = make_train_step(loss_fn, cfg, tcfg)
    return cfg, plan, state, step, data


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--wasi", default=None, help="none|wasi|asi|wsi")
    ap.add_argument("--full", action="store_true",
                    help="full (assigned) config instead of smoke")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--memprof", action="store_true",
                    help="log measured memory columns (utils/memprof.py)")
    ap.add_argument("--print-plan", action="store_true",
                    help="print the resolved SubspacePlan and exit")
    args = ap.parse_args()

    tcfg = TrainConfig(optimizer=args.optimizer, lr=args.lr, steps=args.steps,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir or "/tmp/repro_ckpt")
    if args.print_plan:
        # plan resolution is pure config math — skip model/optimizer init
        cfg = configs.get_smoke(args.arch) if not args.full \
            else configs.get(args.arch)
        if args.wasi is not None:
            cfg = cfg.replace(
                wasi=dataclasses.replace(cfg.wasi, method=args.wasi))
        print(api.resolve(cfg, batch=args.batch, seq=args.seq).summary())
        return
    cfg, plan, state, step, data = build(args.arch, smoke=not args.full,
                                         batch=args.batch, seq=args.seq,
                                         wasi=args.wasi, tcfg=tcfg)
    print(f"[train] arch={cfg.name} wasi={cfg.wasi.method} "
          f"params={sum(x.size for x in jax.tree.leaves(state.params)):,}")
    # plan-bearing checkpoints: the manifest carries the resolved plan, so
    # the checkpoint restores for serving / dense export with no config
    ckpt = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints,
                             plan=plan, label="train_state") \
        if args.ckpt_dir else None
    state, hist = train_loop(state, step, lambda s: data.batch(s), tcfg,
                             ckpt=ckpt, memprof=args.memprof)
    print(f"[train] done: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f}")
    if args.memprof:
        print(f"[train] live-bytes watermark: "
              f"{hist[-1]['mem_live_peak_mib']:.1f} MiB")


if __name__ == "__main__":
    main()
