"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU host it trains SMOKE configs end-to-end (the full configs are
exercised by dryrun.py); on a real TPU slice the same entry point runs the
full config — the launcher only switches mesh construction and config
resolution.

Demonstrates the full production loop: mesh + sharded state, checkpoint /
restart (kill it mid-run and relaunch), WASI maintenance, deterministic
data, straggler/heartbeat hooks.

Data comes from the registry (``--data synthetic`` | ``--data
text:<glob>``): text runs stream shard files through the tokenize/pack/
prefetch pipeline with checkpointable reader state — kill a text run
mid-stream, relaunch, and the token stream continues exactly where the
checkpoint left off (``--verify-replay`` proves it on resume by diffing
against a fast-forwarded fresh stream).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro import api
from repro.checkpoint import CheckpointManager, latest_step, restore_extra
from repro.config import TrainConfig
from repro.data.registry import make_dataset
from repro.train.loop import READER_EXTRA, train_loop
from repro.train.step import (
    dp_batch_sharding,
    dp_state_shardings,
    make_train_state,
    make_train_step,
)


def build(arch: str, *, smoke: bool, batch: int, seq: int, wasi: str | None,
          tcfg: TrainConfig, mesh=None, data: str = "synthetic",
          tokenizer: str = "byte"):
    """``mesh`` (a 1-D DP mesh, launch.mesh.make_host_mesh) switches the
    returned step to the shard_map data-parallel path with factor-only
    gradient collectives; the state is built per-replica-aware
    (dp_degree) and pre-placed, and the plan carries its sharding stamp.

    ``data`` is a registry spec; a text dataset's tokenizer may need more
    vocab rows than the smoke config carries, so the config's vocab is
    widened BEFORE plan resolution."""
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    if wasi is not None:
        cfg = cfg.replace(wasi=dataclasses.replace(cfg.wasi, method=wasi))
    dataset = make_dataset(data, cfg, batch=batch, seq=seq, seed=tcfg.seed,
                           **({"tokenizer": tokenizer}
                              if data.startswith("text") else {}))
    dvocab = getattr(dataset, "vocab_size", 0)
    if dvocab and dvocab > cfg.vocab_size:
        cfg = cfg.replace(vocab_size=dvocab)
    # resolve the subspace plan ONCE (with the training activation-shape
    # hint) and install it — every linear below reads this plan
    plan = api.resolve(cfg, batch=batch, seq=seq)
    if mesh is not None:
        plan = plan.with_sharding()
    plan = api.install(plan)
    key = jax.random.PRNGKey(tcfg.seed)
    dtype = jnp.dtype(cfg.dtype)
    if cfg.family == "encdec":
        from repro.models.encdec import encdec_loss, init_encdec, init_encdec_states
        params = init_encdec(key, cfg, dtype)
        asi = init_encdec_states(key, cfg, batch, seq, dtype) \
            if cfg.wasi.compress_acts else None
        loss_fn = encdec_loss
    else:
        from repro.models.lm import init_lm, init_lm_states, lm_loss
        params = init_lm(key, cfg, dtype)
        asi = init_lm_states(key, cfg, batch, seq, dtype) \
            if cfg.wasi.compress_acts else None
        loss_fn = lm_loss
    dp = mesh.devices.size if mesh is not None else 0
    state = make_train_state(key, params, cfg, tcfg, asi_states=asi,
                             dp_degree=dp)
    step = make_train_step(loss_fn, cfg, tcfg, mesh=mesh)
    if mesh is not None:
        if batch % dp:
            raise ValueError(f"--batch {batch} must divide across the "
                             f"{dp}-device mesh")
        state = jax.device_put(state, dp_state_shardings(state, mesh))
    return cfg, plan, state, step, dataset


def verify_replay(dataset, ckpt_dir: str, *, n_check: int = 2,
                  log_fn=print) -> None:
    """Prove resume determinism against the LATEST published checkpoint:
    restore the saved reader state into a fresh stream and assert its next
    batches are elementwise identical to a fresh stream fast-forwarded by
    the checkpoint's step count — the stream an uninterrupted run would be
    consuming."""
    step0 = latest_step(ckpt_dir)
    if step0 is None:
        raise SystemExit("--verify-replay: no published checkpoint in "
                         f"{ckpt_dir}")
    reader = restore_extra(ckpt_dir, step0, READER_EXTRA)
    if reader is None:
        raise SystemExit(f"--verify-replay: checkpoint step {step0} "
                         "carries no reader state (synthetic run?)")
    ref = dataset.stream()
    for _ in range(step0):
        ref.next_batch()
    resumed = dataset.stream()
    resumed.load_state(reader)
    for _ in range(n_check):
        a, b = ref.next_batch(), resumed.next_batch()
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])
    log_fn(f"[train] replay verified: resumed token stream is elementwise "
           f"identical to an uninterrupted run ({n_check} batches checked "
           f"after fast-forwarding {step0} steps)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--optimizer", default="sgd")
    ap.add_argument("--wasi", default=None, help="none|wasi|asi|wsi")
    ap.add_argument("--full", action="store_true",
                    help="full (assigned) config instead of smoke")
    ap.add_argument("--data", default="synthetic",
                    help="dataset spec via data/registry.py: 'synthetic' or "
                         "'text:<shard glob>' (streamed, packed, prefetched)")
    ap.add_argument("--tokenizer", default="byte",
                    help="text tokenizer: 'byte' or 'bpe:<vocab.json>'")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="prefetch depth of the background host->device "
                         "pipeline (text data)")
    ap.add_argument("--verify-replay", action="store_true",
                    help="on resume, assert the restored reader state "
                         "replays the exact token stream, then train")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--memprof", action="store_true",
                    help="log measured memory columns (utils/memprof.py)")
    ap.add_argument("--mesh", type=int, default=0, metavar="N",
                    help="data-parallel over an N-device mesh (factor-only "
                         "gradient collectives; N=0 single device)")
    ap.add_argument("--print-plan", action="store_true",
                    help="print the resolved SubspacePlan and exit")
    args = ap.parse_args()

    tcfg = TrainConfig(optimizer=args.optimizer, lr=args.lr, steps=args.steps,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir or "/tmp/repro_ckpt")
    if args.print_plan:
        # plan resolution is pure config math — skip model/optimizer init
        cfg = configs.get_smoke(args.arch) if not args.full \
            else configs.get(args.arch)
        if args.wasi is not None:
            cfg = cfg.replace(
                wasi=dataclasses.replace(cfg.wasi, method=args.wasi))
        print(api.resolve(cfg, batch=args.batch, seq=args.seq).summary())
        return
    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(args.mesh)
    cfg, plan, state, step, data = build(args.arch, smoke=not args.full,
                                         batch=args.batch, seq=args.seq,
                                         wasi=args.wasi, tcfg=tcfg, mesh=mesh,
                                         data=args.data,
                                         tokenizer=args.tokenizer)
    print(f"[train] arch={cfg.name} wasi={cfg.wasi.method} "
          f"data={args.data} "
          f"params={sum(x.size for x in jax.tree.leaves(state.params)):,}")
    batch_sharding = None
    if mesh is not None:
        batch_sharding = dp_batch_sharding(mesh)
        # MEASURED per-step collective bytes of the compiled DP step — the
        # factor-only communication story as an observation, not a formula
        from repro.distributed.collectives import measured_collective_bytes
        cb = measured_collective_bytes(
            step, state, jax.device_put(data.batch(0), batch_sharding))
        print(f"[train] mesh={mesh.devices.size}dev per-step collective "
              f"bytes: total={cb['total']:,} "
              f"(all-reduce={cb['all-reduce']:,} over {cb['count']} ops)")
    # plan-bearing checkpoints: the manifest carries the resolved plan, so
    # the checkpoint restores for serving / dense export with no config
    ckpt = CheckpointManager(tcfg.checkpoint_dir, keep=tcfg.keep_checkpoints,
                             plan=plan, label="train_state") \
        if args.ckpt_dir else None
    streaming = hasattr(data, "iterator")
    if streaming:
        if args.verify_replay:
            if ckpt is None:
                raise SystemExit("--verify-replay needs --ckpt-dir")
            verify_replay(data, tcfg.checkpoint_dir)
        feed = data.iterator(sharding=batch_sharding,
                             prefetch=args.prefetch)
    else:
        if args.verify_replay:
            raise SystemExit("--verify-replay only applies to streamed "
                             "(text) data — synthetic batches are a pure "
                             "function of (seed, step)")
        feed = lambda s: data.batch(s)
    try:
        state, hist = train_loop(
            state, step, feed, tcfg, ckpt=ckpt, memprof=args.memprof,
            batch_sharding=None if streaming else batch_sharding)
    finally:
        if streaming:
            feed.close()
    if hist:
        print(f"[train] done: loss {hist[0]['loss']:.4f} -> "
              f"{hist[-1]['loss']:.4f}")
    else:
        print(f"[train] already trained to step {int(state.step)}")
    if streaming and hist:
        s = feed.stats()
        print(f"[train] input pipeline: {s['tok_s']:,.0f} tok/s "
              f"stall_frac={s['stall_frac']:.3f} over {s['batches']} batches")
    if args.memprof:
        print(f"[train] live-bytes watermark: "
              f"{hist[-1]['mem_live_peak_mib']:.1f} MiB")


if __name__ == "__main__":
    main()
