"""Per-user adapter fine-tuning CLI: checkpointed base -> AdapterStore.

``python -m repro.launch.finetune_user --ckpt /tmp/repro_example_lm_smoke
  --tenant alice --store /tmp/adapters --steps 40``

The on-device personalization loop (ROADMAP open item 2): load a
plan-bearing checkpoint, FREEZE it, train only the per-site rank-K_a
delta pair on that tenant's stream (``--data`` via data/registry.py:
``for_tenant`` skews the synthetic topic mixture, or filters a text
corpus to the tenant's sub-corpus — a real shift to learn either way),
and register the result — a few hundred KB, not a model copy — in the
content-addressed store ``launch/serve --adapters`` hot-swaps from.

``--check`` turns the run into an acceptance test: exit non-zero unless
the adapter's CE on the tenant's held-out stream beats the frozen base's.
"""
from __future__ import annotations

import argparse

from repro import api
from repro.data.registry import make_dataset
from repro.tenancy import (AdapterStore, eval_ce, finetune_adapters,
                           merge_adapters)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default="/tmp/repro_example_lm_smoke",
                    help="plan-bearing base checkpoint dir "
                         "(examples/train_lm.py --smoke writes one)")
    ap.add_argument("--tenant", required=True,
                    help="tenant id ([A-Za-z0-9._-]); also seeds the "
                         "tenant's synthetic stream")
    ap.add_argument("--store", default="/tmp/repro_adapters",
                    help="AdapterStore root to register the adapter in")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--rank-frac", type=float, default=0.25,
                    help="adapter rank fraction per site "
                         "(SubspacePlan.with_adapter)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="synthetic",
                    help="tenant stream via data/registry.py: 'synthetic' "
                         "(topic-skewed SyntheticLM) or 'text:<shard glob>' "
                         "(the tenant's filtered sub-corpus)")
    ap.add_argument("--quant", default="", choices=["", "int8"],
                    help="pack the STORED adapter int8 (training stays f32; "
                         "serve loads it dequantized)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless adapter CE < frozen-base CE on the "
                         "tenant's held-out stream")
    args = ap.parse_args()

    params, plan, step = api.convert.load_checkpoint(args.ckpt)
    if plan is None:
        raise SystemExit(f"checkpoint at {args.ckpt} carries no plan")
    aplan = plan.with_adapter(args.rank_frac)
    cfg = plan.model
    # one construction path for every dataset kind; for_tenant is the
    # per-user seam on both (topic skew / per-tenant corpus filter)
    data = make_dataset(args.data, cfg, batch=args.batch, seq=args.seq,
                        seed=args.seed).for_tenant(args.tenant)
    dvocab = getattr(data, "vocab_size", 0)
    if dvocab and dvocab > cfg.vocab_size:
        raise SystemExit(
            f"--data {args.data}: tokenizer vocab {dvocab} exceeds the "
            f"checkpointed model's vocab {cfg.vocab_size} — fine-tune from "
            "a base trained on this corpus (launch/train --data)")

    adapters, metrics = finetune_adapters(
        params, aplan, data, steps=args.steps, seed=args.seed,
        log_every=max(args.steps // 4, 1))
    base_ce = eval_ce(params, cfg, data)
    adapter_ce = eval_ce(merge_adapters(params, adapters), cfg, data)

    store = AdapterStore(args.store)
    meta = store.save(args.tenant, adapters, aplan,
                      fmt=args.quant or "f32",
                      extra={"base_step": step, "steps": args.steps,
                             "base_ce": base_ce, "adapter_ce": adapter_ce})
    print(f"[finetune_user] tenant={args.tenant} base_step={step} "
          f"steps={args.steps} rank_frac={args.rank_frac}")
    print(f"[finetune_user] base_ce={base_ce:.4f} "
          f"adapter_ce={adapter_ce:.4f} "
          f"delta={base_ce - adapter_ce:+.4f}")
    print(f"[finetune_user] stored format={meta['format']} "
          f"bytes={meta['bytes']} ({meta['bytes'] / 2**20:.4f} MiB) "
          f"object={meta['object'][:12]} store={args.store}")
    if args.check and not adapter_ce < base_ce:
        raise SystemExit(
            f"--check failed: adapter CE {adapter_ce:.4f} does not beat "
            f"frozen base CE {base_ce:.4f} on tenant {args.tenant!r}")


if __name__ == "__main__":
    main()
