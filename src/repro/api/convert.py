"""Model-level dense<->factored conversion + plan-bearing checkpoints.

``factorize(dense_params, plan)`` rewrites every plan-covered linear into
its planned layout (truncated SVD per spec — paper Alg. 1 t=0), including
the paper's *project* mode ({"w","L","R"}: dense weight kept, factors
carried) which the legacy ``init_linear_from_dense`` could not emit.
``densify(params, plan)`` is the inverse (L@R for factored sites, factor
drop for project sites), so a trained factored checkpoint exports to a
dense one any framework can load. ``quantize(params, plan)`` packs the
quant-stamped sites of a deployment plan (``plan.quantized("int8")``) to
int8 + per-channel scales — the last conversion before edge serving
(docs/deployment.md).

The plan itself serializes into the checkpoint manifest
(``checkpoint.save_checkpoint(..., plan=...)``), making a checkpoint
self-describing: ``load_checkpoint(dir)`` rebuilds (params, plan) with no
config in hand — loadable for training, serving (ServeEngine
.from_checkpoint), or dense export.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api.bind import (
    is_linear_params,
    is_quantized,
    linear_dims,
    linear_layout,
)
from repro.api.plan import LEAF_TO_SPEC, LinearSpec, SubspacePlan
from repro.checkpoint.ckpt import (
    latest_step,
    load_manifest,
    restore_untyped,
)


# ---------------------------------------------------------------------------
# Truncated SVD over (possibly stacked) weights
# ---------------------------------------------------------------------------

def _svd_factors(w, k: int):
    """W (..., O, I) -> (L (..., O, K), R (..., K, I)) by truncated SVD.
    Batched over leading stack dims (scan repeats, expert banks)."""
    u, s, vt = jnp.linalg.svd(jnp.asarray(w, jnp.float32),
                              full_matrices=False)
    L = u[..., :, :k] * s[..., None, :k]
    R = vt[..., :k, :]
    return L.astype(w.dtype), R.astype(w.dtype)


def factorize_linear(w, spec: LinearSpec, bias=None) -> dict:
    """One dense weight -> the param layout its spec dictates."""
    p: dict = {}
    if spec.mode == "factored":
        p["L"], p["R"] = _svd_factors(w, spec.rank)
    elif spec.mode == "project":
        p["w"] = w
        p["L"], p["R"] = _svd_factors(w, spec.rank)
    else:
        p["w"] = w
    if bias is not None:
        p["b"] = bias
    return p


def densify_linear(p: dict, spec: LinearSpec) -> dict:
    """Inverse of :func:`factorize_linear` (rank-truncation is lossy for
    factored sites, exact for project/dense; int8 sites dequantize first,
    lossy by the quantization error)."""
    if is_quantized(p):
        from repro.quant.quantize import dequantize_linear
        p = dequantize_linear(p, spec)
    out: dict = {}
    if linear_layout(p) == "factored":
        out["w"] = jnp.einsum("...ok,...ki->...oi", p["L"], p["R"]).astype(
            p["L"].dtype)
    else:
        out["w"] = p["w"]
    if p.get("b") is not None:
        out["b"] = p["b"]
    return out


def _walk_linears(tree, plan: SubspacePlan, fn):
    """Apply fn(spec, linear_dict) to every plan-covered linear dict in a
    param tree; everything else (norms, convs, embeddings, heads) passes
    through untouched."""
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, v in node.items():
                if key in LEAF_TO_SPEC and is_linear_params(v):
                    name, role = LEAF_TO_SPEC[key]
                    o, i = linear_dims(v)
                    out[key] = fn(plan.linear(name, i, o, role=role), v)
                else:
                    out[key] = walk(v)
            return out
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node

    return walk(tree)


def factorize(dense_params, plan: SubspacePlan):
    """Dense param tree -> the plan's layouts (factored {L,R}, project
    {w,L,R}, dense passthrough). Generalizes ``init_linear_from_dense`` to
    whole models and to project mode."""
    def one(spec, p):
        if linear_layout(p) != "dense" or is_quantized(p):
            raise ValueError(f"site {spec.name} already factored or "
                             "quantized; factorize expects a dense f32 tree")
        return factorize_linear(p["w"], spec, bias=p.get("b"))

    return _walk_linears(dense_params, plan, one)


def densify(params, plan: SubspacePlan):
    """Any plan-layout param tree -> fully dense ({"w"} everywhere)."""
    return _walk_linears(params, plan, lambda spec, p: densify_linear(p, spec))


def quantize(params, plan: SubspacePlan):
    """Pack every quant-stamped site to int8 + per-channel f32 scales.

    ``plan`` must be the deployment view (``plan.quantized("int8")``) —
    sites whose spec carries no ``quant`` pass through untouched, so the
    same walk serves mixed-precision plans. Layouts after packing
    (quant/quantize.py): factored {L,sL,R,sR}, dense {w,sW}; biases stay
    f32. Save the result with ``plan=plan`` and the checkpoint is a
    self-describing int8 deployment artifact
    (``ServeEngine.from_checkpoint`` needs nothing else in hand)."""
    from repro.quant.quantize import quantize_linear

    return _walk_linears(params, plan,
                         lambda spec, p: quantize_linear(p, spec))


def draft_view(params, plan: SubspacePlan):
    """The speculative-decoding draft param tree for a draft-stamped plan
    (``plan.with_draft(...)``).

    int8 drafts pack every draft-stamped site to int8 + per-channel scales
    (or pass through sites that are ALREADY int8-resident — then the draft
    literally is the serving weights); ``rank:<k>`` drafts slice the
    leading k columns/rows of each factored site's resident L/R. Either
    way the result aliases or derives from the same weights the verify
    pass runs — no second model is loaded (docs/serving.md)."""
    import dataclasses

    from repro.api.bind import draft_slice
    from repro.quant.quantize import quantize_linear

    def one(spec, p):
        if spec.draft is None:
            return p
        if spec.draft == "int8":
            if is_quantized(p):
                return p
            return quantize_linear(p, dataclasses.replace(spec, quant="int8"))
        k = int(spec.draft.split(":", 1)[1])
        if linear_layout(p) != "factored":
            return p
        return draft_slice(p, k)

    return _walk_linears(params, plan, one)


def dequantize(params, plan: SubspacePlan):
    """Inverse of :func:`quantize` (lossy by the quantization error):
    int8 sites back to their f32 layouts, everything else untouched."""
    from repro.quant.quantize import dequantize_linear

    return _walk_linears(params, plan,
                         lambda spec, p: dequantize_linear(p, spec))


# ---------------------------------------------------------------------------
# Plan-bearing checkpoints
# ---------------------------------------------------------------------------

def load_plan(ckpt_dir: str, step: int | None = None) -> SubspacePlan | None:
    """The plan stored in a checkpoint's manifest, or None."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        return None
    m = load_manifest(ckpt_dir, step)
    return SubspacePlan.from_json(m["plan"]) if m.get("plan") else None


def load_checkpoint(ckpt_dir: str, step: int | None = None):
    """Template-free restore of a plan-bearing checkpoint.

    Returns (params, plan, step). Works on params-only checkpoints and on
    full train-state checkpoints (manifest label "train_state": params are
    the state's first field). The plan in the manifest carries the full
    ModelConfig, so nothing else is needed to serve, fine-tune, or
    dense-export the restored weights."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    m = load_manifest(ckpt_dir, step)
    tree = restore_untyped(ckpt_dir, step)
    if m.get("label") == "train_state":
        tree = tree[0]          # TrainState.params
    plan = SubspacePlan.from_json(m["plan"]) if m.get("plan") else None
    return tree, plan, step


def export_dense(ckpt_dir: str, step: int | None = None):
    """(dense_params, plan, step) from a plan-bearing checkpoint — the
    dense-export path for downstream consumers."""
    params, plan, step = load_checkpoint(ckpt_dir, step)
    if plan is None:
        raise ValueError(f"checkpoint at {ckpt_dir} carries no plan; "
                         "cannot infer factored sites")
    return densify(params, plan), plan, step
