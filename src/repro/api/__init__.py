"""Declarative SubspacePlan API: one plan -> init / apply / convert /
checkpoint, shared by train and serve.

    from repro import api

    plan = api.resolve(cfg, batch=B, seq=S)        # decide subspaces ONCE
    api.install(plan)                              # model internals read it
    params = init_lm(key, cfg)                     # plan-driven layouts
    factored = api.convert.factorize(dense, plan)  # pretrained -> subspace
    # ... checkpoint with plan=...; ServeEngine.from_checkpoint(dir)

See docs/api.md for the full lifecycle.
"""
from repro.api import bind, convert, plan
from repro.api.plan import (
    LinearSpec,
    SubspacePlan,
    install,
    plan_of,
    resolve,
    resolve_linear_spec,
    role_treated,
    uninstall,
)

__all__ = [
    "LinearSpec",
    "SubspacePlan",
    "bind",
    "convert",
    "install",
    "plan",
    "plan_of",
    "resolve",
    "resolve_linear_spec",
    "role_treated",
    "uninstall",
]
