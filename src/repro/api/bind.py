"""Plan-driven linear init/apply + the ONLY place allowed to look at raw
param-dict keys.

``init_params`` / ``apply`` replace the cfg-threaded ``nn.linear``
entry points: dispatch is on the typed :class:`~repro.api.plan.LinearSpec`
(mode, rank, kernel route), not on ``"L" in p`` sniffing. Param layouts are
unchanged plain pytrees:

    dense:    {"w": (O, I) [, "b"]}
    factored: {"L": (O, K), "R": (K, I) [, "b"]}
    project:  {"w": (O, I) [, "L", "R"]}   (factors injected per-step by
              core/project.py, or carried by a converted checkpoint)

What each path saves for backward is unchanged (the sketch-saving contract,
docs/training.md): Tucker x~ + rank-K sketch for WASI, x + dense sketch via
the fused kernel for factored-no-ASI, dense x for vanilla.

Everything else in the tree that must walk param structure by key
(factored-refresh mapping, project-factor injection/extraction, legacy
param inspection) lives here too, so no other module dispatches on keys.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.api.plan import (
    LinearSpec,
    SubspacePlan,
    _act_mode_ranks,
    resolve_linear_spec,
    role_treated,
)
from repro.config import WasiConfig
from repro.core.asi import ASIState, asi_init, asi_project, asi_step
from repro.core.lowrank_linear import (
    asi_matmul,
    wasi_matmul,
    wasi_matmul_project,
    wsi_matmul_project_exact,
)
from repro.core.wsi import WSIState


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, spec: LinearSpec, *, dtype=jnp.float32,
                scale: float | None = None, bias: bool | None = None) -> dict:
    """Random init for one linear site, in the layout its spec dictates.
    RNG consumption matches the historical ``nn.linear.init_linear`` so
    seeded runs reproduce across the API change."""
    std = scale if scale is not None else spec.in_dim ** -0.5
    with_bias = spec.bias if bias is None else bias
    kw, kb = jax.random.split(key)
    p: dict = {}
    if spec.mode == "factored":
        k = spec.rank
        kl, kr = jax.random.split(kw)
        split = (std / k ** 0.5) ** 0.5
        p["L"] = (jax.random.normal(kl, (spec.out_dim, k), jnp.float32)
                  * split).astype(dtype)
        p["R"] = (jax.random.normal(kr, (k, spec.in_dim), jnp.float32)
                  * split).astype(dtype)
    else:
        # project mode inits DENSE; its (L, R) live in WSI states (train) or
        # arrive via convert.factorize (checkpoints)
        p["w"] = (jax.random.normal(kw, (spec.out_dim, spec.in_dim),
                                    jnp.float32) * std).astype(dtype)
    if with_bias:
        p["b"] = jnp.zeros((spec.out_dim,), dtype)
    return p


def asi_state(key, act_shape: Sequence[int], wasi: WasiConfig,
              dtype=jnp.float32) -> ASIState | None:
    """Warm-start ASI state for a linear whose input activation has
    ``act_shape`` (B, N, I) or (B, H, W, I). None if compression is off."""
    if not wasi.compress_acts:
        return None
    ranks = _act_mode_ranks(tuple(act_shape), wasi)
    return asi_init(key, act_shape, ranks, dtype)


def init_state(key, spec: LinearSpec, act_shape: Sequence[int],
               wasi: WasiConfig, dtype=jnp.float32) -> ASIState | None:
    """Per-spec ASI warm-start state; None when this site's activations
    stay dense under the plan."""
    if not (wasi.compress_acts and role_treated(wasi, spec.role)):
        return None
    return asi_state(key, act_shape, wasi, dtype)


# ---------------------------------------------------------------------------
# apply
# ---------------------------------------------------------------------------

def apply(spec: LinearSpec, p: dict, x: jax.Array, wasi: WasiConfig,
          state: ASIState | None = None):
    """Apply one linear site per its spec. Returns (y, new_state) —
    new_state is None when no ASI state is involved."""
    new_state = None

    def compress(x_):
        if wasi.asi.frozen:
            return asi_project(jax.lax.stop_gradient(x_), state), state
        return asi_step(jax.lax.stop_gradient(x_), state)

    if is_quantized(p):
        # int8 deployment path (plan.quantized + convert.quantize): weights
        # are {L,sL,R,sR} / {w,sW}; scales fold into the matmul, and the
        # fused int8 kernel keeps factors VMEM-resident on TPU
        if spec.quant is None and spec.draft != "int8":
            # A draft-stamped spec legitimately sees BOTH layouts: f32
            # master params on the verify pass, int8-packed draft params
            # on the draft pass (serve/engine.py builds the latter).
            raise ValueError(
                f"site {spec.name}: params are quantized but the spec is "
                "not — serve under plan.quantized(...) (docs/deployment.md)")
        if state is not None:
            raise ValueError(
                f"site {spec.name}: quantized params are serve-only; ASI "
                "states cannot thread through an int8 site")
        from repro.kernels.ops import dense_matmul_q8, lowrank_matmul_q8
        if "L" in p:
            y = lowrank_matmul_q8(x, p["R"], p["sR"], p["L"], p["sL"])
        else:
            y = dense_matmul_q8(x, p["w"], p["sW"])
    elif spec.quant is not None:
        raise ValueError(
            f"site {spec.name}: plan stamps quant={spec.quant!r} but the "
            "params are not packed — run convert.quantize(params, plan)")
    elif spec.mode == "project" and "L" in p:
        # factored forward, dense-W gradient (paper Eq. 9-11); factors come
        # from the per-step WSI injection or a converted checkpoint
        if state is not None:
            xt, new_state = compress(x)
            y = wasi_matmul_project(x, p["w"], p["L"], p["R"], xt)
        else:
            y = wsi_matmul_project_exact(x, p["w"], p["L"], p["R"])
    elif spec.mode == "factored":
        if state is not None:
            xt, new_state = compress(x)
            y = wasi_matmul(x, p["L"], p["R"], xt)
        else:
            # no-ASI factored path (serving, `wsi` factored training)
            if spec.kernel == "fused_lowrank":
                # fused Pallas kernel on TPU, XLA einsum pair elsewhere
                from repro.kernels.ops import lowrank_matmul
                y = lowrank_matmul(x, p["R"], p["L"])
            else:
                h = jnp.einsum("...i,ki->...k", x, p["R"])
                y = jnp.einsum("...k,ok->...o", h, p["L"])
    else:
        # dense weights (vanilla, ASI baseline, or un-injected project)
        if state is not None:
            xt, new_state = compress(x)
            y = asi_matmul(x, p["w"], xt)
        else:
            y = jnp.einsum("...i,oi->...o", x, p["w"])
    if "La" in p:
        y = y + adapter_delta(x, p["La"], p["Ra"])
    if "b" in p:
        y = y + p["b"]
    return y, new_state


def adapter_delta(x, La, Ra):
    """The per-tenant additive delta ``x R_u^T L_u^T`` (repro/tenancy/).

    Two layouts, told apart by rank alone: the single-tenant pair
    La (O, K_a) / Ra (K_a, I) routes through the same fused lowrank kernel
    the factored sites use; a per-slot GATHERED bank row — La (B, O, K_a) /
    Ra (B, K_a, I), one tenant's factors per batch row, selected inside the
    serve engine's jitted step — contracts per row so one executable serves
    any mix of tenants. A zero pair contributes exactly zero, which is how
    the engine's identity row serves adapter-less slots."""
    if La.ndim == x.ndim:
        h = jnp.einsum("b...i,bki->b...k", x, Ra)
        return jnp.einsum("b...k,bok->b...o", h, La)
    from repro.kernels.ops import lowrank_matmul
    return lowrank_matmul(x, Ra, La)


def linear_out_dim(p: dict) -> int:
    return p["L"].shape[0] if "L" in p else p["w"].shape[0]


def linear_layout(p: dict) -> str:
    """The subspace layout a param dict is in: "dense" | "factored" |
    "project". The canonical key-inspection entry for api.convert."""
    if "L" in p and "w" in p:
        return "project"
    if "L" in p:
        return "factored"
    return "dense"


def is_linear_params(v) -> bool:
    """Does ``v`` look like one linear's param dict (any layout)?"""
    return isinstance(v, dict) and ("w" in v or "L" in v)


def is_quantized(p: dict) -> bool:
    """Is this linear dict in an int8-packed layout (quant/quantize.py:
    scales ride next to the int8 payload as sL/sR/sW)?"""
    return "sL" in p or "sW" in p


def is_adapter_params(v) -> bool:
    """Does ``v`` carry a per-tenant adapter pair (repro/tenancy/)? True
    for both a pure adapter dict ({"La","Ra"}) and a merged linear dict
    that carries the delta next to its base weights."""
    return isinstance(v, dict) and "La" in v


def draft_slice(p: dict, k: int) -> dict:
    """The rank-k draft view of a factored linear dict: the leading k
    columns of L and rows of R (plus the matching sR rows when the site is
    int8-packed — sL scales one-per-output-channel and is untouched).
    These are slices of the ALREADY-RESIDENT factors: the draft model
    costs zero extra weights (docs/serving.md)."""
    out = dict(p)
    out["L"] = p["L"][..., :k]
    out["R"] = p["R"][..., :k, :]
    if "sR" in p:
        out["sR"] = p["sR"][..., :k]
    return out


def dense_weight(v):
    """The dense (…, O, I) weight of a dense-layout linear dict, else
    None (used by plan calibration, which only reads dense trees)."""
    if isinstance(v, dict) and "w" in v and getattr(v["w"], "ndim", 0) >= 2:
        return v["w"]
    return None


def linear_dims(p: dict) -> tuple[int, int]:
    """(out_dim, in_dim) of a linear param dict in any layout."""
    if linear_layout(p) == "factored":
        return int(p["L"].shape[-2]), int(p["R"].shape[-1])
    return int(p["w"].shape[-2]), int(p["w"].shape[-1])


def infer_spec(p: dict, wasi: WasiConfig, *, role: str = "mlp",
               name: str = "adhoc") -> LinearSpec:
    """Bridge for the legacy dict-first API: recover a spec from a param
    dict's layout. Mode comes from the keys (the one sanctioned place),
    dims/rank from the shapes, kernel route from the plan policy."""
    if "L" in p and "w" in p:
        mode, rank = "project", p["L"].shape[-1]
        out_dim, in_dim = p["w"].shape[-2:]
    elif "L" in p:
        mode, rank = "factored", p["L"].shape[-1]
        out_dim, in_dim = p["L"].shape[-2], p["R"].shape[-1]
    else:
        mode, rank = "dense", 0
        out_dim, in_dim = p["w"].shape[-2:]
    return LinearSpec(name=name, role=role, in_dim=int(in_dim),
                      out_dim=int(out_dim), mode=mode, rank=int(rank),
                      bias="b" in p,
                      kernel="fused_lowrank" if mode == "factored"
                      else "einsum",
                      quant="int8" if is_quantized(p) else None)


# ---------------------------------------------------------------------------
# Structure-walking helpers (the key-dispatch monopoly)
# ---------------------------------------------------------------------------

def iter_linear_dicts(tree, prefix: str = ""):
    """Yield (path, linear_dict) for every linear param dict in a tree —
    the sanctioned walk for consumers that only need per-site accounting
    (utils/memprof.py), never dispatch."""
    if isinstance(tree, dict):
        if is_linear_params(tree):
            yield prefix, tree
            return
        for k, v in tree.items():
            yield from iter_linear_dicts(v, f"{prefix}/{k}" if prefix else k)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from iter_linear_dicts(v, f"{prefix}/{i}" if prefix else str(i))


def iter_adapter_dicts(tree, prefix: str = ""):
    """Yield (path, dict) for every adapter-pair-bearing dict in a tree.
    Walks pure adapter trees ({"La","Ra"} at the sites, repro/tenancy/)
    and merged param trees (delta riding next to base weights) alike —
    the sanctioned walk for per-tenant byte accounting."""
    if isinstance(tree, dict):
        if "La" in tree:
            yield prefix, tree
            return
        if is_linear_params(tree):
            return
        for k, v in tree.items():
            yield from iter_adapter_dicts(v, f"{prefix}/{k}" if prefix else k)
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from iter_adapter_dicts(
                v, f"{prefix}/{i}" if prefix else str(i))


def linear_param_bytes(p: dict) -> dict:
    """Storage of one linear dict, split by payload kind:
    {"weights": .., "scales": .., "bias": ..} bytes. Quantized layouts show
    their packing win in the weights/scales split."""
    import numpy as np

    def nbytes(a) -> int:
        return int(np.prod(a.shape)) * np.dtype(a.dtype).itemsize

    out = {"weights": 0, "scales": 0, "bias": 0,
           "adapter_weights": 0, "adapter_scales": 0}
    for k, v in p.items():
        if k in ("w", "L", "R"):
            out["weights"] += nbytes(v)
        elif k in ("sW", "sL", "sR"):
            out["scales"] += nbytes(v)
        elif k in ("La", "Ra"):
            out["adapter_weights"] += nbytes(v)
        elif k in ("sLa", "sRa"):
            out["adapter_scales"] += nbytes(v)
        elif k == "b":
            out["bias"] += nbytes(v)
    return out


def map_factored(params, fn):
    """Apply fn(WSIState) -> WSIState to every {L, R} factor pair in a
    param tree (factored-mode WSI refresh)."""
    def walk(node):
        if isinstance(node, dict):
            if "L" in node and "R" in node and "w" not in node \
                    and "sL" not in node:  # int8 factors are serve-frozen
                st = fn(WSIState(L=node["L"], R=node["R"]))
                out = dict(node)
                out["L"], out["R"] = st.L, st.R
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple) and not hasattr(node, "_fields"):
            return tuple(walk(v) for v in node)
        return node

    return walk(params)


def inject_factors(params, states: dict):
    """Insert (L, R) from ``states`` (path-keyed WSIState dict, paths ending
    "/w") next to each dense W so ``apply`` takes the project path."""
    def patch(node, prefix=""):
        if isinstance(node, dict):
            if "w" in node and prefix + "/w" in states:
                st = states[prefix + "/w"]
                node = dict(node)
                node["L"] = jax.lax.stop_gradient(st.L)
                node["R"] = jax.lax.stop_gradient(st.R)
                return node
            return {k: patch(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [patch(v, f"{prefix}/{i}" if prefix else str(i))
                 for i, v in enumerate(node)]
            return type(node)(t) if not isinstance(node, tuple) else tuple(t)
        return node

    return patch(params)


def extract_project_factors(params):
    """Split converted project-mode params {"w","L","R"} into a dense param
    tree plus a path-keyed {".../w": WSIState} dict (same keying as
    core/project.init_project_states) for warm-starting the WSI states.
    Trees without carried factors return (params, {})."""
    factors: dict[str, WSIState] = {}

    def strip(node, prefix=""):
        if isinstance(node, dict):
            if "w" in node and "L" in node and "R" in node:
                factors[prefix + "/w"] = WSIState(L=node["L"], R=node["R"])
                return {k: v for k, v in node.items() if k not in ("L", "R")}
            return {k: strip(v, f"{prefix}/{k}" if prefix else k)
                    for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [strip(v, f"{prefix}/{i}" if prefix else str(i))
                 for i, v in enumerate(node)]
            return t if isinstance(node, list) else tuple(t)
        return node

    stripped = strip(params)
    return (stripped, factors) if factors else (params, {})


def plan_param_specs(params, plan: SubspacePlan, policy=None, rules=None):
    """Pytree of PartitionSpecs for ``params``, PLAN-DRIVEN: sites whose
    spec carries a ``sharding`` stamp (SubspacePlan.with_sharding) use it
    verbatim — the plan owns placement the same way it owns mode/rank —
    and everything else (embeddings, norms, unstamped plans) falls back to
    the distributed/sharding.py path-rule table. Stacked scan layers pad
    leading replicated axes, exactly like spec_for_path."""
    from jax.sharding import PartitionSpec as P

    from repro.api.plan import LEAF_TO_SPEC
    from repro.distributed.sharding import (
        LM_RULES,
        MeshPolicy,
        _path_str,
        spec_for_path,
    )

    policy = policy if policy is not None else MeshPolicy()
    rules = rules if rules is not None else LM_RULES
    stamped = {s.name: dict(s.sharding) for s in plan.specs
               if s.sharding is not None}

    def one(path, leaf):
        ps = _path_str(path)
        parts = ps.split("/")
        if len(parts) >= 2:
            site = LEAF_TO_SPEC.get(parts[-2], (None, None))[0]
            entries = stamped.get(site, {}).get(parts[-1])
            if entries is not None:
                e = tuple(entries)
                nd = getattr(leaf, "ndim", len(e))
                if nd > len(e):
                    e = (None,) * (nd - len(e)) + e
                elif nd < len(e):
                    e = e[-nd:] if nd else ()
                return P(*e)
        return spec_for_path(ps, leaf, policy, rules)

    return jax.tree_util.tree_map_with_path(one, params)
