"""Declarative subspace plan: which subspace each linear lives in, decided
ONCE per model.

The paper's claim is that a model's essential information lives in a fixed
per-layer subspace. Before this module the repro re-decided *which* subspace
(mode, rank, ASI shape, kernel route) ad hoc at every call site by sniffing
param dict keys. A :class:`SubspacePlan` is the single resolved answer:

    plan = resolve(cfg)                       # static rank policy
    plan = resolve(cfg, calibration=params)   # per-site eps-ranks (Alg. 1 t=0)

and every consumer — ``api.bind`` (init/apply), ``api.convert``
(dense<->factored), the checkpoint manifest, the serve engine, benchmarks —
reads the plan instead of re-deriving policy. ``plan_of(cfg)`` memoizes the
static resolution per (hashable, frozen) ``ModelConfig``; ``install(plan)``
overrides it with an explicitly resolved plan (e.g. calibrated ranks) so
deep model code picks the same plan up without threading a new argument
through every scan body.

A :class:`LinearSpec` names one linear *site* (e.g. ``mlp/up``): sites are
shared across stacked/scanned layers — per-layer heterogeneity inside a
scan would break XLA static shapes, so calibrated ranks take the max over a
site's stack, exactly as ``core/project.py`` always did.
"""
from __future__ import annotations

import dataclasses
import functools
import json
from dataclasses import dataclass, field
from typing import Any, Iterable, Literal, Mapping, Sequence

from repro.config import (
    AsiConfig,
    LayerGroup,
    ModelConfig,
    MoeConfig,
    SsmConfig,
    WasiConfig,
)
from repro.core.rank_policy import asi_mode_ranks, static_rank
from repro.core.svd import pick_rank

Mode = Literal["dense", "factored", "project"]
Kernel = Literal["einsum", "fused_lowrank"]

#: linear-dict key in a param tree -> (spec name, role). The single place
#: that knows how param-tree naming maps onto plan sites.
LEAF_TO_SPEC: dict[str, tuple[str, str]] = {
    "gate": ("mlp/gate", "mlp"),
    "up": ("mlp/up", "mlp"),
    "down": ("mlp/down", "mlp"),
    "wq": ("attn/wq", "attn"),
    "wk": ("attn/wk", "attn"),
    "wv": ("attn/wv", "attn"),
    "wo": ("attn/wo", "attn"),
    "in_proj": ("ssm/in_proj", "ssm"),
    "x_proj": ("ssm/x_proj", "ssm"),
    "dt_proj": ("ssm/dt_proj", "ssm"),
    "out_proj": ("ssm/out_proj", "ssm"),
    "bcdt_proj": ("ssm/bcdt_proj", "ssm_small"),
    "w_gate": ("moe/w_gate", "moe"),
    "w_up": ("moe/w_up", "moe"),
    "w_down": ("moe/w_down", "moe"),
}


def role_treated(wasi: WasiConfig, role: str) -> bool:
    """Does WASI treat this linear? role in {mlp, attn, ssm, ssm_small,
    moe, head}. (Formerly nn.linear.wasi_applies.)"""
    if wasi.method == "none" or wasi.scope == "none":
        return False
    if role == "head":
        return False  # embeddings / lm_head stay dense (DESIGN.md §5)
    if wasi.scope == "mlp":
        return role in ("mlp", "moe")
    return True  # scope == "all"


@dataclass(frozen=True)
class LinearSpec:
    """One linear site, fully resolved: where its weights live (mode/rank),
    how its saved activations are compressed (ASI mode-ranks), and which
    kernel route applies it."""

    name: str                 # site id, e.g. "mlp/up"
    role: str                 # mlp | attn | ssm | ssm_small | moe | head
    in_dim: int
    out_dim: int
    mode: Mode = "dense"
    rank: int = 0             # 0 <=> dense
    bias: bool = False
    # ASI Tucker mode-ranks for this site's input activation at the plan's
    # (batch, seq) hint; None when activations stay dense or no hint given.
    asi_ranks: tuple[int, ...] | None = None
    kernel: Kernel = "einsum"
    # Advisory: does the single-launch fused backward fit the VMEM budget at
    # the standard 128-row tile (kernels/ops._bwd_fits_vmem)? None for dense.
    bwd_fits_vmem: bool | None = None
    # Deployment packing of this site's weights: None (f32 master) or
    # "int8" (per-channel absmax, quant/quantize.py). Stamped by
    # SubspacePlan.quantized(), never by policy resolution — quantization
    # is a deployment decision, not a training one.
    quant: str | None = None
    # Speculative-decoding draft view of this site: None (site plays no
    # part in drafting), "int8" (draft through the q8 kernels), or
    # "rank:<K'>" (draft through the leading K' columns/rows of L/R —
    # zero extra weights, just narrower slices). Stamped by
    # SubspacePlan.with_draft(); like quant, a serving decision.
    draft: str | None = None
    # Per-tenant adapter rank: None (site carries no tenant delta) or the
    # rank K_a of the additive (L_u, R_u) delta pair a fine-tuned tenant
    # contributes at this site (y += x R_u^T L_u^T, repro/tenancy/).
    # Stamped by SubspacePlan.with_adapter(); orthogonal to mode/quant —
    # the base weights keep their layout, the delta rides NEXT TO them.
    adapter: int | None = None
    # Mesh placement of this site's weight leaves: None (unstamped) or a
    # PartitionSpec-shaped tuple of (leaf, entries) pairs, e.g.
    # (("L", ("model", None)), ("R", (None, None))) — each entry a mesh
    # axis name, None, or a tuple of axis names, exactly what
    # jax.sharding.PartitionSpec(*entries) accepts. Stamped by
    # SubspacePlan.with_sharding() from a MeshPolicy; like quant/draft it
    # is a deployment decision that never changes math — consumers
    # (bind.plan_param_specs) read it, unstamped plans fall back to the
    # path-rule tables in distributed/sharding.py.
    sharding: tuple[tuple[str, tuple], ...] | None = None

    @property
    def factored_params(self) -> bool:
        """Do this site's PARAMS carry (L, R) factors?"""
        return self.mode == "factored"

    @property
    def weight_shape(self) -> tuple[int, ...]:
        return (self.out_dim, self.in_dim)

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if self.asi_ranks is not None:
            d["asi_ranks"] = list(self.asi_ranks)
        if self.sharding is not None:
            d["sharding"] = [[leaf, [list(e) if isinstance(e, tuple) else e
                                     for e in entries]]
                             for leaf, entries in self.sharding]
        return d

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "LinearSpec":
        d = dict(d)
        if d.get("asi_ranks") is not None:
            d["asi_ranks"] = tuple(d["asi_ranks"])
        if d.get("sharding") is not None:
            d["sharding"] = tuple(
                (leaf, tuple(tuple(e) if isinstance(e, list) else e
                             for e in entries))
                for leaf, entries in d["sharding"])
        return LinearSpec(**d)


def resolve_linear_spec(wasi: WasiConfig, name: str, role: str,
                        in_dim: int, out_dim: int, *, bias: bool = False,
                        act_shape: Sequence[int] | None = None,
                        weight=None) -> LinearSpec:
    """Resolve ONE site under ``wasi``. ``weight`` (a dense (…, O, I) array)
    switches the rank policy from static ``rank_frac`` to the paper's
    explained-variance ``epsilon`` (Alg. 1 t=0 truncated-SVD rank; max over
    any leading stack dims)."""
    treated = role_treated(wasi, role)
    if treated and wasi.factored:
        mode: Mode = "factored"
    elif treated and wasi.project:
        mode = "project"
    else:
        mode = "dense"
    rank = 0
    if mode != "dense":
        if weight is not None:
            rank = _epsilon_rank(weight, wasi)
        else:
            rank = static_rank(in_dim, out_dim, wasi.rank_frac,
                               align=wasi.rank_align, min_rank=wasi.min_rank)
    asi_ranks = None
    if treated and wasi.compress_acts and act_shape is not None:
        asi_ranks = _act_mode_ranks(tuple(act_shape), wasi)
    kernel: Kernel = "fused_lowrank" if mode == "factored" else "einsum"
    fits = None
    if mode != "dense":
        from repro.kernels.ops import _bwd_fits_vmem
        fits = _bwd_fits_vmem(128, out_dim, in_dim, rank)
    return LinearSpec(name=name, role=role, in_dim=in_dim, out_dim=out_dim,
                      mode=mode, rank=rank, bias=bias, asi_ranks=asi_ranks,
                      kernel=kernel, bwd_fits_vmem=fits)


def _act_mode_ranks(act_shape: tuple[int, ...],
                    wasi: WasiConfig) -> tuple[int, ...]:
    """ASI Tucker mode-ranks for an input activation of ``act_shape``
    ((B, N, I) or (B, H, W, I))."""
    a = wasi.asi
    if len(act_shape) == 3:
        fracs = (a.batch_frac, a.token_frac, a.feature_frac)
    else:
        fracs = (a.batch_frac,) + (a.token_frac,) * (len(act_shape) - 2) \
            + (a.feature_frac,)
    return asi_mode_ranks(act_shape, fracs, skip_batch=a.skip_batch,
                          align=a.align)


def _epsilon_rank(weight, wasi: WasiConfig) -> int:
    """pick_rank at wasi.epsilon; max over leading stack dims (scan/expert
    banks must share one static rank)."""
    import numpy as np

    w = np.asarray(weight)
    if w.ndim == 2:
        return pick_rank(w, wasi.epsilon, align=wasi.rank_align)
    flat = w.reshape((-1,) + w.shape[-2:])
    return max(pick_rank(flat[j], wasi.epsilon, align=wasi.rank_align)
               for j in range(flat.shape[0]))


@dataclass(frozen=True)
class SubspacePlan:
    """The resolved-once subspace decision for a whole model: one
    :class:`LinearSpec` per linear site, plus the configs they were resolved
    from. Hashable and JSON-serializable — it rides inside checkpoint
    manifests (api.convert) so a checkpoint is self-describing."""

    model: ModelConfig
    specs: tuple[LinearSpec, ...] = ()
    batch: int | None = None   # activation-shape hint used for asi_ranks
    seq: int | None = None
    calibrated: bool = False   # ranks from epsilon on real weights?

    @property
    def wasi(self) -> WasiConfig:
        return self.model.wasi

    @functools.cached_property
    def _by_name(self) -> dict[str, LinearSpec]:
        return {s.name: s for s in self.specs}

    def spec(self, name: str) -> LinearSpec:
        return self._by_name[name]

    def linear(self, name: str, in_dim: int | None = None,
               out_dim: int | None = None, *, role: str | None = None,
               bias: bool = False) -> LinearSpec:
        """Spec lookup for a call site. Unknown names or dim overrides (a
        layer instantiated at non-config dims) fall back to resolving a
        fresh site under the SAME policy — still one resolver, never ad hoc
        dict sniffing."""
        s = self._by_name.get(name)
        if s is not None and (in_dim is None or s.in_dim == in_dim) \
                and (out_dim is None or s.out_dim == out_dim):
            return s
        if in_dim is None or out_dim is None:
            raise KeyError(f"unknown linear site {name!r} and no dims given")
        r = role or (s.role if s is not None
                     else LEAF_TO_SPEC.get(name.split("/")[-1],
                                           (name, name.split("/")[0]))[1])
        return resolve_linear_spec(
            self.wasi, name, r, in_dim, out_dim, bias=bias,
            act_shape=(self.batch, self.seq, in_dim)
            if self.batch and self.seq else None)

    def by_role(self, role: str) -> tuple[LinearSpec, ...]:
        return tuple(s for s in self.specs if s.role == role)

    def quantized(self, fmt: str = "int8") -> "SubspacePlan":
        """The deployment view of this plan: every packable site (factored
        {L,R} pairs and dense 2D weights) stamped ``quant=fmt``. Project
        sites keep their training layout — they carry the dense W by
        definition; ``convert.factorize`` them first to deploy quantized.
        Pair with ``convert.quantize(params, plan)``; the stamped plan
        rides in checkpoint manifests so ``ServeEngine.from_checkpoint``
        serves int8 with no config in hand (docs/deployment.md)."""
        specs = tuple(dataclasses.replace(s, quant=fmt)
                      if s.mode in ("factored", "dense") else s
                      for s in self.specs)
        return dataclasses.replace(self, specs=specs)

    @property
    def is_quantized(self) -> bool:
        return any(s.quant is not None for s in self.specs)

    def with_draft(self, source: str = "int8") -> "SubspacePlan":
        """Stamp a speculative-decoding draft view per site.

        ``source`` is ``"int8"`` (every packable site — factored pairs and
        dense 2D weights — drafts through the q8 kernels) or
        ``"rank:<frac>"`` (factored sites draft through the leading
        ``max(1, int(frac * rank))`` columns of L / rows of R; dense sites
        have no narrower view and stay out of the draft stamp — the draft
        forward simply runs them at full precision). The stamp never
        changes f32 verify semantics: ``bind.apply`` only consults
        ``draft`` to *permit* layouts, the engine builds the actual draft
        params (serve/engine.py)."""
        if source == "int8":
            specs = tuple(dataclasses.replace(s, draft="int8")
                          if s.mode in ("factored", "dense") else s
                          for s in self.specs)
        elif source.startswith("rank:"):
            frac = float(source.split(":", 1)[1])
            if not 0.0 < frac <= 1.0:
                raise ValueError(f"draft rank fraction must be in (0, 1]: "
                                 f"{source!r}")
            specs = tuple(
                dataclasses.replace(
                    s, draft=f"rank:{max(1, int(frac * s.rank))}")
                if s.mode == "factored" and s.rank > 0 else s
                for s in self.specs)
        else:
            raise ValueError(f"unknown draft source {source!r} "
                             "(expected 'int8' or 'rank:<frac>')")
        return dataclasses.replace(self, specs=specs)

    def with_adapter(self, rank_frac: float = 0.25) -> "SubspacePlan":
        """Stamp a per-tenant adapter rank per site (repro/tenancy/).

        Every non-MoE site gains ``adapter = static_rank(in, out,
        rank_frac)`` (unaligned, min 1 — adapters are deliberately tiny):
        a fine-tuned tenant contributes an additive rank-K_a delta pair
        ``(L_u, R_u)`` there, applied by ``bind.apply`` as
        ``y += x R_u^T L_u^T`` whenever the param dict carries the
        ``La/Ra`` keys. MoE sites stay out: their expert-banked matmul
        does not route through the per-site delta path. Like quant/draft
        stamps, this never changes base semantics — a tree without
        adapter factors is bitwise the unstamped forward."""
        if not 0.0 < rank_frac <= 1.0:
            raise ValueError(
                f"adapter rank fraction must be in (0, 1]: {rank_frac!r}")
        specs = tuple(
            dataclasses.replace(s, adapter=static_rank(
                s.in_dim, s.out_dim, rank_frac, align=1, min_rank=1))
            if s.role != "moe" else s
            for s in self.specs)
        return dataclasses.replace(self, specs=specs)

    def with_sharding(self, policy=None) -> "SubspacePlan":
        """Stamp per-leaf mesh placement per site (distributed/sharding.py).

        Resolves the LM path-rule table under ``policy`` (default
        ``MeshPolicy()``) ONCE and freezes the result into each spec's
        ``sharding`` field — the WASI tensor-parallel story made explicit:
        an up-projection's L (O, K) shards O on the model axis while its R
        stays replicated; a down-projection's R (K, I) shards I while its
        L stays replicated (DESIGN.md §4). Adapter La/Ra pairs, when
        stamped, are always replicated (per-tenant deltas ride the batch
        axis, not the weight mesh). Like quant/draft/adapter this changes
        placement only, never math, and it JSON round-trips with the plan
        so a checkpoint manifest carries its own partitioning."""
        from repro.distributed.sharding import MeshPolicy, site_sharding

        policy = policy if policy is not None else MeshPolicy()
        specs = tuple(
            dataclasses.replace(s, sharding=site_sharding(s, policy))
            for s in self.specs)
        return dataclasses.replace(self, specs=specs)

    @property
    def is_sharded(self) -> bool:
        return any(s.sharding is not None for s in self.specs)

    @property
    def has_adapters(self) -> bool:
        return any(s.adapter is not None for s in self.specs)

    @property
    def draft_source(self) -> str | None:
        """"int8" | "rank" | None — the stamped draft family, if any."""
        for s in self.specs:
            if s.draft is not None:
                return "int8" if s.draft == "int8" else "rank"
        return None

    def summary(self) -> str:
        """Human-readable one-line-per-site table."""
        lines = [f"SubspacePlan[{self.model.name}] method={self.wasi.method} "
                 f"update={self.wasi.update_mode} scope={self.wasi.scope}"
                 + (" (eps-calibrated)" if self.calibrated else "")]
        for s in self.specs:
            extra = f" rank={s.rank}" if s.mode != "dense" else ""
            if s.asi_ranks is not None:
                extra += f" asi={list(s.asi_ranks)}"
            if s.bwd_fits_vmem is not None:
                extra += f" bwd={'fused' if s.bwd_fits_vmem else 'xla'}"
            if s.quant is not None:
                extra += f" quant={s.quant}"
            if s.draft is not None:
                extra += f" draft={s.draft}"
            if s.adapter is not None:
                extra += f" adapter={s.adapter}"
            if s.sharding is not None:
                extra += " shard=" + ",".join(
                    f"{leaf}({'x'.join(str(e) for e in entries)})"
                    for leaf, entries in s.sharding)
            lines.append(f"  {s.name:16s} {s.role:9s} "
                         f"({s.in_dim}->{s.out_dim}) {s.mode:8s}"
                         f" {s.kernel}{extra}")
        return "\n".join(lines)

    # -- serialization ------------------------------------------------------

    def to_json(self) -> dict:
        return {"version": 1,
                "model": model_config_to_json(self.model),
                "specs": [s.to_json() for s in self.specs],
                "batch": self.batch, "seq": self.seq,
                "calibrated": self.calibrated}

    @staticmethod
    def from_json(d: Mapping[str, Any]) -> "SubspacePlan":
        return SubspacePlan(
            model=model_config_from_json(d["model"]),
            specs=tuple(LinearSpec.from_json(s) for s in d["specs"]),
            batch=d.get("batch"), seq=d.get("seq"),
            calibrated=bool(d.get("calibrated", False)))

    def dumps(self) -> str:
        return json.dumps(self.to_json(), sort_keys=True)

    @staticmethod
    def loads(s: str) -> "SubspacePlan":
        return SubspacePlan.from_json(json.loads(s))


# ---------------------------------------------------------------------------
# Config (de)serialization — makes plan-bearing checkpoints self-describing.
# ---------------------------------------------------------------------------

def model_config_to_json(cfg: ModelConfig) -> dict:
    return dataclasses.asdict(cfg)


def model_config_from_json(d: Mapping[str, Any]) -> ModelConfig:
    d = dict(d)
    d["groups"] = tuple(LayerGroup(pattern=tuple(g["pattern"]),
                                   repeat=int(g["repeat"]))
                        for g in d.get("groups", ()))
    d["moe"] = MoeConfig(**d.get("moe", {}))
    d["ssm"] = SsmConfig(**d.get("ssm", {}))
    w = dict(d.get("wasi", {}))
    w["asi"] = AsiConfig(**w.get("asi", {}))
    d["wasi"] = WasiConfig(**w)
    return ModelConfig(**d)


# ---------------------------------------------------------------------------
# Whole-model resolution
# ---------------------------------------------------------------------------

def _block_kinds(cfg: ModelConfig) -> set[str]:
    return {k for g in cfg.groups for k in g.pattern}


def _site_dims(cfg: ModelConfig) -> list[tuple[str, str, int, int, bool, int]]:
    """Enumerate (name, role, in_dim, out_dim, bias, act_in_dim) linear
    sites for a config, by family + block kinds. act_in_dim is the feature
    dim of the site's input activation (== in_dim for every current site)."""
    d, f = cfg.d_model, cfg.d_ff
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    sites: list[tuple[str, str, int, int, bool, int]] = []
    kinds = _block_kinds(cfg)
    has_attn = cfg.family in ("vit", "encdec") or bool(
        kinds & {"dense", "local", "moe", "moe_swa", "mamba2_attn", "enc", "dec"})
    has_mlp = cfg.family in ("vit", "encdec") or bool(
        kinds & {"dense", "local", "mamba2_attn", "enc", "dec"})
    if has_attn:
        sites += [("attn/wq", "attn", d, h * dh, cfg.qkv_bias, d),
                  ("attn/wk", "attn", d, kvh * dh, cfg.qkv_bias, d),
                  ("attn/wv", "attn", d, kvh * dh, cfg.qkv_bias, d),
                  ("attn/wo", "attn", h * dh, d, False, h * dh)]
    if has_mlp:
        if cfg.mlp_act == "swiglu":
            sites.append(("mlp/gate", "mlp", d, f, False, d))
        sites += [("mlp/up", "mlp", d, f, False, d),
                  ("mlp/down", "mlp", f, d, False, f)]
    ssm = cfg.ssm
    di = ssm.expand * d
    if "mamba1" in kinds:
        n = ssm.d_state
        dtr = ssm.dt_rank or max(d // 16, 1)
        sites += [("ssm/in_proj", "ssm", d, 2 * di, False, d),
                  ("ssm/x_proj", "ssm", di, dtr + 2 * n, False, di),
                  ("ssm/dt_proj", "ssm", dtr, di, True, dtr),
                  ("ssm/out_proj", "ssm", di, d, False, di)]
    if kinds & {"mamba2", "mamba2_attn"}:
        n = ssm.d_state
        nh = di // ssm.head_dim
        sites += [("ssm/in_proj", "ssm", d, 2 * di, False, d),
                  ("ssm/bcdt_proj", "ssm_small", d, 2 * n + nh, False, d),
                  ("ssm/out_proj", "ssm", di, d, False, di)]
    if kinds & {"moe", "moe_swa"}:
        fe = cfg.moe.expert_d_ff or f
        sites += [("moe/w_gate", "moe", d, fe, False, d),
                  ("moe/w_up", "moe", d, fe, False, d),
                  ("moe/w_down", "moe", fe, d, False, fe)]
    # dedupe (mamba1 + mamba2 hybrids share in_proj/out_proj dims)
    seen, out = set(), []
    for s in sites:
        if s[0] not in seen:
            seen.add(s[0])
            out.append(s)
    return out


def collect_linear_weights(tree) -> dict[str, list]:
    """Walk a (possibly stacked) DENSE param tree collecting each site's
    weight leaves, keyed by spec name. Used for eps-rank calibration."""
    from repro.api.bind import dense_weight  # lazy: bind imports plan

    found: dict[str, list] = {}

    def walk(node):
        if isinstance(node, dict):
            for k, v in node.items():
                w = dense_weight(v) if k in LEAF_TO_SPEC else None
                if w is not None:
                    found.setdefault(LEAF_TO_SPEC[k][0], []).append(w)
                else:
                    walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(tree)
    return found


def resolve(cfg: ModelConfig, *, batch: int | None = None,
            seq: int | None = None, calibration=None) -> SubspacePlan:
    """Resolve the plan for ``cfg`` ONCE.

    ``batch``/``seq`` give the training activation-shape hint so specs carry
    concrete ASI mode-ranks (telemetry + serialization; bind recomputes for
    other shapes). ``calibration`` is a dense param tree (or a
    {site-name: weight} mapping): when given, factored/project ranks come
    from the paper's explained-variance threshold on the actual weights
    instead of the static ``rank_frac`` policy.
    """
    weights: Mapping[str, Any] = {}
    if calibration is not None:
        # {site-name: weight array} mapping vs a whole dense param tree
        if isinstance(calibration, Mapping) and calibration and all(
                hasattr(v, "shape") for v in calibration.values()):
            weights = {k: [v] for k, v in calibration.items()}
        else:
            weights = collect_linear_weights(calibration)
    specs = []
    for name, role, i_dim, o_dim, bias, act_in in _site_dims(cfg):
        w = None
        if name in weights:
            import numpy as np

            ws = weights[name]
            # stack-aware: _epsilon_rank maxes over all leading dims, so
            # concatenate the flattened stacks
            flat = [np.asarray(x).reshape((-1, o_dim, i_dim)) for x in ws
                    if np.asarray(x).shape[-2:] == (o_dim, i_dim)]
            if flat:
                w = np.concatenate(flat, axis=0)
        act = (batch, seq, act_in) if batch and seq else None
        specs.append(resolve_linear_spec(cfg.wasi, name, role, i_dim, o_dim,
                                         bias=bias, act_shape=act, weight=w))
    return SubspacePlan(model=cfg, specs=tuple(specs), batch=batch, seq=seq,
                        calibrated=calibration is not None)


# ---------------------------------------------------------------------------
# Per-config memoized lookup + explicit install
# ---------------------------------------------------------------------------

_INSTALLED: dict[ModelConfig, SubspacePlan] = {}


@functools.lru_cache(maxsize=64)
def _resolve_static(cfg: ModelConfig) -> SubspacePlan:
    return resolve(cfg)


def plan_of(cfg: ModelConfig) -> SubspacePlan:
    """The plan every internal consumer reads: the installed plan for this
    config if one was explicitly resolved (calibrated ranks, shape hints),
    else the memoized static resolution. Resolution happens once per
    config either way."""
    p = _INSTALLED.get(cfg)
    return p if p is not None else _resolve_static(cfg)


def install(plan: SubspacePlan) -> SubspacePlan:
    """Make ``plan`` the one ``plan_of(plan.model)`` returns. Use after an
    explicit ``resolve(...)`` with calibration or shape hints."""
    _INSTALLED[plan.model] = plan
    return plan


def installed(cfg: ModelConfig) -> SubspacePlan | None:
    """The explicitly-installed plan for ``cfg``, if any (no fallback)."""
    return _INSTALLED.get(cfg)


def uninstall(cfg: ModelConfig) -> None:
    _INSTALLED.pop(cfg, None)
