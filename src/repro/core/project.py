"""Paper-faithful "project" update mode plumbing (Eq. 9-11 + Alg. 1).

Parameters stay DENSE (full W, like the paper's own implementation); a
parallel WSIState tree carries each wasi-scoped layer's (L, R). Per step:

  forward:   y = x R^T L^T    (factors from the PREVIOUS iteration)
  backward:  dW~ = f_LR(x~, dy) lands on W        (wasi_matmul_project)
  update:    W <- W - lr dW~                      (optimizer)
  WSI:       (L, R) <- subspace_iteration(W_new)  (Alg. 1 lines 6-7)

Role scoping is path-based (same convention as distributed/sharding.py).
Stacked layers (leading scan/expert dims) are handled by the batched
wsi_init/wsi_step.
"""
from __future__ import annotations

import re

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.rank_policy import static_rank
from repro.core.svd import pick_rank
from repro.core.wsi import WSIState, wsi_init, wsi_step

_ROLE_PATTERNS = (
    (r".*(embed|lm_head|head|router|patch|pos|cls)(/|$)", "head"),
    (r".*(experts|shared)/", "moe"),
    (r".*(wq|wk|wv|wo|q_proj|k_proj|v_proj|o_proj)(/|$)", "attn"),
    (r".*(in_proj|x_proj|dt_proj|out_proj)(/|$)", "ssm"),
    (r".*(up|gate|down)(/|$)", "mlp"),
)


def role_of_path(path: str) -> str:
    for pat, role in _ROLE_PATTERNS:
        if re.match(pat, path):
            return role
    return "other"


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(parts)


def _wasi_weight_paths(params, cfg: ModelConfig) -> list[str]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        ps = _path_str(path)
        if not ps.endswith("/w"):
            continue
        role = role_of_path(ps)
        if role in ("head", "other"):
            continue
        if getattr(leaf, "ndim", 0) < 2:
            continue
        from repro.api.plan import role_treated
        if role_treated(cfg.wasi, role):
            out.append(ps)
    return out


def _batched(fn, w, *rest):
    """Apply fn over leading stack dims of w (..., O, I). ``rest`` pytrees
    (e.g. a WSIState of (..., O, K)/(..., K, I) factors) have their leaves
    flattened the same way — expert banks inside scanned groups carry TWO
    leading dims (repeat, E), which a bare ``.reshape`` on the state object
    could not handle."""
    if w.ndim == 2:
        return fn(w, *rest)
    flat = w.reshape((-1,) + w.shape[-2:])
    rest_flat = [jax.tree.map(lambda x: x.reshape((-1,) + x.shape[-2:]), r)
                 for r in rest]
    out = jax.vmap(fn)(flat, *rest_flat)
    return jax.tree.map(
        lambda x: x.reshape(w.shape[:-2] + x.shape[-2:]), out)


def init_project_states(params, cfg: ModelConfig,
                        use_epsilon: bool = False,
                        warm: dict[str, WSIState] | None = None
                        ) -> dict[str, WSIState]:
    """WSIState per wasi-scoped dense weight, keyed by path. Rank from
    rank_frac (static) or, if ``use_epsilon``, from explained variance on
    the actual weights (paper Alg. 1 t=0; max over stacked layers).

    ``warm`` carries factors extracted from a converted checkpoint
    (api.bind.extract_project_factors) — those paths skip the SVD init and
    resume the checkpoint's subspace instead."""
    states: dict[str, WSIState] = {}
    flat = dict((_path_str(p), l) for p, l in
                jax.tree_util.tree_flatten_with_path(params)[0])
    for ps in _wasi_weight_paths(params, cfg):
        if warm and ps in warm:
            states[ps] = warm[ps]
            continue
        w = flat[ps]
        o, i = w.shape[-2], w.shape[-1]
        if use_epsilon:
            if w.ndim == 2:
                k = pick_rank(w, cfg.wasi.epsilon, align=cfg.wasi.rank_align)
            else:
                ks = [pick_rank(w.reshape((-1, o, i))[j], cfg.wasi.epsilon,
                                align=cfg.wasi.rank_align)
                      for j in range(int(jnp.prod(jnp.array(w.shape[:-2]))))]
                k = max(ks)
        else:
            k = static_rank(i, o, cfg.wasi.rank_frac, align=cfg.wasi.rank_align,
                            min_rank=cfg.wasi.min_rank)
        states[ps] = _batched(lambda m: wsi_init(m, k), w)
    return states


def project_forward_params(params, states: dict[str, WSIState]):
    """Insert (L, R) next to each dense W so the bound apply takes the
    factored-forward/dense-gradient path (wasi_matmul_project). The
    structure walk itself lives in api.bind (the key-dispatch monopoly)."""
    from repro.api.bind import inject_factors

    return inject_factors(params, states)


def update_project_states(params, states: dict[str, WSIState]) -> dict:
    """One WSI step against the freshly-updated dense weights (Alg. 1)."""
    flat = dict((_path_str(p), l) for p, l in
                jax.tree_util.tree_flatten_with_path(params)[0])
    return {ps: _batched(wsi_step, flat[ps], st) for ps, st in states.items()}
