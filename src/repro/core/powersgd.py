"""PowerSGD gradient compression with error feedback (Vogels et al. 2019).

ASI is a descendant of PowerSGD's warm-started subspace iteration; at pod
scale we close the loop and use the same primitive to compress the
data-parallel gradient all-reduce of the remaining DENSE parameters
(embeddings, norms, lm_head). WASI-factored parameters need no compression:
their gradients are already K(O+I) instead of O*I.

Protocol per matrix gradient G (O, I), rank q, warm-start Q (I, q):
    P = G Q               -> all-reduce P        (O*q bytes instead of O*I)
    P = orth(P)           (CholeskyQR)
    Q = G^T P             -> all-reduce Q        (I*q bytes)
    G~ = P Q^T
Error feedback: e <- G - G~ is added to the next step's gradient, making the
compression unbiased in the long run (critical for convergence).

The all-reduces are expressed with jax.lax.pmean inside shard_map over the
"data" (and "pod") mesh axes; distributed/grad_compress.py is the mesh-aware
wrapper and train/step.py (make_train_step(..., mesh=...)) wires it into the
DP train step. This module is the pure math + state handling.

Under DP the error accumulator is PER-REPLICA state (each worker keeps the
residual of its own local gradient, Vogels et al. §3): ``powersgd_init``
with ``local_copies=D`` allocates the error with a leading device axis that
the mesh step shards over the DP axes, while the warm-start ``q`` stays
replicated. The transmitted update then depends only on cross-replica
MEANS, so the decompressed sequence equals the single-device oracle run on
the mean gradient — the parity tests/test_mesh_parity.py pins.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.orthogonal import cholesky_qr


class PowerSGDState(NamedTuple):
    q: jax.Array      # (I, rank) warm-start right factor
    error: jax.Array  # (O, I) error-feedback accumulator
                      # ((D, O, I) per-replica under DP: local_copies=D)


def powersgd_init(key: jax.Array, shape: tuple[int, int], rank: int,
                  dtype=jnp.float32, *, local_copies: int = 0) -> PowerSGDState:
    """``local_copies=0`` (single device): error is (O, I). ``local_copies=D``
    (DP over D replicas): error is (D, O, I) — one residual per replica,
    sharded over the DP mesh axes by the train step; q stays replicated."""
    o, i = shape
    q = jax.random.normal(key, (i, rank), jnp.float32).astype(dtype)
    eshape = (local_copies, o, i) if local_copies else (o, i)
    return PowerSGDState(q=q, error=jnp.zeros(eshape, dtype))


def compress_decompress(grad: jax.Array, state: PowerSGDState,
                        mean_fn=None) -> tuple[jax.Array, PowerSGDState]:
    """One PowerSGD round. ``mean_fn`` performs the cross-replica averaging
    of the small factors (identity for single-host tests; lax.pmean inside
    shard_map at scale). Returns (decompressed mean gradient, new state)."""
    if mean_fn is None:
        mean_fn = lambda x: x
    g = (grad + state.error).astype(jnp.float32)
    p = mean_fn(g @ state.q.astype(jnp.float32))      # (O, q) all-reduce
    p = cholesky_qr(p)
    q = mean_fn(g.T @ p)                              # (I, q) all-reduce
    approx = p @ q.T
    new_err = (g - approx).astype(state.error.dtype)
    return approx.astype(grad.dtype), PowerSGDState(
        q=q.astype(state.q.dtype), error=new_err)


def compression_factor(shape: tuple[int, int], rank: int) -> float:
    o, i = shape
    return (o * i) / (rank * (o + i))
