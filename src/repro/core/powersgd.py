"""PowerSGD gradient compression with error feedback (Vogels et al. 2019).

ASI is a descendant of PowerSGD's warm-started subspace iteration; at pod
scale we close the loop and use the same primitive to compress the
data-parallel gradient all-reduce of the remaining DENSE parameters
(embeddings, norms, lm_head). WASI-factored parameters need no compression:
their gradients are already K(O+I) instead of O*I.

Protocol per matrix gradient G (O, I), rank q, warm-start Q (I, q):
    P = G Q               -> all-reduce P        (O*q bytes instead of O*I)
    P = orth(P)           (CholeskyQR)
    Q = G^T P             -> all-reduce Q        (I*q bytes)
    G~ = P Q^T
Error feedback: e <- G - G~ is added to the next step's gradient, making the
compression unbiased in the long run (critical for convergence).

The all-reduces are expressed with jax.lax.psum inside shard_map over the
"data" (and "pod") mesh axes; see distributed/grad_compress.py for the
mesh-aware wrapper. This module is the pure math + state handling.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.orthogonal import cholesky_qr


class PowerSGDState(NamedTuple):
    q: jax.Array      # (I, rank) warm-start right factor
    error: jax.Array  # (O, I) error-feedback accumulator


def powersgd_init(key: jax.Array, shape: tuple[int, int], rank: int,
                  dtype=jnp.float32) -> PowerSGDState:
    o, i = shape
    q = jax.random.normal(key, (i, rank), jnp.float32).astype(dtype)
    return PowerSGDState(q=q, error=jnp.zeros((o, i), dtype))


def compress_decompress(grad: jax.Array, state: PowerSGDState,
                        mean_fn=None) -> tuple[jax.Array, PowerSGDState]:
    """One PowerSGD round. ``mean_fn`` performs the cross-replica averaging
    of the small factors (identity for single-host tests; lax.pmean inside
    shard_map at scale). Returns (decompressed mean gradient, new state)."""
    if mean_fn is None:
        mean_fn = lambda x: x
    g = (grad + state.error).astype(jnp.float32)
    p = mean_fn(g @ state.q.astype(jnp.float32))      # (O, q) all-reduce
    p = cholesky_qr(p)
    q = mean_fn(g.T @ p)                              # (I, q) all-reduce
    approx = p @ q.T
    new_err = (g - approx).astype(state.error.dtype)
    return approx.astype(grad.dtype), PowerSGDState(
        q=q.astype(state.q.dtype), error=new_err)


def compression_factor(shape: tuple[int, int], rank: int) -> float:
    o, i = shape
    return (o * i) / (rank * (o + i))
