"""Orthogonalization for subspace iteration.

Paper Alg. 1 uses classical Gram-Schmidt — a sequential per-column loop that
is a poor fit for the TPU MXU. We adapt it to CholeskyQR:

    G = Y^T Y        (tall-skinny Gram: one MXU matmul)
    G = C C^T        (K x K Cholesky, tiny)
    Q = Y C^{-T}     (K x K triangular solve applied as matmul)

CholeskyQR spans exactly the same subspace as Gram-Schmidt on the same input
(both produce the unique QR factor up to column signs for full-rank Y), so
fidelity to the paper is preserved; see tests/test_orthogonal.py.

A jnp Gram-Schmidt reference is kept as the fidelity oracle, plus a
CholeskyQR2 variant for ill-conditioned inputs (two passes restore
orthogonality to machine precision).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def gram_schmidt(y: jax.Array) -> jax.Array:
    """Classical Gram-Schmidt (paper-faithful oracle). y: (M, K) -> Q (M, K)."""
    y = y.astype(jnp.float32)
    m, k = y.shape

    def body(i, q):
        v = y[:, i]
        # subtract projections onto previously produced columns
        coeff = q.T @ v  # (K,)
        mask = (jnp.arange(k) < i).astype(v.dtype)
        v = v - q @ (coeff * mask)
        v = v / jnp.maximum(jnp.linalg.norm(v), 1e-12)
        return q.at[:, i].set(v)

    q0 = jnp.zeros_like(y)
    return jax.lax.fori_loop(0, k, body, q0)


def cholesky_qr(y: jax.Array, shift: float = 1e-6) -> jax.Array:
    """Shifted CholeskyQR. y: (..., M, K) -> Q with orthonormal columns.

    A relative shift keeps the Cholesky PSD under round-off / rank-deficient
    inputs (the shifted direction is immaterial: only the spanned subspace
    matters for subspace iteration). If the first factorization still fails
    (NaN), a second attempt with a 1e4-times larger shift is selected via
    ``where`` — see ``_shifted_cholesky``.

    NOTE for callers implementing power iteration: never orthogonalize
    ``A (A^T U)`` in one shot — the Gram condition is cond(A)^4. Stage it:
    ``V = cholesky_qr(A^T U); Q = cholesky_qr(A V)`` (cond^2 per stage).
    """
    yf = y.astype(jnp.float32)
    g = jnp.einsum("...mk,...mn->...kn", yf, yf)
    c = _shifted_cholesky(g, shift)
    # Q = Y C^{-T}  <=>  solve  C Q^T = Y^T  (lower-triangular)
    qt = jax.scipy.linalg.solve_triangular(c, jnp.swapaxes(yf, -1, -2), lower=True)
    return jnp.swapaxes(qt, -1, -2).astype(y.dtype)


def _shifted_cholesky(g: jax.Array, shift: float) -> jax.Array:
    """Lower Cholesky of g + shift*scale*I with the NaN-fallback ladder:
    if the first factorization fails, a 1e4-times larger shift is selected
    via ``where`` — branch-free, so it stays jit/scan-safe; the extra K×K
    Cholesky is noise next to the Gram matmul."""
    k = g.shape[-1]
    scale = jnp.maximum(jnp.trace(g, axis1=-2, axis2=-1) / k, 1e-30)
    eye = jnp.eye(k, dtype=g.dtype)
    c1 = jnp.linalg.cholesky(g + (shift * scale)[..., None, None] * eye)
    c2 = jnp.linalg.cholesky(g + (1e4 * shift * scale)[..., None, None] * eye)
    bad = ~jnp.isfinite(c1).all(axis=(-2, -1), keepdims=True)
    return jnp.where(bad, c2, c1)


def cholesky_qr_mix_ref(y: jax.Array, shift: float = 1e-6):
    """(Q, M = Q^T Y) with the mix derived from the Gram factor, not a
    second tall-skinny product: Q = Y C^{-T} implies
    Q^T Y = C^{-1} (Y^T Y) = C^{-1} G — a K×K triangular solve instead of
    an O(M·K^2) sweep over Y. jnp reference for the fused CholeskyQR
    kernel (kernels/qr.py); also the off-TPU / batched fallback behind
    ``kernels.ops.cholesky_qr_mix``. Batched over leading dims."""
    yf = y.astype(jnp.float32)
    g = jnp.einsum("...mk,...mn->...kn", yf, yf)
    c = _shifted_cholesky(g, shift)
    qt = jax.scipy.linalg.solve_triangular(c, jnp.swapaxes(yf, -1, -2), lower=True)
    mix = jax.scipy.linalg.solve_triangular(c, g, lower=True)
    return jnp.swapaxes(qt, -1, -2).astype(y.dtype), mix


def cholesky_qr2(y: jax.Array) -> jax.Array:
    """Two-pass CholeskyQR — orthogonality to ~machine eps even when Y is
    ill-conditioned. Used when WSI runs many steps between SVD refreshes."""
    return cholesky_qr(cholesky_qr(y))


def orthonormality_error(q: jax.Array) -> jax.Array:
    """||Q^T Q - I||_F — invariant checked by property tests."""
    qf = q.astype(jnp.float32)
    g = jnp.einsum("...mk,...mn->...kn", qf, qf)
    eye = jnp.eye(g.shape[-1], dtype=g.dtype)
    return jnp.linalg.norm(g - eye, axis=(-2, -1))
