"""Core WASI algorithms (paper's contribution).

- svd: explained-variance rank selection + truncated SVD   (Eq. 5-7)
- orthogonal: CholeskyQR orthogonalization (TPU-adapted Gram-Schmidt)
- wsi: Weight Subspace Iteration                            (Alg. 1)
- asi: Activation Subspace Iteration / Tucker + f_LR        (Alg. 2, App. A.1)
- lowrank_linear: custom-VJP WASI/ASI matmuls               (Eq. 8-11)
- rank_policy: eps ranks, App. A.2 perplexity DP, static ranks
- powersgd: DP gradient compression with error feedback (beyond-paper)
"""

from repro.core.svd import (
    SVDFactors,
    explained_variance,
    pick_rank,
    rank_for_threshold,
    truncated_svd,
)
from repro.core.orthogonal import cholesky_qr, cholesky_qr2, gram_schmidt
from repro.core.wsi import WSIState, wsi_init, wsi_step, wsi_refresh_factored
from repro.core.asi import (
    ASIState,
    TuckerFactors,
    asi_init,
    asi_step,
    tucker_reconstruct,
    flr_weight_grad_3d,
    flr_weight_grad_4d,
)
from repro.core.lowrank_linear import (
    WasiLinearParams,
    asi_matmul,
    init_wasi_linear,
    wasi_linear_apply,
    wasi_matmul,
    wasi_matmul_project,
)
from repro.core.rank_policy import (
    asi_mode_ranks,
    epsilon_ranks,
    perplexity_dp,
    static_rank,
)
from repro.core.powersgd import PowerSGDState, compress_decompress, powersgd_init
