"""Rank selection policies (paper §3.3 + App. A.2).

Three layers of policy, from paper-faithful to scale-pragmatic:

1. ``epsilon_ranks``     — per-layer weight rank K_i from explained variance
                           threshold eps (paper Eq. 5-7). Data-dependent;
                           used at calibration time / paper-scale runs.
2. ``perplexity_dp``     — WASI's App. A.2 selection: given a perplexity
                           matrix P (layers × thresholds) and memory matrix M,
                           pick one threshold index per layer minimizing total
                           perplexity under a memory budget — solved by
                           dynamic programming over a discretized budget in
                           O(layers × thresholds × budget_bins), replacing the
                           exponential brute force (and the recursive
                           backtracking) with a linear-in-layers pass.
3. ``static_ranks``      — scale branch: rank fraction × min(O, I), rounded
                           up to an MXU-aligned multiple. Deterministic at
                           config time (XLA static shapes). The eps→fraction
                           mapping is calibrated offline by benchmarks.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

import jax.numpy as jnp

from repro.core.svd import pick_rank


def align_up(k: int, align: int) -> int:
    return max(align, -(-k // align) * align)


def static_rank(in_dim: int, out_dim: int, rank_frac: float, *,
                align: int = 128, min_rank: int = 8) -> int:
    """Deterministic rank for the scale branch."""
    full = min(in_dim, out_dim)
    k = max(min_rank, int(round(rank_frac * full)))
    if align > 1:
        k = align_up(k, align)
    return min(k, full)


def epsilon_ranks(weights: Sequence[jnp.ndarray], eps: float,
                  align: int = 1) -> list[int]:
    """Paper-faithful per-layer ranks under explained-variance eps."""
    return [pick_rank(w, eps, align=align) for w in weights]


def asi_mode_ranks(shape: Sequence[int], frac: Sequence[float], *,
                   skip_batch: bool = False, align: int = 8,
                   min_rank: int = 1) -> tuple[int, ...]:
    """Per-mode Tucker ranks for an activation of ``shape``.

    ``skip_batch=True`` keeps mode 0 at full rank (identity factor) so the
    compression never couples samples across data-parallel shards — the
    TPU-sharding adaptation discussed in DESIGN.md §4.

    Ranks are capped at min(D_m, prod_{j!=m} D_j) — the rank of the mode-m
    unfolding (paper Alg. 2 line 1) — else the Gram matrix in CholeskyQR is
    singular.
    """
    total = 1
    for d in shape:
        total *= d
    ranks = []
    for m, (d, f) in enumerate(zip(shape, frac)):
        cap = min(d, total // d)
        if m == 0 and skip_batch:
            ranks.append(cap)
            continue
        r = max(min(min_rank, cap), int(round(f * d)))
        if align > 1 and r < d:
            r = align_up(r, align)
        ranks.append(min(r, cap))
    return tuple(ranks)


# ---------------------------------------------------------------------------
# App. A.2 — perplexity-constrained rank selection via DP.
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DPResult:
    choice: tuple[int, ...]      # threshold index j chosen per layer
    total_perplexity: float
    total_memory: float


def perplexity_dp(perplexity: np.ndarray, memory: np.ndarray,
                  budget: float, bins: int = 512) -> DPResult:
    """Pick one threshold index per layer minimizing sum of perplexities
    subject to sum of memories <= budget (paper Eq. 29-32).

    perplexity, memory: (num_layers, num_thresholds) float arrays.
    Discretizes the budget into ``bins`` levels -> knapsack-style DP that is
    linear in layers (the paper's stated goal: exponential -> linear).
    """
    P = np.asarray(perplexity, np.float64)
    M = np.asarray(memory, np.float64)
    n, e = P.shape
    if budget <= 0:
        raise ValueError("budget must be positive")
    scale = bins / budget
    mq = np.minimum(np.ceil(M * scale).astype(np.int64), bins + 1)

    INF = np.inf
    # best[b] = min perplexity using layers [0..i] with quantized memory b
    best = np.full(bins + 1, INF)
    parent = np.full((n, bins + 1), -1, np.int64)
    # layer 0
    for j in range(e):
        b = mq[0, j]
        if b <= bins and P[0, j] < best[b]:
            best[b] = P[0, j]
            parent[0, b] = j
    for i in range(1, n):
        nxt = np.full(bins + 1, INF)
        for j in range(e):
            c = mq[i, j]
            if c > bins:
                continue
            shifted = np.full(bins + 1, INF)
            shifted[c:] = best[: bins + 1 - c] + P[i, j]
            better = shifted < nxt
            nxt = np.where(better, shifted, nxt)
            parent[i, better] = j
        best = nxt
    if not np.isfinite(best).any():
        raise ValueError("no feasible selection under the given budget")
    b = int(np.argmin(best))
    total_p = float(best[b])
    # backtrack
    choice = []
    for i in range(n - 1, -1, -1):
        j = int(parent[i, b])
        choice.append(j)
        b -= int(mq[i, j])
    choice.reverse()
    total_m = float(sum(M[i, j] for i, j in enumerate(choice)))
    return DPResult(choice=tuple(choice), total_perplexity=total_p,
                    total_memory=total_m)


def gradient_perplexity(exact_grad: jnp.ndarray, approx_grad: jnp.ndarray) -> float:
    """Paper Eq. 28: Frobenius norm of the gradient approximation error."""
    d = jnp.asarray(exact_grad, jnp.float32) - jnp.asarray(approx_grad, jnp.float32)
    return float(jnp.linalg.norm(d))
