"""Weight Subspace Iteration (paper Alg. 1).

State per layer: factors (L, R) with W ~= L @ R,  L (O,K), R (K,I).

  t = 0 : L, R <- truncated SVD of W at explained-variance threshold eps
  t > 0 : R^T  <- W^T L_{t-1}
          L    <- orth(W R^T)            (CholeskyQR; see core/orthogonal.py)

Two update modes connect WSI to the optimizer:

* ``project`` (paper-faithful, Eq. 9-11): the full W is kept as the parameter;
  the (activation-compressed) gradient updates W, then one WSI step re-extracts
  (L, R) used by the *next* forward. Costs O_WSI = 4*I*O*K + 2*O*K^2 FLOPs per
  step (paper Eq. 36) and holds W in memory — exactly like the paper's own
  implementation.

* ``factored`` (beyond-paper, scale branch): L and R are themselves the
  trainable parameters; gradients flow to them directly through the factored
  forward, and WSI re-orthogonalization runs every ``refresh_every`` steps to
  keep L well-conditioned. No O×I tensor is ever materialized, so weight
  memory, optimizer state, and the DP gradient all-reduce all shrink by
  O*I / (K*(O+I)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.orthogonal import cholesky_qr
from repro.core.svd import SVDFactors, truncated_svd


class WSIState(NamedTuple):
    L: jax.Array  # (O, K)
    R: jax.Array  # (K, I)


def wsi_init(w: jax.Array, k: int) -> WSIState:
    """t=0: truncated SVD (paper Alg. 1 line 3-4)."""
    f: SVDFactors = truncated_svd(w, k)
    return WSIState(L=f.L, R=f.R)


def wsi_step(w: jax.Array, prev: WSIState) -> WSIState:
    """One warm-started subspace iteration against (possibly updated) W.

    Paper Alg. 1 lines 6-7, with CholeskyQR orthogonalization. The singular
    values ride in R (L is orthonormal; R = L^T W carries magnitude), which is
    the transpose-equivalent of the paper's L = U Sigma convention — the
    product L @ R and the spanned subspaces are identical (tested).

    Supports leading batch dims (stacked scan layers / expert banks):
    w (..., O, I), prev.L (..., O, K).
    """
    # L <- orth(W @ orth(W^T L_prev))  == one power-iteration on the column
    # space; stage-wise orthogonalization keeps Gram condition at cond(W)^2
    wf = w.astype(jnp.float32)
    lnorm = cholesky_qr(prev.L).astype(jnp.float32)
    v = cholesky_qr(jnp.einsum("...oi,...ok->...ik", wf, lnorm))
    L = cholesky_qr(jnp.einsum("...oi,...ik->...ok", wf, v))
    R = jnp.einsum("...ok,...oi->...ki", L, wf)
    return WSIState(L=L.astype(w.dtype), R=R.astype(w.dtype))


def wsi_refresh_factored(state: WSIState) -> WSIState:
    """Re-balance a directly-trained (L, R) pair without a full W.

    Equivalent to one WSI step on the implicit W = L R:
        W^T L = R^T (L^T L);  W (W^T L) = L (R R^T) (L^T L)
    i.e. the column space of W W^T L lives inside span(L) — so the refresh
    reduces to orthogonalizing L and folding the mixing matrix into R.
    Cost O(O*K^2 + K^2*I): no O×I product, scales to pods.

    The orthogonalization AND the mixing matrix M = Q^T L come from ONE
    fused CholeskyQR (kernels.ops.cholesky_qr_mix: single Pallas launch on
    TPU, Gram-factor identity M = C^{-1}(L^T L) everywhere) — L is swept
    twice total and the second O(O*K^2) tall-skinny product of the naive
    formulation is gone.
    """
    from repro.kernels.ops import cholesky_qr_mix  # lazy: core stays pallas-free

    q, m = cholesky_qr_mix(state.L)                       # (...,O,K), (...,K,K)
    r = jnp.einsum("...kj,...ji->...ki", m, state.R.astype(jnp.float32))
    return WSIState(L=q.astype(state.L.dtype), R=r.astype(state.R.dtype))


def wsi_apply(state: WSIState) -> jax.Array:
    """Materialize W~ = L R (small-scale / tests only)."""
    return state.L @ state.R


def wsi_flops(o: int, i: int, k: int) -> int:
    """Per-step WSI overhead FLOPs (paper Eq. 36): 4*I*O*K + 2*O*K^2."""
    return 4 * i * o * k + 2 * o * k * k
