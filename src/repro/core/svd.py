"""Truncated SVD and explained-variance rank selection (paper §3.3, Eq. 5-7).

The paper picks, per layer, the smallest rank K such that the cumulative
explained variance of the leading singular values reaches a threshold eps:

    sigma_j^2 = s_j^2 / sum_k s_k^2,   K = min{K : sum_{j<=K} sigma_j^2 >= eps}

This module provides both the dynamic (data-dependent K; used at calibration
time and in paper-scale experiments) and static (fixed K; required for XLA
static shapes at scale) entry points.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SVDFactors(NamedTuple):
    """W ~= L @ R with L (O,K), R (K,I)."""

    L: jax.Array
    R: jax.Array


def explained_variance(s: jax.Array) -> jax.Array:
    """Per-singular-value explained variance sigma_j^2 (paper §3.3)."""
    e = s.astype(jnp.float32) ** 2
    return e / jnp.maximum(jnp.sum(e), 1e-30)


def rank_for_threshold(s: jax.Array, eps: float) -> jax.Array:
    """Smallest K with cumulative explained variance >= eps. Traceable.

    Returns a scalar int32 in [1, len(s)].
    """
    cum = jnp.cumsum(explained_variance(s))
    # first index where cum >= eps (eps clipped so eps=1.0 keeps full rank)
    k = jnp.argmax(cum >= jnp.minimum(eps, cum[-1] - 1e-7))
    return jnp.maximum(k + 1, 1).astype(jnp.int32)


def pick_rank(w, eps: float, align: int = 1, max_rank: int | None = None) -> int:
    """Concrete (python int) rank for weight matrix `w` under threshold `eps`.

    Used offline / at-init where shapes may be data-dependent. `align` rounds
    the rank UP to a hardware-friendly multiple (128 for the TPU MXU) without
    ever lowering the information kept.
    """
    s = jnp.linalg.svd(jnp.asarray(w, jnp.float32), compute_uv=False)
    k = int(rank_for_threshold(s, eps))
    if align > 1:
        k = -(-k // align) * align
    full = min(w.shape[-2], w.shape[-1])
    k = min(k, full if max_rank is None else min(full, max_rank))
    return max(k, 1)


def truncated_svd(w: jax.Array, k: int) -> SVDFactors:
    """Rank-k factorization W ~= L R via SVD (paper Eq. 5-7).

    L = U_k S_k  (O,K);  R = V_k^T  (K,I).  R has orthonormal rows and L
    carries the singular values, matching Eq. 7.
    """
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    L = (u[:, :k] * s[:k][None, :]).astype(w.dtype)
    R = vt[:k, :].astype(w.dtype)
    return SVDFactors(L=L, R=R)


def svd_approx(w: jax.Array, k: int) -> jax.Array:
    """Best rank-k approximation of w (oracle for tests)."""
    f = truncated_svd(w, k)
    return (f.L @ f.R).astype(w.dtype)


def reconstruction_rel_error(w: jax.Array, f: SVDFactors) -> jax.Array:
    """||W - LR||_F / ||W||_F."""
    diff = w.astype(jnp.float32) - (f.L.astype(jnp.float32) @ f.R.astype(jnp.float32))
    return jnp.linalg.norm(diff) / jnp.maximum(jnp.linalg.norm(w.astype(jnp.float32)), 1e-30)
