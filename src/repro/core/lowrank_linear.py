"""WASI linear layers: factored weights + compressed saved activations.

This is the paper's core contribution as a composable JAX primitive. Three
custom-VJP matmul variants cover the paper's experiment matrix:

  wasi_matmul    — factored W = L R  AND  ASI-compressed residuals  (WASI)
  asi_matmul     — dense W, ASI-compressed residuals                (ASI)
  wasi_matmul_project — forward through (L, R) but gradient delivered to the
                   FULL W via f_LR (paper Eq. 9-11 "project" update mode)

Math (3D activations; 4D analogous — paper App. A.1):
  forward   y = (x R^T) L^T                       (Eq. 8)
  dx        = (dy L) R                            (Eq. 10)
  dL[o,k]   = sum_bn dy[b,n,o] h~[b,n,k],  h~ = x~ R^T
  dR[k,i]   = sum_bn dh[b,n,k] x~[b,n,i],  dh = dy L
  dW[o,i]   = sum_bn dy[b,n,o] x~[b,n,i]          (project mode, Eqs. 15-18)

where x~ is the Tucker form of x — the contractions consume the factors
directly (core/asi.flr_weight_grad_*), the dense activation is NEVER rebuilt.
Key trick: h~ = x~ R^T is itself a Tucker tensor whose last-mode factor is
(R @ U_last); so dL reuses the same f_LR kernel as dW.

SKETCH-SAVING RESIDUALS: the custom-VJP boundary is what makes the paper's
memory claim real — JAX saves exactly what the fwd rule returns, nothing
else. ``wasi_matmul`` saves the Tucker factors of x~ plus the rank-K sketch
h~ = x~ R^T (itself in Tucker form: same core, last factor R @ U_last,
materialized at FORWARD time so backward does zero residual rebuilding) —
never the (B, N, I) activation. ``measured_residual_bytes`` in
utils/memprof.py verifies this against a jax.vjp probe; the no-ASI factored
path gets the analogous treatment in kernels/ops.py (dense rank-K sketch
saved by the fused Pallas forward, consumed by the single-launch backward).

The ASI warm-start state is threaded functionally: compress() is called on a
stop-gradient copy of x OUTSIDE the custom-VJP boundary and its output rides
in as residual-only input (zero cotangent).
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.asi import (
    ASIState,
    TuckerFactors,
    asi_init,
    asi_step,
    flr_weight_grad_3d,
    flr_weight_grad_4d,
)


def _flr(xt: TuckerFactors, dy: jax.Array) -> jax.Array:
    """Dispatch f_LR on activation tensor order (3D/4D)."""
    if dy.ndim == 3:
        return flr_weight_grad_3d(xt, dy)
    if dy.ndim == 4:
        return flr_weight_grad_4d(xt, dy)
    raise ValueError(f"f_LR supports 3D/4D activations, got ndim={dy.ndim}")


def _project_last_mode(xt: TuckerFactors, r: jax.Array) -> TuckerFactors:
    """Tucker form of (x~ contracted with R^T on the feature mode):
    replace last factor U_I (I, r_m) by R @ U_I (K, r_m). If the feature
    mode is identity (None), R itself becomes the factor (K, I)."""
    last = xt.us[-1]
    new_last = r if last is None else r.astype(last.dtype) @ last
    return TuckerFactors(core=xt.core, us=xt.us[:-1] + (new_last,))


# ---------------------------------------------------------------------------
# WASI: factored weights, compressed residuals (the scale branch).
# ---------------------------------------------------------------------------

@jax.custom_vjp
def wasi_matmul(x: jax.Array, L: jax.Array, R: jax.Array, xt: TuckerFactors):
    """y = (x @ R^T) @ L^T with Tucker residuals. x: (..., I) -> (..., O)."""
    h = jnp.einsum("...i,ki->...k", x, R)
    return jnp.einsum("...k,ok->...o", h, L)


def _wasi_fwd(x, L, R, xt):
    y = wasi_matmul(x, L, R, xt)
    # Residuals are the SKETCH, not the activation: the Tucker factors of
    # x~ plus h~ = x~ R^T in Tucker form (shares x~'s core; only the K×r_m
    # last factor is new, built here at forward time). x itself is dropped
    # at this boundary — residual bytes per linear are
    # tucker_storage(shape, ranks) + K*r_m + |L| + |R| instead of B*N*I
    # (utils/memprof.py measures exactly this via a jax.vjp probe).
    ht = _project_last_mode(xt, R)
    return y, (xt, ht, L, R)


def _wasi_bwd(res, dy):
    xt, ht, L, R = res
    dh = jnp.einsum("...o,ok->...k", dy, L)            # (B,N,K)
    dx = jnp.einsum("...k,ki->...i", dh, R)            # Eq. 10
    # _flr returns dW[o,i] for dy[...,o], act[...,i]; here the activation is
    # h~ whose feature dim is K, so this is directly dL (O, K).
    dL = _flr(ht, dy)
    dR = _flr(xt, dh)                                   # "o"=K, "i"=I -> (K,I)
    zeros_xt = jax.tree.map(jnp.zeros_like, xt)
    return dx, dL.astype(L.dtype), dR.astype(R.dtype), zeros_xt


wasi_matmul.defvjp(_wasi_fwd, _wasi_bwd)


# ---------------------------------------------------------------------------
# ASI-only: dense weight, compressed residuals (paper's ASI baseline).
# ---------------------------------------------------------------------------

@jax.custom_vjp
def asi_matmul(x: jax.Array, w: jax.Array, xt: TuckerFactors):
    """y = x @ W^T with Tucker residuals. w: (O, I)."""
    return jnp.einsum("...i,oi->...o", x, w)


def _asi_fwd(x, w, xt):
    return asi_matmul(x, w, xt), (xt, w)


def _asi_bwd(res, dy):
    xt, w = res
    dx = jnp.einsum("...o,oi->...i", dy, w)
    dw = _flr(xt, dy)
    zeros_xt = jax.tree.map(jnp.zeros_like, xt)
    return dx, dw.astype(w.dtype), zeros_xt


asi_matmul.defvjp(_asi_fwd, _asi_bwd)


# ---------------------------------------------------------------------------
# Project mode: paper-faithful Eq. 9-11 (full W param, factored forward).
# ---------------------------------------------------------------------------

@jax.custom_vjp
def wasi_matmul_project(x, w, L, R, xt: TuckerFactors):
    """Forward uses the factors; gradient lands on the full W (Eq. 11).
    L, R are derived from W by WSI *outside* the step (non-trainable here)."""
    h = jnp.einsum("...i,ki->...k", x, R)
    return jnp.einsum("...k,ok->...o", h, L)


def _wasi_proj_fwd(x, w, L, R, xt):
    return wasi_matmul_project(x, w, L, R, xt), (xt, L, R)


def _wasi_proj_bwd(res, dy):
    xt, L, R = res
    dx = jnp.einsum("...o,ok,ki->...i", dy, L, R)       # Eq. 10
    dw = _flr(xt, dy)                                   # Eqs. 15-18: dW~
    zeros_xt = jax.tree.map(jnp.zeros_like, xt)
    return dx, dw, jnp.zeros_like(L), jnp.zeros_like(R), zeros_xt


wasi_matmul_project.defvjp(_wasi_proj_fwd, _wasi_proj_bwd)


@jax.custom_vjp
def wsi_matmul_project_exact(x, w, L, R):
    """Project mode without activation compression (WSI ablation): factored
    forward, EXACT dense gradient dW = dy^T x (residual: uncompressed x)."""
    h = jnp.einsum("...i,ki->...k", x, R)
    return jnp.einsum("...k,ok->...o", h, L)


def _wsi_proj_exact_fwd(x, w, L, R):
    return wsi_matmul_project_exact(x, w, L, R), (x, L, R)


def _wsi_proj_exact_bwd(res, dy):
    x, L, R = res
    dx = jnp.einsum("...o,ok,ki->...i", dy, L, R)
    dw = jnp.einsum("...o,...i->oi", dy, x)
    return dx, dw, jnp.zeros_like(L), jnp.zeros_like(R)


wsi_matmul_project_exact.defvjp(_wsi_proj_exact_fwd, _wsi_proj_exact_bwd)


# ---------------------------------------------------------------------------
# Module-level convenience: compress-then-matmul with threaded ASI state.
# ---------------------------------------------------------------------------

class WasiLinearParams(NamedTuple):
    L: jax.Array           # (O, K)
    R: jax.Array           # (K, I)
    bias: jax.Array | None = None


def init_wasi_linear(key, in_dim: int, out_dim: int, rank: int, *,
                     bias: bool = False, dtype=jnp.float32,
                     scale: float | None = None) -> WasiLinearParams:
    """Initialize factored linear. The product L R matches a LeCun-normal
    dense init in expectation: both factors get std (fan_in)^-1/4-ish split;
    we draw a dense W then factor exactly via its top-K subspace? That costs
    an SVD per layer at init — instead we use the variance-preserving split
    std_L = std_R = (std_W / sqrt(K))^0.5 heuristic (tested: output variance
    matches dense init within 10%)."""
    kl, kr, kb = jax.random.split(key, 3)
    std_w = scale if scale is not None else (1.0 / jnp.sqrt(in_dim))
    split = jnp.sqrt(std_w / jnp.sqrt(rank))
    L = (jax.random.normal(kl, (out_dim, rank), jnp.float32) * split).astype(dtype)
    R = (jax.random.normal(kr, (rank, in_dim), jnp.float32) * split).astype(dtype)
    b = jnp.zeros((out_dim,), dtype) if bias else None
    return WasiLinearParams(L=L, R=R, bias=b)


def init_asi_state_for(key, act_shape: Sequence[int], ranks: Sequence[int],
                       dtype=jnp.float32) -> ASIState:
    return asi_init(key, act_shape, ranks, dtype)


def wasi_linear_apply(params: WasiLinearParams, x: jax.Array,
                      asi_state: ASIState | None):
    """Apply a WASI linear. Returns (y, new_asi_state).

    If ``asi_state`` is None the layer runs without activation compression
    (inference / serve path, or ASI disabled) — the fused kernel path then
    applies, with exact gradients from its sketch-saving custom VJP.
    """
    if asi_state is None:
        from repro.kernels.ops import lowrank_matmul  # kernel on TPU

        y = lowrank_matmul(x, params.R, params.L)
    else:
        xt, new_state = asi_step(jax.lax.stop_gradient(x), asi_state)
        y = wasi_matmul(x, params.L, params.R, xt)
    if params.bias is not None:
        y = y + params.bias
    return y, (new_state if asi_state is not None else None)
