"""Activation Subspace Iteration (paper §3.2, Alg. 2, App. A.1).

Compresses a saved-for-backward activation tensor A (3D: B×N×I, or 4D:
B×H×W×I) into a Tucker form

    A ~= S ×_1 U1 ×_2 U2 ... ×_m Um

with fixed per-mode ranks r, maintained across training steps by ONE
warm-started power-iteration per mode (PowerSGD-style; Vogels et al. 2019):

    t = 0 : V ~ N(0,1);                 U_m = orth(A_(m) V)
    t > 0 : V = A_(m)^T U_m^{(t-1)};    U_m = orth(A_(m) V)

Storage drops from prod(D) to prod(r) + sum(D_m * r_m)  (paper Eq. 31/44).

TPU adaptation: unfoldings are expressed as reshapes+transposes feeding plain
matmuls (MXU), mode products via einsum; orthogonalization via CholeskyQR.
All functions are shape-polymorphic over leading batch dims and jit/scansafe.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.orthogonal import cholesky_qr


class TuckerFactors(NamedTuple):
    """Tucker core + per-mode factor matrices. ``core``: (r1,...,rm);
    ``us``: tuple of (D_m, r_m) matrices with orthonormal columns — or None
    for a mode kept at FULL rank (identity factor; the scale default for the
    batch mode, which keeps the compression DP-shard-local AND skips the
    dense (B,B) rotation that would otherwise dominate f_LR FLOPs)."""

    core: jax.Array
    us: tuple


class ASIState(NamedTuple):
    """Warm-start state carried across training steps: per-mode factors."""

    us: tuple  # tuple of (D_m, r_m)


def _unfold(a: jax.Array, mode: int) -> jax.Array:
    """Mode-m unfolding: (D_m, prod_{j!=m} D_j)."""
    order = (mode,) + tuple(i for i in range(a.ndim) if i != mode)
    return jnp.transpose(a, order).reshape(a.shape[mode], -1)


def _mode_product(t: jax.Array, m: jax.Array, mode: int) -> jax.Array:
    """t ×_mode m  where m: (Q, D_mode) — contracts D_mode (paper Eq. 27)."""
    t2 = jnp.moveaxis(t, mode, -1)
    out = jnp.einsum("...d,qd->...q", t2, m)
    return jnp.moveaxis(out, -1, mode)


def asi_init(key: jax.Array, shape: Sequence[int], ranks: Sequence[int],
             dtype=jnp.float32) -> ASIState:
    """t=0 warm-start: random orthonormal factors (Alg. 2 line 7).
    rank == dim => identity mode (factor None, no iteration ever)."""
    us = []
    for d, r in zip(shape, ranks):
        if r >= d:
            us.append(None)
            continue
        key, sub = jax.random.split(key)
        v = jax.random.normal(sub, (d, r), jnp.float32)
        us.append(cholesky_qr(v).astype(dtype))
    return ASIState(us=tuple(us))


def _gram_last(v: jax.Array) -> jax.Array:
    """(r, r) Gram over ALL leading dims of v (..., r) — pure contraction,
    no reshape (a sharded leading dim stays a contraction dim)."""
    axes = tuple(range(v.ndim - 1))
    return jnp.tensordot(v, v, axes=(axes, axes))


def _orth_last(v: jax.Array, shift: float = 1e-6) -> jax.Array:
    """Orthonormalize the last axis of v against all leading dims via
    shifted Cholesky (tensor CholeskyQR; same shift ladder as
    core/orthogonal.cholesky_qr)."""
    vf = v.astype(jnp.float32)
    g = _gram_last(vf)
    r = g.shape[-1]
    scale = jnp.maximum(jnp.trace(g) / r, 1e-30)
    eye = jnp.eye(r, dtype=g.dtype)
    c1 = jnp.linalg.cholesky(g + shift * scale * eye)
    c2 = jnp.linalg.cholesky(g + 1e4 * shift * scale * eye)
    c = jnp.where(jnp.isfinite(c1).all(), c1, c2)
    inv = jax.scipy.linalg.solve_triangular(c, eye, lower=True)  # C^{-1}
    return jnp.einsum("...r,jr->...j", vf, inv)


def asi_project(a: jax.Array, state: ASIState) -> TuckerFactors:
    """Project ``a`` onto the EXISTING factors (no power iteration) — the
    cheap steady-state compression when refreshes are amortized."""
    core = a
    for mode, u in enumerate(state.us):
        if u is None:
            continue
        core = _mode_product(core, u.T.astype(a.dtype), mode)
    return TuckerFactors(core=core, us=state.us)


def asi_step(a: jax.Array, state: ASIState) -> tuple[TuckerFactors, ASIState]:
    """One warm-started subspace-iteration Tucker compression (Alg. 2).

    Returns the factors approximating ``a`` and the refreshed warm-start
    state to feed the next training step.

    RESHAPE-FREE: the textbook mode-m unfolding (D_m, prod other dims) puts
    the sharded batch dim INSIDE the merged axis, which GSPMD cannot
    represent — it all-gathers the whole activation per mode per linear
    (measured 150+ GiB/device on zamba2; EXPERIMENTS.md §Perf iter. 6). All
    unfolding matmuls are therefore expressed as tensor contractions over
    the ORIGINAL dims: sharded dims remain contraction dims and only (D_m,r)
    / (r,r) partials cross shards.
    """
    new_us = []
    core = a
    rest_axes = None
    for mode, u_prev in enumerate(state.us):
        if u_prev is None:  # identity (full-rank) mode: nothing to iterate
            new_us.append(None)
            continue
        af = a.astype(jnp.float32)
        rest = tuple(i for i in range(a.ndim) if i != mode)
        # v = A^T U  without unfolding: contract D_m, keep rest dims + r
        v = _mode_product(af, u_prev.astype(jnp.float32).T, mode)
        v = jnp.moveaxis(v, mode, -1)              # (..., r) rest-ordered
        # stage-wise orthogonalization (cond^2 per stage, see orthogonal.py)
        v = _orth_last(v)
        v = jnp.moveaxis(v, -1, mode)              # r back at mode position
        # u = orth(A V): contract ALL rest dims of a with those of v
        u = jnp.tensordot(af, v, axes=(rest, rest))  # (D_m, r)
        u = cholesky_qr(u).astype(a.dtype)
        new_us.append(u)
        core = _mode_product(core, u.T.astype(a.dtype), mode)  # project
    return TuckerFactors(core=core, us=tuple(new_us)), ASIState(us=tuple(new_us))


def tucker_reconstruct(f: TuckerFactors) -> jax.Array:
    """A~ = S ×_1 U1 ... ×_m Um (oracle / tests; backward never calls this
    at scale — it consumes the factors directly, see core/lowrank_linear)."""
    out = f.core
    for mode, u in enumerate(f.us):
        if u is None:
            continue
        out = _mode_product(out, u, mode)
    return out


def tucker_storage(shape: Sequence[int], ranks: Sequence[int]) -> int:
    """Element count of the compressed form (paper Eq. 31/44)."""
    prod_r = 1
    for r in ranks:
        prod_r *= r
    return prod_r + sum(d * r for d, r in zip(shape, ranks))


def compression_ratio(shape: Sequence[int], ranks: Sequence[int]) -> float:
    dense = 1
    for d in shape:
        dense *= d
    return dense / tucker_storage(shape, ranks)


def tucker_rel_error(a: jax.Array, f: TuckerFactors) -> jax.Array:
    """||A - A~||_F / ||A||_F."""
    diff = a.astype(jnp.float32) - tucker_reconstruct(f).astype(jnp.float32)
    return jnp.linalg.norm(diff) / jnp.maximum(jnp.linalg.norm(a.astype(jnp.float32)), 1e-30)


# ---------------------------------------------------------------------------
# f_LR — weight gradient straight from Tucker factors (paper App. A.1).
# ---------------------------------------------------------------------------

def _flr_general(f: TuckerFactors, dy: jax.Array) -> jax.Array:
    """dW for ANY None pattern of Tucker factors: partially reconstruct all
    modes but the feature mode (so the biggest intermediate is dy-sized,
    never the dense activation), contract with dy over every position dim,
    then expand the feature factor. Fallback for factor patterns the
    specialized reorderings below don't cover (e.g. compressed batch with
    identity token mode)."""
    t = f.core
    for mode, u in enumerate(f.us[:-1]):
        if u is not None:
            t = _mode_product(t, u, mode)           # expand (D_m, r_m)
    lead = tuple(range(dy.ndim - 1))
    g = jnp.tensordot(dy, t, axes=(lead, lead))     # (O, r_last or I)
    u_last = f.us[-1]
    return g if u_last is None else jnp.einsum("ot,it->oi", g, u_last)


def flr_weight_grad_3d(f: TuckerFactors, dy: jax.Array) -> jax.Array:
    """dW (O,I) from Tucker-compressed A (B,N,I) and dy (B,N,O).

    General path implements Eqs. 15-18 via reordered contractions so the
    dense (B,N,I) activation is never rebuilt:
        Z1[n,o,r1]   = sum_b dy[b,n,o] U1[b,r1]
        Z2[r1,r3,n]  = sum_r2 S[r1,r2,r3] U2[n,r2]
        Z3[r1,i,n]   = sum_r3 Z2[r1,r3,n] U3[i,r3]
        dW[o,i]      = sum_{n,r1} Z1[n,o,r1] Z3[r1,i,n]

    Identity-batch path (u1 is None — the sharding-friendly scale mode):
    contract the small ranks FIRST so no (r1, I, N)-sized intermediate ever
    exists:
        T[b,q,o]  = sum_n dy[b,n,o] U2[n,q]          (or dy directly if u2 None)
        G[t,o]    = sum_{b,q} S[b,q,t] T[b,q,o]
        dW[o,i]   = sum_t G[t,o] U3[i,t]
    """
    s, (u1, u2, u3) = f.core, f.us
    if u1 is None:
        # batch mode at full rank: core is (B, r2, r3)
        t = dy if u2 is None else jnp.einsum("bno,nq->bqo", dy, u2)
        if u3 is None:
            return jnp.einsum("bqi,bqo->oi", s, t)
        g = jnp.einsum("bqt,bqo->to", s, t)
        return jnp.einsum("to,it->oi", g, u3)
    if u2 is None or u3 is None:
        return _flr_general(f, dy)
    z1 = jnp.einsum("bno,br->nor", dy, u1)          # Eq. 15
    z2 = jnp.einsum("rqt,nq->rtn", s, u2)           # Eq. 16 (r=r1,q=r2,t=r3)
    z3 = jnp.einsum("rtn,it->rin", z2, u3)          # Eq. 17
    return jnp.einsum("nor,rin->oi", z1, z3)        # Eq. 18


def flr_weight_grad_4d(f: TuckerFactors, dy: jax.Array) -> jax.Array:
    """dW (O,I) from Tucker-compressed A (B,H,W,I) and dy (B,H,W,O).

    Eqs. 22-26 analogue (same reordering idea, one extra mode).
    """
    s, (u1, u2, u3, u4) = f.core, f.us
    if u1 is None:
        # identity batch mode: core (B, r2, r3, r4)
        t = dy
        if u2 is not None:
            t = jnp.einsum("bhwo,hq->bqwo", t, u2)
        if u3 is not None:
            t = jnp.einsum("bqwo,wt->bqto", t, u3)
        if u4 is None:
            return jnp.einsum("bqti,bqto->oi", s, t)
        g = jnp.einsum("bqtf,bqto->fo", s, t)
        return jnp.einsum("fo,if->oi", g, u4)
    if u2 is None or u3 is None or u4 is None:
        return _flr_general(f, dy)
    z1 = jnp.einsum("bhwo,br->rhwo", dy, u1)        # Eq. 22
    z2 = jnp.einsum("rqtf,hq->rhtf", s, u2)         # Eq. 23
    z3 = jnp.einsum("rhwo,wt->rhto", z1, u3)        # Eq. 24
    z4 = jnp.einsum("rhtf,if->rhit", z2, u4)        # Eq. 25
    return jnp.einsum("rhto,rhit->oi", z3, z4)      # Eq. 26
