"""Configuration system: typed dataclasses + registry.

Every assigned architecture is a ``ModelConfig`` built by a module under
``repro/configs``; ``repro.configs.get(name)`` resolves ``--arch <id>``.
Configs are plain frozen dataclasses — hashable, printable, diffable.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Literal, Sequence

BlockKind = Literal[
    "dense",        # attn + MLP (standard decoder block)
    "local",        # sliding-window attn + MLP
    "moe",          # attn + MoE FFN
    "moe_swa",      # sliding-window attn + MoE FFN (mixtral)
    "mamba1",       # Mamba-1 selective-scan block
    "mamba2",       # Mamba-2 SSD block
    "mamba2_attn",  # Mamba-2 block followed by the shared attention block (zamba2)
    "enc",          # bidirectional attn + MLP (encoder)
    "dec",          # causal self-attn + cross-attn + MLP (decoder)
]


@dataclass(frozen=True)
class AsiConfig:
    """Activation Subspace Iteration (paper Alg. 2) knobs."""

    # per-mode rank fractions for (batch, token, feature[, extra]) modes
    batch_frac: float = 1.0     # 1.0 => identity (DP-sharding friendly)
    token_frac: float = 0.25
    feature_frac: float = 0.25
    align: int = 8
    skip_batch: bool = True     # never couple samples across DP shards
    # frozen=True skips the per-step power iteration and only PROJECTS onto
    # the existing factors — the steady-state step when the subspace refresh
    # is amortized every cfg.wasi.refresh_every steps from the host loop
    # (paper runs the iteration every step; EXPERIMENTS.md §Perf iter. 9)
    frozen: bool = False


@dataclass(frozen=True)
class WasiConfig:
    """Weight-Activation Subspace Iteration (the paper's method).

    method: "none"  — vanilla dense training
            "wasi"  — factored weights + ASI-compressed residuals (the paper)
            "asi"   — dense weights + ASI-compressed residuals (ASI baseline)
            "wsi"   — factored weights only (WSI ablation)
    """

    method: Literal["none", "wasi", "asi", "wsi"] = "none"
    scope: Literal["none", "mlp", "all"] = "all"   # which linears get factored
    # paper knob (explained variance). Used by calibration + paper-scale runs.
    epsilon: float = 0.9
    # scale knob: static rank fraction of min(O, I); eps->frac calibrated offline
    rank_frac: float = 0.25
    rank_align: int = 128       # MXU lane alignment (DESIGN.md §3.2)
    min_rank: int = 8
    update_mode: Literal["factored", "project"] = "factored"
    refresh_every: int = 64     # WSI re-orthogonalization period (factored mode)
    asi: AsiConfig = field(default_factory=AsiConfig)

    @property
    def factored(self) -> bool:
        """Parameters ARE the factors (scale branch)."""
        return self.method in ("wasi", "wsi") and self.update_mode == "factored"

    @property
    def project(self) -> bool:
        """Paper-faithful Eq. 9-11: dense W param + per-step WSI extraction."""
        return self.method in ("wasi", "wsi") and self.update_mode == "project"

    @property
    def compress_acts(self) -> bool:
        """Saved-for-backward activations Tucker-compressed?"""
        return self.method in ("wasi", "asi")


@dataclass(frozen=True)
class MoeConfig:
    n_experts: int = 0
    top_k: int = 2
    n_shared: int = 0           # deepseek-style always-on shared experts
    expert_d_ff: int = 0        # per-expert hidden dim (fine-grained MoE)
    capacity_factor: float = 1.25
    shard: Literal["expert", "ffn"] = "expert"   # EP vs TP sharding of experts


@dataclass(frozen=True)
class SsmConfig:
    d_state: int = 16
    expand: int = 2
    d_conv: int = 4
    head_dim: int = 64          # mamba2 only
    chunk: int = 256            # SSD chunk length
    dt_rank: int = 0            # mamba1: 0 => d_model // 16


@dataclass(frozen=True)
class LayerGroup:
    """A repeated pattern of block kinds, scanned over ``repeat``.

    Scan-over-groups keeps HLO size independent of depth; heterogeneous
    stacks (gemma3 5:1, zamba2 shared-attn interleave) become homogeneous at
    group granularity (DESIGN.md §6).
    """

    pattern: tuple[BlockKind, ...]
    repeat: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["lm", "encdec", "vit"] = "lm"
    n_layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    n_kv_heads: int = 12
    d_ff: int = 3072
    vocab_size: int = 32000
    head_dim: int = 0           # 0 => d_model // n_heads
    groups: tuple[LayerGroup, ...] = ()
    # attention
    rope_theta: float = 10000.0
    qkv_bias: bool = False
    window: int = 4096          # sliding-window size for local/SWA blocks
    mlp_act: Literal["gelu", "swiglu"] = "swiglu"
    tie_embeddings: bool = False
    norm: Literal["rmsnorm", "layernorm"] = "rmsnorm"
    # encoder-decoder
    n_enc_layers: int = 0
    enc_seq: int = 0            # fixed encoder memory length (whisper: 1500)
    # subconfigs
    moe: MoeConfig = field(default_factory=MoeConfig)
    ssm: SsmConfig = field(default_factory=SsmConfig)
    wasi: WasiConfig = field(default_factory=WasiConfig)
    # numerics / memory
    dtype: str = "bfloat16"
    remat: Literal["none", "block"] = "block"
    logit_softcap: float = 0.0
    max_seq: int = 131072
    # metadata
    sub_quadratic: bool = False   # eligible for long_500k
    has_decoder: bool = True      # False => skip decode shapes

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        """Embedding/lm_head table size: vocab rounded up to a multiple of
        256 so the vocab dim shards evenly on any production mesh axis
        (standard practice; logical vocab_size is unchanged — labels and
        sampling never touch the pad rows)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def total_pattern_layers(self) -> int:
        return sum(len(g.pattern) * g.repeat for g in self.groups)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    """Paper §B.1 recipe + scale knobs."""

    optimizer: Literal["sgd", "adamw"] = "sgd"
    lr: float = 0.05
    momentum: float = 0.0
    weight_decay: float = 1e-4
    clip_norm: float = 2.0
    schedule: Literal["cosine", "constant"] = "cosine"
    steps: int = 1000
    warmup: int = 0
    seed: int = 233             # paper §B.2 fixes seed 233
    microbatch: int = 0         # 0 => no gradient accumulation
    powersgd_rank: int = 0      # 0 => no DP gradient compression of dense params
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
