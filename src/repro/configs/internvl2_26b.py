"""internvl2-26b [vlm] 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 [arXiv:2404.16821; hf].

Backbone only; the ViT frontend is a STUB: input_specs supplies precomputed
patch embeddings (B, S, d) consumed directly by lm_forward."""
from repro.config import ModelConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="lm",
        n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
        vocab_size=92553, head_dim=128, mlp_act="swiglu", norm="rmsnorm",
        groups=uniform_groups("dense", 48),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=False, has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab_size=256, head_dim=16, mlp_act="swiglu", norm="rmsnorm",
        groups=uniform_groups("dense", 2),
        wasi=SMOKE_WASI, dtype="float32", remat="none")
