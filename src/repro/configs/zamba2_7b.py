"""zamba2-7b [hybrid] 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000 ssm_state=64 — Mamba2 + shared attn blocks
[arXiv:2411.15242; unverified].

81 mamba2 layers; a SHARED transformer block (attn+MLP, one weight copy)
fires after every 6th mamba2 layer: 13 x (5 mamba2 + mamba2_attn) + 3 tail."""
from repro.config import ModelConfig, SsmConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, patterned_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="lm",
        n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336,
        vocab_size=32000, head_dim=112, mlp_act="swiglu", norm="rmsnorm",
        groups=patterned_groups(("mamba2",) * 5 + ("mamba2_attn",), 13,
                                tail=("mamba2",) * 3),
        ssm=SsmConfig(d_state=64, expand=2, d_conv=4, head_dim=64, chunk=256),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=True,  # hybrid — long_500k runs
        has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="lm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=256, head_dim=16, mlp_act="swiglu", norm="rmsnorm",
        groups=patterned_groups(("mamba2", "mamba2", "mamba2_attn"), 1),
        ssm=SsmConfig(d_state=8, expand=2, d_conv=4, head_dim=16, chunk=8),
        wasi=SMOKE_WASI, dtype="float32", remat="none", sub_quadratic=True)
