"""mixtral-8x7b [moe] 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000
— MoE 8 experts top-2, sliding-window attention [arXiv:2401.04088; hf]."""
from repro.config import ModelConfig, MoeConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="lm",
        n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
        vocab_size=32000, head_dim=128, window=4096,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=1e6,
        groups=uniform_groups("moe_swa", 32),
        moe=MoeConfig(n_experts=8, top_k=2, expert_d_ff=14336,
                      capacity_factor=1.25, shard="ffn"),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=True,  # SWA — long_500k runs
        has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, window=8,
        mlp_act="swiglu", norm="rmsnorm",
        groups=uniform_groups("moe_swa", 2),
        moe=MoeConfig(n_experts=4, top_k=2, expert_d_ff=128,
                      capacity_factor=2.0, shard="ffn"),
        wasi=SMOKE_WASI, dtype="float32", remat="none", sub_quadratic=True)
