"""stablelm-3b [dense] 32L d_model=2560 32H (GQA kv=32) d_ff=6912
vocab=50304 [hf:stabilityai/stablelm-2-1_6b; unverified]."""
from repro.config import ModelConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-3b", family="lm",
        n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=6912,
        vocab_size=50304, head_dim=80, mlp_act="swiglu", norm="layernorm",
        groups=uniform_groups("dense", 32),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=False, has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, head_dim=16, mlp_act="swiglu", norm="layernorm",
        groups=uniform_groups("dense", 2),
        wasi=SMOKE_WASI, dtype="float32", remat="none")
