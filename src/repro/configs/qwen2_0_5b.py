"""qwen2-0.5b [dense] 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936
— GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.config import ModelConfig, SsmConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-0.5b", family="lm",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
        vocab_size=151936, head_dim=64, qkv_bias=True, tie_embeddings=True,
        rope_theta=1e6, mlp_act="swiglu", norm="rmsnorm",
        groups=uniform_groups("dense", 24),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=False, has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, qkv_bias=True, tie_embeddings=True,
        mlp_act="swiglu", norm="rmsnorm",
        groups=uniform_groups("dense", 2),
        wasi=SMOKE_WASI, dtype="float32", remat="none")
