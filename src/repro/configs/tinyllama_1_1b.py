"""tinyllama-1.1b — the paper's own decoder-only model (Fig. 7 experiments).
22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000 [arXiv:2401.02385]."""
from repro.config import ModelConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-1.1b", family="lm",
        n_layers=22, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=5632,
        vocab_size=32000, head_dim=64, mlp_act="swiglu", norm="rmsnorm",
        groups=uniform_groups("dense", 22),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=False, has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="tinyllama-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, mlp_act="swiglu", norm="rmsnorm",
        groups=uniform_groups("dense", 2),
        wasi=SMOKE_WASI, dtype="float32", remat="none")
