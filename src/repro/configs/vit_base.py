"""vit-base — the paper's primary experimental model (ViT-B/16).
12L d_model=768 12H d_ff=3072, 196 patches + cls, ImageNet-1K pretrain."""
from repro.config import AsiConfig, ModelConfig, WasiConfig
from repro.configs.common import SMOKE_WASI, uniform_groups

# Paper-faithful setting: eps-controlled ranks, project update mode, MLP
# scope for the main experiments (Fig. 5); scope="all" for Tab. 1.
PAPER_WASI = WasiConfig(
    method="wasi", scope="mlp", epsilon=0.8, rank_frac=0.33, rank_align=1,
    min_rank=4, update_mode="project",
    asi=AsiConfig(batch_frac=0.25, token_frac=0.25, feature_frac=0.25,
                  align=1, skip_batch=False))


def config() -> ModelConfig:
    return ModelConfig(
        name="vit-base", family="vit",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
        vocab_size=0, head_dim=64, mlp_act="gelu", norm="layernorm",
        rope_theta=0.0, groups=uniform_groups("dense", 12),
        wasi=PAPER_WASI, dtype="float32", remat="none",
        sub_quadratic=False, has_decoder=False)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="vit-smoke", family="vit",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=0, head_dim=16, mlp_act="gelu", norm="layernorm",
        rope_theta=0.0, groups=uniform_groups("dense", 2),
        wasi=SMOKE_WASI, dtype="float32", remat="none", has_decoder=False)
