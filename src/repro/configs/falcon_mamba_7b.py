"""falcon-mamba-7b [ssm] 64L d_model=4096 (attn-free) vocab=65024
ssm_state=16 — mamba1 arch [arXiv:2410.05355; unverified].

Attention-free: WASI still applies (linear-layer technique; DESIGN.md §5)."""
from repro.config import ModelConfig, SsmConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-7b", family="lm",
        n_layers=64, d_model=4096, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab_size=65024, head_dim=64, norm="rmsnorm",
        groups=uniform_groups("mamba1", 64),
        ssm=SsmConfig(d_state=16, expand=2, d_conv=4, dt_rank=256),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=True, has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=1, n_kv_heads=1, d_ff=0,
        vocab_size=256, head_dim=16, norm="rmsnorm",
        groups=uniform_groups("mamba1", 2),
        ssm=SsmConfig(d_state=8, expand=2, d_conv=4, dt_rank=8),
        wasi=SMOKE_WASI, dtype="float32", remat="none", sub_quadratic=True)
