"""deepseek-moe-16b [moe] 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400 — 2 shared + 64 routed top-6, fine-grained; dense layer 0
(d_ff 10944) [arXiv:2401.06066; hf]."""
from repro.config import LayerGroup, ModelConfig, MoeConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-16b", family="lm",
        n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
        vocab_size=102400, head_dim=128, mlp_act="swiglu", norm="rmsnorm",
        groups=(LayerGroup(pattern=("dense",), repeat=1),
                LayerGroup(pattern=("moe",), repeat=27)),
        moe=MoeConfig(n_experts=64, top_k=6, n_shared=2, expert_d_ff=1408,
                      capacity_factor=1.25, shard="expert"),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=False, has_decoder=True)


def smoke_config() -> ModelConfig:
    from repro.config import LayerGroup
    return ModelConfig(
        name="deepseek-smoke", family="lm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=160,
        vocab_size=256, head_dim=16, mlp_act="swiglu", norm="rmsnorm",
        groups=(LayerGroup(pattern=("dense",), repeat=1),
                LayerGroup(pattern=("moe",), repeat=2)),
        moe=MoeConfig(n_experts=8, top_k=2, n_shared=1, expert_d_ff=32,
                      capacity_factor=2.0, shard="expert"),
        wasi=SMOKE_WASI, dtype="float32", remat="none")
