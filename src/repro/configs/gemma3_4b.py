"""gemma3-4b [dense] 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
— 5:1 local:global, 128k context [hf:google/gemma-3-1b-pt; unverified]."""
from repro.config import ModelConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, patterned_groups


def config() -> ModelConfig:
    # 34 layers = 5 groups of (5 local + 1 global) + 4 local tail
    return ModelConfig(
        name="gemma3-4b", family="lm",
        n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_ff=10240,
        vocab_size=262144, head_dim=256, window=1024, tie_embeddings=True,
        mlp_act="swiglu", norm="rmsnorm", rope_theta=1e6, logit_softcap=30.0,
        groups=patterned_groups(("local",) * 5 + ("dense",), 5,
                                tail=("local",) * 4),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=True,  # 5:1 local:global — long_500k runs (DESIGN §5)
        has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-smoke", family="lm",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=16, window=8, tie_embeddings=True,
        mlp_act="swiglu", norm="rmsnorm", logit_softcap=30.0,
        groups=patterned_groups(("local", "local", "dense"), 1),
        wasi=SMOKE_WASI, dtype="float32", remat="none", sub_quadratic=True)
