"""whisper-tiny [audio] 4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865 —
enc-dec, conv frontend stub [arXiv:2212.04356; unverified].

Frontend is a STUB: input_specs supplies precomputed frame embeddings
(B, 1500, 384). Sinusoidal positions (rope_theta=0)."""
from repro.config import ModelConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny", family="encdec",
        n_layers=4, n_enc_layers=4, enc_seq=1500,
        d_model=384, n_heads=6, n_kv_heads=6, d_ff=1536,
        vocab_size=51865, head_dim=64, mlp_act="gelu", norm="layernorm",
        rope_theta=0.0,
        groups=(),  # encdec has its own enc/dec stacks
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=False,  # full self+cross attention -> skip long_500k
        has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="encdec",
        n_layers=2, n_enc_layers=2, enc_seq=16,
        d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab_size=128, head_dim=16, mlp_act="gelu", norm="layernorm",
        rope_theta=0.0, groups=(),
        wasi=SMOKE_WASI, dtype="float32", remat="none")
