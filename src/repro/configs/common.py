"""Shared config-building helpers."""
from __future__ import annotations

from repro.config import AsiConfig, LayerGroup, ModelConfig, WasiConfig

# Default WASI setting for the scale configs: the paper's method as the
# framework's first-class feature. rank_frac 0.25 corresponds to eps≈0.8 on
# trained transformer linears (calibrated by benchmarks/fig3_wsi_vs_svd.py).
SCALE_WASI = WasiConfig(
    method="wasi", scope="all", epsilon=0.8, rank_frac=0.25, rank_align=128,
    update_mode="factored", refresh_every=64,
    # modest per-mode fractions: Tucker factor state scales with D_m * r_m
    # per linear per layer — 1/16 keeps it ZeRO-shardable (DESIGN.md §4)
    asi=AsiConfig(token_frac=0.0625, feature_frac=0.0625, skip_batch=True))

# Reduced-rank settings for smoke configs (no 128-alignment: tiny dims)
SMOKE_WASI = WasiConfig(
    method="wasi", scope="all", epsilon=0.8, rank_frac=0.5, rank_align=1,
    min_rank=4, update_mode="factored",
    asi=AsiConfig(token_frac=0.5, feature_frac=0.5, align=1, skip_batch=True))


def uniform_groups(kind: str, n: int) -> tuple[LayerGroup, ...]:
    return (LayerGroup(pattern=(kind,), repeat=n),)


def patterned_groups(pattern: tuple[str, ...], repeat: int,
                     tail: tuple[str, ...] = ()) -> tuple[LayerGroup, ...]:
    groups = [LayerGroup(pattern=pattern, repeat=repeat)]
    if tail:
        groups.append(LayerGroup(pattern=tail, repeat=1))
    return tuple(groups)
