"""granite-3-8b [dense] 40L d_model=4096 32H (GQA kv=8) d_ff=12800
vocab=49155 — GQA [hf:ibm-granite/granite-3.0-2b-base; hf]."""
from repro.config import ModelConfig
from repro.configs.common import SCALE_WASI, SMOKE_WASI, uniform_groups


def config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", family="lm",
        n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12800,
        vocab_size=49155, head_dim=128, mlp_act="swiglu", norm="rmsnorm",
        rope_theta=1e7,
        groups=uniform_groups("dense", 40),
        wasi=SCALE_WASI, dtype="bfloat16", remat="block",
        sub_quadratic=False, has_decoder=True)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-smoke", family="lm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab_size=256, head_dim=16, mlp_act="swiglu", norm="rmsnorm",
        groups=uniform_groups("dense", 2),
        wasi=SMOKE_WASI, dtype="float32", remat="none")
