"""Architecture config registry. ``get(name)`` resolves ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.config import ModelConfig

ARCHS = (
    "zamba2-7b",
    "whisper-tiny",
    "gemma3-4b",
    "qwen2-0.5b",
    "granite-3-8b",
    "stablelm-3b",
    "internvl2-26b",
    "falcon-mamba-7b",
    "deepseek-moe-16b",
    "mixtral-8x7b",
    # the paper's own models
    "tinyllama-1.1b",
    "vit-base",
)


def _module(name: str):
    return importlib.import_module("repro.configs." + name.replace("-", "_").replace(".", "_"))


def get(name: str) -> ModelConfig:
    """Full (assigned) config."""
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return _module(name).config()


def get_smoke(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {ARCHS}")
    return _module(name).smoke_config()


def list_archs() -> tuple[str, ...]:
    return ARCHS
