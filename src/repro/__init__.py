"""repro — WASI (Weight-Activation Subspace Iteration) at scale, in JAX.

A production-grade training/serving framework implementing
"Efficient Resource-Constrained Training of Transformers via Subspace
Optimization" (Nguyen et al., 2025) as a first-class feature.
"""

__version__ = "0.1.0"
