"""Sharded, atomic, async checkpointing with restart support.

Layout:  <dir>/step_<N>/
             manifest.json        pytree structure + leaf metadata
             proc<P>_leaf<i>.npy  one file per leaf per process

Fault-tolerance contract (DESIGN.md §4):
* atomic publish: written into ``step_<N>.tmp`` then os.rename — a crash
  mid-save never corrupts the latest checkpoint;
* restart: ``latest_step`` + ``restore_checkpoint(template)`` rebuild the
  exact train state; the data pipeline is a pure function of step, so no
  reader state is persisted;
* async: ``CheckpointManager.save_async`` snapshots to host RAM on the
  caller thread (device->host copy), then writes on a background thread —
  training continues during the (slow) filesystem phase;
* multi-host: each process writes only its addressable shards; restore
  reassembles global arrays from per-process files (single-process runs
  degenerate to one file per leaf).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    process_index: int = 0) -> str:
    """Synchronous sharded save. Returns the final directory path."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + f".tmp{process_index}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _leaf_paths(tree)
    meta = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"proc{process_index}_leaf{i}.npy"), arr)
        meta.append({"index": i, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "n_leaves": len(leaves), "leaves": meta,
                   "treedef": str(treedef)}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                    steps.append(int(name.split("_")[1].split(".")[0]))
            except ValueError:
                continue
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template, *,
                       process_index: int = 0):
    """Restore into the structure of ``template`` (shapes validated)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    leaves, treedef = _leaf_paths(template)
    out = []
    for i, leaf in enumerate(leaves):
        arr = np.load(os.path.join(d, f"proc{process_index}_leaf{i}.npy"))
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async save + retention policy + restart."""

    def __init__(self, ckpt_dir: str, keep: int = 3, process_index: int = 0):
        self.dir = ckpt_dir
        self.keep = keep
        self.process_index = process_index
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree):
        self.wait()
        # snapshot on caller thread (device->host), write on background thread
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save_checkpoint(self.dir, step, host_tree,
                            process_index=self.process_index)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save(self, step: int, tree):
        self.wait()
        save_checkpoint(self.dir, step, tree, process_index=self.process_index)
        self._gc()

    def restore_latest(self, template):
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.dir, step, template,
                                        process_index=self.process_index)

    def _gc(self):
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("step_") and not n.endswith(".tmp")
            and os.path.exists(os.path.join(self.dir, n, "manifest.json")))
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
