"""Sharded, atomic, async checkpointing with restart support.

Layout:  <dir>/step_<N>/
             manifest.json        pytree structure + leaf metadata
                                  (+ optional SubspacePlan + label)
             proc<P>_leaf<i>.npy  one file per leaf per process

Fault-tolerance contract (DESIGN.md §4):
* atomic publish: written into ``step_<N>.tmp<P>`` then os.rename — a crash
  mid-save never corrupts the latest checkpoint; stale ``.tmp`` dirs left
  by a crash are ignored by ``latest_step`` and swept on
  ``CheckpointManager`` startup;
* restart: ``latest_step`` + ``restore_checkpoint(template)`` rebuild the
  exact train state; the data pipeline is a pure function of step, so no
  reader state is persisted;
* async: ``CheckpointManager.save_async`` snapshots to host RAM on the
  caller thread (device->host copy), then writes on a background thread —
  training continues during the (slow) filesystem phase;
* multi-host: each process writes only its addressable shards; restore
  reassembles global arrays from per-process files (single-process runs
  degenerate to one file per leaf);
* self-describing: ``save_checkpoint(..., plan=...)`` serializes the
  resolved SubspacePlan (api/plan.py) into the manifest, and the manifest
  stores a structural tree spec, so ``restore_untyped`` + the plan rebuild
  the params with NO template or config in hand (api/convert.py).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")
_TMP_RE = re.compile(r"^step_(\d+)\.tmp\d*$")


def _leaf_paths(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _tree_spec(tree, counter) -> dict | None:
    """JSON-able structural spec mirroring jax.tree_util flatten order
    (dicts by sorted key, sequences in order, NamedTuples by field, None as
    an empty subtree). Returns None for node types it can't describe —
    the manifest then simply omits the spec and template-free restore is
    unavailable for that checkpoint."""
    if tree is None:
        return {"kind": "none"}
    if isinstance(tree, dict):
        keys = sorted(tree)
        children = [_tree_spec(tree[k], counter) for k in keys]
        if any(c is None for c in children):
            return None
        return {"kind": "dict", "keys": keys, "children": children}
    if isinstance(tree, tuple) and hasattr(tree, "_fields"):
        children = [_tree_spec(v, counter) for v in tree]
        if any(c is None for c in children):
            return None
        return {"kind": "tuple", "children": children}
    if isinstance(tree, (list, tuple)):
        children = [_tree_spec(v, counter) for v in tree]
        if any(c is None for c in children):
            return None
        return {"kind": "list" if isinstance(tree, list) else "tuple",
                "children": children}
    if hasattr(tree, "shape") or np.isscalar(tree):
        i = counter[0]
        counter[0] += 1
        return {"kind": "leaf", "index": i}
    return None


def _build_from_spec(spec: dict, leaves: list):
    kind = spec["kind"]
    if kind == "none":
        return None
    if kind == "dict":
        return {k: _build_from_spec(c, leaves)
                for k, c in zip(spec["keys"], spec["children"])}
    if kind == "list":
        return [_build_from_spec(c, leaves) for c in spec["children"]]
    if kind == "tuple":
        return tuple(_build_from_spec(c, leaves) for c in spec["children"])
    return leaves[spec["index"]]


def save_checkpoint(ckpt_dir: str, step: int, tree, *,
                    process_index: int = 0, plan=None,
                    label: str | None = None,
                    extra: dict[str, Any] | None = None) -> str:
    """Synchronous sharded save. Returns the final directory path.

    ``plan`` (a SubspacePlan, or anything with ``to_json()``) and ``label``
    (e.g. "train_state" vs "params") ride in the manifest so the checkpoint
    is loadable without a matching config in hand (api/convert.py).

    ``extra`` saves named side trees NEXT TO the main one — e.g. the data
    pipeline's reader state (``{"reader": it.state()}``) — under their own
    structural specs, restored template-free by :func:`restore_extra`. A
    checkpoint without a given extra simply restores ``None`` for it, so
    old checkpoints stay loadable."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + f".tmp{process_index}"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _leaf_paths(tree)
    meta = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, f"proc{process_index}_leaf{i}.npy"), arr)
        meta.append({"index": i, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    counter = [0]
    spec = _tree_spec(tree, counter)
    if spec is not None and counter[0] != len(leaves):
        spec = None  # structural walk disagrees with jax flatten; drop it
    manifest: dict[str, Any] = {
        "step": step, "n_leaves": len(leaves), "leaves": meta,
        "treedef": str(treedef), "tree": spec}
    if label is not None:
        manifest["label"] = label
    if plan is not None:
        manifest["plan"] = plan.to_json() if hasattr(plan, "to_json") else plan
    if extra:
        manifest["extras"] = {}
        for name, ext_tree in extra.items():
            if not re.fullmatch(r"[A-Za-z0-9_.-]+", name):
                raise ValueError(f"extra name {name!r} must be a plain "
                                 "filename token")
            ext_leaves, _ = _leaf_paths(ext_tree)
            ecounter = [0]
            espec = _tree_spec(ext_tree, ecounter)
            if espec is None or ecounter[0] != len(ext_leaves):
                raise ValueError(
                    f"extra {name!r} is not a plain dict/list/tuple tree "
                    "of arrays — extras must restore template-free")
            for i, leaf in enumerate(ext_leaves):
                np.save(os.path.join(
                    tmp, f"proc{process_index}_{name}_{i}.npy"),
                    np.asarray(jax.device_get(leaf)))
            manifest["extras"][name] = {"tree": espec,
                                        "n_leaves": len(ext_leaves)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        # the step is already published (another process's shards, or a
        # re-save after restart): MERGE our files in rather than clobbering
        # the directory — an rmtree here would silently destroy the other
        # processes' proc<P>_leaf files
        for name in os.listdir(tmp):
            os.replace(os.path.join(tmp, name), os.path.join(final, name))
        shutil.rmtree(tmp, ignore_errors=True)
    else:
        os.rename(tmp, final)
    return final


def _published_steps(ckpt_dir: str) -> list[int]:
    """Steps with a PUBLISHED (renamed, manifest-bearing) directory. A
    ``step_<N>.tmp<P>`` left by a crash is never counted — even if the
    crash happened after its manifest was written."""
    steps = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = _published_steps(ckpt_dir)
    return steps[-1] if steps else None


def sweep_stale_tmp(ckpt_dir: str, process_index: int | None = None) -> list[str]:
    """Remove ``step_<N>.tmp<P>`` dirs left by a crash mid-save. Returns
    the removed paths.

    ``process_index`` restricts the sweep to that process's own tmp dirs —
    what ``CheckpointManager`` startup uses, since a process cannot have a
    live writer at its own startup but a multi-host peer might be mid-save.
    ``None`` sweeps every tmp dir (offline janitor use, when no writer of
    any process can be live)."""
    removed = []
    if not os.path.isdir(ckpt_dir):
        return removed
    suffix = None if process_index is None else f".tmp{process_index}"
    for name in os.listdir(ckpt_dir):
        if _TMP_RE.match(name) and (suffix is None or name.endswith(suffix)):
            path = os.path.join(ckpt_dir, name)
            shutil.rmtree(path, ignore_errors=True)
            removed.append(path)
    return removed


def load_manifest(ckpt_dir: str, step: int) -> dict:
    with open(os.path.join(ckpt_dir, f"step_{step}", "manifest.json")) as f:
        return json.load(f)


def restore_checkpoint(ckpt_dir: str, step: int, template, *,
                       process_index: int = 0):
    """Restore into the structure of ``template`` (shapes validated)."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    leaves, treedef = _leaf_paths(template)
    out = []
    for i, leaf in enumerate(leaves):
        arr = np.load(os.path.join(d, f"proc{process_index}_leaf{i}.npy"))
        want = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != want:
            raise ValueError(f"leaf {i}: checkpoint shape {arr.shape} != {want}")
        out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out)


def restore_extra(ckpt_dir: str, step: int, name: str, *,
                  process_index: int = 0):
    """Restore a named side tree saved via ``save_checkpoint(extra=...)``
    (template-free, from its structural spec). Returns ``None`` when the
    checkpoint predates the extra — callers decide whether that's fatal."""
    m = load_manifest(ckpt_dir, step)
    ext = (m.get("extras") or {}).get(name)
    if ext is None:
        return None
    d = os.path.join(ckpt_dir, f"step_{step}")
    leaves = [np.load(os.path.join(d, f"proc{process_index}_{name}_{i}.npy"))
              for i in range(ext["n_leaves"])]
    return _build_from_spec(ext["tree"], leaves)


def restore_untyped(ckpt_dir: str, step: int, *, process_index: int = 0):
    """Template-free restore from the manifest's structural tree spec:
    nested dicts/lists/tuples of numpy arrays (NamedTuple classes degrade
    to plain tuples). Raises if the checkpoint predates tree specs."""
    m = load_manifest(ckpt_dir, step)
    spec = m.get("tree")
    if spec is None:
        raise ValueError(
            f"checkpoint {ckpt_dir}/step_{step} has no structural tree spec; "
            "restore with restore_checkpoint(template) instead")
    d = os.path.join(ckpt_dir, f"step_{step}")
    leaves = [np.load(os.path.join(d, f"proc{process_index}_leaf{i}.npy"))
              for i in range(m["n_leaves"])]
    return _build_from_spec(spec, leaves)


class CheckpointManager:
    """Async save + retention policy + restart + crash hygiene."""

    def __init__(self, ckpt_dir: str, keep: int = 3, process_index: int = 0,
                 plan=None, label: str | None = None):
        self.dir = ckpt_dir
        self.keep = keep
        self.process_index = process_index
        self.plan = plan
        self.label = label
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)
        # crash hygiene: a previous run died mid-save -> OUR process's tmp
        # dirs are garbage (never published) and would otherwise accumulate
        # forever; peers' tmp dirs are left alone (they may be mid-save)
        sweep_stale_tmp(ckpt_dir, process_index)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree, extra: dict | None = None):
        self.wait()
        # snapshot on caller thread (device->host), write on background
        # thread — extras too: the reader state must be the one current AT
        # the save point, not whenever the filesystem phase runs
        snap = lambda t: jax.tree.map(
            lambda x: np.asarray(jax.device_get(x)), t)
        host_tree = snap(tree)
        host_extra = {k: snap(v) for k, v in extra.items()} if extra else None

        def _write():
            save_checkpoint(self.dir, step, host_tree,
                            process_index=self.process_index,
                            plan=self.plan, label=self.label,
                            extra=host_extra)
            self._gc()

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()
        save_checkpoint(self.dir, step, tree,
                        process_index=self.process_index,
                        plan=self.plan, label=self.label, extra=extra)
        self._gc()

    def restore_latest(self, template):
        self.wait()
        step = latest_step(self.dir)
        if step is None:
            return None, None
        return step, restore_checkpoint(self.dir, step, template,
                                        process_index=self.process_index)

    def restore_extra(self, step: int, name: str):
        """Named side tree of a published step (None when absent)."""
        self.wait()
        return restore_extra(self.dir, step, name,
                             process_index=self.process_index)

    def _gc(self):
        steps = _published_steps(self.dir)
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"), ignore_errors=True)
