from repro.checkpoint.ckpt import (
    CheckpointManager,
    latest_step,
    load_manifest,
    restore_checkpoint,
    restore_extra,
    restore_untyped,
    save_checkpoint,
    sweep_stale_tmp,
)
