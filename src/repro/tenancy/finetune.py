"""Freeze-base adapter fine-tuning: train ONLY the per-site delta factors.

The paper's subspace claim makes per-user personalization nearly free: a
fine-tune is a rank-K_a pair per site (a few hundred KB), not a model
copy. The mechanism here is gradient masking by construction — the base
params are a closed-over constant of the loss and the differentiated
pytree IS the adapter tree, so ``jax.value_and_grad`` can only produce
adapter gradients and the optimizer state is adapter-sized too. The whole
thing runs through the unmodified ``train/step.py`` machinery (clip,
schedule, optimizer, factored-refresh cond — which no-ops on adapter
trees, their dicts carry no {L,R} pair).

``finetune_adapters(base, plan, data, ...)`` is the library entry;
``launch/finetune_user.py`` is the CLI that closes the loop from a
checkpointed base into an ``AdapterStore``.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.config import TrainConfig
from repro.models.lm import lm_loss
from repro.optim import init_optimizer
from repro.tenancy.adapter import init_adapters, merge_adapters
from repro.train.step import TrainState, make_train_step

#: small-model SGD recipe that moves a rank-K adapter in tens of steps
DEFAULT_TCFG = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9,
                           weight_decay=0.0, schedule="constant",
                           warmup=0, clip_norm=2.0)


def adapter_loss_fn(base_params, loss_fn=lm_loss):
    """A ``train/step.py``-shaped loss over the ADAPTER tree only: merges
    the frozen base in before the forward. Differentiating it w.r.t. its
    first argument touches exactly the (La, Ra) leaves — the base cannot
    receive a gradient because it is not an input."""
    frozen = jax.lax.stop_gradient(base_params)

    def fn(adapters, batch, cfg, *, states=None, policy=None):
        return loss_fn(merge_adapters(frozen, adapters), batch, cfg,
                       states=states, policy=policy)

    return fn


def finetune_adapters(base_params, plan, data, *, steps: int = 40,
                      tcfg: TrainConfig | None = None, seed: int = 0,
                      batch_size: int | None = None, adapters=None,
                      log_every: int = 0):
    """Train a fresh (or resumed) adapter tree against a frozen base.

    ``plan`` must be adapter-stamped (``plan.with_adapter``) and NOT
    quantized — deltas train in f32 against the f32 master; quantize the
    artifact at store time instead. Returns (adapters, last_metrics)."""
    if plan.is_quantized:
        raise ValueError("fine-tune against the f32 master, not an int8 "
                         "deployment view (store the adapter int8 instead)")
    # checkpoint restores hand back numpy leaves; as closed-over constants
    # of the jitted step they must be device arrays (numpy[tracer] throws)
    base_params = jax.tree.map(jnp.asarray, base_params)
    cfg = plan.model
    tcfg = tcfg or dataclasses.replace(DEFAULT_TCFG, steps=steps)
    key = jax.random.PRNGKey(seed)
    if adapters is None:
        adapters = init_adapters(key, base_params, plan)
    # hand-built TrainState: no ASI/WSI/PowerSGD state belongs to a delta
    state = TrainState(params=adapters, opt=init_optimizer(adapters, tcfg),
                       asi=None, wsi=None, psgd=None,
                       step=jnp.zeros((), jnp.int32))
    step = jax.jit(make_train_step(adapter_loss_fn(base_params), cfg, tcfg))
    metrics = {}
    for i in range(steps):
        state, metrics = step(state, data.batch(i, batch_size))
        if log_every and (i + 1) % log_every == 0:
            print(f"[finetune] step {i + 1}/{steps} "
                  f"ce={float(metrics['ce']):.4f}")
    return state.params, {k: float(v) for k, v in metrics.items()}


def eval_ce(params, cfg, data, *, steps: int = 4,
            batch_size: int | None = None, start_step: int = 10_000) -> float:
    """Mean CE of ``params`` (merged or base) on held-out batches of
    ``data`` — held out by step offset, since batches are a pure function
    of (seed, step)."""
    loss = jax.jit(lambda p, b: lm_loss(p, b, cfg)[1][1]["ce"])
    params = jax.tree.map(jnp.asarray, params)
    vals = [float(loss(params, data.batch(start_step + i, batch_size)))
            for i in range(steps)]
    return sum(vals) / len(vals)
