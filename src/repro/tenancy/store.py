"""Content-addressed on-disk registry of plan-stamped tenant adapters.

Layout:

    <root>/objects/<sha256>.npz      leaf payload (leaf_0 .. leaf_N)
    <root>/tenants/<tenant>.json     pointer + metadata

The object name is a sha256 over the tree spec AND every leaf's
dtype/shape/raw bytes — NOT over the npz file (zip timestamps would make
that non-deterministic) — so identical adapter trees dedupe to one object
no matter how many tenants point at them, and a pointer file can be
re-targeted atomically.

Formats: ``"f32"`` stores the training dtype verbatim; ``"int8"`` packs
each (La, Ra) pair per-channel symmetric via ``quant/quantize.py``'s
``quantize_tensor`` (scales ride next to the payload as sLa/sRa, mirroring
the base-weight sL/sR convention without touching ``SCALE_KEY`` — adapter
storage is NOT a serve-time layout, ``load`` always hands back f32).

Metadata pins the adapter-stamped plan (full JSON + sha) and per-site
ranks, so a serving process can refuse an adapter trained under a
different plan before any shape error gets a chance to be cryptic. Byte
accounting is memprof-convention: exact nbytes of what is on disk.
"""
from __future__ import annotations

import hashlib
import json
import os
import re

import jax
import numpy as np

from repro.checkpoint.ckpt import _build_from_spec, _tree_spec
from repro.quant.quantize import dequantize_tensor, quantize_tensor
from repro.tenancy.adapter import adapter_site_ranks

#: adapter weight leaf key -> its scale key (int8 storage packing only;
#: deliberately disjoint from quantize.SCALE_KEY — bind never sees these)
ADAPTER_SCALE_KEY = {"La": "sLa", "Ra": "sRa"}

_TENANT_RE = re.compile(r"^[A-Za-z0-9._-]{1,64}$")

FORMATS = ("f32", "int8")


def _check_tenant(tenant: str) -> str:
    if not _TENANT_RE.match(tenant or ""):
        raise ValueError(f"bad tenant id {tenant!r} (want [A-Za-z0-9._-], "
                         "1-64 chars — it names a file)")
    return tenant


def pack_int8(adapters):
    """Adapter tree -> int8 storage tree: every {"La","Ra"} site becomes
    {"La" int8, "sLa" f32, "Ra" int8, "sRa" f32} (per-channel absmax over
    the contraction axis, exactly the base-weight scheme)."""
    def walk(node):
        if isinstance(node, dict):
            if "La" in node:
                out = {}
                for k, v in node.items():
                    if k in ADAPTER_SCALE_KEY:
                        out[k], out[ADAPTER_SCALE_KEY[k]] = quantize_tensor(v)
                    else:
                        out[k] = v
                return out
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v) for v in node]
            return t if isinstance(node, list) else tuple(t)
        return node

    return walk(adapters)


def unpack_int8(stored):
    """Inverse of :func:`pack_int8` — back to the f32 adapter layout the
    resident banks and ``merge_adapters`` expect."""
    def walk(node):
        if isinstance(node, dict):
            if "sLa" in node:
                return {k: dequantize_tensor(v, node[ADAPTER_SCALE_KEY[k]])
                        for k, v in node.items() if k in ADAPTER_SCALE_KEY}
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [walk(v) for v in node]
            return t if isinstance(node, list) else tuple(t)
        return node

    return walk(stored)


def _flatten(tree):
    leaves = [np.asarray(jax.device_get(x)) for x in jax.tree.leaves(tree)]
    counter = [0]
    spec = _tree_spec(tree, counter)
    if spec is None or counter[0] != len(leaves):
        raise ValueError("adapter tree is not spec-serializable")
    return leaves, spec


def _content_sha(leaves, spec) -> str:
    h = hashlib.sha256()
    h.update(json.dumps(spec, sort_keys=True).encode())
    for leaf in leaves:
        h.update(f"{leaf.dtype}{leaf.shape}".encode())
        h.update(np.ascontiguousarray(leaf).tobytes())
    return h.hexdigest()


def plan_sha(plan) -> str:
    return hashlib.sha256(
        json.dumps(plan.to_json(), sort_keys=True).encode()).hexdigest()


class AdapterStore:
    """save/load/list plan-stamped adapter trees, content-addressed."""

    def __init__(self, root: str):
        self.root = root
        os.makedirs(os.path.join(root, "objects"), exist_ok=True)
        os.makedirs(os.path.join(root, "tenants"), exist_ok=True)

    # -- paths ------------------------------------------------------------
    def _meta_path(self, tenant: str) -> str:
        return os.path.join(self.root, "tenants",
                            f"{_check_tenant(tenant)}.json")

    def _obj_path(self, sha: str) -> str:
        return os.path.join(self.root, "objects", f"{sha}.npz")

    # -- write ------------------------------------------------------------
    def save(self, tenant: str, adapters, plan, *, fmt: str = "f32",
             extra: dict | None = None) -> dict:
        """Persist one tenant's adapter tree. Returns the meta record
        (also written to ``tenants/<tenant>.json``)."""
        if fmt not in FORMATS:
            raise ValueError(f"unknown adapter format {fmt!r}; "
                             f"want one of {FORMATS}")
        if not getattr(plan, "has_adapters", False):
            raise ValueError("plan carries no adapter stamps; refusing to "
                             "store an unstamped tree")
        stored = pack_int8(adapters) if fmt == "int8" else adapters
        leaves, spec = _flatten(stored)
        sha = _content_sha(leaves, spec)
        obj = self._obj_path(sha)
        if not os.path.exists(obj):                      # dedupe
            tmp = obj + f".tmp{os.getpid()}"             # savez appends .npz
            np.savez(tmp, **{f"leaf_{i}": leaf
                             for i, leaf in enumerate(leaves)})
            os.replace(tmp + ".npz", obj)
        meta = {
            "tenant": tenant,
            "object": sha,
            "format": fmt,
            "bytes": int(sum(leaf.nbytes for leaf in leaves)),
            "n_leaves": len(leaves),
            "tree": spec,
            "ranks": adapter_site_ranks(plan),
            "plan_sha": plan_sha(plan),
            "plan": plan.to_json(),
        }
        if extra:
            meta["extra"] = dict(extra)
        mp = self._meta_path(tenant)
        tmp = mp + f".tmp{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(meta, f, indent=1, sort_keys=True)
        os.replace(tmp, mp)
        return meta

    # -- read -------------------------------------------------------------
    def meta(self, tenant: str) -> dict:
        with open(self._meta_path(tenant)) as f:
            return json.load(f)

    def has(self, tenant: str) -> bool:
        try:
            return os.path.exists(self._meta_path(tenant))
        except ValueError:
            return False

    def load(self, tenant: str, *, expect_plan_sha: str | None = None):
        """-> (f32 adapter tree, meta). int8 objects are dequantized here:
        the store format is a disk format, not a serve layout."""
        meta = self.meta(tenant)
        if expect_plan_sha is not None and meta["plan_sha"] != expect_plan_sha:
            raise ValueError(
                f"adapter for tenant {tenant!r} was trained under plan "
                f"{meta['plan_sha'][:12]} but the engine runs "
                f"{expect_plan_sha[:12]} — refusing the shape roulette")
        with np.load(self._obj_path(meta["object"])) as z:
            leaves = [z[f"leaf_{i}"] for i in range(meta["n_leaves"])]
        tree = _build_from_spec(meta["tree"], leaves)
        if meta["format"] == "int8":
            tree = unpack_int8(tree)
        return tree, meta

    # -- accounting -------------------------------------------------------
    def tenants(self) -> list[str]:
        d = os.path.join(self.root, "tenants")
        return sorted(n[:-5] for n in os.listdir(d) if n.endswith(".json"))

    def list(self) -> list[dict]:
        return [self.meta(t) for t in self.tenants()]

    def bytes_by_tenant(self) -> dict[str, int]:
        """Per-tenant on-disk payload bytes (memprof convention: exact
        nbytes of the stored leaves; dedup'd objects count per pointer)."""
        return {m["tenant"]: m["bytes"] for m in self.list()}

    def total_object_bytes(self) -> int:
        """Actual disk footprint of the object pool (after dedupe)."""
        d = os.path.join(self.root, "objects")
        return sum(os.path.getsize(os.path.join(d, n))
                   for n in os.listdir(d) if n.endswith(".npz"))
