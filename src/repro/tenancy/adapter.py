"""Per-tenant adapter trees: init, merge, stack, gather.

An *adapter tree* mirrors a model's param tree but carries only the
additive delta pairs of the plan's adapter-stamped sites
(``SubspacePlan.with_adapter``): each stamped linear dict maps to
``{"La": (*stack, O, K_a), "Ra": (*stack, K_a, I)}`` with the same leading
stack dims as the base weights (scan repeats ride through ``lax.scan``
unchanged). Everything else is a structural placeholder, so a merge is a
lockstep walk — never a key-pattern rename.

Layout lifecycle (repro/tenancy/):

* TRAIN — ``init_adapters`` (La = 0 so the initial delta is exactly the
  base forward, the LoRA convention), ``merge_adapters`` inside the loss,
  only the adapter tree is differentiated (finetune.py).
* SERVE — ``stack_adapters`` piles T tenants' trees into banks with the
  tenant axis at ``ndim - 3`` (after the scan-stack dims, before (O, K_a));
  row 0 is the all-zeros identity for adapter-less slots. The engine's
  jitted step calls ``gather_rows`` with the per-slot int32 index vector,
  so swapping a tenant changes array CONTENTS, never shapes — one compiled
  executable serves any tenant mix.

The delta application itself is ``api.bind.adapter_delta`` (dispatch by
key stays bind's monopoly); this module only builds/walks the trees, keyed
by the same ``LEAF_TO_SPEC`` convert.py walks with.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api.bind import is_linear_params, linear_dims
from repro.api.plan import LEAF_TO_SPEC, SubspacePlan


def _walk_sites(tree, plan: SubspacePlan, fn):
    """Build a PARALLEL tree: ``fn(spec, linear_dict)`` at every
    adapter-stamped site (-> its adapter node), structural placeholders
    ({} / same-length lists) everywhere else, so the result zips against
    the param tree leaf-for-leaf in ``merge_adapters``."""
    def walk(node):
        if isinstance(node, dict):
            out = {}
            for key, v in node.items():
                if key in LEAF_TO_SPEC and is_linear_params(v):
                    name, role = LEAF_TO_SPEC[key]
                    o, i = linear_dims(v)
                    spec = plan.linear(name, i, o, role=role)
                    if spec.adapter:
                        out[key] = fn(spec, v)
                else:
                    out[key] = walk(v)
            return out
        if isinstance(node, (list, tuple)):
            t = [walk(v) for v in node]
            return t if isinstance(node, list) else tuple(t)
        return {}

    return walk(tree)


def init_adapters(key, params, plan: SubspacePlan, *, dtype=jnp.float32,
                  ra_scale: float = 0.02):
    """Fresh adapter tree for ``params`` under an adapter-stamped plan.

    La is ZEROS and Ra small random, so the initial delta is exactly zero
    (fine-tuning starts bitwise at the frozen base) while the first
    gradient step still flows: d/dLa of the delta is (Ra x)-shaped and
    nonzero. Leading stack dims copy the base leaf's."""
    if not plan.has_adapters:
        raise ValueError("plan carries no adapter stamps; call "
                         "plan.with_adapter(rank_frac) first")
    sites = []

    def shape_one(spec, p):
        leaf = p["L"] if "L" in p else p["w"]
        stack = tuple(leaf.shape[:-2])
        sites.append((spec, stack))
        return {"La": None, "Ra": None}     # placeholder, filled below

    skeleton = _walk_sites(params, plan, shape_one)
    keys = jax.random.split(key, max(len(sites), 1))
    filled = iter(zip(sites, keys))

    def fill(node):
        if isinstance(node, dict):
            if "La" in node:
                (spec, stack), k = next(filled)
                ka = spec.adapter
                return {"La": jnp.zeros(stack + (spec.out_dim, ka), dtype),
                        "Ra": (jax.random.normal(
                            k, stack + (ka, spec.in_dim), jnp.float32)
                            * ra_scale).astype(dtype)}
            return {k2: fill(v) for k2, v in node.items()}
        if isinstance(node, (list, tuple)):
            t = [fill(v) for v in node]
            return t if isinstance(node, list) else tuple(t)
        return node

    return fill(skeleton)


def zero_adapters(params, plan: SubspacePlan, *, dtype=jnp.float32):
    """All-zeros adapter tree (the identity delta) — the bank template and
    the ``adapter_id=None`` row."""
    def one(spec, p):
        leaf = p["L"] if "L" in p else p["w"]
        stack = tuple(leaf.shape[:-2])
        return {"La": jnp.zeros(stack + (spec.out_dim, spec.adapter), dtype),
                "Ra": jnp.zeros(stack + (spec.adapter, spec.in_dim), dtype)}

    return _walk_sites(params, plan, one)


def merge_adapters(params, adapters):
    """Inject each site's adapter pair next to its base weights, so
    ``bind.apply`` adds the delta. Works on single-tenant trees (leaves
    (*stack, O, K_a)) and on gathered per-slot bank rows (leaves
    (*stack, B, O, K_a)) alike — traceable, runs inside jit."""
    def walk(p, a):
        if not isinstance(a, (dict, list, tuple)) or not a:
            return p
        if isinstance(p, dict):
            if is_linear_params(p) and isinstance(a, dict) and "La" in a:
                out = dict(p)
                out.update(a)
                return out
            return {k: walk(v, a.get(k) if isinstance(a, dict) else None)
                    for k, v in p.items()}
        if isinstance(p, (list, tuple)):
            t = [walk(v, a[i] if i < len(a) else None)
                 for i, v in enumerate(p)]
            return t if isinstance(p, list) else tuple(t)
        return p

    return walk(params, adapters)


def stack_adapters(trees):
    """Pile per-tenant adapter trees (identical structure) into banks:
    every leaf gains a tenant axis at position ``ndim - 2`` of the input
    leaf — i.e. AFTER the scan-stack dims, BEFORE the (O, K_a) / (K_a, I)
    pair — so banks ride through the group scan untouched and
    ``gather_rows`` can always address the tenant axis as ``ndim - 3``."""
    if not trees:
        raise ValueError("stack_adapters needs at least one tree")
    return jax.tree.map(lambda *ls: jnp.stack(ls, axis=ls[0].ndim - 2),
                        *trees)


def gather_rows(banks, ix):
    """Per-slot bank selection: ``ix`` (B,) int32 tenant-row indices ->
    a tree of (*stack, B, O, K_a) leaves, one tenant's factors per batch
    row. Pure gather — runs inside the jitted serve step, so tenant churn
    changes only the CONTENTS of ``banks``, never any shape."""
    return jax.tree.map(lambda b: jnp.take(b, ix, axis=b.ndim - 3), banks)


def set_bank_row(banks, row: int, tree):
    """Upload one tenant's adapter tree into bank row ``row`` (device-side
    functional update; shapes never change, so no retrace downstream)."""
    return jax.tree.map(
        lambda b, h: b.at[..., row, :, :].set(jnp.asarray(h, b.dtype)),
        banks, tree)


def make_banks(template, capacity: int):
    """Zero banks holding ``capacity`` tenants PLUS the identity row 0,
    shaped from a single-tenant ``template`` adapter tree."""
    return jax.tree.map(
        lambda h: jnp.zeros(h.shape[:-2] + (capacity + 1,) + h.shape[-2:],
                            jnp.float32), template)


def adapter_site_ranks(plan: SubspacePlan) -> dict[str, int]:
    """{site name: K_a} for every adapter-stamped site of the plan."""
    return {s.name: s.adapter for s in plan.specs if s.adapter}
