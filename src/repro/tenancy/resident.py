"""Device-resident LRU of tenant adapter banks for mixed-tenant serving.

The banks are ONE pytree whose leaves carry a tenant axis of fixed size
``capacity + 1`` (row 0 is the all-zeros identity for adapter-less slots).
The serve engine passes the banks plus a per-slot int32 row index into its
jitted step, which gathers each slot's factors (``adapter.gather_rows``)
— so admitting a new tenant is a host-side ``AdapterStore.load`` plus a
``set_bank_row`` in-place-shaped update. Array CONTENTS change; no shape,
dtype, or structure ever does; the compiled executable is reused across
arbitrary tenant churn.

Eviction policy is LRU over rows 1..capacity with PINNING: the engine
pins the rows of every slot still generating, so a tenant mid-decode can
never have its factors swapped out from under it. ``acquire`` returns
``None`` when every row is pinned — the engine defers that request to the
next admission tick instead of blocking.

Construction is EAGER: the banks are built (and their jit-visible
structure fixed) from the store's first adapter at ``__init__`` — the
engine's traced signature never flips None -> tree at runtime.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.tenancy.adapter import make_banks, set_bank_row
from repro.tenancy.store import AdapterStore


class ResidentAdapters:
    """LRU cache of ``capacity`` tenant adapter rows on device.

    ``on_evict(tenant)`` fires when a resident tenant is displaced — the
    engine routes it into its EVICTED event machinery.
    """

    def __init__(self, store: AdapterStore | str, capacity: int = 4, *,
                 on_evict: Callable[[str], None] | None = None):
        self.store = AdapterStore(store) if isinstance(store, str) else store
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1: {capacity}")
        names = self.store.tenants()
        if not names:
            raise ValueError(f"adapter store {self.store.root!r} is empty — "
                             "banks need at least one adapter for shapes")
        self.capacity = int(capacity)
        self.on_evict = on_evict
        template, meta = self.store.load(names[0])
        self.plan_sha: str = meta["plan_sha"]
        self.plan_json: dict = meta["plan"]
        self.banks = make_banks(template, self.capacity)
        self._zero_row = jax.tree.map(jnp.zeros_like, template)
        self.row_of: dict[str, int] = {}      # tenant -> row (1-based)
        self.tenant_of: dict[int, str] = {}   # row -> tenant
        self._last_used: dict[int, int] = {}  # row -> tick
        self._tick = 0
        self.hits = 0
        self.swaps = 0
        self.evictions = 0

    # -- queries ----------------------------------------------------------
    def resident(self) -> list[str]:
        return [self.tenant_of[r] for r in sorted(self.tenant_of)]

    def bank_bytes(self) -> int:
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree.leaves(self.banks))

    def tenant_bytes(self, tenant: str) -> int:
        return int(self.store.meta(tenant)["bytes"])

    # -- the one mutating entry point -------------------------------------
    def acquire(self, tenant: str, pinned: set[int] = frozenset()) -> int | None:
        """Row index for ``tenant``, loading + evicting as needed.

        ``pinned`` rows (slots still generating) are never evicted. Returns
        ``None`` when the tenant is not resident and every row is pinned —
        caller should defer. Raises KeyError/FileNotFoundError for a tenant
        the store has never seen (caller validates at submit time)."""
        self._tick += 1
        row = self.row_of.get(tenant)
        if row is not None:
            self.hits += 1
            self._last_used[row] = self._tick
            return row
        row = self._victim(pinned)
        if row is None:
            return None
        old = self.tenant_of.pop(row, None)
        if old is not None:
            del self.row_of[old]
            self.evictions += 1
            if self.on_evict is not None:
                self.on_evict(old)
        tree, meta = self.store.load(tenant, expect_plan_sha=self.plan_sha)
        self.banks = set_bank_row(self.banks, row, tree)
        self.row_of[tenant] = row
        self.tenant_of[row] = tenant
        self._last_used[row] = self._tick
        self.swaps += 1
        return row

    def _victim(self, pinned: set[int]) -> int | None:
        for row in range(1, self.capacity + 1):      # free row first
            if row not in self.tenant_of and row not in pinned:
                return row
        lru = [r for r in self.tenant_of if r not in pinned]
        if not lru:
            return None
        return min(lru, key=lambda r: self._last_used.get(r, 0))

    def release_row(self, row: int) -> None:
        """Optional hygiene when a tenant's last slot retires: the row
        stays resident (it may be reused — that's the cache), but its
        recency is left alone. Zeroing is NOT needed for correctness (row
        0 handles adapter-less slots); method kept for symmetry/tests."""

    def drop(self, tenant: str) -> None:
        """Forcibly forget a tenant (tests / admin). Zeroes its row so a
        stale gather can never read its factors."""
        row = self.row_of.pop(tenant, None)
        if row is None:
            return
        del self.tenant_of[row]
        self._last_used.pop(row, None)
        self.banks = set_bank_row(self.banks, row, self._zero_row)

    def summary(self) -> dict:
        return {
            "capacity": self.capacity,
            "resident": self.resident(),
            "bank_bytes": self.bank_bytes(),
            "store_tenants": len(self.store.tenants()),
            "hits": self.hits,
            "swaps": self.swaps,
            "evictions": self.evictions,
        }
