"""Multi-tenant subspace adapters (ROADMAP open item 2).

One resident base, thousands of per-user rank-K_a deltas: train them
frozen-base (:mod:`repro.tenancy.finetune`), register them
content-addressed on disk (:mod:`repro.tenancy.store`), and hot-swap them
through a device-resident LRU bank (:mod:`repro.tenancy.resident`) that
the serve engine gathers per slot — one jitted executable for any tenant
mix. Tree plumbing lives in :mod:`repro.tenancy.adapter`.

``resident`` is imported lazily: it is serve-facing, and the serve engine
itself imports :mod:`repro.tenancy.adapter` — an eager import here would
close the cycle.
"""
from __future__ import annotations

from repro.tenancy import adapter, finetune, store
from repro.tenancy.adapter import (adapter_site_ranks, gather_rows,
                                   init_adapters, merge_adapters,
                                   stack_adapters, zero_adapters)
from repro.tenancy.finetune import (adapter_loss_fn, eval_ce,
                                    finetune_adapters)
from repro.tenancy.store import AdapterStore, plan_sha

__all__ = [
    "AdapterStore", "ResidentAdapters", "adapter", "adapter_loss_fn",
    "adapter_site_ranks", "eval_ce", "finetune", "finetune_adapters",
    "gather_rows", "init_adapters", "merge_adapters", "plan_sha",
    "resident", "stack_adapters", "store", "zero_adapters",
]


def __getattr__(name):
    if name in ("resident", "ResidentAdapters"):
        from repro.tenancy import resident
        return resident if name == "resident" else resident.ResidentAdapters
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
