"""RMSNorm / LayerNorm (fp32 statistics, cast back to activation dtype)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(dim: int, dtype=jnp.float32) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(p: dict, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def init_norm(kind: str, dim: int, dtype=jnp.float32) -> dict:
    return init_rmsnorm(dim, dtype) if kind == "rmsnorm" else init_layernorm(dim, dtype)


def apply_norm(kind: str, p: dict, x: jax.Array) -> jax.Array:
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)
