"""DEPRECATED shim over the SubspacePlan API (repro.api) — one release.

Every entry point here now delegates to the plan/bind/convert redesign:

    init_linear / apply_linear  ->  api.bind.init_params / api.bind.apply
                                    (typed LinearSpec dispatch, no dict
                                    key sniffing at call sites)
    init_linear_from_dense      ->  api.convert.factorize_linear
                                    (now ALSO emits project-mode
                                    {"w","L","R"} params)
    asi_spec                    ->  api.bind.asi_state
    wasi_applies / linear_rank  ->  api.plan.role_treated / LinearSpec.rank

The old signatures keep working for out-of-tree users this release; each
process gets ONE DeprecationWarning on first use. In-tree code imports
``repro.api`` directly. See docs/api.md for the migration table.
"""
from __future__ import annotations

import warnings
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.api import bind
from repro.api.plan import resolve_linear_spec, role_treated
from repro.config import AsiConfig, WasiConfig  # noqa: F401 (re-export compat)
from repro.core.asi import ASIState

_warned = False


def _deprecated(replacement: str) -> None:
    global _warned
    if not _warned:
        warnings.warn(
            "repro.nn.linear is deprecated; use the SubspacePlan API "
            f"({replacement} — see docs/api.md). This shim is kept for one "
            "release.", DeprecationWarning, stacklevel=3)
        _warned = True


def linear_rank(in_dim: int, out_dim: int, cfg: WasiConfig) -> int:
    _deprecated("repro.api.resolve_linear_spec(...).rank")
    from repro.core.rank_policy import static_rank
    return static_rank(in_dim, out_dim, cfg.rank_frac,
                       align=cfg.rank_align, min_rank=cfg.min_rank)


def wasi_applies(cfg: WasiConfig, role: str) -> bool:
    """Does WASI treat this linear? role in {mlp, attn, ssm, moe, head}."""
    _deprecated("repro.api.role_treated")
    return role_treated(cfg, role)


def init_linear(key, in_dim: int, out_dim: int, cfg: WasiConfig, *,
                role: str = "mlp", bias: bool = False, dtype=jnp.float32,
                scale: float | None = None) -> dict:
    _deprecated("repro.api.bind.init_params")
    spec = resolve_linear_spec(cfg, f"{role}/adhoc", role, in_dim, out_dim,
                               bias=bias)
    return bind.init_params(key, spec, dtype=dtype, scale=scale, bias=bias)


def init_linear_from_dense(w: jax.Array, cfg: WasiConfig, *, role: str = "mlp",
                           bias=None) -> dict:
    """Paper-faithful init: factor an existing dense W by truncated SVD at
    eps (Alg. 1 t=0). Used when converting pretrained checkpoints. Now
    emits project-mode {"w","L","R"} params too (previously converted
    checkpoints could not train in the paper's project mode)."""
    _deprecated("repro.api.convert.factorize")
    from repro.api.convert import factorize_linear
    spec = resolve_linear_spec(cfg, f"{role}/adhoc", role,
                               int(w.shape[-1]), int(w.shape[-2]),
                               weight=w)
    return factorize_linear(w, spec, bias=bias)


def asi_spec(key, act_shape: Sequence[int], cfg: WasiConfig,
             dtype=jnp.float32) -> ASIState | None:
    """Warm-start ASI state for a linear whose input activation has
    ``act_shape`` (B, N, I) or (B, H, W, I). None if compression is off."""
    _deprecated("repro.api.bind.asi_state")
    return bind.asi_state(key, act_shape, cfg, dtype)


def apply_linear(p: dict, x: jax.Array, cfg: WasiConfig,
                 state: ASIState | None = None):
    """Apply. Returns (y, new_state) — new_state is None when no ASI.
    Dispatch now happens on a LinearSpec recovered from the param layout
    (api.bind.infer_spec), the one sanctioned place that looks at keys."""
    _deprecated("repro.api.bind.apply")
    spec = bind.infer_spec(p, cfg)
    return bind.apply(spec, p, x, cfg, state)


def linear_out_dim(p: dict) -> int:
    _deprecated("repro.api.bind.linear_out_dim")
    return bind.linear_out_dim(p)
