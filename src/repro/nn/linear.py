"""Linear layer that is dense, WASI-factored, or ASI-compressed by config.

Every projection in the framework goes through this module, so flipping
``WasiConfig.method`` swaps the entire model between vanilla / WSI / ASI /
WASI training with identical call sites. Params are plain dicts:

    dense:    {"w": (O, I) [, "b": (O,)]}
    factored: {"L": (O, K), "R": (K, I) [, "b": (O,)]}

ASI warm-start state (when activation compression is on) lives in a parallel
pytree threaded through apply; ``asi_spec`` builds it from activation shapes.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.config import AsiConfig, WasiConfig
from repro.core.asi import ASIState, asi_init, asi_project, asi_step
from repro.core.lowrank_linear import (
    asi_matmul,
    wasi_matmul,
    wasi_matmul_project,
)
from repro.core.rank_policy import asi_mode_ranks, static_rank


def linear_rank(in_dim: int, out_dim: int, cfg: WasiConfig) -> int:
    return static_rank(in_dim, out_dim, cfg.rank_frac,
                       align=cfg.rank_align, min_rank=cfg.min_rank)


def wasi_applies(cfg: WasiConfig, role: str) -> bool:
    """Does WASI treat this linear? role in {mlp, attn, ssm, moe, head}."""
    if cfg.method == "none" or cfg.scope == "none":
        return False
    if role == "head":
        return False  # embeddings / lm_head stay dense (DESIGN.md §5)
    if cfg.scope == "mlp":
        return role in ("mlp", "moe")
    return True  # scope == "all"


def init_linear(key, in_dim: int, out_dim: int, cfg: WasiConfig, *,
                role: str = "mlp", bias: bool = False, dtype=jnp.float32,
                scale: float | None = None) -> dict:
    std = scale if scale is not None else in_dim ** -0.5
    factored = cfg.factored and wasi_applies(cfg, role)
    kw, kb = jax.random.split(key)
    p: dict = {}
    if factored:
        k = linear_rank(in_dim, out_dim, cfg)
        kl, kr = jax.random.split(kw)
        split = (std / k ** 0.5) ** 0.5
        p["L"] = (jax.random.normal(kl, (out_dim, k), jnp.float32) * split).astype(dtype)
        p["R"] = (jax.random.normal(kr, (k, in_dim), jnp.float32) * split).astype(dtype)
    else:
        p["w"] = (jax.random.normal(kw, (out_dim, in_dim), jnp.float32) * std).astype(dtype)
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
    return p


def init_linear_from_dense(w: jax.Array, cfg: WasiConfig, *, role: str = "mlp",
                           bias=None) -> dict:
    """Paper-faithful init: factor an existing dense W by truncated SVD at
    eps (Alg. 1 t=0). Used when converting pretrained checkpoints."""
    from repro.core.svd import pick_rank, truncated_svd

    p: dict = {}
    if cfg.factored and wasi_applies(cfg, role):
        k = pick_rank(w, cfg.epsilon, align=cfg.rank_align)
        f = truncated_svd(w, k)
        p["L"], p["R"] = f.L, f.R
    else:
        p["w"] = w
    if bias is not None:
        p["b"] = bias
    return p


def asi_spec(key, act_shape: Sequence[int], cfg: WasiConfig,
             dtype=jnp.float32) -> ASIState | None:
    """Warm-start ASI state for a linear whose input activation has
    ``act_shape`` (B, N, I) or (B, H, W, I). None if compression is off."""
    if not cfg.compress_acts:
        return None
    a = cfg.asi
    if len(act_shape) == 3:
        fracs = (a.batch_frac, a.token_frac, a.feature_frac)
    else:
        fracs = (a.batch_frac,) + (a.token_frac,) * (len(act_shape) - 2) + (a.feature_frac,)
    ranks = asi_mode_ranks(act_shape, fracs, skip_batch=a.skip_batch, align=a.align)
    return asi_init(key, act_shape, ranks, dtype)


def apply_linear(p: dict, x: jax.Array, cfg: WasiConfig,
                 state: ASIState | None = None):
    """Apply. Returns (y, new_state) — new_state is None when no ASI.

    What each branch saves for backward (the sketch-saving contract;
    measured by utils/memprof.py, reference in docs/training.md):

      {"L","R"} + ASI   -> Tucker x~ and the rank-K sketch h~ = x~ R^T
                           (wasi_matmul; never the dense activation)
      {"L","R"} no ASI  -> x plus the dense rank-K sketch h = x R^T,
                           written by the fused forward kernel; backward is
                           one Pallas launch on TPU (kernels/ops.py)
      {"w","L","R"}     -> Tucker x~ (+ L, R); gradient lands on full W
      {"w"} + ASI       -> Tucker x~ (asi_matmul)
      {"w"} plain       -> dense x via plain autodiff (vanilla baseline)
    """
    new_state = None

    def compress(x_):
        if cfg.asi.frozen:
            return asi_project(jax.lax.stop_gradient(x_), state), state
        return asi_step(jax.lax.stop_gradient(x_), state)

    if "L" in p and "w" in p:  # project mode: factored fwd, dense-W gradient
        if state is not None:
            xt, new_state = compress(x)
            y = wasi_matmul_project(x, p["w"], p["L"], p["R"], xt)
        else:
            from repro.core.lowrank_linear import wsi_matmul_project_exact
            y = wsi_matmul_project_exact(x, p["w"], p["L"], p["R"])
    elif "L" in p:  # factored params (scale branch)
        if state is not None:
            xt, new_state = compress(x)
            y = wasi_matmul(x, p["L"], p["R"], xt)
        else:
            # no-ASI factored path (serving, and `wsi` factored training):
            # fused Pallas kernel on TPU, XLA einsum pair elsewhere —
            # ops.lowrank_matmul dispatches per backend
            from repro.kernels.ops import lowrank_matmul
            y = lowrank_matmul(x, p["R"], p["L"])
    else:
        if state is not None:
            xt, new_state = compress(x)
            y = asi_matmul(x, p["w"], xt)
        else:
            y = jnp.einsum("...i,oi->...o", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y, new_state


def linear_out_dim(p: dict) -> int:
    return p["L"].shape[0] if "L" in p else p["w"].shape[0]
