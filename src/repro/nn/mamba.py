"""Mamba-1 (selective scan) and Mamba-2 (SSD) blocks.

TPU adaptation (DESIGN.md §3): the CUDA reference implements the selective
scan as a fused recurrent kernel over time; on TPU we use

  * Mamba-1: ``jax.lax.associative_scan`` over the (A_bar, B_bar*x) pairs —
    log-depth, maps to large elementwise VPU ops;
  * Mamba-2: the SSD *chunked* formulation — intra-chunk work becomes plain
    (L ⊙ CB^T) matmuls on the MXU and inter-chunk state is a short
    ``lax.scan`` over chunk summaries. A Pallas kernel for the intra-chunk
    matmuls lives in repro/kernels/ssd_scan.py.

Decode keeps O(1) recurrent state per layer:
  Mamba-1 state (B, d_inner, d_state); Mamba-2 state (B, H, dh, d_state);
  both carry a (B, d_conv-1, d_conv_ch) rolling conv buffer.

Projections (in/out/x/dt) bind through the SubspacePlan (repro.api), so
WASI factoring applies (the paper's technique on an attention-free
architecture — falcon-mamba).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import MeshPolicy, shard
from repro.nn.attention import is_vector_pos
from repro.api import bind, plan_of, role_treated


class MambaState(NamedTuple):
    ssm: jax.Array   # m1: (B, d_inner, N)   m2: (B, H, dh, N)
    conv: jax.Array  # rolling conv input buffer (B, d_conv-1, channels)


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. x (B, S, C), w (K, C) -> (B, S, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :] for i in range(k))
    return out + b[None, None, :]


def _conv_step(state_buf: jax.Array, x_t: jax.Array, w: jax.Array,
               b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """One decode step of the causal conv. state_buf (B, K-1, C), x_t (B, C)."""
    window = jnp.concatenate([state_buf, x_t[:, None, :]], axis=1)  # (B, K, C)
    y = jnp.einsum("bkc,kc->bc", window, w) + b[None, :]
    return window[:, 1:, :], y


def _prefill_conv_buf(prev_buf: jax.Array, raw_seq: jax.Array,
                      count) -> jax.Array:
    """Rolling conv buffer after consuming ``count`` tokens of ``raw_seq``
    (pre-conv inputs) — what a scan of ``_conv_step`` from position 0 would
    leave behind. ``count`` is a scalar or (B,) per-row valid length, so
    right-padded (bucketed) prefill rows pick up their own last K-1 REAL
    inputs.

    Prefill always starts at absolute position 0, so the pre-history is
    zeros BY CONSTRUCTION — ``prev_buf`` supplies only the (B, K-1, C)
    buffer shape, never its contents. (A recycled serve slot hands in a
    stale buffer from the previous request; reading it would leak that
    request's activations into prompts shorter than K-1.)
    """
    b, km1 = prev_buf.shape[0], prev_buf.shape[1]
    hist = jnp.concatenate([jnp.zeros_like(prev_buf), raw_seq], axis=1)
    cnt = count if is_vector_pos(count) else jnp.full((b,), count)
    idx = cnt[:, None] + jnp.arange(km1)[None, :]         # hist idx of the
    return jnp.take_along_axis(hist, idx[..., None], axis=1)  # last K-1 valid


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def init_mamba1(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    ssm = cfg.ssm
    di = ssm.expand * d
    n = ssm.d_state
    dtr = ssm.dt_rank or max(d // 16, 1)
    ks = jax.random.split(key, 6)
    plan = plan_of(cfg)
    return {
        "in_proj": bind.init_params(ks[0], plan.linear("ssm/in_proj", d, 2 * di),
                                    dtype=dtype),
        "x_proj": bind.init_params(ks[1], plan.linear("ssm/x_proj", di, dtr + 2 * n),
                                   dtype=dtype),
        "dt_proj": bind.init_params(ks[2], plan.linear("ssm/dt_proj", dtr, di),
                                    dtype=dtype, bias=True),
        "out_proj": bind.init_params(ks[3], plan.linear("ssm/out_proj", di, d),
                                     dtype=dtype, scale=di ** -0.5),
        "conv_w": (jax.random.normal(ks[4], (ssm.d_conv, di), jnp.float32)
                   * ssm.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (di, n))).astype(jnp.float32),
        "D": jnp.ones((di,), jnp.float32),
    }


def init_mamba1_state(key, cfg: ModelConfig, batch: int, seq: int,
                      dtype=jnp.float32) -> dict:
    w = cfg.wasi
    if not (w.compress_acts and role_treated(w, "ssm")):
        return {}
    d = cfg.d_model
    di = cfg.ssm.expand * d
    ks = jax.random.split(key, 3)
    return {
        "in_proj": bind.asi_state(ks[0], (batch, seq, d), w, dtype),
        "x_proj": bind.asi_state(ks[1], (batch, seq, di), w, dtype),
        "out_proj": bind.asi_state(ks[2], (batch, seq, di), w, dtype),
    }


def _selective_scan(u, dt, A, B, C, D, chunk: int = 128, *,
                    return_final: bool = False):
    """u (B,S,di), dt (B,S,di), A (di,N), B/C (B,S,N) -> y (B,S,di).

    h_t = exp(dt_t A) h_{t-1} + dt_t B_t u_t ;  y_t = C_t . h_t + D u_t

    Chunked: an outer lax.scan carries the (B,di,N) state across sequence
    chunks; within a chunk a log-depth associative scan materializes only
    (B,chunk,di,N) — never the full-sequence state history (which for
    falcon-mamba at 4k would be tens of GiB). The chunk body is
    jax.checkpoint'ed so the backward recomputes instead of stacking.

    ``return_final=True`` additionally returns h_S (B,di,N) — the recurrent
    state after the last token, i.e. exactly the decode-cache state a scan
    of single-token steps would have produced (token-parallel prefill).
    """
    bsz, s, di = u.shape
    n = B.shape[-1]
    if s % chunk != 0:
        chunk = s  # short sequences: single chunk
    nc = s // chunk

    def compose(x, y):
        return (y[0] * x[0], y[0] * x[1] + y[1])

    @jax.checkpoint
    def per_chunk(h0, xs):
        uc, dtc, Bc, Cc = xs                                    # (B,chunk,..)
        a = jnp.exp(dtc[..., None] * A[None, None])             # (B,Q,di,N)
        bu = (dtc * uc)[..., None] * Bc[:, :, None, :]
        ca, h = jax.lax.associative_scan(compose, (a, bu), axis=1)
        h = h + ca * h0[:, None]                                # carry in
        y = jnp.einsum("bsdn,bsn->bsd", h, Cc)
        return h[:, -1], y

    xs = tuple(jnp.moveaxis(t.reshape(bsz, nc, chunk, *t.shape[2:]), 1, 0)
               for t in (u, dt, B, C))
    h0 = jnp.zeros((bsz, di, n), u.dtype)
    h_last, ys = jax.lax.scan(per_chunk, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, di)
    y = y + D[None, None] * u
    if return_final:
        return y, h_last
    return y


def apply_mamba1(p: dict, x: jax.Array, cfg: ModelConfig, *,
                 state: MambaState | None = None,
                 states: dict | None = None,
                 policy: MeshPolicy | None = None,
                 valid_len: jax.Array | None = None):
    """Returns (y, new_state, new_asi_states).

    Modes: train (state None); token-parallel prefill (state given, S > 1 —
    the full-sequence scan also emits the final recurrent state + conv
    buffer, so decode continues exactly where a scanned prefill would);
    decode (state given, S == 1). ``valid_len`` (B,) freezes the recurrence
    (dt = 0) past each row's true prompt length for right-padded prefill.
    """
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    n = ssm.d_state
    dtr = ssm.dt_rank or max(cfg.d_model // 16, 1)
    st = states or {}
    new_st = dict(st)
    prefill = state is not None and x.shape[1] > 1

    plan = plan_of(cfg)

    def lin(name, inp):
        spec = plan.linear(f"ssm/{name}", inp.shape[-1],
                           bind.linear_out_dim(p[name]))
        y, ns = bind.apply(spec, p[name], inp, cfg.wasi, st.get(name))
        if ns is not None:
            new_st[name] = ns
        return y

    xz = lin("in_proj", x)                                      # (B,S,2*di)
    xz = shard(xz, policy, "batch", "seq", "model")
    u, z = jnp.split(xz, 2, axis=-1)
    A = -jnp.exp(p["A_log"])

    if state is None or prefill:  # train, or prefill (cache-building) pass
        s = u.shape[1]
        u_raw = u
        u = _causal_conv(u, p["conv_w"], p["conv_b"])
        u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
        dbc = lin("x_proj", u)
        dt_r, B, C = jnp.split(dbc, [dtr, dtr + n], axis=-1)
        dt = jax.nn.softplus(lin("dt_proj", dt_r).astype(jnp.float32))
        if valid_len is not None:
            # dt = 0 past the true length: exp(0*A) = 1 and dt*B*u = 0, so
            # the state rides through padding untouched
            live = jnp.arange(s)[None, :] < valid_len[:, None]
            dt = jnp.where(live[..., None], dt, 0.0)
        scanned = _selective_scan(u.astype(jnp.float32), dt, A,
                                  B.astype(jnp.float32), C.astype(jnp.float32),
                                  p["D"], return_final=prefill)
        if prefill:
            y, h_final = scanned
            cnt = s if valid_len is None else valid_len
            new_state = MambaState(
                ssm=h_final,
                conv=_prefill_conv_buf(state.conv, u_raw, cnt))
        else:
            y = scanned
            new_state = None
    else:  # decode one token: x (B,1,d)
        u1 = u[:, 0]
        conv_buf, u1 = _conv_step(state.conv, u1, p["conv_w"], p["conv_b"])
        u1 = jax.nn.silu(u1.astype(jnp.float32)).astype(x.dtype)
        dbc = lin("x_proj", u1[:, None, :])[:, 0]
        dt_r, B, C = jnp.split(dbc, [dtr, dtr + n], axis=-1)
        dt = jax.nn.softplus(lin("dt_proj", dt_r[:, None, :])[:, 0].astype(jnp.float32))
        a = jnp.exp(dt[..., None] * A[None])                    # (B,di,N)
        h = a * state.ssm + (dt * u1.astype(jnp.float32))[..., None] * B[:, None, :].astype(jnp.float32)
        y = jnp.einsum("bdn,bn->bd", h, C.astype(jnp.float32)) + p["D"][None] * u1.astype(jnp.float32)
        y = y[:, None, :]
        new_state = MambaState(ssm=h, conv=conv_buf)

    y = (y.astype(x.dtype) * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype))
    out = lin("out_proj", y)
    return shard(out, policy, "batch", "seq", None), new_state, new_st


def init_mamba1_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaState:
    di = cfg.ssm.expand * cfg.d_model
    return MambaState(
        ssm=jnp.zeros((batch, di, cfg.ssm.d_state), jnp.float32),
        conv=jnp.zeros((batch, cfg.ssm.d_conv - 1, di), dtype))


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, chunked)
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    ssm = cfg.ssm
    di = ssm.expand * d
    n = ssm.d_state
    nh = di // ssm.head_dim
    ks = jax.random.split(key, 5)
    plan = plan_of(cfg)
    # Sharding-aligned projection split (DESIGN.md §4): a fused [u|z|B|C|dt]
    # projection puts split boundaries inside model-axis shards (involuntary
    # reshard of the full (B,S,14k+) tensor per layer — measured 150 GiB on
    # zamba2). in_proj emits [u|z] (2*di, boundary at di aligns with any
    # 2^k-way sharding); the tiny B/C/dt head is a separate REPLICATED
    # projection, and the depthwise convs are split the same way.
    return {
        "in_proj": bind.init_params(ks[0], plan.linear("ssm/in_proj", d, 2 * di),
                                    dtype=dtype),
        "bcdt_proj": bind.init_params(ks[1], plan.linear("ssm/bcdt_proj", d, 2 * n + nh),
                                      dtype=dtype),
        "out_proj": bind.init_params(ks[2], plan.linear("ssm/out_proj", di, d),
                                     dtype=dtype, scale=di ** -0.5),
        "conv_w": (jax.random.normal(ks[3], (ssm.d_conv, di), jnp.float32)
                   * ssm.d_conv ** -0.5).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "conv_w_bc": (jax.random.normal(ks[4], (ssm.d_conv, 2 * n), jnp.float32)
                      * ssm.d_conv ** -0.5).astype(dtype),
        "conv_b_bc": jnp.zeros((2 * n,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
    }


def init_mamba2_state(key, cfg: ModelConfig, batch: int, seq: int,
                      dtype=jnp.float32) -> dict:
    w = cfg.wasi
    if not (w.compress_acts and role_treated(w, "ssm")):
        return {}
    d = cfg.d_model
    di = cfg.ssm.expand * d
    ks = jax.random.split(key, 3)
    return {
        "in_proj": bind.asi_state(ks[0], (batch, seq, d), w, dtype),
        "bcdt_proj": bind.asi_state(ks[2], (batch, seq, d), w, dtype),
        "out_proj": bind.asi_state(ks[1], (batch, seq, di), w, dtype),
    }


def _ssd_chunked(u, dt, A, B, C, D, chunk: int, *,
                 return_final: bool = False):
    """SSD (Mamba-2) chunked scan.

    u (B,S,H,dh); dt (B,S,H) >0; A (H,)<0; B,C (B,S,N); D (H,).
    Within each chunk of length Q: y_intra = (L ⊙ (C B^T)) (dt u), where
    L[i,j] = exp(sum_{j<k<=i} dt_k A) for j<=i. Across chunks a scan carries
    the (H, dh, N) state. All heavy ops are matmuls (MXU-friendly).

    Ragged S is zero-padded up to a chunk multiple with dt = 0 — an identity
    step (decay exp(0) = 1, zero input), so the carried state and the sliced
    output are exactly those of the unpadded sequence. ``return_final=True``
    additionally returns the (B,H,dh,N) state after token S (prefill).
    """
    b, s, h, dh = u.shape
    n = B.shape[-1]
    s_orig = s
    if s % chunk != 0:
        pad = chunk - s % chunk
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
        s = s + pad
    nc = s // chunk
    uc = u.reshape(b, nc, chunk, h, dh)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    @jax.checkpoint
    def per_chunk(s_prev, xs):
        """One chunk: intra-chunk quadratic + inter-chunk state pass.
        Live memory O(B*Q*Q*H) for this chunk only (scan, not batched)."""
        ucb, dtb, Bb, Cb = xs                               # (B,Q,H,dh) etc.
        da = dtb * A[None, None, :]                         # (B,Q,H)
        cum = jnp.cumsum(da, axis=1)
        li = cum[:, :, None, :] - cum[:, None, :, :]        # (B,Q,Q,H)
        L = jnp.where(tri[None, :, :, None], jnp.exp(li), 0.0)
        cbm = jnp.einsum("bqn,bkn->bqk", Cb, Bb)            # (B,Q,Q)
        du = dtb[..., None] * ucb                           # (B,Q,H,dh)
        y_intra = jnp.einsum("bqkh,bkhd->bqhd", cbm[..., None] * L, du)
        # inter-chunk contribution from carried state
        decay_in = jnp.exp(cum)                             # (B,Q,H)
        y_inter = jnp.einsum("bqn,bhdn,bqh->bqhd", Cb, s_prev, decay_in)
        # update carried state with this chunk's summary
        decay_out = jnp.exp(cum[:, -1:, :] - cum)           # (B,Q,H)
        s_c = jnp.einsum("bqh,bqhd,bqn->bhdn", decay_out, du, Bb)
        chunk_decay = jnp.exp(jnp.sum(da, axis=1))          # (B,H)
        s_new = chunk_decay[..., None, None] * s_prev + s_c
        return s_new, y_intra + y_inter

    s0 = jnp.zeros((b, h, dh, n), u.dtype)
    xs = (jnp.moveaxis(uc, 1, 0), jnp.moveaxis(dtc, 1, 0),
          jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0))
    s_last, ys = jax.lax.scan(per_chunk, s0, xs)            # (NC,B,Q,H,dh)
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dh)
    y = (y + D[None, None, :, None] * u)[:, :s_orig]
    if return_final:
        return y, s_last
    return y


def apply_mamba2(p: dict, x: jax.Array, cfg: ModelConfig, *,
                 state: MambaState | None = None,
                 states: dict | None = None,
                 policy: MeshPolicy | None = None,
                 valid_len: jax.Array | None = None):
    """Returns (y, new_state, new_asi_states).

    Same mode split as :func:`apply_mamba1`: train / token-parallel prefill
    (state given, S > 1: emits final SSD state + both conv buffers) / decode.
    """
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    n = ssm.d_state
    nh = di // ssm.head_dim
    dh = ssm.head_dim
    st = states or {}
    new_st = dict(st)
    prefill = state is not None and x.shape[1] > 1

    plan = plan_of(cfg)

    def lin(name, inp):
        spec = plan.linear(f"ssm/{name}", inp.shape[-1],
                           bind.linear_out_dim(p[name]))
        y, ns = bind.apply(spec, p[name], inp, cfg.wasi, st.get(name))
        if ns is not None:
            new_st[name] = ns
        return y

    proj = lin("in_proj", x)                                # (B,S,2di)
    proj = shard(proj, policy, "batch", "seq", "model")
    u, z = jnp.split(proj, 2, axis=-1)                      # aligned split
    bcdt = lin("bcdt_proj", x)                              # (B,S,2n+nh) repl.
    Bv, Cv, dt_raw = jnp.split(bcdt, [n, 2 * n], axis=-1)
    A = -jnp.exp(p["A_log"])

    if state is None or prefill:
        u_raw, bc_raw = u, jnp.concatenate([Bv, Cv], axis=-1)
        u = _causal_conv(u, p["conv_w"], p["conv_b"])       # sharded channels
        u = jax.nn.silu(u.astype(jnp.float32)).astype(x.dtype)
        bc = _causal_conv(bc_raw, p["conv_w_bc"], p["conv_b_bc"])  # repl, tiny
        bc = jax.nn.silu(bc.astype(jnp.float32)).astype(x.dtype)
        Bv, Cv = jnp.split(bc, 2, axis=-1)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, None])
        bsz, s, _ = u.shape
        if valid_len is not None:
            live = jnp.arange(s)[None, :] < valid_len[:, None]
            dt = jnp.where(live[..., None], dt, 0.0)        # identity steps
        scanned = _ssd_chunked(u.reshape(bsz, s, nh, dh).astype(jnp.float32),
                               dt, A, Bv.astype(jnp.float32),
                               Cv.astype(jnp.float32),
                               p["D"], min(ssm.chunk, s), return_final=prefill)
        if prefill:
            y, s_final = scanned
            cnt = s if valid_len is None else valid_len
            conv_u_prev, conv_bc_prev = state.conv
            new_state = MambaState(
                ssm=s_final,
                conv=(_prefill_conv_buf(conv_u_prev, u_raw, cnt),
                      _prefill_conv_buf(conv_bc_prev, bc_raw, cnt)))
        else:
            y = scanned
            new_state = None
        y = y.reshape(bsz, s, di)
    else:  # decode
        conv_u, conv_bc = state.conv
        conv_u, u1 = _conv_step(conv_u, u[:, 0], p["conv_w"], p["conv_b"])
        u1 = jax.nn.silu(u1.astype(jnp.float32))
        bc1 = jnp.concatenate([Bv[:, 0], Cv[:, 0]], axis=-1)
        conv_bc, bc1 = _conv_step(conv_bc, bc1, p["conv_w_bc"], p["conv_b_bc"])
        bc1 = jax.nn.silu(bc1.astype(jnp.float32))
        B1, C1 = jnp.split(bc1, 2, axis=-1)
        conv_buf = (conv_u, conv_bc)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"][None])
        uh = u1.reshape(-1, nh, dh)
        a = jnp.exp(dt * A[None])                           # (B,H)
        h_new = (a[..., None, None] * state.ssm
                 + (dt[..., None] * uh)[..., None] * B1[:, None, None, :])
        y = jnp.einsum("bhdn,bn->bhd", h_new, C1) + p["D"][None, :, None] * uh
        y = y.reshape(-1, 1, di)
        new_state = MambaState(ssm=h_new, conv=conv_buf)

    # gated RMSNorm (mamba2 norm before out_proj)
    yz = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yz * yz, axis=-1, keepdims=True)
    yz = yz * jax.lax.rsqrt(var + 1e-6) * p["norm_scale"].astype(jnp.float32)
    out = lin("out_proj", yz.astype(x.dtype))
    return shard(out, policy, "batch", "seq", None), new_state, new_st


def init_mamba2_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> MambaState:
    ssm = cfg.ssm
    di = ssm.expand * cfg.d_model
    nh = di // ssm.head_dim
    return MambaState(
        ssm=jnp.zeros((batch, nh, ssm.head_dim, ssm.d_state), jnp.float32),
        conv=(jnp.zeros((batch, ssm.d_conv - 1, di), dtype),
              jnp.zeros((batch, ssm.d_conv - 1, 2 * ssm.d_state), dtype)))
