"""Mixture-of-Experts FFN: GShard-style capacity dispatch, top-k routing,
DeepSeek-style shared experts, expert- or tensor-parallel expert banks.

Dispatch plan (per token group of size S):
  router logits (S, E) -> top-k -> capacity positions via cumsum over the
  expert axis -> dispatch one-hot (S, E, C) -> expert inputs (E, C, d) ->
  batched expert FFN -> combine weighted by router probs.

Tokens over capacity are DROPPED (standard GShard; capacity_factor sizes C).
EP: the expert axis of the (E, C, d) buffers is sharded on the `model` mesh
axis, which makes XLA materialize the dispatch as an all-to-all — exactly
the production communication pattern (DESIGN.md §4).

WASI on experts: per-expert factor banks L (E, O, K), R (E, K, I) — factored
weights, exact autodiff gradients (capacity-bounded activations make ASI's
residual win marginal here; noted in DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import MeshPolicy, shard
from repro.api import bind
from repro.api.plan import LinearSpec, plan_of


def _init_bank(key, n: int, spec: LinearSpec, *, dtype, scale=None) -> dict:
    in_dim, out_dim = spec.in_dim, spec.out_dim
    std = scale if scale is not None else in_dim ** -0.5
    if spec.mode == "factored":
        k = spec.rank
        kl, kr = jax.random.split(key)
        split = (std / k ** 0.5) ** 0.5
        return {
            "L": (jax.random.normal(kl, (n, out_dim, k), jnp.float32) * split).astype(dtype),
            "R": (jax.random.normal(kr, (n, k, in_dim), jnp.float32) * split).astype(dtype),
        }
    return {"w": (jax.random.normal(key, (n, out_dim, in_dim), jnp.float32) * std).astype(dtype)}


def _bank_matmul(spec: LinearSpec, p: dict, x: jax.Array) -> jax.Array:
    """x (E, C, I) through per-expert weights -> (E, C, O), dispatched on
    the site's planned mode (factor banks keep exact autodiff gradients;
    DESIGN.md §5). In project mode the per-step WSI injection leaves
    (L, R) next to each bank's dense w: run the paper's factored forward
    with the exact dense-W gradient, vmapped over the expert axis."""
    if bind.is_quantized(p):
        # int8 deployment banks (convert.quantize): per-expert per-channel
        # scales fold into the f32 accumulators, same as the 2D q8 routes
        xf = x.astype(jnp.float32)
        if "L" in p:
            h = jnp.einsum("eci,eki->eck", xf,
                           p["R"].astype(jnp.float32)) * p["sR"][:, None, :]
            y = jnp.einsum("eck,eok->eco", h,
                           p["L"].astype(jnp.float32)) * p["sL"][:, None, :]
        else:  # dense banks pack to {w, sW} (untreated moe role)
            y = jnp.einsum("eci,eoi->eco", xf,
                           p["w"].astype(jnp.float32)) * p["sW"][:, None, :]
        return y.astype(x.dtype)
    if spec.mode == "factored":
        h = jnp.einsum("eci,eki->eck", x, p["R"])
        return jnp.einsum("eck,eok->eco", h, p["L"])
    if spec.mode == "project" and bind.linear_layout(p) == "project":
        from repro.core.lowrank_linear import wsi_matmul_project_exact
        return jax.vmap(wsi_matmul_project_exact)(x, p["w"], p["L"], p["R"])
    return jnp.einsum("eci,eoi->eco", x, p["w"])


def _bank_specs(cfg: ModelConfig) -> dict[str, LinearSpec]:
    plan = plan_of(cfg)
    d = cfg.d_model
    f = cfg.moe.expert_d_ff or cfg.d_ff
    return {"w_gate": plan.linear("moe/w_gate", d, f),
            "w_up": plan.linear("moe/w_up", d, f),
            "w_down": plan.linear("moe/w_down", f, d)}


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.moe
    d = cfg.d_model
    f = m.expert_d_ff or cfg.d_ff
    specs = _bank_specs(cfg)
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    p = {
        "router": {"w": (jax.random.normal(kr, (m.n_experts, d), jnp.float32)
                          * d ** -0.5).astype(jnp.float32)},
        "experts": {
            "w_gate": _init_bank(kg, m.n_experts, specs["w_gate"], dtype=dtype),
            "w_up": _init_bank(ku, m.n_experts, specs["w_up"], dtype=dtype),
            "w_down": _init_bank(kd, m.n_experts, specs["w_down"],
                                 dtype=dtype, scale=f ** -0.5),
        },
    }
    if m.n_shared > 0:
        kg2, ku2, kd2 = jax.random.split(ks, 3)
        p["shared"] = {
            "w_gate": _init_bank(kg2, m.n_shared, specs["w_gate"], dtype=dtype),
            "w_up": _init_bank(ku2, m.n_shared, specs["w_up"], dtype=dtype),
            "w_down": _init_bank(kd2, m.n_shared, specs["w_down"],
                                 dtype=dtype, scale=f ** -0.5),
        }
    return p


def _expert_ffn(specs: dict, bank: dict, x: jax.Array) -> jax.Array:
    g = _bank_matmul(specs["w_gate"], bank["w_gate"], x)
    u = _bank_matmul(specs["w_up"], bank["w_up"], x)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return _bank_matmul(specs["w_down"], bank["w_down"], h)


def moe_capacity(group_tokens: int, cfg: ModelConfig) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * group_tokens * m.top_k / m.n_experts)
    return max(8, -(-c // 8) * 8)  # pad to multiple of 8


def apply_moe(p: dict, x: jax.Array, cfg: ModelConfig,
              policy: MeshPolicy | None = None):
    """x (B, S, d) -> (y, aux_loss). Routing in fp32.

    The batch dim doubles as the GShard *group* dim: dispatch/capacity are
    computed per batch row, so the position cumsum never crosses DP shards
    and the (B, E, C, d) buffers shard batch-on-data / expert-on-model.
    """
    m = cfg.moe
    b, s, d = x.shape
    cap = moe_capacity(s, cfg)
    e_axis = "model" if m.shard == "expert" else None

    logits = jnp.einsum("bsd,ed->bse", x.astype(jnp.float32), p["router"]["w"])
    probs = jax.nn.softmax(logits, axis=-1)                     # (B, S, E)
    top_p, top_e = jax.lax.top_k(probs, m.top_k)                # (B, S, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    def group(xg, top_pg, top_eg):
        """One group: xg (S, d); returns (y (S, d))."""
        onehot = jax.nn.one_hot(top_eg, m.n_experts, dtype=jnp.int32)  # (S,K,E)
        flat = onehot.reshape(s * m.top_k, m.n_experts)
        pos = jnp.cumsum(flat, axis=0) - flat
        pos = (pos * flat).sum(-1).reshape(s, m.top_k)                 # (S, K)
        fits = pos < cap
        gate = top_pg * fits

        e_idx = top_eg.reshape(-1)
        keep = fits.reshape(-1)
        safe_c = jnp.where(keep, pos.reshape(-1), cap - 1)
        tok_idx = jnp.repeat(jnp.arange(s), m.top_k)
        disp = jnp.zeros((m.n_experts, cap, d), xg.dtype)
        disp = disp.at[e_idx, safe_c].add(
            jnp.where(keep[:, None], xg[tok_idx], 0).astype(xg.dtype))
        return disp, (e_idx, safe_c, keep, gate)

    disp, meta = jax.vmap(group)(x, top_p, top_e)               # (B,E,C,d)
    # EP communication pattern: the scatter above runs BATCH-LOCAL (first
    # constraint), then ONE reshard moves expert rows to their owners (the
    # all-to-all); constraining the scatter output expert-sharded directly
    # makes XLA gather the whole buffer around the scatter (measured 45 GiB
    # of collectives on deepseek — EXPERIMENTS.md §Perf).
    disp = shard(disp, policy, "batch", None, None, None)
    disp = shard(disp, policy, "batch", e_axis, None, None)
    # fold groups into the expert batch: (E, B*C, d) expert-major layout
    specs = _bank_specs(cfg)
    out = _expert_ffn(specs, p["experts"],
                      disp.transpose(1, 0, 2, 3).reshape(m.n_experts, b * cap, d))
    out = out.reshape(m.n_experts, b, cap, d).transpose(1, 0, 2, 3)
    out = shard(out, policy, "batch", e_axis, None, None)
    out = shard(out, policy, "batch", None, None, None)  # back for the gather

    def combine(out_g, meta_g):
        e_idx, safe_c, keep, gate = meta_g
        gathered = out_g[e_idx, safe_c]                          # (S*K, d)
        gathered = jnp.where(keep[:, None], gathered, 0)
        return (gathered.reshape(s, m.top_k, d)
                * gate[..., None].astype(out_g.dtype)).sum(axis=1)

    y = jax.vmap(combine)(out, meta)                             # (B, S, d)

    if m.n_shared > 0:
        xs = jnp.broadcast_to(x.reshape(1, b * s, d), (m.n_shared, b * s, d))
        y = y + _expert_ffn(specs, p["shared"], xs).sum(axis=0).reshape(b, s, d)

    # load-balancing aux loss (Switch-style)
    me = probs.mean(axis=(0, 1))                                 # (E,)
    ce = jax.nn.one_hot(top_e[..., 0], m.n_experts).mean(axis=(0, 1))
    aux = m.n_experts * jnp.sum(me * ce)
    return y.astype(x.dtype), aux
