"""Rotary position embeddings (half-rotation convention)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                              # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv    # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_embedding(seq: int, dim: int, dtype=jnp.float32) -> jax.Array:
    """Whisper-style fixed sinusoidal position table (extrapolates)."""
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    inv = jnp.exp(-jnp.log(10000.0) * jnp.arange(dim // 2, dtype=jnp.float32)
                  / max(dim // 2 - 1, 1))
    ang = pos * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)
