"""MLP blocks: GELU (ViT/Whisper-style) and SwiGLU (LLaMA-style).

All projections route through nn.linear so WASI factoring / ASI compression
apply uniformly. The WASI sharding trick (DESIGN.md §4): up/gate L sharded on
d_ff (column-parallel), down R sharded on d_ff (row-parallel) — the residual
all-reduce payload after `down` is d_model-sized in vanilla but the factored
pair turns the contraction into a K-sized partial first.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import MeshPolicy, shard
from repro.nn.linear import apply_linear, asi_spec, init_linear, wasi_applies


def init_mlp(key, cfg: ModelConfig, d_in: int | None = None,
             d_ff: int | None = None, dtype=jnp.float32) -> dict:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    w = cfg.wasi
    if cfg.mlp_act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "gate": init_linear(k1, d, f, w, role="mlp", dtype=dtype),
            "up": init_linear(k2, d, f, w, role="mlp", dtype=dtype),
            "down": init_linear(k3, f, d, w, role="mlp", dtype=dtype,
                                scale=f ** -0.5),
        }
    k1, k2 = jax.random.split(key)
    return {
        "up": init_linear(k1, d, f, w, role="mlp", dtype=dtype),
        "down": init_linear(k2, f, d, w, role="mlp", dtype=dtype, scale=f ** -0.5),
    }


def init_mlp_state(key, cfg: ModelConfig, batch: int, seq: int,
                   d_in: int | None = None, d_ff: int | None = None,
                   dtype=jnp.float32) -> dict:
    w = cfg.wasi
    if not (w.compress_acts and wasi_applies(w, "mlp")):
        return {}
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    st = {"up": asi_spec(ks[0], (batch, seq, d), w, dtype),
          "down": asi_spec(ks[1], (batch, seq, f), w, dtype)}
    if cfg.mlp_act == "swiglu":
        st["gate"] = asi_spec(ks[2], (batch, seq, d), w, dtype)
    return st


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig,
              states: dict | None = None,
              policy: MeshPolicy | None = None):
    """Returns (y, new_states)."""
    st = states or {}
    new_st = dict(st)

    def lin(name, inp):
        y, ns = apply_linear(p[name], inp, cfg.wasi, st.get(name))
        if ns is not None:
            new_st[name] = ns
        return y

    if "gate" in p:
        g = lin("gate", x)
        u = lin("up", x)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(lin("up", x).astype(jnp.float32)).astype(x.dtype)
    h = shard(h, policy, "batch", "seq", "model")
    y = lin("down", h)
    return shard(y, policy, "batch", "seq", None), new_st
