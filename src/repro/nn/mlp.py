"""MLP blocks: GELU (ViT/Whisper-style) and SwiGLU (LLaMA-style).

All projections route through the SubspacePlan (repro.api): each linear
site ("mlp/gate", "mlp/up", "mlp/down") is a resolved LinearSpec, so WASI
factoring / ASI compression apply uniformly and no call site inspects param
dict keys. The WASI sharding trick (DESIGN.md §4): up/gate L sharded on
d_ff (column-parallel), down R sharded on d_ff (row-parallel) — the residual
all-reduce payload after `down` is d_model-sized in vanilla but the factored
pair turns the contraction into a K-sized partial first.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import bind, plan_of, role_treated
from repro.config import ModelConfig
from repro.distributed.sharding import MeshPolicy, shard


def init_mlp(key, cfg: ModelConfig, d_in: int | None = None,
             d_ff: int | None = None, dtype=jnp.float32) -> dict:
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    plan = plan_of(cfg)
    if cfg.mlp_act == "swiglu":
        k1, k2, k3 = jax.random.split(key, 3)
        return {
            "gate": bind.init_params(k1, plan.linear("mlp/gate", d, f),
                                     dtype=dtype),
            "up": bind.init_params(k2, plan.linear("mlp/up", d, f),
                                   dtype=dtype),
            "down": bind.init_params(k3, plan.linear("mlp/down", f, d),
                                     dtype=dtype, scale=f ** -0.5),
        }
    k1, k2 = jax.random.split(key)
    return {
        "up": bind.init_params(k1, plan.linear("mlp/up", d, f), dtype=dtype),
        "down": bind.init_params(k2, plan.linear("mlp/down", f, d),
                                 dtype=dtype, scale=f ** -0.5),
    }


def init_mlp_state(key, cfg: ModelConfig, batch: int, seq: int,
                   d_in: int | None = None, d_ff: int | None = None,
                   dtype=jnp.float32) -> dict:
    w = cfg.wasi
    if not (w.compress_acts and role_treated(w, "mlp")):
        return {}
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    st = {"up": bind.asi_state(ks[0], (batch, seq, d), w, dtype),
          "down": bind.asi_state(ks[1], (batch, seq, f), w, dtype)}
    if cfg.mlp_act == "swiglu":
        st["gate"] = bind.asi_state(ks[2], (batch, seq, d), w, dtype)
    return st


def apply_mlp(p: dict, x: jax.Array, cfg: ModelConfig,
              states: dict | None = None,
              policy: MeshPolicy | None = None):
    """Returns (y, new_states)."""
    st = states or {}
    new_st = dict(st)
    plan = plan_of(cfg)

    def lin(name, inp):
        spec = plan.linear(f"mlp/{name}", inp.shape[-1],
                           bind.linear_out_dim(p[name]))
        y, ns = bind.apply(spec, p[name], inp, cfg.wasi, st.get(name))
        if ns is not None:
            new_st[name] = ns
        return y

    if "gate" in p:
        g = lin("gate", x)
        u = lin("up", x)
        h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = jax.nn.gelu(lin("up", x).astype(jnp.float32)).astype(x.dtype)
    h = shard(h, policy, "batch", "seq", "model")
    y = lin("down", h)
    return shard(y, policy, "batch", "seq", None), new_st
