"""Memory-aware cross-entropy.

The naive ``logits.astype(f32) -> logsumexp -> softmax-grad`` materializes
TWO fp32 (B, S, V) tensors; at train_4k with a 152k-262k vocab that is
multiple GiB/device (measured — EXPERIMENTS.md §Perf). This custom-VJP CE
keeps logits in their storage dtype, runs reductions in fp32 (numerics), and
emits the backward softmax in the LOGITS dtype:

  fwd residuals: logits (bf16), lse (f32, (B,S)), labels, mask
  bwd: d_logits = (softmax(logits) - onehot) * g / n_valid   (bf16)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


@jax.custom_vjp
def masked_xent(logits: jax.Array, labels: jax.Array, mask: jax.Array):
    """Mean CE over mask>0 positions. logits (B,S,V); labels (B,S) int."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def _fwd(logits, labels, mask):
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    n = jnp.maximum(mask.sum(), 1.0)
    loss = ((lse - gold) * mask).sum() / n
    return loss, (logits, lse, labels, mask, n)


def _bwd(res, g):
    logits, lse, labels, mask, n = res
    p = jnp.exp(logits.astype(jnp.float32) - lse[..., None])
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    scale = (g * mask / n)[..., None]
    d = ((p - onehot) * scale).astype(logits.dtype)
    return d, None, None


masked_xent.defvjp(_fwd, _bwd)
