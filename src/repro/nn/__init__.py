"""Neural-network substrate: layers used by every architecture."""
