"""Grouped-query attention with causal / sliding-window / cross variants,
chunked (flash-style) computation, and full + rolling KV caches.

Shapes: hidden (B, S, d); heads laid out (B, S, H, Dh). GQA repeats each of
the KVH key/value heads across G = H // KVH query heads via a reshape —
no materialized repetition.

Long-sequence prefill/train uses :func:`chunked_attention` — an online-
softmax scan over KV chunks that never materializes the (S, S) score matrix
(the pure-JAX analogue of the Pallas flash kernel in repro/kernels; XLA maps
it to a fori loop with O(S * chunk) live memory).

Sliding-window layers keep a ROLLING cache of ``window`` slots: absolute
position p lives in slot p % W; slot validity and relative distance are
reconstructed arithmetically (see ``_rolling_slot_positions``) so decode is
O(W) compute and memory regardless of sequence length — this is what makes
`long_500k` decode cheap for gemma3/mixtral local layers.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import MeshPolicy, shard
from repro.api import bind, plan_of, role_treated
from repro.nn.rotary import apply_rope

NEG_INF = -1e30


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_cache, KVH, Dh)
    v: jax.Array  # (B, S_cache, KVH, Dh)


class PagedKVCache(NamedTuple):
    """Pooled KV storage: pages instead of per-slot rows.

    The batch dimension is gone — storage is a pool of ``n_pages`` pages of
    ``page_size`` token slots each, shared by every serve slot. A per-slot
    ``page_table`` (B, pages_per_slot) int32 maps logical page j of slot b
    to a physical page; reads gather the table into a (B, S_logical) view,
    writes scatter through it. Page 0 is the engine's trash page (dead and
    still-prefilling rows point their table there), so the pool never needs
    per-row validity flags: a position is readable iff the causal mask says
    so, exactly as with dense caches.
    """

    k: jax.Array  # (n_pages, page_size, KVH, Dh)
    v: jax.Array  # (n_pages, page_size, KVH, Dh)

    @property
    def page_size(self) -> int:
        return self.k.shape[1]


# ---------------------------------------------------------------------------
# Parameter init / projection plumbing
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    plan = plan_of(cfg)
    qb = cfg.qkv_bias
    return {
        "wq": bind.init_params(kq, plan.linear("attn/wq", d, h * dh), dtype=dtype, bias=qb),
        "wk": bind.init_params(kk, plan.linear("attn/wk", d, kvh * dh), dtype=dtype, bias=qb),
        "wv": bind.init_params(kv, plan.linear("attn/wv", d, kvh * dh), dtype=dtype, bias=qb),
        "wo": bind.init_params(ko, plan.linear("attn/wo", h * dh, d), dtype=dtype,
                               scale=(h * dh) ** -0.5 / max(cfg.total_pattern_layers, 1) ** 0.5),
    }


def init_attention_state(key, cfg: ModelConfig, batch: int, seq: int,
                         dtype=jnp.float32) -> dict:
    """ASI warm-start states for the four projections (train path)."""
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    w = cfg.wasi
    if not (w.compress_acts and role_treated(w, "attn")):
        return {}
    return {
        "wq": bind.asi_state(ks[0], (batch, seq, d), w, dtype),
        "wk": bind.asi_state(ks[1], (batch, seq, d), w, dtype),
        "wv": bind.asi_state(ks[2], (batch, seq, d), w, dtype),
        "wo": bind.asi_state(ks[3], (batch, seq, h * dh), w, dtype),
    }


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q (B,Sq,KVH,G,Dh) x k (B,Sk,KVH,Dh) -> (B,KVH,G,Sq,Sk)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k)


def _gqa_combine(p: jax.Array, v: jax.Array) -> jax.Array:
    """p (B,KVH,G,Sq,Sk) x v (B,Sk,KVH,Dh) -> (B,Sq,KVH,G,Dh)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v)


def _mask_bias(sq: int, sk: int, q_offset, *, causal: bool,
               window: int) -> jax.Array:
    """Additive mask (Sq, Sk). q position = q_offset + row index."""
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def dense_attention(q, k, v, *, causal: bool, window: int = 0,
                    q_offset=0) -> jax.Array:
    """Reference attention materializing scores. q (B,Sq,H,Dh)."""
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, dh) * (dh ** -0.5)
    s = _gqa_scores(qg, k).astype(jnp.float32)
    s = s + _mask_bias(sq, k.shape[1], q_offset, causal=causal, window=window)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = _gqa_combine(p, v)
    return o.reshape(b, sq, h, dh)


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      q_offset=0, chunk: int = 1024,
                      q_chunk: int = 1024) -> jax.Array:
    """Online-softmax attention, tiled over BOTH query blocks and KV chunks
    (flash semantics, pure JAX).

    Live score memory: O(q_chunk * chunk) per (B, KVH, G). Both the KV-scan
    body and the q-block body are jax.checkpoint'ed so the BACKWARD pass
    recomputes scores per tile instead of stacking them across the scan —
    without this, autodiff through the scan saves every chunk's f32 scores
    (measured: 7 GiB/device at train_4k before the fix; EXPERIMENTS.md §Perf).
    """
    b, sq, h, dh = q.shape
    if sq > q_chunk:
        nq = -(-sq // q_chunk)
        pad = nq * q_chunk - sq
        qp = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else q
        qb = qp.reshape(b, nq, q_chunk, h, dh)

        @jax.checkpoint
        def qblock(qi, idx):
            return chunked_attention(qi, k, v, causal=causal, window=window,
                                     q_offset=q_offset + idx * q_chunk,
                                     chunk=chunk, q_chunk=q_chunk)

        out = jax.lax.map(lambda t: qblock(t[0], t[1]),
                          (jnp.moveaxis(qb, 1, 0), jnp.arange(nq)))
        out = jnp.moveaxis(out, 0, 1).reshape(b, nq * q_chunk, h, dh)
        return out[:, :sq]
    sk = k.shape[1]
    kvh = k.shape[2]
    g = h // kvh
    sk_orig = sk
    if sk % chunk != 0:
        pad = chunk - sk % chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sk = sk + pad
    n_chunks = sk // chunk
    qg = (q.reshape(b, sq, kvh, g, dh) * (dh ** -0.5)).astype(q.dtype)
    kc = k.reshape(b, n_chunks, chunk, kvh, dh)
    vc = v.reshape(b, n_chunks, chunk, kvh, dh)

    qpos = q_offset + jnp.arange(sq)

    @jax.checkpoint
    def step(carry, xs):
        m, l, acc = carry
        kb, vb, c_idx = xs
        s = _gqa_scores(qg, kb).astype(jnp.float32)      # (B,KVH,G,Sq,chunk)
        kpos = c_idx * chunk + jnp.arange(chunk)
        ok = jnp.ones((sq, chunk), bool)
        if causal:
            ok &= kpos[None, :] <= qpos[:, None]
        if window > 0:
            ok &= kpos[None, :] > qpos[:, None] - window
        if sk != sk_orig:
            ok &= (kpos < sk_orig)[None, :]
        s = s + jnp.where(ok, 0.0, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        scale_old = jnp.exp(m - m_new)
        l_new = l * scale_old + p.sum(axis=-1)
        acc_new = acc * scale_old[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(q.dtype), vb).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, kvh, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, dh), jnp.float32)
    kc_t = jnp.moveaxis(kc, 1, 0)
    vc_t = jnp.moveaxis(vc, 1, 0)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0),
                                  (kc_t, vc_t, jnp.arange(n_chunks)))
    o = acc / jnp.maximum(l[..., None], 1e-30)
    o = jnp.moveaxis(o.reshape(b, kvh * g, sq, dh), 1, 2)
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# Rolling-window cache arithmetic
# ---------------------------------------------------------------------------

def _rolling_slot_positions(pos: jax.Array, w: int) -> jax.Array:
    """Absolute position stored in each of the W slots when the writer is at
    absolute position ``pos`` (already written). Slots never written hold a
    negative value (=> masked)."""
    slots = jnp.arange(w)
    return pos - (pos - slots) % w  # in (pos-W, pos]; negative if unwritten


def is_vector_pos(pos) -> bool:
    """Per-slot (B,) vector vs a single shared scalar — the convention for
    decode positions and prefill valid lengths across nn/ modules."""
    return hasattr(pos, "ndim") and pos.ndim == 1


def decode_attention(q, cache: KVCache, pos, *, window: int = 0) -> jax.Array:
    """Single-token decode. q (B,1,H,Dh); cache holds positions <= pos.

    ``pos`` is either a scalar (all rows at the same absolute position —
    lockstep batch) or a (B,) vector of per-row positions (continuous
    batching: every serve slot decodes at its own offset).

    For full caches, slot index == absolute position; for rolling caches
    (cache length == window) slot positions are reconstructed.
    """
    b, _, h, dh = q.shape
    s_cache = cache.k.shape[1]
    kvh = cache.k.shape[2]
    g = h // kvh
    qg = q.reshape(b, 1, kvh, g, dh) * (dh ** -0.5)
    s = _gqa_scores(qg, cache.k).astype(jnp.float32)   # (B,KVH,G,1,S)
    posb = pos[:, None] if is_vector_pos(pos) else jnp.full((1, 1), pos)
    if window > 0 and s_cache == window:
        slot_pos = _rolling_slot_positions(posb, window)   # (B|1, W)
        ok = slot_pos >= 0
    else:
        kpos = jnp.arange(s_cache)[None, :]
        ok = kpos <= posb
        if window > 0:
            ok &= kpos > posb - window
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = _gqa_combine(p, cache.v)
    return o.reshape(b, 1, h, dh)


def cache_update(cache: KVCache, k_new, v_new, pos, *, window: int = 0) -> KVCache:
    """Write one token's K/V at ``pos`` (rolling if cache len == window).
    ``pos`` scalar or (B,) per-row positions."""
    s_cache = cache.k.shape[1]
    rolling = window > 0 and s_cache == window
    if is_vector_pos(pos):
        slot = pos % window if rolling else pos
        rows = jnp.arange(cache.k.shape[0])
        k = cache.k.at[rows, slot].set(k_new[:, 0])
        v = cache.v.at[rows, slot].set(v_new[:, 0])
        return KVCache(k=k, v=v)
    slot = pos % window if rolling else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, slot, axis=1)
    return KVCache(k=k, v=v)


def cache_update_prefill(cache: KVCache, k_new, v_new, offset, *,
                         window: int = 0,
                         valid_len: jax.Array | None = None) -> KVCache:
    """Write a whole prompt's K/V (S tokens starting at absolute position
    ``offset``) into the cache in one pass — the batched-prefill analogue of
    scanning :func:`cache_update` token by token.

    ``valid_len`` (B,) marks per-row true prompt lengths for right-padded
    (length-bucketed) prefill: positions >= valid_len are NOT written, so
    the cache is indistinguishable from an exact-length prefill and the
    decode masks (slot-position arithmetic included) stay correct.
    """
    s_cache = cache.k.shape[1]
    b, s = k_new.shape[:2]
    rolling = window > 0 and s_cache == window
    if rolling:
        # Slot j of a rolling cache must hold the LAST valid position p with
        # p % W == j. Gather that position's K/V per (row, slot); slots whose
        # owner predates this prefill chunk keep their current contents.
        last = (jnp.full((b,), offset + s)
                if valid_len is None else jnp.minimum(offset + s, valid_len)) - 1
        slots = jnp.arange(window)[None, :]
        owner = last[:, None] - (last[:, None] - slots) % window   # (B, W)
        take = jnp.clip(owner - offset, 0, s - 1)
        kg = jnp.take_along_axis(k_new, take[..., None, None], axis=1)
        vg = jnp.take_along_axis(v_new, take[..., None, None], axis=1)
        write = (owner >= offset)[..., None, None]
        return KVCache(k=jnp.where(write, kg, cache.k),
                       v=jnp.where(write, vg, cache.v))
    if valid_len is not None:
        cur_k = jax.lax.dynamic_slice_in_dim(cache.k, offset, s, axis=1)
        cur_v = jax.lax.dynamic_slice_in_dim(cache.v, offset, s, axis=1)
        pos_abs = offset + jnp.arange(s)
        valid = (pos_abs[None, :] < valid_len[:, None])[..., None, None]
        k_new = jnp.where(valid, k_new, cur_k)
        v_new = jnp.where(valid, v_new, cur_v)
    k = jax.lax.dynamic_update_slice_in_dim(cache.k, k_new, offset, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache.v, v_new, offset, axis=1)
    return KVCache(k=k, v=v)


def cache_update_verify(cache: KVCache, k_new, v_new, offset,
                        valid_len: jax.Array | None = None) -> KVCache:
    """Write a (B, C) token block at PER-ROW absolute offsets — the dense
    analogue of :func:`paged_update_prefill`, built for the spec-decode
    verify pass where every serve slot sits at its own position.

    Positions past ``valid_len[b]`` are routed OUT OF BOUNDS (index ==
    cache length), which JAX scatter drops — so rows drafting fewer than k
    tokens (and dead rows, valid_len 0) leave the cache untouched, the
    same trick the paged path plays with its trash page."""
    b, c = k_new.shape[:2]
    pos = offset[:, None] + jnp.arange(c)[None, :]            # (B, C) abs
    if valid_len is not None:
        pos = jnp.where(jnp.arange(c)[None, :] < valid_len[:, None],
                        pos, cache.k.shape[1])
    rows = jnp.arange(b)[:, None]
    return KVCache(k=cache.k.at[rows, pos].set(k_new, mode="drop"),
                   v=cache.v.at[rows, pos].set(v_new, mode="drop"))


def dense_verify_attention(q, cache: KVCache, qpos):
    """Token-parallel attention over a row's ENTIRE dense cache: q
    (B, C, H, Dh) at absolute positions ``qpos`` (B, C), masked causally
    by absolute position. Same math as :func:`paged_prefill_attention`
    minus the page gather — the dense prefill path cannot serve here
    because it attends only within the chunk, and a verify block's
    positions condition on all the history before them."""
    b, c, h, dh = q.shape
    kvh = cache.k.shape[2]
    g = h // kvh
    qg = q.reshape(b, c, kvh, g, dh) * (dh ** -0.5)
    s = _gqa_scores(qg, cache.k).astype(jnp.float32)  # (B,KVH,G,C,S_cache)
    kpos = jnp.arange(cache.k.shape[1])
    ok = kpos[None, None, :] <= qpos[:, :, None]      # (B, C, S_cache)
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = _gqa_combine(p, cache.v)
    return o.reshape(b, c, h, dh)


def init_cache(cfg: ModelConfig, batch: int, seq: int, *, window: int = 0,
               dtype=jnp.bfloat16) -> KVCache:
    kvh, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    s = min(seq, window) if window > 0 else seq
    shape = (batch, s, kvh, dh)
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def init_paged_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                     dtype=jnp.bfloat16) -> PagedKVCache:
    """Pooled KV storage for one layer: ``n_pages`` pages shared by every
    serve slot (serve/kvpool.py owns the page accounting)."""
    kvh, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    shape = (n_pages, page_size, kvh, dh)
    return PagedKVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# Paged gather / scatter / attention
# ---------------------------------------------------------------------------

def paged_gather(pool: jax.Array, page_table: jax.Array) -> jax.Array:
    """(n_pages, PG, KVH, Dh) pool + (B, P) table -> (B, P*PG, KVH, Dh)
    per-slot logical view. Positions no page was written at hold whatever
    the physical page last held — every read below masks by position, so
    such slots contribute an exact softmax weight of zero."""
    g = pool[page_table]                        # (B, P, PG, KVH, Dh)
    b, p, pg = g.shape[:3]
    return g.reshape(b, p * pg, *g.shape[3:])


def paged_update_decode(cache: PagedKVCache, k_new, v_new, pos,
                        page_table: jax.Array) -> PagedKVCache:
    """Write one token's K/V per row at absolute position ``pos`` (B,)
    through the page table. The engine guarantees the page under any LIVE
    row's write position is exclusively owned (shared prefix pages are
    read-only by construction); dead rows carry an all-trash table, so
    their lockstep writes collide harmlessly on page 0."""
    pg = cache.page_size
    pid = jnp.take_along_axis(page_table, (pos // pg)[:, None], axis=1)[:, 0]
    off = pos % pg
    return PagedKVCache(k=cache.k.at[pid, off].set(k_new[:, 0]),
                        v=cache.v.at[pid, off].set(v_new[:, 0]))


def paged_update_prefill(cache: PagedKVCache, k_new, v_new, offset,
                         page_table: jax.Array,
                         valid_len: jax.Array | None = None) -> PagedKVCache:
    """Write one prefill chunk (B, C) of K/V at absolute positions
    ``offset[b] + i`` through the page table. ``valid_len`` (B,) counts the
    chunk's valid rows; padded positions are routed to the trash page."""
    b, c = k_new.shape[:2]
    pos = offset[:, None] + jnp.arange(c)[None, :]            # (B, C) abs
    pid = jnp.take_along_axis(page_table, pos // cache.page_size, axis=1)
    if valid_len is not None:
        pid = jnp.where(jnp.arange(c)[None, :] < valid_len[:, None], pid, 0)
    off = pos % cache.page_size
    return PagedKVCache(k=cache.k.at[pid, off].set(k_new),
                        v=cache.v.at[pid, off].set(v_new))


def paged_prefill_attention(q, cache: PagedKVCache, page_table, qpos):
    """Chunked-prefill attention: q (B, C, H, Dh) at absolute positions
    ``qpos`` (B, C) attends over the slot's ENTIRE logical cache (history
    from earlier chunks and shared prefix pages included), masked causally
    by absolute position. This is what lets a prompt prefill in chunks —
    unlike the dense prefill path, which attends only within the chunk."""
    k = paged_gather(cache.k, page_table)
    v = paged_gather(cache.v, page_table)
    b, c, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, c, kvh, g, dh) * (dh ** -0.5)
    s = _gqa_scores(qg, k).astype(jnp.float32)      # (B,KVH,G,C,S_log)
    kpos = jnp.arange(k.shape[1])
    ok = kpos[None, None, :] <= qpos[:, :, None]    # (B, C, S_log)
    s = s + jnp.where(ok, 0.0, NEG_INF)[:, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = _gqa_combine(p, v)
    return o.reshape(b, c, h, dh)


# ---------------------------------------------------------------------------
# Full block-level attention apply
# ---------------------------------------------------------------------------

def apply_attention(p: dict, x: jax.Array, cfg: ModelConfig, *,
                    causal: bool = True, window: int = 0,
                    cache: KVCache | None = None, pos=None,
                    states: dict | None = None,
                    policy: MeshPolicy | None = None,
                    kv_memory: jax.Array | None = None,
                    valid_len: jax.Array | None = None,
                    page_table: jax.Array | None = None,
                    chunked_threshold: int = 2048):
    """Attention sublayer (projections + core + output projection).

    Modes:
      - train:   cache None            -> full (chunked) attention over x
      - prefill: cache given, S > 1    -> token-parallel forward over the
                 whole prompt; K/V for ALL positions written to the cache in
                 one pass (``pos`` = offset of x[_, 0], normally 0;
                 ``valid_len`` (B,) masks right-padding of bucketed prompts)
      - decode:  cache given, S == 1   -> one-token step, cache updated
                 (``pos`` scalar, or (B,) per-slot for continuous batching)
      - cross:   kv_memory given       -> keys/values from encoder memory

    A :class:`PagedKVCache` (``page_table`` required, (B, pages_per_slot))
    switches the prefill/decode modes to the paged pool: reads gather the
    slot's logical view through the table, writes scatter through it, and
    prefill becomes CHUNKED — ``pos`` is a (B,) vector of absolute chunk
    offsets and q attends over the whole logical cache (earlier chunks and
    shared prefix pages), not just the chunk. Paged mode is causal
    full-attention only (no sliding window, no cross-attention).
    Returns (out, new_cache, new_states).
    """
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    b, sq, _ = x.shape
    st = states or {}
    new_st = dict(st)
    paged = isinstance(cache, PagedKVCache)
    if paged:
        if page_table is None:
            raise ValueError("PagedKVCache needs a page_table")
        if window > 0 or kv_memory is not None or not causal:
            raise ValueError("paged KV supports causal full attention only")

    def maybe_rope(t, positions):
        # rope_theta <= 0 disables RoPE (whisper: absolute sinusoidal embeds)
        if cfg.rope_theta <= 0:
            return t
        return apply_rope(t, positions, cfg.rope_theta)

    plan = plan_of(cfg)

    def proj(name, inp):
        spec = plan.linear(f"attn/{name}", inp.shape[-1],
                           bind.linear_out_dim(p[name]))
        y, ns = bind.apply(spec, p[name], inp, cfg.wasi, st.get(name))
        if ns is not None:
            new_st[name] = ns
        return y

    q = proj("wq", x).reshape(b, sq, h, dh)
    if kv_memory is not None:  # cross-attention: KV from encoder memory
        src = kv_memory
        k = proj("wk", src).reshape(b, src.shape[1], kvh, dh)
        v = proj("wv", src).reshape(b, src.shape[1], kvh, dh)
        o = dense_attention(q, k, v, causal=False)
        new_cache = cache
    elif cache is None:  # train / prefill over the full sequence
        k = proj("wk", x).reshape(b, sq, kvh, dh)
        v = proj("wv", x).reshape(b, sq, kvh, dh)
        positions = jnp.arange(sq)
        q = maybe_rope(q, positions)
        k = maybe_rope(k, positions)
        # NOTE: no explicit q/k head-dim constraints — H / KVH are often not
        # divisible by the model axis (GQA); GSPMD propagates from the
        # projection outputs without forcing an involuntary reshard.
        if sq > chunked_threshold:
            o = chunked_attention(q, k, v, causal=causal, window=window)
        else:
            o = dense_attention(q, k, v, causal=causal, window=window)
        new_cache = None
    elif sq > 1 and paged:  # chunked prefill through the page table
        k = proj("wk", x).reshape(b, sq, kvh, dh)
        v = proj("wv", x).reshape(b, sq, kvh, dh)
        offset = jnp.zeros((b,), jnp.int32) if pos is None else pos
        qpos = offset[:, None] + jnp.arange(sq)[None, :]      # (B, C) abs
        q = maybe_rope(q, qpos)
        k = maybe_rope(k, qpos)
        new_cache = paged_update_prefill(cache, k, v, offset, page_table,
                                         valid_len=valid_len)
        o = paged_prefill_attention(q, new_cache, page_table, qpos)
    elif sq > 1 and is_vector_pos(pos):  # spec-decode verify, dense cache
        # each row carries its own absolute offset; q attends over the
        # row's WHOLE cache (history included), unlike the prefill branch
        # below which only sees the chunk itself
        if window > 0:
            raise ValueError("per-row dense verify needs full attention "
                             "(spec decode is gated on supports_paging)")
        k = proj("wk", x).reshape(b, sq, kvh, dh)
        v = proj("wv", x).reshape(b, sq, kvh, dh)
        qpos = pos[:, None] + jnp.arange(sq)[None, :]         # (B, C) abs
        q = maybe_rope(q, qpos)
        k = maybe_rope(k, qpos)
        new_cache = cache_update_verify(cache, k, v, pos, valid_len=valid_len)
        o = dense_verify_attention(q, new_cache, qpos)
    elif sq > 1:  # token-parallel prefill: attend + build caches in one pass
        k = proj("wk", x).reshape(b, sq, kvh, dh)
        v = proj("wv", x).reshape(b, sq, kvh, dh)
        offset = 0 if pos is None else pos
        positions = offset + jnp.arange(sq)
        q = maybe_rope(q, positions)
        k = maybe_rope(k, positions)
        new_cache = cache_update_prefill(cache, k, v, offset, window=window,
                                         valid_len=valid_len)
        if sq > chunked_threshold:
            o = chunked_attention(q, k, v, causal=causal, window=window,
                                  q_offset=offset)
        else:
            o = dense_attention(q, k, v, causal=causal, window=window,
                                q_offset=offset)
    elif paged:  # decode one token per row through the page table
        k = proj("wk", x).reshape(b, sq, kvh, dh)
        v = proj("wv", x).reshape(b, sq, kvh, dh)
        if not is_vector_pos(pos):
            raise ValueError("paged decode needs per-row (B,) positions")
        q = maybe_rope(q, pos[:, None])
        k = maybe_rope(k, pos[:, None])
        new_cache = paged_update_decode(cache, k, v, pos, page_table)
        # gather the per-slot logical view and run the SAME masked decode
        # attention the dense path runs — with pages_per_slot * page_size
        # equal to the dense cache length this is the identical executable
        # shape, which is what makes paged decode bitwise-comparable to the
        # dense oracle in tests/test_serve_fuzz.py
        gathered = KVCache(k=paged_gather(new_cache.k, page_table),
                           v=paged_gather(new_cache.v, page_table))
        o = decode_attention(q, gathered, pos, window=0)
    else:  # decode one token at absolute position ``pos`` (scalar or (B,))
        k = proj("wk", x).reshape(b, sq, kvh, dh)
        v = proj("wv", x).reshape(b, sq, kvh, dh)
        rope_pos = pos[:, None] if is_vector_pos(pos) else jnp.full((sq,), pos)
        q = maybe_rope(q, rope_pos)
        k = maybe_rope(k, rope_pos)
        new_cache = cache_update(cache, k, v, pos, window=window)
        o = decode_attention(q, new_cache, pos, window=window)
    o = o.reshape(b, sq, h * dh)
    o = shard(o, policy, "batch", "seq", "model")
    out = proj("wo", o)
    out = shard(out, policy, "batch", "seq", None)
    return out, new_cache, new_st
