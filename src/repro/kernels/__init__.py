"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships three artifacts:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrappers (interpret=True on CPU hosts)
  ref.py    — pure-jnp oracles the tests assert against

Kernels:
  matmul_tiled     — f32-accumulator tiled matmul (general building block)
  lowrank          — FUSED (x R^T) L^T (paper Eq. 8): rank-K intermediate
                     lives in VMEM across both contractions; every factored
                     linear (training and serving) routes through it.
                     Training adds a sketch-saving single-launch backward
                     (dx, dL, dR with dh = dy L VMEM-resident)
  quant            — FUSED int8 variant for deployment: int8 L/R factors
                     stay VMEM-resident, per-channel scales fold into the
                     f32 accumulator, no dequantized weight materialized
  gram             — tall-skinny Y^T Y reduction (CholeskyQR stage of WSI/ASI)
  qr               — FUSED CholeskyQR: Gram -> in-kernel Cholesky/triangular
                     inverse -> apply, plus the Q^T Y mix matrix, one launch
                     (the WSI factored-refresh hot path)
  flash_attention  — causal/sliding-window online-softmax attention
  ssd_scan         — Mamba-2 SSD chunked scan with on-chip state carry

See docs/kernels.md for grid/BlockSpec conventions and the interpret-mode
(CPU) caveats.
"""

from repro.kernels.ops import (
    cholesky_qr_mix,
    choleskyqr_fused,
    dense_matmul_q8,
    flash_attention,
    gram,
    lowrank_bwd_fused,
    lowrank_matmul,
    lowrank_matmul_fused,
    lowrank_matmul_q8,
    lowrank_matmul_q8_fused,
    lowrank_matmul_unfused,
    matmul,
)
from repro.kernels.ssd_scan import ssd_scan_tiled
