"""Pallas TPU kernels for the paper's compute hot spots.

Each kernel ships three artifacts:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling
  ops.py    — jit'd public wrappers (interpret=True on CPU hosts)
  ref.py    — pure-jnp oracles the tests assert against

Kernels:
  matmul_tiled     — f32-accumulator tiled matmul (general building block)
  lowrank          — FUSED (x R^T) L^T (paper Eq. 8): rank-K intermediate
                     lives in VMEM across both contractions; every factored
                     linear (training and serving) routes through it
  gram             — tall-skinny Y^T Y reduction (CholeskyQR stage of WSI/ASI)
  flash_attention  — causal/sliding-window online-softmax attention
  ssd_scan         — Mamba-2 SSD chunked scan with on-chip state carry
"""

from repro.kernels.ops import (
    flash_attention,
    gram,
    lowrank_matmul,
    lowrank_matmul_fused,
    lowrank_matmul_unfused,
    matmul,
)
from repro.kernels.ssd_scan import ssd_scan_tiled
