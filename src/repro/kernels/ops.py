"""Public jit'd wrappers around the Pallas kernels.

``interpret`` defaults to True because this host is CPU-only (TPU v5e is
the compile TARGET); on a real TPU runtime set
``repro.kernels.ops.INTERPRET = False`` (launcher does this when
jax.default_backend() == 'tpu').
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_tiled
from repro.kernels.gram import gram_tiled
from repro.kernels.lowrank import lowrank_bwd_tiled, lowrank_fused_tiled
from repro.kernels.matmul_tiled import matmul_tiled
from repro.kernels.qr import choleskyqr_tiled
from repro.kernels.quant import lowrank_q8_tiled

INTERPRET = jax.default_backend() != "tpu"

# VMEM headroom for the single-launch fused backward (kernels/lowrank.py):
# all five operand tiles plus the two (O,K)/(K,I) f32 accumulators must
# co-reside. Larger layers fall back to the XLA einsum backward.
_BWD_VMEM_BUDGET = 12 * 1024 * 1024


def _bwd_fits_vmem(m: int, o: int, i: int, k: int, bm: int = 128) -> bool:
    bm = min(bm, m)
    o_, i_, k_ = (-(-o // 128)) * 128, (-(-i // 128)) * 128, (-(-k // 128)) * 128
    tiles = bm * (o_ + 2 * i_ + 2 * k_) + 3 * (o_ * k_ + k_ * i_)
    return 4 * tiles <= _BWD_VMEM_BUDGET


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(a, b, *, bm: int = 128, bn: int = 128, bk: int = 128):
    return matmul_tiled(a, b, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)


@jax.custom_vjp
def _lowrank_fused(x2, r_factor, l_factor):
    """Fused (x R^T) L^T — the rank-K intermediate stays in VMEM."""
    return lowrank_fused_tiled(x2, r_factor.T, l_factor.T,
                               interpret=INTERPRET)


def _lowrank_fused_fwd(x2, r_factor, l_factor):
    # Sketch-saving forward: the kernel writes the rank-K sketch h = x R^T
    # out of its VMEM scratch once per row block, and h rides along as a
    # residual. The backward therefore never recomputes the projection
    # (2*M*I*K FLOPs saved) at a residual cost of M*K f32 — with the WASI
    # rank policy (K <= 0.5*I) that is at most half the x residual we
    # already keep for dR.
    y, h = lowrank_fused_tiled(x2, r_factor.T, l_factor.T, save_sketch=True,
                               interpret=INTERPRET)
    return y, (x2, h, r_factor, l_factor)


def _lowrank_fused_bwd(res, dy):
    x2, h, r_factor, l_factor = res
    m, i = x2.shape
    o, k = l_factor.shape
    if not INTERPRET and _bwd_fits_vmem(m, o, i, k):
        # single launch: dh = dy L stays VMEM-resident across dx, dL, dR
        dx, dl, dr = lowrank_bwd_tiled(dy, x2, h, l_factor, r_factor,
                                       interpret=INTERPRET)
        return dx, dr.astype(r_factor.dtype), dl.astype(l_factor.dtype)
    # XLA fallback (off-TPU, or layer too large for the VMEM budget);
    # consumes the saved sketch rather than recomputing it
    xf = x2.astype(jnp.float32)
    rf = r_factor.astype(jnp.float32)
    lf = l_factor.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    dh = dyf @ lf                                   # (M, K)
    dx = (dh @ rf).astype(x2.dtype)
    dr = (dh.T @ xf).astype(r_factor.dtype)         # (K, I)
    dl = (dyf.T @ h).astype(l_factor.dtype)         # (O, K)
    return dx, dr, dl


_lowrank_fused.defvjp(_lowrank_fused_fwd, _lowrank_fused_bwd)


@jax.jit
def lowrank_bwd_fused(dy, x, h, l_factor, r_factor):
    """The fused backward kernel, unconditionally (tests/benchmarks).
    dy (M, O), x (M, I), h (M, K) = x @ R^T -> (dx, dL f32, dR f32)."""
    return lowrank_bwd_tiled(dy, x, h, l_factor, r_factor,
                             interpret=INTERPRET)


@jax.jit
def lowrank_matmul_fused(x, r_factor, l_factor):
    """The fused Pallas kernel, unconditionally (tests/benchmarks).
    x (..., I), R (K, I), L (O, K) -> (..., O). Leading dims flattened.
    One kernel launch; the (M, K) intermediate never round-trips HBM.
    Differentiable (custom VJP with exact rank-K backward)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _lowrank_fused(x2, r_factor, l_factor)
    return y.reshape(lead + (l_factor.shape[0],))


@jax.jit
def lowrank_matmul(x, r_factor, l_factor):
    """WASI factored linear (Eq. 8): y = (x @ R^T) @ L^T — the public entry
    every factored linear routes through.

    On TPU this is the FUSED kernel (rank-K intermediate stays in VMEM
    across both contractions). Off-TPU the kernel would run in interpret
    mode — measured ~2x slower than the XLA einsum pair — so the dispatch
    falls back there; callers get the fast path on every backend."""
    if INTERPRET:
        h = jnp.einsum("...i,ki->...k", x, r_factor)
        return jnp.einsum("...k,ok->...o", h, l_factor)
    return lowrank_matmul_fused(x, r_factor, l_factor)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def lowrank_matmul_unfused(x, r_factor, l_factor, *, bm: int = 128,
                           bn: int = 128, bk: int = 128):
    """Two-launch reference path (pre-fusion): kept for benchmarking the
    HBM round-trip the fused kernel removes (benchmarks/tab2_latency.py)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    h = matmul_tiled(x2, r_factor.T, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    y = matmul_tiled(h, l_factor.T, bm=bm, bn=bn, bk=bk, interpret=INTERPRET)
    return y.reshape(lead + (l_factor.shape[0],))


@jax.jit
def lowrank_matmul_q8_fused(x, r_q, r_s, l_q, l_s):
    """The fused int8 Pallas kernel, unconditionally (tests/benchmarks).
    x (..., I); Rq int8 (K, I) + sR f32 (K,); Lq int8 (O, K) + sL f32 (O,)
    -> (..., O). One launch; int8 factors stay VMEM-resident, scales fold
    into the f32 accumulator, no dequantized weight is materialized."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = lowrank_q8_tiled(x2, r_q.T, r_s, l_q.T, l_s, interpret=INTERPRET)
    return y.reshape(lead + (l_q.shape[0],))


@jax.jit
def lowrank_matmul_q8(x, r_q, r_s, l_q, l_s):
    """Quantized factored linear: y = ((x Rq^T) * sR) Lq^T * sL — the
    public entry every int8-deployed factored linear routes through
    (api/bind.py dispatches here when the plan stamps ``quant="int8"``).

    On TPU this is the fused int8 kernel. Off-TPU the scale-folded einsum
    pair runs instead (same math, same f32 accumulation) — the per-channel
    scales multiply the rank-K intermediate and the output, so no
    dequantized O×I weight ever exists on either path."""
    if INTERPRET:
        xf = x.astype(jnp.float32)
        h = jnp.einsum("...i,ki->...k", xf, r_q.astype(jnp.float32)) * r_s
        y = jnp.einsum("...k,ok->...o", h, l_q.astype(jnp.float32)) * l_s
        return y.astype(x.dtype)
    return lowrank_matmul_q8_fused(x, r_q, r_s, l_q, l_s)


@jax.jit
def dense_matmul_q8(x, w_q, w_s):
    """Quantized DENSE linear: y = (x Wq^T) * sW. Kept as a scaled einsum
    on every backend — XLA fuses the int8->f32 convert into the matmul, so
    the dequantized weight lives only in registers/VMEM, never HBM; a
    dedicated kernel would buy nothing the lowrank one doesn't already
    demonstrate (dense sites are the untreated minority of a WASI plan)."""
    xf = x.astype(jnp.float32)
    y = jnp.einsum("...i,oi->...o", xf, w_q.astype(jnp.float32)) * w_s
    return y.astype(x.dtype)


@functools.partial(jax.jit, static_argnames=("bm",))
def gram(y, *, bm: int = 512):
    """G = Y^T Y (f32), the CholeskyQR reduction. y (M, K)."""
    return gram_tiled(y, bm=bm, interpret=INTERPRET)


@functools.partial(jax.jit, static_argnames=("bm",))
def choleskyqr_fused(y, *, bm: int = 512):
    """The fused CholeskyQR kernel, unconditionally (tests/benchmarks).
    y (M, K) -> (Q (M, K), mix (K, K) f32 = Q^T Y) in one launch."""
    return choleskyqr_tiled(y, bm=bm, interpret=INTERPRET)


def cholesky_qr_mix(y):
    """(Q, M = Q^T Y) for the WSI factored refresh — the public entry
    core/wsi.py routes through.

    On TPU with a 2D operand this is the single-launch fused kernel
    (Gram -> in-kernel Cholesky/inverse -> apply; Y swept twice, nothing
    else touches HBM). Off-TPU, or with leading batch dims (stacked scan
    layers / expert banks), it falls back to the jnp CholeskyQR with the
    mix computed from the Gram factor — still sparing the second
    tall-skinny (M,K)^T (M,K) product either way."""
    if INTERPRET or y.ndim != 2:
        from repro.core.orthogonal import cholesky_qr_mix_ref
        return cholesky_qr_mix_ref(y)
    return choleskyqr_fused(y)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    bq: int = 128, bk: int = 128):
    """GQA flash attention. q (B, Sq, H, dh); k/v (B, Sk, KVH, dh).

    KV heads are expanded to H by index (gather, no copy through the MXU),
    heads folded into the batch grid dim, dh padded to a lane multiple.
    """
    b, sq, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    if g > 1:
        idx = jnp.arange(h) // g
        k = k[:, :, idx, :]
        v = v[:, :, idx, :]
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, k.shape[1], dh)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, v.shape[1], dh)
    pad = (-dh) % 128
    if pad:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, pad)))
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, pad)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad)))
        # zero-padding k changes q.k by nothing (zeros), v-padding adds zero
        # columns sliced off below; but the softmax scale must use the REAL dh
    out = flash_attention_tiled(qf, kf, vf, causal=causal, window=window,
                                bq=bq, bk=bk, scale=dh ** -0.5,
                                interpret=INTERPRET)
    out = out[..., :dh]
    return out.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
