"""Tiled matmul kernel with f32 VMEM accumulator.

C (M, N) = A (M, K) @ B (K, N); grid (M/bm, N/bn, K/bk) with K innermost so
the (bm, bn) f32 accumulator scratch lives across the contraction. Blocks
default to 128 — the MXU lane width — and the wrapper pads ragged shapes up
to block multiples (output sliced back).

This is the building block for WASI's factored forward (Eq. 8): the pair
(x R^T) L^T lowers to two calls whose K-dim is the WASI rank — the FLOP
savings the paper claims come from the shapes; the kernel's job is to keep
the MXU busy on them (f32 accumulation, aligned tiles, no HBM round-trip
inside the contraction).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_tiled(a: jax.Array, b: jax.Array, *, bm: int = 128, bn: int = 128,
                 bk: int = 128, out_dtype=None,
                 interpret: bool = True) -> jax.Array:
    """2D matmul via Pallas; pads to block multiples, slices back."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    out_dtype = out_dtype or a.dtype
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)

    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    if pm or pk:
        a = jnp.pad(a, ((0, pm), (0, pk)))
    if pk or pn:
        b = jnp.pad(b, ((0, pk), (0, pn)))
    M, K = a.shape
    N = b.shape[1]
    k_steps = K // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(a, b)
    return out[:m, :n]
