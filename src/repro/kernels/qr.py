"""Fused CholeskyQR kernel: Q, M = qr(Y) in ONE pallas_call.

The WSI factored refresh (core/wsi.py::wsi_refresh_factored) is
CholeskyQR-shaped: G = L^T L (tall-skinny Gram), C = chol(G), Q = L C^{-T},
plus the mixing matrix M = Q^T L that folds into R. Composed from XLA ops
that is four HBM sweeps of the (M, K) operand — Gram read, solve read,
Q write, mix read — with G, C and Q round-tripping HBM between them.

This kernel pipelines the whole factorization behind a two-phase grid
(grid (2, M/bm), phase outermost, so the grid is sequential):

  phase 0  Gram reduction: G += y_b^T y_b into a VMEM (K, K) f32 scratch
           (exactly kernels/gram.py, inlined). At the LAST phase-0 step the
           K x K tail runs in-register: shifted Cholesky C of G, the
           triangular inverse X = C^{-1}, and the mix M = X G = Q^T Y are
           all computed inside the kernel (see below) and C^{-T} parks in a
           second VMEM scratch. M is written out — the caller folds it into
           R without ever touching Y again (M = C^{-1}(Y^T Y) algebraically
           equals Q^T Y, so the refresh's second tall-skinny product is
           gone entirely).
  phase 1  Apply: q_b = y_b @ C^{-T} per row block.

Y is read twice (phases 0 and 1) and Q written once — the unavoidable
minimum for CholeskyQR — and nothing else touches HBM.

TPU Pallas has no lax.linalg, so the K x K Cholesky and triangular inverse
are implemented as masked rank-1 update loops (jax.lax.fori_loop over K):
every iteration is a handful of (K, K) x (K, 1) products against a one-hot
column — VPU/MXU-friendly, no dynamic slicing, no 1D iota. K iterations of
O(K^2) work adds 2*K^3 FLOPs total, noise next to the 2*M*K^2 Gram for the
tall-skinny M >> K regime this kernel serves. The shift (1e-6 * trace/K,
same ladder base as core/orthogonal.cholesky_qr) is applied across the
FULL padded diagonal so lane padding of K keeps the factorization
invertible; sqrt/divide guards make the kernel NaN-free — pathologically
conditioned inputs should go through cholesky_qr2 instead (two passes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _masked_cholesky(g: jax.Array) -> jax.Array:
    """Lower Cholesky factor of PSD g (K, K) f32 via K masked rank-1
    updates — no dynamic indexing (Pallas-TPU-safe)."""
    kdim = g.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (kdim, 1), 0)

    def body(j, c):
        ej = (rows == j).astype(jnp.float32)              # one-hot col (K,1)
        row_j = jnp.dot(ej.T, c)                          # row j of C (1,K)
        s = jnp.dot(c, row_j.T)                           # sum_p C[:,p]C[j,p]
        v = jnp.dot(g, ej) - s                            # G[:,j] - partials
        vjj = jnp.dot(ej.T, v)                            # (1,1)
        d = jnp.sqrt(jnp.maximum(vjj, 1e-30))
        col = (v / d) * (rows >= j).astype(jnp.float32)   # zero above diag
        return c + jnp.dot(col, ej.T)

    return jax.lax.fori_loop(0, kdim, body, jnp.zeros_like(g))


def _tril_inverse(c: jax.Array) -> jax.Array:
    """X = C^{-1} for lower-triangular C (K, K) f32 by forward substitution,
    all columns at once, masked — row i of X lands per iteration."""
    kdim = c.shape[0]
    rows = jax.lax.broadcasted_iota(jnp.int32, (kdim, 1), 0)
    eye = (jax.lax.broadcasted_iota(jnp.int32, (kdim, kdim), 0) ==
           jax.lax.broadcasted_iota(jnp.int32, (kdim, kdim), 1)
           ).astype(jnp.float32)

    def body(i, x):
        ei = (rows == i).astype(jnp.float32)              # (K,1)
        row_i = jnp.dot(ei.T, c)                          # row i of C (1,K)
        cii = jnp.dot(row_i, ei)                          # (1,1)
        # rows >= i of x are still zero, so this picks up only p < i terms
        contrib = jnp.dot(row_i, x)                       # (1,K)
        new_row = (jnp.dot(ei.T, eye) - contrib) / jnp.maximum(cii, 1e-30)
        return x + jnp.dot(ei, new_row)

    return jax.lax.fori_loop(0, kdim, body, jnp.zeros_like(c))


def _choleskyqr_kernel(y_ref, q_ref, m_ref, g_acc, cinvt_ref, *,
                       m_steps: int, shift: float):
    phase = pl.program_id(0)
    step = pl.program_id(1)

    @pl.when(jnp.logical_and(phase == 0, step == 0))
    def _init():
        g_acc[...] = jnp.zeros_like(g_acc)

    @pl.when(phase == 0)
    def _gram():
        yb = y_ref[...].astype(jnp.float32)
        g_acc[...] += jnp.dot(yb.T, yb, preferred_element_type=jnp.float32)
        # deterministic output: phase 0 visits every q block before phase 1
        # rewrites it with the real values
        q_ref[...] = jnp.zeros_like(q_ref)

    @pl.when(jnp.logical_and(phase == 0, step == m_steps - 1))
    def _factor():
        g = g_acc[...]
        kdim = g.shape[0]
        eye = (jax.lax.broadcasted_iota(jnp.int32, (kdim, kdim), 0) ==
               jax.lax.broadcasted_iota(jnp.int32, (kdim, kdim), 1)
               ).astype(jnp.float32)
        # shifted over the FULL padded diagonal: lane-pad rows stay SPD
        scale = jnp.maximum(jnp.sum(g * eye) / kdim, 1e-30)
        c = _masked_cholesky(g + shift * scale * eye)
        x = _tril_inverse(c)                              # C^{-1}
        cinvt_ref[...] = x.T                              # C^{-T} for phase 1
        # mix M = C^{-1} (Y^T Y) == Q^T Y — the refresh folds this into R,
        # sparing the second (M,K)-sweep tall-skinny product entirely
        m_ref[...] = jnp.dot(x, g,
                             preferred_element_type=jnp.float32
                             ).astype(m_ref.dtype)

    @pl.when(phase == 1)
    def _apply():
        q_ref[...] = jnp.dot(y_ref[...].astype(jnp.float32), cinvt_ref[...],
                             preferred_element_type=jnp.float32
                             ).astype(q_ref.dtype)


def choleskyqr_tiled(y: jax.Array, *, bm: int = 512, shift: float = 1e-6,
                     interpret: bool = True):
    """(Q, M) = fused CholeskyQR of y (M rows, K cols), K <= ~1024.

    Q (M, K) has orthonormal columns spanning col(y); M (K, K) = Q^T y is
    the mixing matrix (f32). One launch; see module docstring.
    """
    m, k = y.shape
    bm = min(bm, m)
    pm, pk = (-m) % bm, (-k) % 128
    if pm or pk:
        y = jnp.pad(y, ((0, pm), (0, pk)))  # zero rows/cols: see docstring
    M, K = y.shape
    m_steps = M // bm

    q, mix = pl.pallas_call(
        functools.partial(_choleskyqr_kernel, m_steps=m_steps, shift=shift),
        grid=(2, m_steps),
        in_specs=[pl.BlockSpec((bm, K), lambda p, s: (s, 0))],
        out_specs=[pl.BlockSpec((bm, K), lambda p, s: (s, 0)),
                   pl.BlockSpec((K, K), lambda p, s: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, K), y.dtype),
                   jax.ShapeDtypeStruct((K, K), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((K, K), jnp.float32),
                        pltpu.VMEM((K, K), jnp.float32)],
        interpret=interpret,
    )(y)
    return q[:m, :k], mix[:k, :k]
