"""Fused low-rank matmul kernel: y = (x R^T) L^T in ONE pallas_call.

The factored WASI forward (Eq. 8) lowers naturally to two matmuls whose
shared dim is the rank K — but two separate kernel launches round-trip the
(M, K) intermediate through HBM. Serving runs *every* linear factored, so
that round-trip is pure overhead on the hot path (2*M*K extra HBM traffic
per linear per step, and K is small enough that the intermediate fits in
VMEM comfortably).

This kernel keeps the rank-K intermediate resident in a VMEM scratch across
both contractions:

    grid (M/bm, O/bn), O innermost. At j == 0 the row block's projection
    h = x_i @ R^T is computed once into an f32 scratch; every j then reads
    h from VMEM for y_ij = h @ (L^T)_j. The intermediate never touches HBM.

VMEM budget per step: bm*I (x block) + I*K (R^T) + K*bn (L^T block) +
bm*K f32 (scratch) + bm*bn (out). With the WASI rank policy
(K = rank_frac * min(O, I), frac <= 0.5) this fits 16 MB VMEM up to
I ~ 8k at bm = 128 — every assigned arch's linears qualify. I and K are
zero-padded to lane multiples (128); zero columns/rows contribute nothing
to either contraction.

The second dot promotes L^T to f32 (the scratch is f32): rank-K thin
matmuls are bandwidth-bound, so the MXU throughput cost of f32 operands is
hidden; accuracy matches the two-matmul reference at f32 tolerance.

TRAINING (sketch-saving backward): with ``save_sketch=True`` the forward
additionally writes the rank-K sketch h = x R^T (already computed into the
VMEM scratch) out once per row block — the custom VJP in kernels/ops.py
then saves (x, h) as residuals and never recomputes the projection. The
backward is ONE launch too (``lowrank_bwd_tiled``): per row block the
rank-K cotangent dh = dy L lives only in a VMEM scratch while all three
gradients are formed from it —

    dx_b  = dh_b R                  (written per block)
    dL   += dy_b^T h_b              (accumulated in a VMEM (O, K) tile)
    dR   += dh_b^T x_b              (accumulated in a VMEM (K, I) tile)

so dh never round-trips HBM (the unfused backward writes and re-reads it
three times). VMEM budget per step (operand tiles + output tiles + f32
scratches): 4 * (bm*(O + 2I + 2K) + 3*(O*K + K*I)) bytes —
``kernels/ops.py::_bwd_fits_vmem`` is the authoritative gate (12 MiB
headroom); with the WASI rank policy (K <= 0.5*min(O,I)) that admits
layers up to O ~ 3k, I ~ 3k at bm = 128, and larger ones fall back to the
XLA einsum backward.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lowrank_kernel(x_ref, rt_ref, lt_ref, o_ref, h_ref):
    # first O block of this row block: project into the rank-K subspace once
    @pl.when(pl.program_id(1) == 0)
    def _project():
        h_ref[...] = jnp.dot(x_ref[...], rt_ref[...],
                             preferred_element_type=jnp.float32)

    # every O block: expand from the VMEM-resident intermediate
    o_ref[...] = jnp.dot(h_ref[...], lt_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def _lowrank_sketch_kernel(x_ref, rt_ref, lt_ref, o_ref, hout_ref, h_ref):
    @pl.when(pl.program_id(1) == 0)
    def _project():
        h_ref[...] = jnp.dot(x_ref[...], rt_ref[...],
                             preferred_element_type=jnp.float32)
        # persist the sketch for the backward: one extra (bm, K) store per
        # row block — the residual the sketch-saving VJP keeps instead of
        # recomputing the projection (2*M*I*K FLOPs) at backward time
        hout_ref[...] = h_ref[...].astype(hout_ref.dtype)

    o_ref[...] = jnp.dot(h_ref[...], lt_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def lowrank_fused_tiled(x: jax.Array, rt: jax.Array, lt: jax.Array, *,
                        bm: int = 128, bn: int = 128, out_dtype=None,
                        save_sketch: bool = False, interpret: bool = True):
    """y (M, O) = x (M, I) @ rt (I, K) @ lt (K, O), fused.

    Pads ragged shapes (M to bm, O to bn, I/K to lane multiples of 128) and
    slices the output back. With ``save_sketch`` returns ``(y, h)`` where
    h (M, K) f32 is the rank-K sketch x @ rt written from the same VMEM
    scratch the expansion reads.
    """
    m, i = x.shape
    i2, k = rt.shape
    k2, n = lt.shape
    assert i == i2 and k == k2, (x.shape, rt.shape, lt.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn = min(bm, m), min(bn, n)

    pm, pn = (-m) % bm, (-n) % bn
    pi, pk = (-i) % 128, (-k) % 128
    if pm or pi:
        x = jnp.pad(x, ((0, pm), (0, pi)))
    if pi or pk:
        rt = jnp.pad(rt, ((0, pi), (0, pk)))
    if pk or pn:
        lt = jnp.pad(lt, ((0, pk), (0, pn)))
    M, I = x.shape
    K = rt.shape[1]
    N = lt.shape[1]

    in_specs = [
        pl.BlockSpec((bm, I), lambda i_, j: (i_, 0)),
        pl.BlockSpec((I, K), lambda i_, j: (0, 0)),
        pl.BlockSpec((K, bn), lambda i_, j: (0, j)),
    ]
    if save_sketch:
        out, h = pl.pallas_call(
            _lowrank_sketch_kernel,
            grid=(M // bm, N // bn),
            in_specs=in_specs,
            out_specs=[pl.BlockSpec((bm, bn), lambda i_, j: (i_, j)),
                       pl.BlockSpec((bm, K), lambda i_, j: (i_, 0))],
            out_shape=[jax.ShapeDtypeStruct((M, N), out_dtype),
                       jax.ShapeDtypeStruct((M, K), jnp.float32)],
            scratch_shapes=[pltpu.VMEM((bm, K), jnp.float32)],
            interpret=interpret,
        )(x, rt, lt)
        return out[:m, :n], h[:m, :k]

    out = pl.pallas_call(
        _lowrank_kernel,
        grid=(M // bm, N // bn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i_, j: (i_, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, K), jnp.float32)],
        interpret=interpret,
    )(x, rt, lt)
    return out[:m, :n]


def _lowrank_bwd_kernel(dy_ref, x_ref, h_ref, l_ref, r_ref,
                        dx_ref, dl_ref, dr_ref,
                        dh_ref, dl_acc, dr_acc, *, m_steps: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dl_acc[...] = jnp.zeros_like(dl_acc)
        dr_acc[...] = jnp.zeros_like(dr_acc)

    dy = dy_ref[...].astype(jnp.float32)
    # rank-K cotangent of the sketch: dh = dy L — VMEM-resident for all
    # three consumers below (the unfused path round-trips it through HBM)
    dh_ref[...] = jnp.dot(dy, l_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
    dh = dh_ref[...]
    dx_ref[...] = jnp.dot(dh, r_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32).astype(dx_ref.dtype)
    dl_acc[...] += jnp.dot(dy.T, h_ref[...],
                           preferred_element_type=jnp.float32)
    dr_acc[...] += jnp.dot(dh.T, x_ref[...].astype(jnp.float32),
                           preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == m_steps - 1)
    def _store():
        dl_ref[...] = dl_acc[...].astype(dl_ref.dtype)
        dr_ref[...] = dr_acc[...].astype(dr_ref.dtype)


def lowrank_bwd_tiled(dy: jax.Array, x: jax.Array, h: jax.Array,
                      l: jax.Array, r: jax.Array, *, bm: int = 128,
                      interpret: bool = True):
    """Fused factored-matmul backward: (dx, dL, dR) in ONE pallas_call.

    dy (M, O), x (M, I), h (M, K) [the forward's saved sketch x R^T],
    l (O, K), r (K, I)  ->  dx (M, I), dL (O, K), dR (K, I).

    Grid (M/bm,): per row block the rank-K dh = dy L is computed once into
    a VMEM scratch and consumed by all three products; dL/dR accumulate in
    revisited f32 VMEM tiles (gram.py-style) and are stored at the last
    step. Zero-padding (M to bm; O/I/K to lane multiples) is sound: padded
    dy/x/h rows contribute zero to every accumulation.
    """
    m, o = dy.shape
    m2, i = x.shape
    m3, k = h.shape
    assert m == m2 == m3 and l.shape == (o, k) and r.shape == (k, i), (
        dy.shape, x.shape, h.shape, l.shape, r.shape)
    bm = min(bm, m)
    pm = (-m) % bm
    po, pi, pk = (-o) % 128, (-i) % 128, (-k) % 128
    if pm or po:
        dy = jnp.pad(dy, ((0, pm), (0, po)))
    if pm or pi:
        x = jnp.pad(x, ((0, pm), (0, pi)))
    if pm or pk:
        h = jnp.pad(h, ((0, pm), (0, pk)))
    if po or pk:
        l = jnp.pad(l, ((0, po), (0, pk)))
    if pk or pi:
        r = jnp.pad(r, ((0, pk), (0, pi)))
    M, O = dy.shape
    I, K = x.shape[1], h.shape[1]
    m_steps = M // bm

    dx, dl, dr = pl.pallas_call(
        functools.partial(_lowrank_bwd_kernel, m_steps=m_steps),
        grid=(m_steps,),
        in_specs=[
            pl.BlockSpec((bm, O), lambda s: (s, 0)),
            pl.BlockSpec((bm, I), lambda s: (s, 0)),
            pl.BlockSpec((bm, K), lambda s: (s, 0)),
            pl.BlockSpec((O, K), lambda s: (0, 0)),
            pl.BlockSpec((K, I), lambda s: (0, 0)),
        ],
        out_specs=[pl.BlockSpec((bm, I), lambda s: (s, 0)),
                   pl.BlockSpec((O, K), lambda s: (0, 0)),
                   pl.BlockSpec((K, I), lambda s: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((M, I), x.dtype),
                   jax.ShapeDtypeStruct((O, K), jnp.float32),
                   jax.ShapeDtypeStruct((K, I), jnp.float32)],
        scratch_shapes=[pltpu.VMEM((bm, K), jnp.float32),
                        pltpu.VMEM((O, K), jnp.float32),
                        pltpu.VMEM((K, I), jnp.float32)],
        interpret=interpret,
    )(dy, x, h, l, r)
    return dx[:m, :i], dl[:o, :k], dr[:k, :i]
