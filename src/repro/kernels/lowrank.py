"""Fused low-rank matmul kernel: y = (x R^T) L^T in ONE pallas_call.

The factored WASI forward (Eq. 8) lowers naturally to two matmuls whose
shared dim is the rank K — but two separate kernel launches round-trip the
(M, K) intermediate through HBM. Serving runs *every* linear factored, so
that round-trip is pure overhead on the hot path (2*M*K extra HBM traffic
per linear per step, and K is small enough that the intermediate fits in
VMEM comfortably).

This kernel keeps the rank-K intermediate resident in a VMEM scratch across
both contractions:

    grid (M/bm, O/bn), O innermost. At j == 0 the row block's projection
    h = x_i @ R^T is computed once into an f32 scratch; every j then reads
    h from VMEM for y_ij = h @ (L^T)_j. The intermediate never touches HBM.

VMEM budget per step: bm*I (x block) + I*K (R^T) + K*bn (L^T block) +
bm*K f32 (scratch) + bm*bn (out). With the WASI rank policy
(K = rank_frac * min(O, I), frac <= 0.5) this fits 16 MB VMEM up to
I ~ 8k at bm = 128 — every assigned arch's linears qualify. I and K are
zero-padded to lane multiples (128); zero columns/rows contribute nothing
to either contraction.

The second dot promotes L^T to f32 (the scratch is f32): rank-K thin
matmuls are bandwidth-bound, so the MXU throughput cost of f32 operands is
hidden; accuracy matches the two-matmul reference at f32 tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lowrank_kernel(x_ref, rt_ref, lt_ref, o_ref, h_ref):
    # first O block of this row block: project into the rank-K subspace once
    @pl.when(pl.program_id(1) == 0)
    def _project():
        h_ref[...] = jnp.dot(x_ref[...], rt_ref[...],
                             preferred_element_type=jnp.float32)

    # every O block: expand from the VMEM-resident intermediate
    o_ref[...] = jnp.dot(h_ref[...], lt_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32).astype(o_ref.dtype)


def lowrank_fused_tiled(x: jax.Array, rt: jax.Array, lt: jax.Array, *,
                        bm: int = 128, bn: int = 128, out_dtype=None,
                        interpret: bool = True) -> jax.Array:
    """y (M, O) = x (M, I) @ rt (I, K) @ lt (K, O), fused.

    Pads ragged shapes (M to bm, O to bn, I/K to lane multiples of 128) and
    slices the output back.
    """
    m, i = x.shape
    i2, k = rt.shape
    k2, n = lt.shape
    assert i == i2 and k == k2, (x.shape, rt.shape, lt.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn = min(bm, m), min(bn, n)

    pm, pn = (-m) % bm, (-n) % bn
    pi, pk = (-i) % 128, (-k) % 128
    if pm or pi:
        x = jnp.pad(x, ((0, pm), (0, pi)))
    if pi or pk:
        rt = jnp.pad(rt, ((0, pi), (0, pk)))
    if pk or pn:
        lt = jnp.pad(lt, ((0, pk), (0, pn)))
    M, I = x.shape
    K = rt.shape[1]
    N = lt.shape[1]

    out = pl.pallas_call(
        _lowrank_kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, I), lambda i_, j: (i_, 0)),
            pl.BlockSpec((I, K), lambda i_, j: (0, 0)),
            pl.BlockSpec((K, bn), lambda i_, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i_, j: (i_, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, K), jnp.float32)],
        interpret=interpret,
    )(x, rt, lt)
    return out[:m, :n]
