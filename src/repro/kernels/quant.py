"""Fused INT8 low-rank matmul: y = (x R^T) L^T with int8 factors, one launch.

Deployment variant of ``lowrank.py``. The factors arrive packed —
R int8 (K, I) with per-row scales sR (K,), L int8 (O, K) with per-row
scales sL (O,) (symmetric per-channel absmax, quant/quantize.py) — and the
kernel NEVER materializes a dequantized weight:

    grid (M/bm, O/bn), O innermost. At j == 0 the row block's projection
    is computed straight off the int8 tile, h = (x @ Rq^T) * sR, into an
    f32 VMEM scratch (the int8->f32 convert happens on the VMEM-resident
    tile, feeding the MXU directly); every j then expands
    y_ij = (h @ Lq^T_j) * sL_j from the same scratch.

Why this is the right shape for edge serving: the factored pair already
cut weight FLOPs to the rank-K subspace, so a decode-step linear is
bandwidth-bound on factor bytes — int8 packing cuts that HBM traffic 4x,
and folding the scales into the f32 accumulator (one VPU multiply per
output tile) keeps the dequantization entirely on-chip. The per-channel
scale vectors ride as (1, C) f32 rows, blocked with their factor's output
axis.

Padding is inert: I/K/O pad to lane multiples (128) and M to bm with
zeros; padded int8 columns/rows are zero and padded scale entries are
zero, so they contribute nothing to either contraction and the padded
output columns are sliced off. Accuracy: both contractions accumulate in
f32 (`preferred_element_type`), so the only error is the quantization
itself — the off-TPU fallback (kernels/ops.py) computes the identical
scale-folded einsum pair and tests pin the two together.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _lowrank_q8_kernel(x_ref, rt_ref, rs_ref, lt_ref, ls_ref, o_ref, h_ref):
    # first O block of this row block: project off the int8 tile once,
    # folding R's per-channel scales into the f32 scratch
    @pl.when(pl.program_id(1) == 0)
    def _project():
        h_ref[...] = jnp.dot(x_ref[...].astype(jnp.float32),
                             rt_ref[...].astype(jnp.float32),
                             preferred_element_type=jnp.float32) * rs_ref[...]

    # every O block: expand from the VMEM-resident intermediate, rescaling
    # the f32 accumulator by L's per-channel scales for this column block
    o_ref[...] = (jnp.dot(h_ref[...], lt_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)
                  * ls_ref[...]).astype(o_ref.dtype)


def lowrank_q8_tiled(x: jax.Array, rt: jax.Array, rs: jax.Array,
                     lt: jax.Array, ls: jax.Array, *, bm: int = 128,
                     bn: int = 128, out_dtype=None, interpret: bool = True):
    """y (M, O) = ((x (M, I) @ rt (I, K)) * rs (K,)) @ lt (K, O) * ls (O,).

    ``rt``/``lt`` are int8 transposed factors, ``rs``/``ls`` their f32
    per-channel scales. Pads ragged shapes (M to bm, O to bn, I/K to lane
    multiples of 128, scales zero-padded) and slices the output back.
    """
    m, i = x.shape
    i2, k = rt.shape
    k2, n = lt.shape
    assert i == i2 and k == k2 and rs.shape == (k,) and ls.shape == (n,), (
        x.shape, rt.shape, rs.shape, lt.shape, ls.shape)
    out_dtype = out_dtype or x.dtype
    bm, bn = min(bm, m), min(bn, n)

    pm, pn = (-m) % bm, (-n) % bn
    pi, pk = (-i) % 128, (-k) % 128
    if pm or pi:
        x = jnp.pad(x, ((0, pm), (0, pi)))
    if pi or pk:
        rt = jnp.pad(rt, ((0, pi), (0, pk)))
    if pk or pn:
        lt = jnp.pad(lt, ((0, pk), (0, pn)))
    rs2 = jnp.pad(rs.astype(jnp.float32), (0, pk)).reshape(1, -1)
    ls2 = jnp.pad(ls.astype(jnp.float32), (0, pn)).reshape(1, -1)
    M, I = x.shape
    K = rt.shape[1]
    N = lt.shape[1]

    out = pl.pallas_call(
        _lowrank_q8_kernel,
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, I), lambda i_, j: (i_, 0)),
            pl.BlockSpec((I, K), lambda i_, j: (0, 0)),
            pl.BlockSpec((1, K), lambda i_, j: (0, 0)),
            pl.BlockSpec((K, bn), lambda i_, j: (0, j)),
            pl.BlockSpec((1, bn), lambda i_, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i_, j: (i_, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, K), jnp.float32)],
        interpret=interpret,
    )(x, rt, rs2, lt, ls2)
    return out[:m, :n]
