"""Tall-skinny Gram kernel: G (K, K) = Y^T (M, K) Y.

The MXU stage of CholeskyQR (DESIGN.md §3.1) — WSI/ASI orthogonalize via
G = Y^T Y; K is the WASI rank (<= ~1024) so G fits in a single VMEM tile
and the kernel is a pure reduction over M: grid (M/bm,), one revisited
(K, K) f32 output block accumulated across grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(y_ref, o_ref, acc_ref, *, m_steps: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    yb = y_ref[...]
    acc_ref[...] += jnp.dot(yb.T, yb, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == m_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def gram_tiled(y: jax.Array, *, bm: int = 512,
               interpret: bool = True) -> jax.Array:
    """G = Y^T Y in f32. y: (M, K) with K <= ~1024 (one VMEM tile)."""
    m, k = y.shape
    bm = min(bm, m)
    pm = (-m) % bm
    if pm:
        y = jnp.pad(y, ((0, pm), (0, 0)))  # zero rows don't change Y^T Y
    M = y.shape[0]
    m_steps = M // bm

    return pl.pallas_call(
        functools.partial(_gram_kernel, m_steps=m_steps),
        grid=(m_steps,),
        in_specs=[pl.BlockSpec((bm, k), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((k, k), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((k, k), jnp.float32)],
        interpret=interpret,
    )(y)
