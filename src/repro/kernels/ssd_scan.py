"""Mamba-2 SSD chunked-scan kernel (zamba2's compute hot spot).

Grid (B, H, NC) with the chunk index INNERMOST: for a fixed (batch, head)
the kernel revisits sequentially, carrying the (dh, N) recurrent state in a
VMEM scratch across chunk steps — the inter-chunk recurrence lives entirely
on-chip, while the intra-chunk work is three MXU matmuls:

    cb       = C B^T                      (Q, Q)
    y_intra  = (cb ⊙ L) (dt·u)            (Q, dh)   L = causal decay kernel
    y_inter  = (C S^T) ⊙ exp(cum)         (Q, dh)
    S       <- exp(cum_Q)·S + (dt·u·decay_out)^T B

This is the TPU-native form of the CUDA selective-scan kernel (DESIGN.md
§3): no sequential per-timestep recurrence ever touches the MXU path.
Oracle: repro.nn.mamba._ssd_chunked (pure JAX).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(u_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, *,
                q: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    u = u_ref[0, 0, 0].astype(jnp.float32)       # (Q, dh)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)     # (Q,)
    a = a_ref[0].astype(jnp.float32)             # scalar A_h (negative)
    bm = b_ref[0, 0].astype(jnp.float32)         # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)         # (Q, N)

    da = dt * a                                  # (Q,)
    cum = jnp.cumsum(da)
    li = cum[:, None] - cum[None, :]
    tri = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    L = jnp.where(tri, jnp.exp(li), 0.0)         # (Q, Q)
    du = dt[:, None] * u                         # (Q, dh)
    cb = jnp.dot(cm, bm.T, preferred_element_type=jnp.float32)
    y = jnp.dot(cb * L, du, preferred_element_type=jnp.float32)

    s_prev = s_ref[...]                          # (dh, N)
    y += jnp.exp(cum)[:, None] * jnp.dot(
        cm, s_prev.T, preferred_element_type=jnp.float32)

    decay_out = jnp.exp(cum[-1] - cum)           # (Q,)
    s_c = jnp.dot((du * decay_out[:, None]).T, bm,
                  preferred_element_type=jnp.float32)  # (dh, N)
    s_ref[...] = jnp.exp(cum[-1]) * s_prev + s_c
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)


def ssd_scan_tiled(u, dt, A, B, C, *, chunk: int = 128,
                   interpret: bool = True):
    """u (Bz,S,H,dh); dt (Bz,S,H) >0; A (H,)<0; B,C (Bz,S,N).
    Returns y (Bz,S,H,dh) WITHOUT the D·u skip term (added by the wrapper).
    """
    bz, s, h, dh = u.shape
    n = B.shape[-1]
    assert s % chunk == 0, "pad sequence to the SSD chunk"
    nc = s // chunk
    uc = u.transpose(0, 2, 1, 3).reshape(bz, h, nc, chunk, dh)
    dtc = dt.transpose(0, 2, 1).reshape(bz, h, nc, chunk)
    bc = B.reshape(bz, nc, chunk, n)
    cc = C.reshape(bz, nc, chunk, n)

    y = pl.pallas_call(
        functools.partial(_ssd_kernel, q=chunk, n_chunks=nc),
        grid=(bz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, dh), lambda b, hh, c: (b, hh, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, hh, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, hh, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, dh),
                               lambda b, hh, c: (b, hh, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bz, h, nc, chunk, dh), u.dtype),
        scratch_shapes=[pltpu.VMEM((dh, n), jnp.float32)],
        interpret=interpret,
    )(uc, dtc, A, bc, cc)
    return y.reshape(bz, h, s, dh).transpose(0, 2, 1, 3)
