"""Flash attention kernel: causal / sliding-window online-softmax.

Grid (B*H, Sq/bq, Sk/bk), K innermost. Scratch per (b*h, q-tile): running
max m (bq,), normalizer l (bq,), and f32 accumulator (bq, dh) — the online
softmax recurrence. The output tile is written at the last K step.

Sliding-window causal masking is tile-aware: tiles entirely outside
[qpos - window, qpos] are skipped with ``pl.when`` (no MXU work), which is
what makes the 32k-prefill local layers cheap — the XLA oracle
(nn.attention.chunked_attention) cannot skip, the kernel can.

Head-dim and tile sizes are MXU/VREG aligned (dh padded to 128 by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  bq: int, bk: int, k_steps: int, causal: bool, window: int,
                  sk_valid: int, scale: float):
    kk = pl.program_id(2)
    qi = pl.program_id(1)

    @pl.when(kk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = qi * bq
    k_lo = kk * bk
    # tile-level skip: is the whole K tile outside every q row's visible
    # range? visible range for q row r: [r - window + 1, r] (causal+window),
    # [0, r] (causal), or everything (bidirectional)
    run = k_lo >= 0  # traced True
    if causal:
        run = jnp.logical_and(run, k_lo <= q_lo + bq - 1)
    if window > 0:
        run = jnp.logical_and(run, k_lo + bk - 1 > q_lo - window)

    @pl.when(run)
    def _compute():
        qb = q_ref[0]
        kb = k_ref[0]
        s = jnp.dot(qb, kb.T, preferred_element_type=jnp.float32) * scale
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = kpos < sk_valid
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window > 0:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jnp.dot(p.astype(v_ref.dtype), v_ref[0],
                                  preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(kk == k_steps - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_tiled(q: jax.Array, k: jax.Array, v: jax.Array, *,
                          causal: bool = True, window: int = 0,
                          bq: int = 128, bk: int = 128, scale: float | None = None,
                          interpret: bool = True) -> jax.Array:
    """q/k/v: (BH, S, dh) with identical head counts (GQA expansion done by
    ops.flash_attention). Returns (BH, Sq, dh). ``scale`` defaults to
    dh**-0.5 — pass the REAL head dim's scale when dh is lane-padded."""
    bh, sq, dh = q.shape
    sk = k.shape[1]
    scale = scale if scale is not None else dh ** -0.5
    bq = min(bq, sq)
    bk = min(bk, sk)
    pq, pk_ = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk_:
        k = jnp.pad(k, ((0, 0), (0, pk_), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk_), (0, 0)))
    SQ, SK = q.shape[1], k.shape[1]
    k_steps = SK // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, k_steps=k_steps,
                          causal=causal, window=window, sk_valid=sk,
                          scale=scale),
        grid=(bh, SQ // bq, k_steps),
        in_specs=[
            pl.BlockSpec((1, bq, dh), lambda b, i, kk: (b, i, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, kk: (b, kk, 0)),
            pl.BlockSpec((1, bk, dh), lambda b, i, kk: (b, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, dh), lambda b, i, kk: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, SQ, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq, :]
