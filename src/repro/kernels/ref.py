"""Pure-jnp oracles for every kernel. Tests assert_allclose against these
across shape/dtype sweeps (tests/test_kernels.py)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a, b, out_dtype=None):
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32)).astype(
        out_dtype or a.dtype)


def lowrank_matmul_ref(x, r_factor, l_factor, out_dtype=None):
    """y = (x @ R^T) @ L^T; x (..., I), R (K, I), L (O, K) -> (..., O).
    Two-matmul f32 oracle for the FUSED kernel (kernels/lowrank.py); leading
    dims pass through like the jit wrapper's."""
    h = jnp.matmul(x.astype(jnp.float32), r_factor.astype(jnp.float32).T)
    y = jnp.matmul(h, l_factor.astype(jnp.float32).T)
    return y.astype(out_dtype or x.dtype)


def lowrank_bwd_ref(dy, x, h, l_factor, r_factor):
    """(dx, dL, dR) oracle for the fused backward (kernels/lowrank.py).
    dy (M, O), x (M, I), h (M, K) = x @ R^T, l (O, K), r (K, I)."""
    dyf = dy.astype(jnp.float32)
    dh = dyf @ l_factor.astype(jnp.float32)                 # (M, K)
    dx = (dh @ r_factor.astype(jnp.float32)).astype(x.dtype)
    dl = dyf.T @ h.astype(jnp.float32)                      # (O, K)
    dr = dh.T @ x.astype(jnp.float32)                       # (K, I)
    return dx, dl, dr


def gram_ref(y):
    yf = y.astype(jnp.float32)
    return yf.T @ yf


def choleskyqr_ref(y, shift=1e-6):
    """(Q, M) oracle for the fused CholeskyQR kernel (kernels/qr.py):
    Q = Y C^{-T} with C C^T = Y^T Y + shift*scale*I, M = C^{-1} Y^T Y."""
    yf = y.astype(jnp.float32)
    g = yf.T @ yf
    k = g.shape[-1]
    scale = jnp.maximum(jnp.trace(g) / k, 1e-30)
    c = jnp.linalg.cholesky(g + shift * scale * jnp.eye(k, dtype=g.dtype))
    qt = jax.scipy.linalg.solve_triangular(c, yf.T, lower=True)
    mix = jax.scipy.linalg.solve_triangular(c, g, lower=True)
    return qt.T.astype(y.dtype), mix


def flash_attention_ref(q, k, v, *, causal=True, window=0):
    """q/k/v (BH, S, dh) -> (BH, Sq, dh); fp32 softmax."""
    bh, sq, dh = q.shape
    sk = k.shape[1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (dh ** -0.5)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    ok = jnp.ones((sq, sk), bool)
    if causal:
        ok &= kpos <= qpos
    if window > 0:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
