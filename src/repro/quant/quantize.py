"""Symmetric per-channel absmax int8 quantization of linear-site weights.

Scheme: for a weight whose LAST axis is the contraction axis — L (…, O, K),
R (…, K, I), dense w (…, O, I) — each output channel (the second-to-last
axis row) gets one f32 scale ``s = absmax / 127`` and the row is packed to
``q = clip(round(w / s), -127, 127)`` int8. Symmetric (no zero point)
because the matmul then needs only a per-channel rescale of the f32
accumulator; per-channel because one saturated row must not crush the
resolution of every other row. Leading stack dims (scan repeats, expert
banks) quantize independently for free: the reduction is over the last
axis only.

Quantized param layouts (scales ride NEXT TO the int8 payload so a
quantized tree checkpoints/restores like any other pytree):

    factored: {"L": int8 (…, O, K), "sL": f32 (…, O),
               "R": int8 (…, K, I), "sR": f32 (…, K) [, "b" f32]}
    dense:    {"w": int8 (…, O, I), "sW": f32 (…, O) [, "b" f32]}

Biases stay f32 (O-sized — noise next to the weight payload). Project-mode
sites keep their training layout: they carry the dense W by definition, so
deployment should ``convert.factorize`` them first.

This module does the math; which sites quantize is the plan's decision
(``SubspacePlan.quantized``), the tree walk is ``api.convert.quantize``,
and dispatch-by-layout stays ``api.bind``'s monopoly.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

QMAX = 127.0

#: weight leaf key -> its scale key (the quantized-layout contract)
SCALE_KEY = {"L": "sL", "R": "sR", "w": "sW"}


def quantize_tensor(w) -> tuple[jnp.ndarray, jnp.ndarray]:
    """w (…, C, D) -> (q int8 (…, C, D), scale f32 (…, C)): symmetric
    per-channel absmax over the last (contraction) axis. All-zero channels
    get scale 1 so dequantization stays exact (0 * 1 = 0)."""
    w = jnp.asarray(w)
    absmax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-1)
    scale = jnp.where(absmax > 0, absmax / QMAX, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale[..., None]),
                 -QMAX, QMAX).astype(jnp.int8)
    return q, scale


def dequantize_tensor(q, scale) -> jnp.ndarray:
    """Reference inverse: f32 (…, C, D) = q * scale[…, None]. The serve
    path never calls this on a whole weight — kernels/quant.py folds the
    scales into the accumulator instead."""
    return q.astype(jnp.float32) * jnp.asarray(scale)[..., None]


def quantize_linear(p: dict, spec) -> dict:
    """One linear param dict -> its quantized layout per ``spec.quant``.
    Passthrough when the spec carries no quant format or the layout cannot
    pack (project mode); raises on an already-quantized dict."""
    from repro.api.bind import is_quantized, linear_layout

    if is_quantized(p):
        raise ValueError(f"site {spec.name} is already quantized")
    if spec.quant is None or linear_layout(p) == "project":
        return p
    if spec.quant != "int8":
        raise ValueError(f"unknown quant format {spec.quant!r}")
    out: dict = {}
    for key, v in p.items():
        if key in SCALE_KEY:
            out[key], out[SCALE_KEY[key]] = quantize_tensor(v)
        else:
            out[key] = v
    return out


def dequantize_linear(p: dict, spec=None) -> dict:
    """Inverse of :func:`quantize_linear`: back to the f32 layout (lossy —
    the round-trip error is what :func:`error_report` measures)."""
    from repro.api.bind import is_quantized

    if not is_quantized(p):
        return p
    out = {}
    for key, v in p.items():
        if key in SCALE_KEY and SCALE_KEY[key] in p:
            out[key] = dequantize_tensor(v, p[SCALE_KEY[key]])
        elif key not in SCALE_KEY.values():
            out[key] = v
    return out


# ---------------------------------------------------------------------------
# Error reporting (the docs/deployment.md tradeoff table)
# ---------------------------------------------------------------------------

def _tensor_report(name: str, tensor_key: str, w) -> dict:
    q, s = quantize_tensor(w)
    back = np.asarray(dequantize_tensor(q, s))
    w = np.asarray(w, np.float32)
    denom = float(np.linalg.norm(w))
    rel = float(np.linalg.norm(w - back)) / max(denom, 1e-30)
    return {"site": name, "tensor": tensor_key,
            "rel_err": rel,
            "max_abs_err": float(np.max(np.abs(w - back))),
            "f32_bytes": int(w.size) * 4,
            "q8_bytes": int(w.size) + int(np.asarray(s).size) * 4}


def error_report(params, plan) -> list[dict]:
    """Per-site, per-tensor quantization error of ``params`` under the
    quant-stamped ``plan``: one record per weight leaf that would pack —
    {site, tensor, rel_err (Frobenius), max_abs_err, f32_bytes, q8_bytes}.
    ``params`` stay untouched (the report quantizes copies)."""
    from repro.api.bind import is_quantized, linear_layout
    from repro.api.convert import _walk_linears

    records: list[dict] = []

    def one(spec, p):
        if spec.quant is not None and not is_quantized(p) \
                and linear_layout(p) != "project":
            for key in SCALE_KEY:
                if key in p:
                    records.append(_tensor_report(spec.name, key, p[key]))
        return p

    _walk_linears(params, plan, one)
    return records


def format_error_report(records: list[dict]) -> str:
    """Markdown table over :func:`error_report` records plus a totals row."""
    lines = ["| site | tensor | rel err | max abs err | f32 bytes | q8 bytes |",
             "|---|---|---|---|---|---|"]
    for r in records:
        lines.append(f"| {r['site']} | {r['tensor']} | {r['rel_err']:.2e} "
                     f"| {r['max_abs_err']:.2e} | {r['f32_bytes']} "
                     f"| {r['q8_bytes']} |")
    f32 = sum(r["f32_bytes"] for r in records)
    q8 = sum(r["q8_bytes"] for r in records)
    if records:
        worst = max(r["rel_err"] for r in records)
        lines.append(f"| **total** | | worst {worst:.2e} | "
                     f"| {f32} | {q8} ({f32 / max(q8, 1):.2f}x smaller) |")
    return "\n".join(lines)
