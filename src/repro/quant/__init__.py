"""Int8 deployment quantization of subspace factors.

The serve half of the paper's edge claim: the factored forward already
shrinks weight *compute* to the rank-K subspace; packing the L/R factors
(and any remaining dense 2D weights) to int8 with per-channel f32 scales
compounds the subspace compression exactly where on-device inference needs
it — weight bytes and HBM traffic drop ~4x on top of the K(O+I)/(O*I)
factor win, with no dequantized O×I tensor ever materialized
(kernels/quant.py keeps the int8 factors resident in VMEM).

Entry points: ``SubspacePlan.quantized("int8")`` stamps the plan,
``api.convert.quantize(params, plan)`` packs the params, and
``ServeEngine.from_checkpoint`` serves a quant-stamped checkpoint with no
config in hand. See docs/deployment.md for the lifecycle.
"""
from repro.quant.quantize import (
    QMAX,
    dequantize_linear,
    dequantize_tensor,
    error_report,
    format_error_report,
    quantize_linear,
    quantize_tensor,
)

__all__ = [
    "QMAX",
    "dequantize_linear",
    "dequantize_tensor",
    "error_report",
    "format_error_report",
    "quantize_linear",
    "quantize_tensor",
]
