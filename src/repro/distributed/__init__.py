"""Distribution layer: meshes, sharding policies, collectives, resilience."""
