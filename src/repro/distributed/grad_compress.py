"""Mesh-aware PowerSGD gradient compression (DESIGN.md §4).

Applies core/powersgd to the DENSE 2D parameters' gradients; WASI-factored
layers are skipped (their gradients are already rank-K). The cross-replica
mean of the small P/Q factors runs as lax.pmean inside shard_map over the
DP axes — train/step.py (make_train_step(..., mesh=...)) is the wiring,
and the pmean is exactly the collective the compression shrinks.

On a single device (tests) the mean is an identity and the algorithm
degenerates to plain low-rank gradient smoothing with error feedback.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.powersgd import PowerSGDState, compress_decompress, powersgd_init

# leaf-name suffixes that are already low-rank factors or packing metadata:
# WASI (L, R) pairs, tenancy adapter (La, Ra) delta pairs, and the int8
# per-channel scale leaves quant/quantize.py stores next to packed weights.
# None of these may enter the PowerSGD path — the factors are the
# compression, and a scale/int8 leaf has no meaningful dense gradient.
_FACTOR_SUFFIXES = ("/L", "/R", "/La", "/Ra", "/Lq", "/Rq",
                    "/sL", "/sR", "/sW", "/sLa", "/sRa")


def _is_compressible(path: str, leaf) -> bool:
    if getattr(leaf, "ndim", 0) != 2:
        return False
    # dense FLOAT 2D weights only; int8-packed leaves carry no gradient
    dt = getattr(leaf, "dtype", None)
    if dt is not None and not jnp.issubdtype(dt, jnp.floating):
        return False
    # factored L/R, adapter La/Ra, quant scale leaves and tiny tables excluded
    if path.endswith(_FACTOR_SUFFIXES):
        return False
    return min(leaf.shape) >= 64


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(parts)


def init_compression(key, params, rank: int, *,
                     local_copies: int = 0) -> dict[str, PowerSGDState]:
    """State dict keyed by leaf path for every compressible gradient.

    ``local_copies=D`` allocates per-replica error buffers (D, O, I) for a
    D-way DP mesh (see powersgd_init); 0 keeps the single-device (O, I)."""
    states = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for i, (path, leaf) in enumerate(flat):
        ps = _path_str(path)
        if _is_compressible(ps, leaf):
            states[ps] = powersgd_init(jax.random.fold_in(key, i),
                                       leaf.shape, rank,
                                       local_copies=local_copies)
    return states


def compress_gradients(grads, states: dict[str, PowerSGDState],
                       mean_fn=None):
    """Returns (compressed-mean grads, new states). Non-compressible leaves
    pass through ``mean_fn`` directly (or unchanged if mean_fn is None)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    new_states = dict(states)
    out = []
    for path, g in flat:
        ps = _path_str(path)
        if ps in states:
            dec, ns = compress_decompress(g, states[ps], mean_fn)
            new_states[ps] = ns
            out.append(dec)
        else:
            out.append(mean_fn(g) if mean_fn is not None else g)
    return jax.tree_util.tree_unflatten(treedef, [x for x in out]), new_states


def measured_collective_savings(step_fn, state, batch) -> dict[str, int]:
    """MEASURED per-device collective bytes of one compiled train step.

    ``step_fn`` is a mesh-carrying step (make_train_step(..., mesh=...));
    the returned dict is collectives.collective_bytes of its post-SPMD HLO
    — an observation of what actually crosses the DP axis, unlike the
    analytic ``collective_savings`` below."""
    from repro.distributed.collectives import measured_collective_bytes

    return measured_collective_bytes(step_fn, state, batch)


def collective_savings(params, states: dict[str, PowerSGDState]) -> dict:
    """ANALYTIC bytes over the DP axis: dense all-reduce vs PowerSGD factors.
    Prefer ``measured_collective_savings`` when a compiled step exists."""
    import numpy as np

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    dense = comp = 0
    for path, leaf in flat:
        ps = _path_str(path)
        n = int(np.prod(leaf.shape)) * 4
        if ps in states:
            o, i = leaf.shape
            r = states[ps].q.shape[1]
            dense += n
            comp += (o + i) * r * 4
        else:
            dense += n
            comp += n
    return {"dense_bytes": dense, "compressed_bytes": comp,
            "ratio": dense / max(comp, 1)}
