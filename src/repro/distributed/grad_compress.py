"""Mesh-aware PowerSGD gradient compression (DESIGN.md §4).

Applies core/powersgd to the DENSE 2D parameters' gradients; WASI-factored
layers are skipped (their gradients are already rank-K). The cross-replica
mean of the small P/Q factors runs as lax.pmean inside shard_map over the
DP axes, which is exactly the collective the compression shrinks.

On a single device (tests) the mean is an identity and the algorithm
degenerates to plain low-rank gradient smoothing with error feedback.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.powersgd import PowerSGDState, compress_decompress, powersgd_init


def _is_compressible(path: str, leaf) -> bool:
    if getattr(leaf, "ndim", 0) != 2:
        return False
    # dense 2D weights only; factored L/R and tiny tables excluded
    if path.endswith("/L") or path.endswith("/R"):
        return False
    return min(leaf.shape) >= 64


def _path_str(path) -> str:
    parts = []
    for p in path:
        parts.append(str(getattr(p, "key", getattr(p, "idx", getattr(p, "name", p)))))
    return "/".join(parts)


def init_compression(key, params, rank: int) -> dict[str, PowerSGDState]:
    """State dict keyed by leaf path for every compressible gradient."""
    states = {}
    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    for i, (path, leaf) in enumerate(flat):
        ps = _path_str(path)
        if _is_compressible(ps, leaf):
            states[ps] = powersgd_init(jax.random.fold_in(key, i),
                                       leaf.shape, rank)
    return states


def compress_gradients(grads, states: dict[str, PowerSGDState],
                       mean_fn=None):
    """Returns (compressed-mean grads, new states). Non-compressible leaves
    pass through ``mean_fn`` directly (or unchanged if mean_fn is None)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(grads)
    new_states = dict(states)
    out = []
    for path, g in flat:
        ps = _path_str(path)
        if ps in states:
            dec, ns = compress_decompress(g, states[ps], mean_fn)
            new_states[ps] = ns
            out.append(dec)
        else:
            out.append(mean_fn(g) if mean_fn is not None else g)
    return jax.tree_util.tree_unflatten(treedef, [x for x in out]), new_states


def collective_savings(params, states: dict[str, PowerSGDState]) -> dict:
    """Bytes over the DP axis: dense all-reduce vs PowerSGD factors."""
    import numpy as np

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    dense = comp = 0
    for path, leaf in flat:
        ps = _path_str(path)
        n = int(np.prod(leaf.shape)) * 4
        if ps in states:
            o, i = leaf.shape
            r = states[ps].q.shape[1]
            dense += n
            comp += (o + i) * r * 4
        else:
            dense += n
            comp += n
    return {"dense_bytes": dense, "compressed_bytes": comp,
            "ratio": dense / max(comp, 1)}
