"""Sharding policy: how logical tensor axes map onto mesh axes.

``MeshPolicy`` is threaded through model apply functions; every activation
constraint in the model goes through :func:`shard` so a single object flips
the whole network between data-parallel, tensor-parallel, sequence-parallel
and combinations — and ``policy=None`` turns all constraints off for
single-device unit tests.

Parameter shardings are assigned by path-pattern rules (:func:`param_specs`),
the way production launchers (MaxText etc.) do it: the model code stays
sharding-agnostic, the launcher owns placement.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclass(frozen=True)
class MeshPolicy:
    """Logical->mesh axis assignment.

    batch: mesh axes sharding the batch dim of activations (DP).
    seq:   mesh axes sharding the sequence dim (SP; empty = unsharded).
    model: mesh axis sharding hidden/head/expert dims (TP/EP).
    """

    batch: tuple[str, ...] = ("data",)
    seq: tuple[str, ...] = ()
    model: str | None = "model"
    # MoE expert banks: "expert" shards the expert dim on the model axis
    # (EP, all-to-all dispatch); "ffn" shards each expert's hidden dim (TP).
    expert_mode: str = "expert"
    # Megatron-style sequence parallelism for RESIDUAL storage: block
    # boundary activations shard their seq dim on these axes, so per-layer
    # saved-for-backward tensors shrink by the TP degree (GSPMD inserts the
    # all-gather/reduce-scatter pair around each block — same bytes as the
    # TP all-reduce it replaces).
    seq_resid: tuple[str, ...] = ()

    def batch_spec(self):
        return self.batch if self.batch else None

    def seq_spec(self):
        return self.seq if self.seq else None

    def model_spec(self):
        return self.model


def shard(x, policy: "MeshPolicy | None", *dims):
    """Constrain activation sharding. ``dims`` name each tensor axis with one
    of: 'batch', 'seq', 'model', None. No-op when policy is None."""
    if policy is None:
        return x
    spec = []
    for d in dims:
        if d == "batch":
            spec.append(policy.batch_spec())
        elif d == "seq":
            spec.append(policy.seq_spec())
        elif d == "model":
            spec.append(policy.model_spec())
        elif d == "seq_resid":
            spec.append(policy.seq_resid if policy.seq_resid else None)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# Parameter placement rules (regex on pytree path).
# ---------------------------------------------------------------------------

# Megatron-style rules for the unified LM. First match wins.
#   column-parallel (shard output dim):  q/k/v, mlp up & gate, L of WASI pairs
#   row-parallel    (shard input dim):   o-proj, mlp down, R of WASI pairs
# WASI note (DESIGN.md §4): for an up-projection, L (O,K) shards O; its R
# (K,I) is replicated. For a down-projection, R (K,I) shards I; its L is
# replicated. The K-dim contraction between them is the (tiny) all-reduce.
LM_RULES: tuple[tuple[str, tuple], ...] = (
    # embeddings / head: vocab on model axis
    (r".*(embed|lm_head)/w$", ("model", None)),
    # MoE expert banks (E, O, I) or factored (E, O, K)/(E, K, I).
    # "expert" and "ffn_model" resolve to the model axis under EP and TP
    # respectively — never both (DuplicateSpec otherwise).
    (r".*experts.*/(w_up|w_gate)/w$", ("expert", "ffn_model", None)),
    (r".*experts.*/w_down/w$", ("expert", None, "ffn_model")),
    (r".*experts.*/(w_up|w_gate)/L$", ("expert", "ffn_model", None)),
    (r".*experts.*/(w_up|w_gate)/R$", ("expert", None, None)),
    (r".*experts.*/w_down/L$", ("expert", None, None)),
    (r".*experts.*/w_down/R$", ("expert", None, "ffn_model")),
    # shared experts: always-on, shard like dense FFN banks
    (r".*shared/(w_up|w_gate)/w$", (None, "model", None)),
    (r".*shared/w_down/w$", (None, None, "model")),
    (r".*shared/(w_up|w_gate)/L$", (None, "model", None)),
    (r".*shared/(w_up|w_gate)/R$", (None, None, None)),
    (r".*shared/w_down/L$", (None, None, None)),
    (r".*shared/w_down/R$", (None, None, "model")),
    # router stays replicated
    (r".*router.*", (None, None)),
    # attention projections
    (r".*(wq|wk|wv|q_proj|k_proj|v_proj)/w$", ("model", None)),
    (r".*(wo|o_proj)/w$", (None, "model")),
    (r".*(wq|wk|wv|q_proj|k_proj|v_proj)/L$", ("model", None)),
    (r".*(wq|wk|wv|q_proj|k_proj|v_proj)/R$", (None, None)),
    (r".*(wo|o_proj)/L$", (None, None)),
    (r".*(wo|o_proj)/R$", (None, "model")),
    (r".*(wq|wk|wv|q_proj|k_proj|v_proj)/b$", ("model",)),
    # MLP
    (r".*(up|gate)/w$", ("model", None)),
    (r".*down/w$", (None, "model")),
    (r".*(up|gate)/L$", ("model", None)),
    (r".*(up|gate)/R$", (None, None)),
    (r".*down/L$", (None, None)),
    (r".*down/R$", (None, "model")),
    # SSM projections (in_proj col-parallel, out_proj row-parallel; the
    # small B/C/dt heads replicated -- split-boundary alignment, DESIGN §4)
    (r".*(bcdt_proj|x_proj)/.*$", None),
    (r".*(in_proj|dt_proj)/(w|L)$", ("model", None)),
    (r".*(in_proj|dt_proj)/R$", (None, None)),
    (r".*out_proj/(w)$", (None, "model")),
    (r".*out_proj/L$", (None, None)),
    (r".*out_proj/R$", (None, "model")),
    (r".*(A_log|D|dt_bias|conv_w|conv_b)$", None),  # small ssm tensors replicated
)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def spec_for_path(path_str: str, leaf, policy: MeshPolicy,
                  rules=LM_RULES, scan_prefix: bool = True):
    """PartitionSpec for one parameter. ``scan_prefix`` accounts for stacked
    scan layers: leaves with more dims than the rule pattern get leading
    ``None`` axes (the layer/stack dims are never sharded)."""
    model = policy.model_spec()
    # EP rides the model axis (DESIGN.md §4); exactly one of expert/ffn_model
    # resolves, per policy.expert_mode
    expert = model if policy.expert_mode == "expert" else None
    ffn_model = model if policy.expert_mode == "ffn" else None

    def resolve(tok):
        return {"model": model, "expert": expert,
                "ffn_model": ffn_model}.get(tok, None)

    for pat, spec in rules:
        if re.match(pat, path_str):
            if spec is None:
                return P()
            resolved = tuple(resolve(s) for s in spec)
            ndim = getattr(leaf, "ndim", len(resolved))
            if scan_prefix and ndim > len(resolved):
                resolved = (None,) * (ndim - len(resolved)) + resolved
            elif ndim < len(resolved):
                resolved = resolved[-ndim:] if ndim else ()
            return P(*resolved)
    return P()  # replicate by default (norms, scalars)


class _Ndim:
    def __init__(self, n: int):
        self.ndim = n


def site_sharding(spec, policy: MeshPolicy,
                  rules=LM_RULES) -> tuple[tuple[str, tuple], ...]:
    """Resolve one plan site (api.plan.LinearSpec) against the path-rule
    table: ((leaf, PartitionSpec entries), ...) for every weight leaf the
    site's mode implies — (L, R) for factored, w for dense/project, plus b
    when biased and the (replicated) La/Ra pair when an adapter is stamped.
    This is what SubspacePlan.with_sharding() freezes into the plan."""
    nd = 3 if spec.role == "moe" else 2  # MoE banks carry the expert dim
    # plan site names say "moe/..."; the param-tree paths the rule table
    # matches say ".../experts/..." — translate before matching
    site = spec.name.replace("moe/", "experts/")
    leaves = ["L", "R"] if spec.mode == "factored" else ["w"]
    if spec.bias:
        leaves.append("b")
    if spec.adapter is not None:
        leaves += ["La", "Ra"]
    out = []
    for leaf in leaves:
        if leaf in ("La", "Ra"):
            p = P()  # per-tenant deltas are replicated, never mesh-sharded
        else:
            p = spec_for_path(f"{site}/{leaf}",
                              _Ndim(1 if leaf == "b" else nd),
                              policy, rules, scan_prefix=False)
        out.append((leaf, tuple(p)))
    return tuple(out)


def param_specs(params, policy: MeshPolicy, rules=LM_RULES):
    """Pytree of PartitionSpecs matching ``params``."""
    return jax.tree_util.tree_map_with_path(
        lambda p, x: spec_for_path(_path_str(p), x, policy, rules), params)


def param_shardings(params, mesh: Mesh, policy: MeshPolicy, rules=LM_RULES):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, policy, rules))


def bytes_per_device(tree, mesh: Mesh, specs) -> int:
    """Estimated per-device bytes for a sharded pytree (dry-run sanity)."""
    total = 0
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def leaf_bytes(x, spec):
        n = int(np.prod(x.shape)) if x.shape else 1
        denom = 1
        for entry in spec:
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            for nm in names:
                denom *= axis_sizes[nm]
        import jax.numpy as jnp
        return n * jnp.dtype(x.dtype).itemsize // max(denom, 1)

    for x, s in zip(jax.tree.leaves(tree), jax.tree.leaves(specs, is_leaf=lambda t: isinstance(t, P))):
        total += leaf_bytes(x, s)
    return total
