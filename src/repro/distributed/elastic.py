"""Elastic scaling: rebuild the mesh when the device set changes.

At 1000+ nodes, device loss is routine. The protocol here (exercised by
tests/test_elastic.py with simulated device subsets):

1. A health probe detects the surviving device set.
2. ``plan_mesh`` picks the largest valid (data, model) mesh that (a) fits
   the survivors, (b) keeps the model axis unchanged (TP degree is baked
   into weight shards — changing it requires resharding ALL params), and
   (c) drops whole data replicas first (cheapest: DP replicas are
   interchangeable).
3. Training resumes from the last checkpoint; params are resharded onto the
   new mesh by restore (checkpoints store unsharded global arrays, so any
   mesh can load them); the global batch either shrinks proportionally
   (throughput-preserving per-device work) or per-device batch grows
   (convergence-preserving global batch) per ``batch_policy``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
from jax.sharding import Mesh
import numpy as np


@dataclass(frozen=True)
class ElasticPlan:
    data: int
    model: int
    devices_used: int
    global_batch: int
    note: str


def plan_mesh(n_devices: int, model_parallel: int, old_global_batch: int,
              old_data: int, batch_policy: str = "shrink") -> ElasticPlan:
    """Largest (data, model) mesh with fixed TP degree on survivors."""
    if n_devices < model_parallel:
        raise ValueError(
            f"cannot keep TP degree {model_parallel} with {n_devices} devices; "
            "full reshard required")
    data = n_devices // model_parallel
    used = data * model_parallel
    if batch_policy == "shrink":
        gb = max(1, old_global_batch * data // old_data)
        note = "per-device batch preserved; global batch shrunk"
    else:
        gb = old_global_batch
        note = "global batch preserved; per-device batch grew"
    return ElasticPlan(data=data, model=model_parallel, devices_used=used,
                       global_batch=gb, note=note)


def build_mesh(devices, data: int, model: int,
               axis_names=("data", "model")) -> Mesh:
    dev = np.asarray(devices[: data * model]).reshape(data, model)
    return Mesh(dev, axis_names)


def survivors(devices, failed_ids: set[int]):
    return [d for d in devices if d.id not in failed_ids]
