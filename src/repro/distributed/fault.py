"""Failure detection + straggler mitigation policies for the train loop.

These are the *control-plane* pieces of fault tolerance (the data plane —
atomic checkpoints, deterministic data skip-ahead, elastic re-mesh — lives
in checkpoint/ and distributed/elastic.py). Policies are plain-python and
unit-tested with simulated timings; the launcher wires them to real step
timings.

Straggler mitigation (DESIGN.md §4): synchronous training can't drop a slow
worker mid-allreduce, so mitigation acts BETWEEN steps:
* ``StragglerDetector`` flags workers whose step time exceeds
  median * threshold for ``patience`` consecutive steps;
* the launcher's response ladder: (1) re-shard that worker's data slice to
  spares ("backup workers" — speculative execution at step granularity),
  (2) if persistent, evict via the elastic plan at the next checkpoint
  boundary.
"""
from __future__ import annotations

import time
from collections import defaultdict, deque
from dataclasses import dataclass, field


@dataclass
class StepTimer:
    window: int = 32
    times: deque = field(default_factory=lambda: deque(maxlen=32))
    _t0: float | None = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> float:
        dt = time.monotonic() - self._t0
        self.times.append(dt)
        return dt

    def median(self) -> float:
        if not self.times:
            return 0.0
        s = sorted(self.times)
        return s[len(s) // 2]


class StragglerDetector:
    """Flags persistently slow workers from per-step timings."""

    def __init__(self, threshold: float = 1.5, patience: int = 3):
        self.threshold = threshold
        self.patience = patience
        self._strikes: dict[int, int] = defaultdict(int)

    def observe(self, step_times: dict[int, float]) -> list[int]:
        """step_times: worker_id -> seconds. Returns workers to act on."""
        if not step_times:
            return []
        s = sorted(step_times.values())
        med = s[len(s) // 2]
        flagged = []
        for w, t in step_times.items():
            if med > 0 and t > self.threshold * med:
                self._strikes[w] += 1
            else:
                self._strikes[w] = 0
            if self._strikes[w] >= self.patience:
                flagged.append(w)
        return flagged


class HeartbeatMonitor:
    """Declares workers dead after ``timeout`` without a heartbeat."""

    def __init__(self, timeout: float = 60.0):
        self.timeout = timeout
        self._last: dict[int, float] = {}

    def beat(self, worker: int, now: float | None = None):
        self._last[worker] = time.monotonic() if now is None else now

    def dead(self, now: float | None = None) -> list[int]:
        t = time.monotonic() if now is None else now
        return [w for w, last in self._last.items() if t - last > self.timeout]


@dataclass
class RestartPolicy:
    """Exponential-backoff restart budget (per incident class)."""

    max_restarts: int = 10
    backoff_base: float = 2.0
    _count: int = 0

    def next_delay(self) -> float | None:
        if self._count >= self.max_restarts:
            return None
        d = min(self.backoff_base ** self._count, 300.0)
        self._count += 1
        return d

    def reset(self):
        self._count = 0
