"""Hand-scheduled collectives for the sharded train/serve hot paths.

``shard_map`` — one compat alias every mesh consumer (train/step.py, the
parity tests, this module) imports, so the jax.shard_map ->
jax.experimental.shard_map rename difference across jax versions lives in
exactly one place.

``collective_bytes`` / ``measured_collective_bytes`` — the MEASURED side of
the communication story: parse the post-SPMD HLO of a compiled executable
and sum the result sizes of every collective op. ``launch/dryrun.py`` uses
it for the planning matrix; ``distributed/grad_compress.py`` and
``benchmarks/fig_comm.py`` use it to report the factor-only DP all-reduce
bytes as an observation, not a formula.

``flash_decode`` — sequence-sharded single-token attention: the KV cache for
a 500k-token context is sharded along the SEQUENCE dim across the ``data``
mesh axis. Each shard computes a LOCAL partial softmax (max, sum, weighted
value) over its KV slice; partials are combined with three tiny psums
(per-head scalars + one Dh vector) instead of all-gathering the cache —
collective bytes drop from O(S * d_kv) to O(H * Dh).

This is the shard_map fast path; the pjit path (XLA-scheduled) is the
baseline it is hillclimbed against in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
import re

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.5 exposes it at the top level
    shard_map = jax.shard_map
except AttributeError:  # 0.4.x
    from jax.experimental.shard_map import shard_map

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Measured collective bytes (post-SPMD HLO)
# ---------------------------------------------------------------------------

DTYPE_RE = re.compile(
    r"(f64|f32|bf16|f16|s32|u32|s64|u64|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]")
BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
         "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
         "pred": 1, "c64": 8, "c128": 16}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(m) -> int:
    dt, dims = m.group(1), m.group(2)
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * BYTES[dt]


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum RESULT sizes of collective ops in post-SPMD HLO (per device)."""
    out = {c: 0 for c in COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        ls = line.strip()
        for c in COLLECTIVES:
            # match op lines: "%x = TYPE[dims] all-reduce(...)" (incl. -start)
            if re.search(rf"\b{c}(-start)?\(", ls):
                m = DTYPE_RE.search(ls)
                if m:
                    out[c] += _shape_bytes(m)
                    out["count"] += 1
                break
    out["total"] = sum(out[c] for c in COLLECTIVES)
    return out


def measured_collective_bytes(fn, *args) -> dict[str, int]:
    """Compile ``fn(*args)`` and read its per-device collective bytes out of
    the post-SPMD HLO. ``fn`` must already carry its mesh (a shard_map-
    wrapped step, or a jit with explicit shardings); args are concrete
    arrays or ShapeDtypeStructs."""
    compiled = jax.jit(fn).lower(*args).compile()
    return collective_bytes(compiled.as_text())


def _local_partials(q, k, v, valid):
    """q (B,H,Dh); k/v (B,Sl,KVH,Dh); valid (Sl,) bool.
    Returns (m (B,H), l (B,H), acc (B,H,Dh)) local partial softmax."""
    b, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh) * (dh ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k).astype(jnp.float32)
    s = s + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p.astype(q.dtype), v).astype(jnp.float32)
    return m.reshape(b, h), l.reshape(b, h), acc.reshape(b, h, dh)


def flash_decode(q, k_shard, v_shard, valid_shard, axis_name: str):
    """Inside shard_map: combine per-shard partial softmaxes via psum.

    q (B,H,Dh) replicated across the sequence shards; k/v (B,S_local,KVH,Dh);
    valid_shard (S_local,). Returns (B,H,Dh) fully-reduced attention output.
    """
    m, l, acc = _local_partials(q, k_shard, v_shard, valid_shard)
    m_glob = jax.lax.pmax(m, axis_name)                       # (B,H)
    scale = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * scale, axis_name)
    acc_glob = jax.lax.psum(acc * scale[..., None], axis_name)
    return (acc_glob / jnp.maximum(l_glob[..., None], 1e-30)).astype(q.dtype)


def make_flash_decode(mesh: Mesh, seq_axis: str = "data"):
    """shard_map-wrapped flash decode over a sequence-sharded KV cache.

    Returns fn(q (B,H,Dh), k (B,S,KVH,Dh), v, pos) -> (B,H,Dh), where k/v are
    sharded P(None, seq_axis, None, None) and q is replicated.
    """
    def fn(q, k, v, pos):
        s = k.shape[1]

        def local(qi, ki, vi, posi):
            idx = jax.lax.axis_index(seq_axis)
            sl = ki.shape[1]
            kpos = idx * sl + jnp.arange(sl)
            return flash_decode(qi, ki, vi, kpos <= posi, seq_axis)

        return shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(None, seq_axis, None, None),
                      P(None, seq_axis, None, None), P()),
            out_specs=P(),
        )(q, k, v, pos)

    return fn
