"""Hand-scheduled collectives for the long-context serve path.

``flash_decode`` — sequence-sharded single-token attention: the KV cache for
a 500k-token context is sharded along the SEQUENCE dim across the ``data``
mesh axis. Each shard computes a LOCAL partial softmax (max, sum, weighted
value) over its KV slice; partials are combined with three tiny psums
(per-head scalars + one Dh vector) instead of all-gathering the cache —
collective bytes drop from O(S * d_kv) to O(H * Dh).

This is the shard_map fast path; the pjit path (XLA-scheduled) is the
baseline it is hillclimbed against in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def _local_partials(q, k, v, valid):
    """q (B,H,Dh); k/v (B,Sl,KVH,Dh); valid (Sl,) bool.
    Returns (m (B,H), l (B,H), acc (B,H,Dh)) local partial softmax."""
    b, h, dh = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, kvh, g, dh) * (dh ** -0.5)
    s = jnp.einsum("bhgd,bshd->bhgs", qg, k).astype(jnp.float32)
    s = s + jnp.where(valid, 0.0, NEG_INF)[None, None, None, :]
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    l = p.sum(axis=-1)
    acc = jnp.einsum("bhgs,bshd->bhgd", p.astype(q.dtype), v).astype(jnp.float32)
    return m.reshape(b, h), l.reshape(b, h), acc.reshape(b, h, dh)


def flash_decode(q, k_shard, v_shard, valid_shard, axis_name: str):
    """Inside shard_map: combine per-shard partial softmaxes via psum.

    q (B,H,Dh) replicated across the sequence shards; k/v (B,S_local,KVH,Dh);
    valid_shard (S_local,). Returns (B,H,Dh) fully-reduced attention output.
    """
    m, l, acc = _local_partials(q, k_shard, v_shard, valid_shard)
    m_glob = jax.lax.pmax(m, axis_name)                       # (B,H)
    scale = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * scale, axis_name)
    acc_glob = jax.lax.psum(acc * scale[..., None], axis_name)
    return (acc_glob / jnp.maximum(l_glob[..., None], 1e-30)).astype(q.dtype)


def make_flash_decode(mesh: Mesh, seq_axis: str = "data"):
    """shard_map-wrapped flash decode over a sequence-sharded KV cache.

    Returns fn(q (B,H,Dh), k (B,S,KVH,Dh), v, pos) -> (B,H,Dh), where k/v are
    sharded P(None, seq_axis, None, None) and q is replicated.
    """
    def fn(q, k, v, pos):
        s = k.shape[1]

        def local(qi, ki, vi, posi):
            idx = jax.lax.axis_index(seq_axis)
            sl = ki.shape[1]
            kpos = idx * sl + jnp.arange(sl)
            return flash_decode(qi, ki, vi, kpos <= posi, seq_axis)

        return jax.shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(None, seq_axis, None, None),
                      P(None, seq_axis, None, None), P()),
            out_specs=P(),
        )(q, k, v, pos)

    return fn
