"""Encoder-decoder transformer (Whisper-tiny backbone).

The audio conv frontend is a STUB per the assignment: the encoder consumes
precomputed frame embeddings (B, S_enc, d) supplied by ``input_specs``.
Positions use fixed sinusoidal tables (rope_theta=0 disables RoPE), which
extrapolate mechanically beyond the trained length (fidelity caveat in
DESIGN.md §5).

Decoder blocks: causal self-attn -> cross-attn over encoder memory -> MLP.
Decode keeps (a) a self-attn KV cache and (b) precomputed cross-attn K/V of
the encoder memory (computed once at prefill, reused every step).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import MeshPolicy, shard
from repro.nn.attention import (
    KVCache,
    apply_attention,
    init_attention,
    init_attention_state,
    init_cache,
)
from repro.nn.mlp import apply_mlp, init_mlp, init_mlp_state
from repro.nn.norms import apply_norm, init_norm
from repro.nn.rotary import sinusoidal_embedding


def init_encdec(key, cfg: ModelConfig, dtype=jnp.float32, *, plan=None) -> dict:
    """``plan``: optional explicitly-resolved SubspacePlan (calibrated
    ranks); installed so every linear init below reads it."""
    if plan is not None:
        from repro.api import install
        install(plan)
    d, v = cfg.d_model, cfg.padded_vocab
    ks = jax.random.split(key, 6)

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_norm(cfg.norm, d, dtype),
                "attn": init_attention(k1, cfg, dtype),
                "ln2": init_norm(cfg.norm, d, dtype),
                "mlp": init_mlp(k2, cfg, dtype=dtype)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": init_norm(cfg.norm, d, dtype),
                "self_attn": init_attention(k1, cfg, dtype),
                "ln_x": init_norm(cfg.norm, d, dtype),
                "cross_attn": init_attention(k2, cfg, dtype),
                "ln2": init_norm(cfg.norm, d, dtype),
                "mlp": init_mlp(k3, cfg, dtype=dtype)}

    return {
        "embed": {"w": (jax.random.normal(ks[0], (v, d), jnp.float32) * 0.02).astype(dtype)},
        "enc": jax.vmap(enc_block)(jax.random.split(ks[1], cfg.n_enc_layers)),
        "enc_norm": init_norm(cfg.norm, d, dtype),
        "dec": jax.vmap(dec_block)(jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": init_norm(cfg.norm, d, dtype),
    }


def init_encdec_states(key, cfg: ModelConfig, batch: int, seq: int,
                       dtype=jnp.float32) -> dict:
    """ASI warm-start states (train path). seq = decoder length."""
    ks = jax.random.split(key, 2)
    se = cfg.enc_seq

    def enc_state(k):
        k1, k2 = jax.random.split(k)
        return {"attn": init_attention_state(k1, cfg, batch, se, dtype),
                "mlp": init_mlp_state(k2, cfg, batch, se, dtype=dtype)}

    def dec_state(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"self_attn": init_attention_state(k1, cfg, batch, seq, dtype),
                "cross_attn": {},  # cross-attn K/V from fixed memory: no ASI
                "mlp": init_mlp_state(k2, cfg, batch, seq, dtype=dtype)}

    return {"enc": jax.vmap(enc_state)(jax.random.split(ks[0], cfg.n_enc_layers)),
            "dec": jax.vmap(dec_state)(jax.random.split(ks[1], cfg.n_layers))}


def encode(params, frames: jax.Array, cfg: ModelConfig, *,
           states=None, policy: MeshPolicy | None = None):
    """frames (B, S_enc, d) from the frontend stub -> memory (B, S_enc, d)."""
    x = frames.astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_embedding(x.shape[1], cfg.d_model, x.dtype)[None]
    with_states = states is not None

    def body(h, xs):
        p, st = xs
        a, _, ns_a = apply_attention(p["attn"], apply_norm(cfg.norm, p["ln1"], h),
                                     cfg, causal=False,
                                     states=st["attn"] if with_states else None,
                                     policy=policy)
        h = h + a
        f, ns_m = apply_mlp(p["mlp"], apply_norm(cfg.norm, p["ln2"], h), cfg,
                            st["mlp"] if with_states else None, policy)
        return h + f, {"attn": ns_a if with_states else {},
                       "mlp": ns_m if with_states else {}}

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    # scan over stacked encoder blocks; disabled states ride as a leafless
    # dict (no stacking dim needed — no leaves)
    st_xs = states["enc"] if with_states else {"attn": {}, "mlp": {}}
    if with_states:
        x, ns = jax.lax.scan(body, x, (params["enc"], st_xs))
    else:
        x, ns = jax.lax.scan(lambda h, p: body(h, (p, st_xs)), x, params["enc"])
    return apply_norm(cfg.norm, params["enc_norm"], x), ns


def _dec_body(cfg, policy, with_states, with_cache, pos):
    def body(h_mem, xs):
        h, mem = h_mem
        p, st, cache = xs
        a, nkv, ns_s = apply_attention(
            p["self_attn"], apply_norm(cfg.norm, p["ln1"], h), cfg,
            causal=True, cache=cache["kv"] if with_cache else None, pos=pos,
            states=st["self_attn"] if with_states else None, policy=policy)
        h = h + a
        c, _, _ = apply_attention(
            p["cross_attn"], apply_norm(cfg.norm, p["ln_x"], h), cfg,
            causal=False, kv_memory=mem, policy=policy)
        h = h + c
        f, ns_m = apply_mlp(p["mlp"], apply_norm(cfg.norm, p["ln2"], h), cfg,
                            st["mlp"] if with_states else None, policy)
        h = h + f
        ns = {"self_attn": ns_s if with_states else {},
              "cross_attn": {}, "mlp": ns_m if with_states else {}}
        nc = {"kv": nkv} if with_cache else {}
        return (h, mem), (ns, nc)
    return body


def decode_train(params, tokens, memory, cfg: ModelConfig, *, states=None,
                 policy: MeshPolicy | None = None):
    """Teacher-forced decoder pass. tokens (B, S) -> logits (B, S, V)."""
    x = params["embed"]["w"].astype(jnp.float32)[tokens].astype(jnp.dtype(cfg.dtype))
    x = x + sinusoidal_embedding(x.shape[1], cfg.d_model, x.dtype)[None]
    with_states = states is not None
    body = _dec_body(cfg, policy, with_states, with_cache=False, pos=None)
    if cfg.remat == "block":
        body = jax.checkpoint(body)
    st_xs = states["dec"] if with_states else {"self_attn": {}, "cross_attn": {}, "mlp": {}}
    if with_states:
        (x, _), (ns, _) = jax.lax.scan(
            lambda c, xs: body(c, (xs[0], xs[1], {})),
            (x, memory), (params["dec"], st_xs))
    else:
        (x, _), (ns, _) = jax.lax.scan(
            lambda c, p: body(c, (p, st_xs, {})),
            (x, memory), params["dec"])
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"])
    return shard(logits, policy, "batch", "seq", "model"), ns


def encdec_loss(params, batch: dict, cfg: ModelConfig, *, states=None,
                policy: MeshPolicy | None = None):
    """batch: {frames (B,S_enc,d), tokens (B,S), labels (B,S)}."""
    memory, ns_enc = encode(params, batch["frames"], cfg,
                            states=states, policy=policy)
    logits, ns_dec = decode_train(params, batch["tokens"], memory, cfg,
                                  states=states, policy=policy)
    from repro.nn.losses import masked_xent

    mask = (batch["labels"] >= 0).astype(jnp.float32)
    ce = masked_xent(logits, jnp.maximum(batch["labels"], 0), mask)
    ns = {"enc": ns_enc, "dec": ns_dec} if states is not None else None
    return ce, (ns, {"ce": ce})


def init_encdec_cache(cfg: ModelConfig, batch: int, seq: int,
                      dtype=jnp.bfloat16) -> dict:
    """Self-attn KV caches for all decoder layers (stacked)."""
    one = init_cache(cfg, batch, seq, window=0, dtype=dtype)
    return {"kv": jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)}


def encdec_decode_step(params, token, memory, caches, pos, cfg: ModelConfig, *,
                       policy: MeshPolicy | None = None):
    """One decode step. token (B,1); memory (B,S_enc,d); returns (logits, caches)."""
    x = params["embed"]["w"].astype(jnp.float32)[token].astype(jnp.dtype(cfg.dtype))
    pe = sinusoidal_embedding(cfg.max_seq, cfg.d_model, x.dtype)
    x = x + jax.lax.dynamic_slice_in_dim(pe, pos, 1, axis=0)[None]
    body = _dec_body(cfg, policy, with_states=False, with_cache=True, pos=pos)
    st_xs = {"self_attn": {}, "cross_attn": {}, "mlp": {}}
    (x, _), (_, nc) = jax.lax.scan(
        lambda c, xs: body(c, (xs[0], st_xs, {"kv": xs[1]})),
        (x, memory), (params["dec"], caches["kv"]))
    x = apply_norm(cfg.norm, params["final_norm"], x)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"])
    return logits[:, 0], nc
