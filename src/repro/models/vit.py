"""Vision Transformer (the paper's primary experimental model).

Patch extraction is a host-side reshape (16x16x3 -> 768 vector); the model
starts at the linear patch embedding, exactly the layer granularity the
paper instruments. Used by the paper-reproduction benchmarks and the
fine-tune example; 4D-activation (Swin-like) paths are exercised through the
core ASI 4D tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.nn.attention import apply_attention, init_attention
from repro.nn.mlp import apply_mlp, init_mlp, init_mlp_state
from repro.nn.norms import apply_norm, init_norm
from repro.nn.attention import init_attention_state


def init_vit(key, cfg: ModelConfig, n_classes: int, patch_dim: int = 768,
             n_patches: int = 196, dtype=jnp.float32, *, plan=None) -> dict:
    """``plan``: optional explicitly-resolved SubspacePlan (calibrated
    ranks); installed so every linear init below reads it."""
    if plan is not None:
        from repro.api import install
        install(plan)
    d = cfg.d_model
    ks = jax.random.split(key, 5)

    def block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": init_norm("layernorm", d, dtype),
                "attn": init_attention(k1, cfg, dtype),
                "ln2": init_norm("layernorm", d, dtype),
                "mlp": init_mlp(k2, cfg, dtype=dtype)}

    return {
        "patch": {"w": (jax.random.normal(ks[0], (d, patch_dim), jnp.float32)
                        * patch_dim ** -0.5).astype(dtype)},
        "cls": jnp.zeros((1, 1, d), dtype),
        "pos": (jax.random.normal(ks[1], (1, n_patches + 1, d), jnp.float32)
                * 0.02).astype(dtype),
        "blocks": jax.vmap(block)(jax.random.split(ks[2], cfg.n_layers)),
        "final_norm": init_norm("layernorm", d, dtype),
        "head": {"w": (jax.random.normal(ks[3], (n_classes, d), jnp.float32)
                       * d ** -0.5).astype(dtype),
                 "b": jnp.zeros((n_classes,), dtype)},
    }


def init_vit_states(key, cfg: ModelConfig, batch: int,
                    n_patches: int = 196, dtype=jnp.float32):
    seq = n_patches + 1

    def block_state(k):
        k1, k2 = jax.random.split(k)
        return {"attn": init_attention_state(k1, cfg, batch, seq, dtype),
                "mlp": init_mlp_state(k2, cfg, batch, seq, dtype=dtype)}

    return jax.vmap(block_state)(jax.random.split(key, cfg.n_layers))


def vit_forward(params, patches: jax.Array, cfg: ModelConfig, *,
                states=None, policy=None):
    """patches (B, N, patch_dim) -> logits (B, n_classes)."""
    b = patches.shape[0]
    x = jnp.einsum("bnp,dp->bnd", patches.astype(jnp.dtype(cfg.dtype)),
                   params["patch"]["w"])
    cls = jnp.broadcast_to(params["cls"], (b, 1, x.shape[-1]))
    x = jnp.concatenate([cls, x], axis=1) + params["pos"]
    with_states = states is not None

    def body(h, xs):
        p, st = xs
        a, _, ns_a = apply_attention(p["attn"], apply_norm("layernorm", p["ln1"], h),
                                     cfg, causal=False,
                                     states=st["attn"] if with_states else None,
                                     policy=policy)
        h = h + a
        f, ns_m = apply_mlp(p["mlp"], apply_norm("layernorm", p["ln2"], h), cfg,
                            st["mlp"] if with_states else None, policy)
        return h + f, {"attn": ns_a if with_states else {},
                       "mlp": ns_m if with_states else {}}

    st_xs = states if with_states else {"attn": {}, "mlp": {}}
    if with_states:
        x, ns = jax.lax.scan(body, x, (params["blocks"], st_xs))
    else:
        x, ns = jax.lax.scan(lambda h, p: body(h, (p, st_xs)), x, params["blocks"])
    x = apply_norm("layernorm", params["final_norm"], x)
    logits = jnp.einsum("bd,cd->bc", x[:, 0], params["head"]["w"]) + params["head"]["b"]
    return logits.astype(jnp.float32), (ns if with_states else None)


def vit_loss(params, batch: dict, cfg: ModelConfig, *, states=None, policy=None):
    logits, ns = vit_forward(params, batch["patches"], cfg, states=states,
                             policy=policy)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, batch["labels"][:, None], axis=-1)[:, 0]
    loss = (lse - gold).mean()
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return loss, (ns, {"ce": loss, "acc": acc})
