"""Unified decoder language model over layer-group scans.

Depth is organized as ``cfg.groups``: a list of (pattern, repeat) where
``pattern`` is a tuple of BlockKinds. Each group scans over ``repeat`` with
its pattern unrolled inside the scan body — HLO size stays independent of
total depth while supporting heterogeneous stacks (gemma3's 5 local : 1
global, zamba2's shared-attention interleave, deepseek's dense layer 0).

Parameters for a group are the per-pattern-position block params stacked on
a leading ``repeat`` axis (initialized via vmap over split keys). ASI
warm-start states and decode caches mirror the same structure, riding
through the scan as xs/ys.

Entry points:
    init_lm / init_lm_states / init_lm_cache
    lm_forward(...)            train/prefill logits (+ caches optionally)
    lm_loss(...)               cross-entropy train objective
    lm_decode_step(...)        one-token serve step
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import MeshPolicy, shard
from repro.models.blocks import (
    apply_block,
    init_block,
    init_block_cache,
    init_block_state,
)
from repro.nn.attention import init_attention
from repro.nn.norms import apply_norm, init_norm


def _needs_shared(cfg: ModelConfig) -> bool:
    return any("mamba2_attn" in g.pattern for g in cfg.groups)


def init_lm(key, cfg: ModelConfig, dtype=jnp.float32, *, plan=None) -> dict:
    """Init params in the layouts the SubspacePlan dictates. ``plan`` (an
    explicitly resolved SubspacePlan, e.g. with calibrated eps-ranks) is
    installed so every linear below reads it; default is the memoized
    static resolution for ``cfg`` (api.plan_of)."""
    if plan is not None:
        from repro.api import install
        install(plan)
    keys = jax.random.split(key, len(cfg.groups) + 4)
    d, v = cfg.d_model, cfg.padded_vocab
    params: dict[str, Any] = {
        "embed": {"w": (jax.random.normal(keys[0], (v, d), jnp.float32) * 0.02).astype(dtype)},
        "final_norm": init_norm(cfg.norm, d, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": (jax.random.normal(keys[1], (v, d), jnp.float32)
                                   * d ** -0.5).astype(dtype)}
    if _needs_shared(cfg):
        from repro.nn.mlp import init_mlp

        k_sh1, k_sh2 = jax.random.split(keys[2])
        params["shared_attn"] = {"ln": init_norm(cfg.norm, d, dtype),
                                 "attn": init_attention(k_sh1, cfg, dtype),
                                 "ln2": init_norm(cfg.norm, d, dtype),
                                 "mlp": init_mlp(k_sh2, cfg, dtype=dtype)}
    groups = []
    for gi, g in enumerate(cfg.groups):
        gkey = jax.random.fold_in(keys[3], gi)
        stacked = []
        for pi, kind in enumerate(g.pattern):
            pkeys = jax.random.split(jax.random.fold_in(gkey, pi), g.repeat)
            stacked.append(jax.vmap(
                lambda k, kind=kind: init_block(k, kind, cfg, dtype))(pkeys))
        groups.append(stacked)
    params["groups"] = groups
    return params


def init_lm_states(key, cfg: ModelConfig, batch: int, seq: int,
                   dtype=jnp.float32) -> list:
    """ASI warm-start states, mirroring params['groups'] structure."""
    out = []
    for gi, g in enumerate(cfg.groups):
        gkey = jax.random.fold_in(key, gi)
        stacked = []
        for pi, kind in enumerate(g.pattern):
            pkeys = jax.random.split(jax.random.fold_in(gkey, pi), g.repeat)
            stacked.append(jax.vmap(
                lambda k, kind=kind: init_block_state(k, kind, cfg, batch, seq, dtype)
            )(pkeys))
        out.append(stacked)
    return out


def init_lm_cache(cfg: ModelConfig, batch: int, seq: int,
                  dtype=jnp.bfloat16, *,
                  pages: int | None = None,
                  page_size: int | None = None) -> list:
    """Decode caches, mirroring params['groups'] structure (stacked).

    With ``pages``/``page_size`` set, full-attention KV caches become
    per-layer PAGED pools of shape (repeat, pages, page_size, KVH, Dh)
    shared by all serve slots — ``batch``/``seq`` then only bound the
    LOGICAL per-slot view the engine gathers through its page table
    (serve/kvpool.py), decoupling live slot count from ``max_cache``.
    Only causal full-attention layers can be paged; sliding-window and
    recurrent (Mamba) caches raise — the engine gates paged mode to
    configs where every layer qualifies (``supports_paging``)."""
    if (pages is None) != (page_size is None):
        raise ValueError("pages and page_size must be given together")
    out = []
    for g in cfg.groups:
        stacked = []
        for kind in g.pattern:
            if pages is None:
                one = init_block_cache(kind, cfg, batch, seq, dtype)
            else:
                from repro.models.blocks import block_window
                from repro.nn.attention import init_paged_cache

                if kind not in ("dense", "moe") or block_window(kind, cfg):
                    raise ValueError(
                        f"block kind {kind!r} cannot use a paged KV cache "
                        "(causal full attention only)")
                one = {"kv": init_paged_cache(cfg, pages, page_size, dtype)}
            stacked.append(jax.tree.map(
                lambda x: jnp.broadcast_to(x, (g.repeat,) + x.shape), one))
        out.append(stacked)
    return out


def supports_paging(cfg: ModelConfig) -> bool:
    """True when every layer's decode state can live in a paged pool:
    causal full attention only (no sliding window, no recurrent SSM/conv
    state, no shared-attention interleave)."""
    from repro.models.blocks import block_window

    return all(kind in ("dense", "moe") and not block_window(kind, cfg)
               for g in cfg.groups for kind in g.pattern)


def _empty_like_states(cfg: ModelConfig) -> list:
    """Leafless states structure for paths with ASI off (serve)."""
    return [[{} for _ in g.pattern] for g in cfg.groups]


def _group_scan(cfg: ModelConfig, gi: int, x, gparams, gstates, gcaches,
                shared, pos, policy, with_states: bool, valid_len=None,
                page_table=None):
    """Scan one layer group. gparams/gstates/gcaches: list per pattern pos."""
    g = cfg.groups[gi]

    n_pat = len(g.pattern)
    with_caches = gcaches is not None

    def body(h, xs):
        pslices, sslices, cslices = xs
        new_s, new_c = [], []
        aux_sum = jnp.zeros((), jnp.float32)
        for j, kind in enumerate(g.pattern):
            h, nc, ns, aux = apply_block(
                kind, pslices[j], h, cfg, shared=shared,
                cache=cslices[j] if with_caches else None,
                pos=pos, states=sslices[j] if with_states else None,
                policy=policy, valid_len=valid_len, page_table=page_table)
            # SP residual storage: the tensor saved at the remat boundary
            # is seq-sharded on the model axis (EXPERIMENTS.md §Perf)
            h = shard(h, policy, "batch", "seq_resid", None)
            new_s.append(ns if with_states else {})
            new_c.append(nc if with_caches else {})
            aux_sum = aux_sum + aux
        return h, (new_s, new_c, aux_sum)

    if cfg.remat == "block":
        body = jax.checkpoint(body)

    # scan requires every xs leaf to carry a leading ``repeat`` dim; disabled
    # states/caches ride as empty dicts (no leaves) -- structure-safe.
    xs = (gparams,
          gstates if with_states else [{}] * n_pat,
          gcaches if with_caches else [{}] * n_pat)
    x, (ns, nc, aux) = jax.lax.scan(body, x, xs)
    return x, ns, (nc if with_caches else None), aux


def lm_backbone(params, x, cfg: ModelConfig, *, states=None, caches=None,
                pos=None, policy: MeshPolicy | None = None, valid_len=None,
                page_table=None):
    """Run embedded hidden states through all layer groups.
    Returns (x, new_states, new_caches, aux)."""
    shared = params.get("shared_attn")
    with_states = states is not None
    new_states, new_caches = [], []
    aux_total = jnp.zeros((), jnp.float32)
    for gi in range(len(cfg.groups)):
        x, ns, nc, aux = _group_scan(
            cfg, gi, x, params["groups"][gi],
            states[gi] if with_states else None,
            caches[gi] if caches is not None else None,
            shared, pos, policy, with_states, valid_len, page_table)
        new_states.append(ns)
        new_caches.append(nc)
        aux_total = aux_total + aux.sum()
    x = apply_norm(cfg.norm, params["final_norm"], x)
    return x, (new_states if with_states else None), \
        (new_caches if caches is not None else None), aux_total


def _logits(params, x, cfg: ModelConfig, policy):
    head = params["embed"]["w"] if cfg.tie_embeddings else params["lm_head"]["w"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)
    if cfg.logit_softcap > 0:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(logits, policy, "batch", "seq", "model")


def lm_forward(params, tokens, cfg: ModelConfig, *, states=None, caches=None,
               pos=None, policy: MeshPolicy | None = None):
    """tokens (B, S) -> logits (B, S, V). Returns (logits, states, caches, aux).

    Float ``tokens`` are treated as precomputed embeddings (B, S, d) — the
    modality-frontend stub path for VLM backbones (internvl2)."""
    if jnp.issubdtype(tokens.dtype, jnp.floating):
        x = tokens.astype(jnp.dtype(cfg.dtype))
    else:
        x = params["embed"]["w"].astype(jnp.float32)[tokens].astype(
            jnp.dtype(cfg.dtype))
    x = shard(x, policy, "batch", "seq", None)
    x, ns, nc, aux = lm_backbone(params, x, cfg, states=states, caches=caches,
                                 pos=pos, policy=policy)
    return _logits(params, x, cfg, policy), ns, nc, aux


def lm_loss(params, batch: dict, cfg: ModelConfig, *, states=None,
            policy: MeshPolicy | None = None):
    """Cross-entropy (fp32) + MoE aux. batch: {tokens (B,S), labels (B,S)}.
    Returns (loss, (new_states, metrics))."""
    logits, ns, _, aux = lm_forward(params, batch["tokens"], cfg,
                                    states=states, policy=policy)
    from repro.nn.losses import masked_xent

    mask = (batch["labels"] >= 0).astype(jnp.float32)
    ce = masked_xent(logits, jnp.maximum(batch["labels"], 0), mask)
    loss = ce + 0.01 * aux
    metrics = {"ce": ce, "aux": aux,
               "ppl_proxy": jnp.exp(jnp.minimum(ce, 20.0))}
    return loss, (ns, metrics)


def lm_decode_step(params, token, caches, pos, cfg: ModelConfig, *,
                   policy: MeshPolicy | None = None, page_table=None):
    """One serve step. token (B, 1) int32; pos: absolute position of this
    token — a scalar (lockstep batch) or a (B,) vector of per-slot positions
    (continuous batching: each serve slot is at its own depth).
    ``page_table`` (B, pages_per_slot) routes reads/writes through the
    paged KV pool when ``caches`` came from ``init_lm_cache(..., pages=)``.
    Returns (logits (B, V), new_caches)."""
    x = params["embed"]["w"].astype(jnp.float32)[token].astype(
        jnp.dtype(cfg.dtype))
    x, _, nc, _ = lm_backbone(params, x, cfg, states=None, caches=caches,
                              pos=pos, policy=policy, page_table=page_table)
    return _logits(params, x, cfg, policy)[:, 0], nc


def lm_prefill(params, tokens, cfg: ModelConfig, *, caches,
               valid_len=None, last_only: bool = False,
               policy: MeshPolicy | None = None,
               pos=None, page_table=None):
    """Token-parallel prefill: ONE forward over the whole prompt that also
    writes every layer's decode cache (KV slots — full and rolling — plus
    Mamba conv buffers and recurrent states) in the same pass. No per-token
    Python loop; decode continues from position ``tokens.shape[1]`` exactly
    as if the prompt had been scanned through ``lm_decode_step``.

    tokens (B, P) int32 prompts starting at absolute position 0; ``caches``
    from :func:`init_lm_cache`. ``valid_len`` (B,) gives per-row true prompt
    lengths when rows are right-padded to a common bucket length (serve
    admission): padded positions are masked out of cache writes and freeze
    recurrent states, so each row's caches match an exact-length prefill.

    Returns (logits, new_caches): logits (B, P, V), with the next-token
    logits for row b at ``logits[b, valid_len[b] - 1]`` (or ``[:, -1]``
    unpadded). ``last_only=True`` gathers each row's last VALID hidden state
    before the output projection and returns (B, 1, V) — serving only needs
    one next-token distribution per prompt, so this skips P-1 rows of vocab
    projection (with bucket-padded admission the saving is bucket-sized).

    Paged chunked prefill: with ``page_table`` and a paged cache, ``pos``
    is a (B,) vector of absolute chunk offsets and ``tokens`` is ONE chunk
    of a longer prompt; attention runs against the slot's whole logical
    cache (earlier chunks, shared prefix pages), so a prompt may prefill
    across several calls. ``valid_len`` then counts valid rows WITHIN the
    chunk, and the ``last_only`` gather picks the chunk's last valid row —
    only the final chunk's logits mean anything (the engine ignores the
    rest).

    Speculative verify: the serve engine's spec-decode path reuses this
    same entry point mid-decode — ``tokens`` is [last_committed, d_1..d_k]
    drafted ahead of position ``pos``, ``valid_len`` masks each row's true
    draft length, and the cache writes double as the rollback mechanism
    (accepted positions land exact full-precision KV; rejected tail
    positions are overwritten before they are ever attended to).
    """
    x = params["embed"]["w"].astype(jnp.float32)[tokens].astype(
        jnp.dtype(cfg.dtype))
    x = shard(x, policy, "batch", "seq", None)
    x, _, nc, _ = lm_backbone(params, x, cfg, states=None, caches=caches,
                              pos=0 if pos is None else pos, policy=policy,
                              valid_len=valid_len, page_table=page_table)
    if last_only:
        last = (jnp.full((x.shape[0],), x.shape[1] - 1, jnp.int32)
                if valid_len is None else valid_len - 1)
        x = jnp.take_along_axis(x, last[:, None, None], axis=1)   # (B, 1, d)
    return _logits(params, x, cfg, policy), nc


def count_params(params) -> int:
    import numpy as np

    return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))
