"""Model zoo: unified decoder LM, encoder-decoder (Whisper), ViT (paper)."""
