"""Per-kind transformer block init/apply, dispatched by BlockKind.

A block is the unit the layer-group scan iterates over. Every kind exposes:
    init_block(key, kind, cfg, dtype)                 -> params dict
    init_block_state(key, kind, cfg, B, S, dtype)     -> ASI states dict
    init_block_cache(kind, cfg, B, S, dtype)          -> decode cache
    apply_block(kind, params, x, cfg, ...)            -> (x, cache, states, aux)

zamba2's shared attention block (kind "mamba2_attn") closes over shared
params passed via ``shared`` — the weights are NOT stacked per layer (one
copy for the whole net, per the architecture), but each occurrence keeps its
own KV cache.

Every projection inside a block binds through the SubspacePlan
(``api.plan_of(cfg)`` in the nn layers): which subspace a linear lives in
(dense / factored / project, rank, kernel route) is resolved ONCE per
config — blocks never inspect param layouts (docs/api.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.distributed.sharding import MeshPolicy
from repro.nn.attention import (
    KVCache,
    apply_attention,
    init_attention,
    init_attention_state,
    init_cache,
)
from repro.nn.mamba import (
    MambaState,
    apply_mamba1,
    apply_mamba2,
    init_mamba1,
    init_mamba1_cache,
    init_mamba1_state,
    init_mamba2,
    init_mamba2_cache,
    init_mamba2_state,
)
from repro.nn.mlp import apply_mlp, init_mlp, init_mlp_state
from repro.nn.moe import apply_moe, init_moe
from repro.nn.norms import apply_norm, init_norm

ATTN_KINDS = ("dense", "local", "moe", "moe_swa")
MAMBA_KINDS = ("mamba1", "mamba2", "mamba2_attn")


def block_window(kind: str, cfg: ModelConfig) -> int:
    return cfg.window if kind in ("local", "moe_swa") else 0


def init_block(key, kind: str, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d = cfg.d_model
    if kind in ("dense", "local"):
        return {"ln1": init_norm(cfg.norm, d, dtype),
                "attn": init_attention(k1, cfg, dtype),
                "ln2": init_norm(cfg.norm, d, dtype),
                "mlp": init_mlp(k2, cfg, dtype=dtype)}
    if kind in ("moe", "moe_swa"):
        return {"ln1": init_norm(cfg.norm, d, dtype),
                "attn": init_attention(k1, cfg, dtype),
                "ln2": init_norm(cfg.norm, d, dtype),
                "moe": init_moe(k2, cfg, dtype)}
    if kind == "mamba1":
        return {"ln": init_norm(cfg.norm, d, dtype),
                "mixer": init_mamba1(k1, cfg, dtype)}
    if kind in ("mamba2", "mamba2_attn"):
        return {"ln": init_norm(cfg.norm, d, dtype),
                "mixer": init_mamba2(k1, cfg, dtype)}
    raise ValueError(f"unknown block kind {kind}")


def init_block_state(key, kind: str, cfg: ModelConfig, batch: int, seq: int,
                     dtype=jnp.float32) -> dict:
    k1, k2 = jax.random.split(key)
    if kind in ("dense", "local"):
        return {"attn": init_attention_state(k1, cfg, batch, seq, dtype),
                "mlp": init_mlp_state(k2, cfg, batch, seq, dtype=dtype)}
    if kind in ("moe", "moe_swa"):
        return {"attn": init_attention_state(k1, cfg, batch, seq, dtype)}
    if kind == "mamba1":
        return {"mixer": init_mamba1_state(k1, cfg, batch, seq, dtype)}
    if kind == "mamba2":
        return {"mixer": init_mamba2_state(k1, cfg, batch, seq, dtype)}
    if kind == "mamba2_attn":
        # shared attention runs without ASI (weights shared across layers;
        # per-occurrence warm-start states would defeat the sharing)
        return {"mixer": init_mamba2_state(k1, cfg, batch, seq, dtype),
                "shared_attn": {}}
    raise ValueError(kind)


def init_block_cache(kind: str, cfg: ModelConfig, batch: int, seq: int,
                     dtype=jnp.bfloat16):
    if kind in ATTN_KINDS:
        return {"kv": init_cache(cfg, batch, seq, window=block_window(kind, cfg),
                                 dtype=dtype)}
    if kind == "mamba1":
        return {"ssm": init_mamba1_cache(cfg, batch, dtype)}
    if kind == "mamba2":
        return {"ssm": init_mamba2_cache(cfg, batch, dtype)}
    if kind == "mamba2_attn":
        # shared attention block sees the FULL sequence (global)
        return {"ssm": init_mamba2_cache(cfg, batch, dtype),
                "kv": init_cache(cfg, batch, seq, window=0, dtype=dtype)}
    raise ValueError(kind)


def apply_block(kind: str, p: dict, x: jax.Array, cfg: ModelConfig, *,
                shared: dict | None = None,
                cache: dict | None = None, pos=None,
                states: dict | None = None,
                policy: MeshPolicy | None = None,
                valid_len: jax.Array | None = None,
                page_table: jax.Array | None = None):
    """Returns (x, new_cache, new_states, aux_loss).

    With a cache and S > 1 this is a token-parallel PREFILL step: the block
    attends/scans over the whole prompt and writes its decode cache in the
    same pass. ``valid_len`` (B,) masks right-padded rows (length-bucketed
    serve admission) out of cache writes and recurrent-state updates.
    ``page_table`` (B, pages_per_slot) rides along when the cache is the
    paged pool (nn/attention.py::PagedKVCache) — one table serves every
    layer, since page allocation is layer-independent."""
    st = states or {}
    new_st = {}
    aux = jnp.zeros((), jnp.float32)
    window = block_window(kind, cfg)

    if kind in ATTN_KINDS:
        h = apply_norm(cfg.norm, p["ln1"], x)
        a, new_kv, s_attn = apply_attention(
            p["attn"], h, cfg, causal=True, window=window,
            cache=None if cache is None else cache["kv"], pos=pos,
            states=st.get("attn"), policy=policy, valid_len=valid_len,
            page_table=page_table)
        new_st["attn"] = s_attn
        x = x + a
        h = apply_norm(cfg.norm, p["ln2"], x)
        if kind in ("moe", "moe_swa"):
            f, aux = apply_moe(p["moe"], h, cfg, policy)
        else:
            f, s_mlp = apply_mlp(p["mlp"], h, cfg, st.get("mlp"), policy)
            new_st["mlp"] = s_mlp
        x = x + f
        new_cache = None if cache is None else {"kv": new_kv}
        return x, new_cache, new_st, aux

    if kind in MAMBA_KINDS:
        h = apply_norm(cfg.norm, p["ln"], x)
        fn = apply_mamba1 if kind == "mamba1" else apply_mamba2
        m, new_ssm, s_m = fn(p["mixer"], h, cfg,
                             state=None if cache is None else cache["ssm"],
                             states=st.get("mixer"), policy=policy,
                             valid_len=valid_len)
        new_st["mixer"] = s_m
        x = x + m
        new_cache = None if cache is None else {"ssm": new_ssm}
        if kind == "mamba2_attn":
            # zamba2: shared transformer block (attn + MLP) after the mixer;
            # weights shared across all occurrences, caches per-occurrence.
            h = apply_norm(cfg.norm, shared["ln"], x)
            a, new_kv, s_sh = apply_attention(
                shared["attn"], h, cfg, causal=True, window=0,
                cache=None if cache is None else cache["kv"], pos=pos,
                states=st.get("shared_attn"), policy=policy,
                valid_len=valid_len)
            new_st["shared_attn"] = s_sh
            x = x + a
            h = apply_norm(cfg.norm, shared["ln2"], x)
            f, _ = apply_mlp(shared["mlp"], h, cfg, None, policy)
            x = x + f
            if new_cache is not None:
                new_cache["kv"] = new_kv
        return x, new_cache, new_st, aux

    raise ValueError(kind)
