"""Gradient clipping (paper §B.1: L2 clip at 2.0)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves))) if leaves else jnp.zeros(())


def clip_by_global_norm(tree, max_norm: float):
    """Returns (clipped_tree, pre_clip_norm)."""
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        tree), n
