"""Optimizers built from scratch: SGD(+momentum) — the paper's recipe — and
AdamW for the scale configs. fp32 master statistics over bf16 params.

WASI synergy: for factored layers the optimizer state lives on (L, R), i.e.
K(O+I) elements instead of O*I — momentum/adam memory shrinks by the same
ratio as the weights (reported by benchmarks/fig5_tab1_resources.py).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


class OptState(NamedTuple):
    step: jax.Array
    mu: object        # first moment / momentum (pytree or None)
    nu: object        # second moment (adamw only; pytree or None)


def init_optimizer(params, cfg: TrainConfig) -> OptState:
    zeros = lambda: jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    if cfg.optimizer == "sgd":
        mu = zeros() if cfg.momentum > 0 else None
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu=None)
    if cfg.optimizer == "adamw":
        return OptState(step=jnp.zeros((), jnp.int32), mu=zeros(), nu=zeros())
    raise ValueError(cfg.optimizer)


def optimizer_update(params, grads, state: OptState, cfg: TrainConfig, lr):
    """Returns (new_params, new_state). Decoupled weight decay on both."""
    step = state.step + 1
    wd = cfg.weight_decay

    if cfg.optimizer == "sgd":
        if cfg.momentum > 0:
            mu = jax.tree.map(
                lambda m, g: cfg.momentum * m + g.astype(jnp.float32),
                state.mu, grads)
            upd = mu
        else:
            mu = None
            upd = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
                          ).astype(p.dtype),
            params, upd)
        return new_params, OptState(step=step, mu=mu, nu=None)

    # adamw
    b1, b2, eps = 0.9, 0.95, 1e-8
    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                      state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                      state.nu, grads)
    c1 = 1 - b1 ** step.astype(jnp.float32)
    c2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / c1
        vhat = v / c2
        return (p.astype(jnp.float32)
                - lr * (mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32))
                ).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step=step, mu=mu, nu=nu)
