from repro.optim.optimizers import (
    OptState,
    init_optimizer,
    optimizer_update,
)
from repro.optim.schedule import cosine_schedule, make_schedule
from repro.optim.clip import clip_by_global_norm, global_norm
