"""LR schedules (paper §B.1: cosine annealing from 0.05)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.config import TrainConfig


def cosine_schedule(step, base_lr: float, total_steps: int, warmup: int = 0,
                    final_frac: float = 0.0):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0) if warmup > 0 else 1.0
    t = jnp.clip((step - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return base_lr * warm * (final_frac + (1 - final_frac) * cos)


def make_schedule(cfg: TrainConfig):
    if cfg.schedule == "constant":
        return lambda step: jnp.asarray(cfg.lr, jnp.float32)
    return lambda step: cosine_schedule(step, cfg.lr, cfg.steps, cfg.warmup)
