#!/usr/bin/env python
"""Docs checks for CI: markdown link integrity + EXECUTABLE snippets.

Two passes over the repo's markdown (supersedes check_md_links.py):

1. **Links** — every relative link/image target in README + root *.md +
   docs/**/*.md must exist on disk (anchors stripped; external
   http(s)/mailto links skipped — CI must not depend on network; absolute
   paths flagged, they break on clones).

2. **Snippets** — every fenced ```python block in docs/**/*.md is extracted
   and EXECUTED. Blocks within one page are concatenated in order and run
   as one script in a fresh subprocess (so a page reads like a session:
   imports at the top, later blocks build on earlier ones), with
   PYTHONPATH=src:. and CWD=repo root — exactly the environment the docs
   tell readers to use. A page whose snippets exit non-zero fails CI, so
   documented code cannot rot.

   Opt-outs are deliberate and visible: a fence tagged ``python no-run``
   is extracted but not executed (use sparingly — e.g. TPU-only code this
   CPU host cannot run). Plain ``python`` always runs. Keep snippets
   smoke-sized: the whole docs job budget is minutes, not hours.

Exit code 1 on any broken link or failing snippet.
"""
from __future__ import annotations

import argparse
import re
import subprocess
import sys
import tempfile
import os
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
FENCE_RE = re.compile(r"^```(\S+(?:[ \t]+\S+)*)?[ \t]*$")
ROOT = Path(__file__).resolve().parent.parent
SNIPPET_TIMEOUT_S = 600


# ---------------------------------------------------------------------------
# Pass 1: links
# ---------------------------------------------------------------------------

def md_files() -> list[Path]:
    files = [p for p in ROOT.glob("*.md")]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return files


def check_links(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        if target.startswith("/"):
            errors.append(f"{path.relative_to(ROOT)}: absolute link {target}")
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link {target}")
    return errors


# ---------------------------------------------------------------------------
# Pass 2: snippets
# ---------------------------------------------------------------------------

def extract_snippets(path: Path) -> list[tuple[int, str, bool]]:
    """[(start_line, source, runnable)] for every ```python fence."""
    out = []
    lines = path.read_text(encoding="utf-8").splitlines()
    i = 0
    while i < len(lines):
        m = FENCE_RE.match(lines[i])
        if m and m.group(1):
            info = m.group(1).split()
            if info[0] == "python":
                runnable = "no-run" not in info[1:]
                body, start = [], i + 1
                i += 1
                while i < len(lines) and not lines[i].startswith("```"):
                    body.append(lines[i])
                    i += 1
                out.append((start + 1, "\n".join(body), runnable))
        i += 1
    return out


def run_page_snippets(path: Path) -> list[str]:
    """Concatenate a page's runnable ```python blocks and execute them as
    one script in a subprocess. Returns error strings (empty = pass)."""
    snippets = extract_snippets(path)
    runnable = [(ln, src) for ln, src, run in snippets if run]
    if not runnable:
        return []
    parts = [f"# --- {path.name}:{ln} ---\n{src}" for ln, src in runnable]
    script = "\n\n".join(parts) + "\n"
    env = dict(os.environ)
    env["PYTHONPATH"] = f"src:.:{env.get('PYTHONPATH', '')}".rstrip(":")
    with tempfile.NamedTemporaryFile("w", suffix=f"_{path.stem}.py",
                                     delete=False) as f:
        f.write(script)
        tmp = f.name
    try:
        proc = subprocess.run([sys.executable, tmp], cwd=ROOT, env=env,
                              capture_output=True, text=True,
                              timeout=SNIPPET_TIMEOUT_S)
    except subprocess.TimeoutExpired:
        return [f"{path.relative_to(ROOT)}: snippets timed out "
                f"(> {SNIPPET_TIMEOUT_S}s) — keep docs code smoke-sized"]
    finally:
        os.unlink(tmp)
    if proc.returncode != 0:
        tail = "\n".join(proc.stderr.strip().splitlines()[-12:])
        return [f"{path.relative_to(ROOT)}: snippets failed "
                f"(exit {proc.returncode}):\n{tail}"]
    return []


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--links-only", action="store_true")
    ap.add_argument("--snippets-only", action="store_true")
    args = ap.parse_args()

    errors: list[str] = []
    if not args.snippets_only:
        files = md_files()
        for f in files:
            errors += check_links(f)
        print(f"[docs] link check: {len(files)} files, "
              f"{len(errors)} broken link(s)")
    if not args.links_only:
        pages = sorted((ROOT / "docs").glob("**/*.md"))
        for page in pages:
            n = len([1 for _, _, run in extract_snippets(page) if run])
            errs = run_page_snippets(page)
            errors += errs
            status = "FAIL" if errs else "ok"
            print(f"[docs] snippets: {page.relative_to(ROOT)} "
                  f"({n} block(s)) {status}")
    for e in errors:
        print(f"[docs] {e}", file=sys.stderr)
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
