#!/usr/bin/env python
"""Markdown link check for README + docs/ (CI docs job).

Verifies that every relative link/image target in the repo's markdown
files exists on disk (anchors are stripped; external http(s)/mailto links
are skipped — CI must not depend on network). Also flags absolute-path
links, which would break on clones. Exit code 1 on any broken link.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
ROOT = Path(__file__).resolve().parent.parent


def md_files() -> list[Path]:
    files = [p for p in ROOT.glob("*.md")]
    files += sorted((ROOT / "docs").glob("**/*.md"))
    return files


def check(path: Path) -> list[str]:
    errors = []
    text = path.read_text(encoding="utf-8")
    for m in LINK_RE.finditer(text):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        if target.startswith("/"):
            errors.append(f"{path.relative_to(ROOT)}: absolute link {target}")
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (path.parent / rel).exists():
            errors.append(f"{path.relative_to(ROOT)}: broken link {target}")
    return errors


def main() -> int:
    errors = []
    files = md_files()
    for f in files:
        errors += check(f)
    for e in errors:
        print(f"[md-links] {e}", file=sys.stderr)
    print(f"[md-links] checked {len(files)} files, "
          f"{len(errors)} broken link(s)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
