#!/usr/bin/env python
"""Trend gate over benchmark JSON (schema v3, benchmarks/common.py).

``python scripts/bench_gate.py NEW.json [--baseline BENCH_serve.json]``
``python scripts/bench_gate.py BENCH_train_new.json --suite train``

Two suites share the machinery: ``serve`` (tab2_latency.py vs
BENCH_serve.json, the default) and ``train`` (fig_comm.py vs
BENCH_train.json — DP collective bytes, where MORE bytes is the harmful
direction). Fails LOUDLY (non-zero exit, one line per violation) when a
gated metric regresses beyond tolerance. Two kinds of checks:

* ABSOLUTE bars on host-load-invariant RATIOS — the acceptance criteria
  themselves, checked on every run regardless of baseline:
    - chunked-prefill TPOT tax: ``tpot_p95_ratio`` <= 1.5 (a mixed trace
      with an 8k prefill in flight vs the no-long-prompt baseline);
    - paged decode overhead: ``paged_over_dense`` >= 0.5 (the page-table
      gather must not halve decode throughput);
    - prefix attach win: ``cold_over_hit`` >= 2 and ``prefix_hit_tokens``
      >= 8000 (an 8k shared prefix must actually attach, not re-prefill);
    - speculative decode: ``greedy_match`` == 1 at k in {2, 4} (greedy
      spec decode is LOSSLESS by construction — any mismatch is a bug,
      not a regression) and ``acceptance_rate`` >= 0.5 (the int8 draft of
      a trained model must actually predict its own f32 argmax).

* RELATIVE drift vs the committed baseline, ratio metrics only — raw
  microsecond columns vary with runner hardware and are NOT gated, so a
  slower CI machine cannot fake a regression; a changed engine can.

Exit codes: 0 clean, 1 violations, 2 malformed input.
"""
from __future__ import annotations

import argparse
import json
import sys

# (record name, key, op, bound) — op "max": value must be <= bound,
# "min": value must be >= bound
ABSOLUTE_BARS = [
    ("tab2/serve_chunked_mixed", "tpot_p95_ratio", "max", 1.5),
    ("tab2/serve_paged_decode", "paged_over_dense", "min", 0.5),
    ("tab2/serve_prefix_attach_8k", "cold_over_hit", "min", 2.0),
    ("tab2/serve_prefix_attach_8k", "prefix_hit_tokens", "min", 8000),
    ("tab2/serve_spec_decode_k2", "greedy_match", "min", 1),
    ("tab2/serve_spec_decode_k4", "greedy_match", "min", 1),
    ("tab2/serve_spec_decode_k4", "acceptance_rate", "min", 0.5),
    # tenancy: mixed-tenant greedy decoding is LOSSLESS vs per-tenant solo
    # engines by construction; an int8-stored adapter must actually pack
    ("tab2/serve_tenancy_mixed", "tenant_greedy_match", "min", 1),
    ("tab2/serve_tenancy_mixed", "mixed_over_solo_tpot", "max", 1.6),
    ("tab2/serve_tenancy_adapter_bytes", "int8_over_f32_bytes", "max", 0.5),
]

# ratio metrics allowed to drift at most this factor vs the baseline
RELATIVE_KEYS = [
    ("tab2/serve_chunked_mixed", "tpot_p95_ratio"),
    ("tab2/serve_paged_decode", "paged_over_dense"),
    ("tab2/serve_spec_decode_k2", "acceptance_rate"),
    ("tab2/serve_spec_decode_k4", "acceptance_rate"),
    ("tab2/serve_spec_decode_k2", "spec_tpot_ratio"),
    ("tab2/serve_spec_decode_k4", "spec_tpot_ratio"),
    ("tab2/serve_tenancy_mixed", "mixed_over_solo_tpot"),
]
RELATIVE_TOLERANCE = 1.35

# -- train suite (benchmarks/fig_comm.py -> BENCH_train.json) --------------
# The acceptance criterion itself, as an absolute bar: MEASURED factor-only
# collective bytes strictly below the dense all-reduce (< 1, with margin so
# a rounding artifact cannot sneak a ~1.0 through), ditto PowerSGD.
_COMM_ROW = "comm/train_dp8_qwen2-0.5b_smoke"
# input pipeline (benchmarks/bench_input.py): the prefetcher must hide
# the host-side tokenize/pack work behind the device step — a stall
# fraction above 0.15 means streamed text taxes every training run
_INPUT_ROW = "input/train_stream_qwen2-0.5b_smoke"
ABSOLUTE_BARS_TRAIN = [
    (_COMM_ROW, "factor_over_dense_bytes", "max", 0.999),
    (_COMM_ROW, "powersgd_over_dense_bytes", "max", 0.999),
    (_INPUT_ROW, "train_input_stall_frac", "max", 0.15),
]
RELATIVE_KEYS_TRAIN = [
    (_COMM_ROW, "train_comm_dense_bytes"),
    (_COMM_ROW, "train_comm_factor_bytes"),
    (_COMM_ROW, "train_comm_powersgd_bytes"),
    (_COMM_ROW, "factor_over_dense_bytes"),
    (_COMM_ROW, "dp_step_ratio"),
    # NOTE: train_input_tok_s is deliberately NOT here — raw throughput
    # varies with runner hardware (same reason us columns aren't gated);
    # the load-invariant claim is the stall-fraction absolute bar above
]

# keys where a LARGER value is the harmful direction (latency-style
# ratios, and collective BYTE counts — extra traffic is the regression);
# everything else regresses by shrinking (throughput, acceptance)
REGRESS_UP_KEYS = {"tpot_p95_ratio", "spec_tpot_ratio",
                   "mixed_over_solo_tpot",
                   "train_comm_dense_bytes", "train_comm_factor_bytes",
                   "train_comm_powersgd_bytes", "factor_over_dense_bytes",
                   "powersgd_over_dense_bytes", "dp_step_ratio",
                   "train_input_stall_frac"}

SUITES = {
    "serve": (ABSOLUTE_BARS, RELATIVE_KEYS, "BENCH_serve.json"),
    "train": (ABSOLUTE_BARS_TRAIN, RELATIVE_KEYS_TRAIN, "BENCH_train.json"),
}

# rows deliberately deleted from the benchmark suite: a baseline row
# missing from the current run fails the gate UNLESS listed here (or
# passed via --retire) — renaming/dropping a row must be an explicit
# decision, never a silent skip that masks a dead benchmark
RETIRED_ROWS: set[str] = set()


def load(path: str) -> dict:
    with open(path) as f:
        payload = json.load(f)
    if "records" not in payload:
        sys.exit(f"bench_gate: {path} has no 'records' (schema v3 expected)")
    return {r["name"]: r for r in payload["records"]}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("new", help="freshly produced benchmark JSON")
    ap.add_argument("--suite", default="serve", choices=sorted(SUITES),
                    help="which bar/drift table to apply (default: serve)")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline to diff ratio metrics against "
                         "(default: the suite's committed BENCH_*.json; "
                         "'' skips the relative checks)")
    ap.add_argument("--retire", default="",
                    help="comma-separated row names retired this run (on "
                         "top of RETIRED_ROWS) — missing-vs-baseline "
                         "failures are waived for them")
    args = ap.parse_args()
    retired = RETIRED_ROWS | {n for n in args.retire.split(",") if n}
    bars, relative_keys, default_baseline = SUITES[args.suite]
    if args.baseline is None:
        args.baseline = default_baseline

    try:
        new = load(args.new)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench_gate: cannot read {args.new}: {e}", file=sys.stderr)
        return 2

    bad = []
    for name, key, op, bound in bars:
        rec = new.get(name)
        if rec is None or key not in rec:
            bad.append(f"MISSING {name}:{key} — the serve benchmark no "
                       "longer emits the gated metric")
            continue
        v = rec[key]
        ok = v <= bound if op == "max" else v >= bound
        if not ok:
            sign = "<=" if op == "max" else ">="
            bad.append(f"ABSOLUTE {name}:{key} = {v} violates {sign} {bound}")

    if args.baseline:
        try:
            base = load(args.baseline)
        except (OSError, json.JSONDecodeError) as e:
            print(f"bench_gate: cannot read baseline {args.baseline}: {e}",
                  file=sys.stderr)
            return 2
        # every baseline row must still exist (or be explicitly retired) —
        # a silently vanished row is how a dead benchmark masks a real
        # regression behind it
        for name in sorted(set(base) - set(new) - retired):
            bad.append(f"MISSING_VS_BASELINE {name} — row exists in "
                       f"{args.baseline} but the current run did not emit "
                       "it; retire it explicitly (--retire or "
                       "RETIRED_ROWS) if that is intended")
        for name, key in relative_keys:
            if name in retired or name not in new or name not in base:
                continue
            v, b = new[name].get(key), base[name].get(key)
            if v is None or b is None or b == 0:
                continue
            # direction-aware: tpot-style ratios regress UP, throughput
            # and acceptance regress DOWN — flag only the harmful direction
            worse = v / b if key in REGRESS_UP_KEYS else b / v
            if worse > RELATIVE_TOLERANCE:
                bad.append(f"RELATIVE {name}:{key} = {v} vs baseline {b} "
                           f"(x{worse:.2f} worse > x{RELATIVE_TOLERANCE} "
                           "tolerance)")

    if bad:
        print("bench_gate: FAIL", file=sys.stderr)
        for line in bad:
            print(f"  {line}", file=sys.stderr)
        return 1
    print(f"bench_gate: OK [{args.suite}] "
          f"({len(bars)} absolute bars"
          + (f", {len(relative_keys)} relative checks" if args.baseline
             else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
