"""Batched serving with WASI-factored weights: prefill a batch of prompts,
decode new tokens, report tok/s (paper's C_inference/S_inference in action).

  PYTHONPATH=src python examples/serve_lm.py --batch 4 --tokens 32
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.launch.serve import generate
from repro.models.lm import count_params, init_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    for method in ("wasi", "none"):
        cfg = configs.get_smoke(args.arch)
        cfg = cfg.replace(wasi=dataclasses.replace(cfg.wasi, method=method))
        api.install(api.resolve(cfg))  # one subspace decision per method
        params = init_lm(key, cfg, jnp.dtype(cfg.dtype))
        prompt = jax.random.randint(key, (args.batch, 8), 0, cfg.vocab_size)
        # warmup compile
        generate(params, cfg, prompt, max_cache=8 + args.tokens + 1, n_new=2)
        t0 = time.time()
        out = generate(params, cfg, prompt, max_cache=8 + args.tokens + 1,
                       n_new=args.tokens)
        dt = time.time() - t0
        n = args.batch * args.tokens
        print(f"[serve_lm] {method:5s} params={count_params(params):,} "
              f"{n} tokens in {dt:.2f}s = {n / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
