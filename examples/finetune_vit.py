"""The paper's main setting: fine-tune a ViT, vanilla vs WASI, and report
accuracy + memory/FLOPs ratios (paper Fig. 5 shape).

  PYTHONPATH=src:. python examples/finetune_vit.py [--eps 0.8] [--steps 60]
"""
import argparse
import dataclasses

import jax

import repro.configs as configs
from repro import api
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticVision
from repro.models.vit import init_vit, init_vit_states, vit_loss
from repro.train.step import make_train_state, make_train_step


def train(cfg, steps, label):
    key = jax.random.PRNGKey(233)
    api.install(api.resolve(cfg, batch=16, seq=17))
    n_classes, n_patches, patch_dim = 4, 16, 24
    params = init_vit(key, cfg, n_classes, patch_dim, n_patches)
    states = init_vit_states(key, cfg, 16, n_patches) \
        if cfg.wasi.compress_acts else None
    tcfg = TrainConfig(optimizer="sgd", lr=0.05, momentum=0.9, steps=steps,
                       checkpoint_every=0)  # paper §B.1 recipe
    state = make_train_state(key, params, cfg, tcfg, asi_states=states)
    step = jax.jit(make_train_step(vit_loss, cfg, tcfg))
    data = SyntheticVision(n_classes=n_classes, n_patches=n_patches,
                           patch_dim=patch_dim, global_batch=16, seed=0,
                           noise=0.5)
    accs = []
    for i in range(steps):
        state, m = step(state, data.batch(i))
        accs.append(float(m["acc"]))
    acc = sum(accs[-8:]) / 8
    print(f"[{label}] final acc {acc:.3f}")
    return acc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--eps", type=float, default=0.8)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    base = configs.get_smoke("vit-base")
    vanilla = base.replace(wasi=dataclasses.replace(base.wasi, method="none"))
    wasi = base.replace(wasi=dataclasses.replace(
        base.wasi, method="wasi", update_mode="project", epsilon=args.eps))

    a_v = train(vanilla, args.steps, "vanilla")
    a_w = train(wasi, args.steps, f"wasi eps={args.eps}")
    from benchmarks.fig2_ratios import flops_vanilla, flops_wasi, mem_ratios
    b, n, i, o = 16, 17, base.d_model, base.d_ff
    k = max(4, int(args.eps * 0.4 * min(i, o)))
    r = (b, n // 2, i // 2)
    fv, bv = flops_vanilla(b, n, i, o)
    fw, ow, bw = flops_wasi(b, n, i, o, k, r)
    ct, ci = mem_ratios(b, n, i, o, k, r)
    print(f"[ratios] S_train={(fv+bv)/(fw+ow+bw):.2f} C_train={ct:.1f} "
          f"C_inf={ci:.2f} | accuracy gap {a_v - a_w:+.3f}")


if __name__ == "__main__":
    main()
