"""60-second WASI quickstart: factor a linear layer, train a toy LM, watch
the subspace do the work.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.config import TrainConfig
from repro.core import pick_rank, truncated_svd, wsi_init, wsi_step
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_lm, init_lm_states, lm_loss
from repro.train.step import make_train_state, make_train_step


def main():
    key = jax.random.PRNGKey(0)

    # --- 1. the core idea on one matrix -----------------------------------
    w = jax.random.normal(key, (256, 256)) @ jnp.diag(0.9 ** jnp.arange(256))
    k = pick_rank(w, eps=0.8)
    st = wsi_init(w, k)
    print(f"[1] eps=0.8 keeps rank {k}/256; "
          f"factored storage = {k * 512}/{256 * 256} elements")
    w = w + 1e-3 * jax.random.normal(jax.random.PRNGKey(1), w.shape)
    st = wsi_step(w, st)  # one cheap iteration tracks the drifted subspace
    err = jnp.linalg.norm(w - st.L @ st.R) / jnp.linalg.norm(w)
    best = truncated_svd(w, k)
    err_best = jnp.linalg.norm(w - best.L @ best.R) / jnp.linalg.norm(w)
    print(f"[2] after a weight update: WSI err {float(err):.4f} "
          f"vs fresh-SVD optimum {float(err_best):.4f}")

    # --- 2. the SubspacePlan: decide every layer's subspace ONCE -----------
    cfg = configs.get_smoke("qwen2-0.5b")  # WASI on by default
    B, S = 8, 32
    plan = api.install(api.resolve(cfg, batch=B, seq=S))
    print("[plan]", plan.summary().replace("\n", "\n[plan] "))

    # --- 3. end-to-end: train a tiny LM with WASI --------------------------
    params = init_lm(key, cfg)   # layouts come from the installed plan
    states = init_lm_states(key, cfg, B, S)
    tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9, steps=40,
                       checkpoint_every=0)
    state = make_train_state(key, params, cfg, tcfg, asi_states=states)
    step = jax.jit(make_train_step(lm_loss, cfg, tcfg))
    data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=S, global_batch=B,
                       seed=1)
    for i in range(40):
        state, m = step(state, data.batch(i))
        if i % 10 == 0 or i == 39:
            print(f"[3] step {i:3d} loss {float(m['loss']):.4f} "
                  f"(weights factored, activations Tucker-compressed)")

    # --- 4. convert: densify the trained factored params via the plan ------
    from repro.api.convert import densify
    dense = densify(state.params, plan)
    n_dense = sum(int(x.size) for x in jax.tree.leaves(dense))
    n_fact = sum(int(x.size) for x in jax.tree.leaves(state.params))
    print(f"[4] densify(params, plan): {n_fact:,} factored params "
          f"-> {n_dense:,} dense (export-ready)")
    print("[5] done — see examples/finetune_vit.py for the paper's setting "
          "and docs/api.md for the plan lifecycle")


if __name__ == "__main__":
    main()
