"""End-to-end driver: train a ~100M-param decoder LM with WASI for a few
hundred steps on synthetic data, with plan-bearing checkpointing + restart.

  PYTHONPATH=src python examples/train_lm.py --steps 300
(defaults to a reduced model so it finishes on CPU; --d-model 768 --layers 12
gives the full ~100M configuration on beefier hosts; --smoke is the CI
configuration: tiny model, a handful of steps, exercising the whole public
API surface — plan resolve -> init -> train -> checkpoint -> serve restore)
"""
import argparse

import jax
import jax.numpy as jnp

from repro import api
from repro.config import LayerGroup, ModelConfig, TrainConfig, WasiConfig, AsiConfig
from repro.checkpoint import CheckpointManager
from repro.data.synthetic import SyntheticLM
from repro.models.lm import count_params, init_lm, init_lm_states, lm_loss
from repro.train.loop import train_loop
from repro.train.step import make_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="/tmp/repro_example_lm")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: tiny model, 12 steps, full plan ->"
                         " train -> checkpoint -> serve-restore round trip")
    args = ap.parse_args()
    if args.smoke:
        args.steps, args.d_model, args.layers = 12, 64, 2
        args.vocab, args.batch, args.seq = 512, 2, 16
        if args.ckpt == ap.get_default("ckpt"):
            args.ckpt += "_smoke"   # never restore a full-size run's ckpt
        import shutil
        shutil.rmtree(args.ckpt, ignore_errors=True)  # smoke runs are fresh

    cfg = ModelConfig(
        name="example-lm", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=max(2, args.d_model // 128),
        d_ff=args.d_model * 4, vocab_size=args.vocab, head_dim=64 if not args.smoke else 16,
        groups=(LayerGroup(("dense",), args.layers),),
        wasi=WasiConfig(method="wasi", scope="all", rank_frac=0.25,
                        rank_align=8, min_rank=8,
                        asi=AsiConfig(token_frac=0.25, feature_frac=0.25)),
        dtype="float32", remat="none")
    tcfg = TrainConfig(optimizer="adamw", lr=3e-3, steps=args.steps,
                       clip_norm=1.0, checkpoint_every=100 if not args.smoke else 8,
                       checkpoint_dir=args.ckpt)
    # ONE plan, resolved up front; the checkpoint manifest carries it
    plan = api.install(api.resolve(cfg, batch=args.batch, seq=args.seq))
    key = jax.random.PRNGKey(tcfg.seed)
    params = init_lm(key, cfg)
    print(f"[train_lm] params: {count_params(params):,}")
    states = init_lm_states(key, cfg, args.batch, args.seq)
    state = make_train_state(key, params, cfg, tcfg, asi_states=states)
    step = make_train_step(lm_loss, cfg, tcfg)
    data = SyntheticLM(vocab_size=args.vocab, seq_len=args.seq,
                       global_batch=args.batch, seed=tcfg.seed)
    ckpt = CheckpointManager(args.ckpt, keep=2, plan=plan, label="train_state")
    state, hist = train_loop(state, step, lambda s: data.batch(s), tcfg,
                             ckpt=ckpt, log_every=20 if not args.smoke else 4)
    if hist:
        print(f"[train_lm] CE {hist[0]['ce']:.3f} -> {hist[-1]['ce']:.3f} "
              f"(log-vocab = {jnp.log(args.vocab):.2f})")
    else:   # resumed at the final step: nothing left to train
        print(f"[train_lm] already trained to step {int(state.step)} "
              f"(checkpoint at {args.ckpt})")

    # the checkpoint is self-describing: restore it into the serve engine
    # with no config in hand (api/convert.py reads the manifest's plan)
    from repro.serve import ServeEngine
    engine = ServeEngine.from_checkpoint(args.ckpt, max_slots=2, max_cache=24)
    req = engine.submit([1, 2, 3], max_new=4)
    engine.run()
    print(f"[train_lm] serve-from-checkpoint OK: {req.tokens}")


if __name__ == "__main__":
    main()
