"""Paper Fig. 4: explained-variance spectra of activation-map modes.

Claim: most activation energy concentrates in the first few singular values
along every mode — that's what makes ASI's aggressive ranks viable. We
measure it on the smoke ViT's MLP input activations after brief training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.core.asi import _unfold
from repro.core.svd import explained_variance
from repro.data.synthetic import SyntheticVision
from repro.models.vit import init_vit, vit_forward


def run() -> list[str]:
    key = jax.random.PRNGKey(0)
    cfg = configs.get_smoke("vit-base")
    n_classes, n_patches, patch_dim = 4, 16, 24
    params = init_vit(key, cfg, n_classes, patch_dim, n_patches)
    data = SyntheticVision(n_classes=n_classes, n_patches=n_patches,
                           patch_dim=patch_dim, global_batch=16, seed=0)
    batch = data.batch(0)

    # capture the hidden states entering block 0's MLP
    x = jnp.einsum("bnp,dp->bnd", batch["patches"], params["patch"]["w"])
    cls = jnp.broadcast_to(params["cls"], (x.shape[0], 1, x.shape[-1]))
    a = jnp.concatenate([cls, x], axis=1) + params["pos"]

    rows = []
    for mode in range(3):
        am = _unfold(a, mode)
        s = jnp.linalg.svd(am, compute_uv=False)
        ev = explained_variance(s)
        top4 = float(jnp.sum(ev[:4]))
        half = int(jnp.argmax(jnp.cumsum(ev) >= 0.9)) + 1
        rows.append(
            f"fig4/mode{mode},0.0,dim={am.shape[0]};top4_ev={top4:.3f};"
            f"rank_for_90pct={half}")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
