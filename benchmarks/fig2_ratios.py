"""Paper Fig. 2: analytic compression/speedup ratio curves (Eqs. 33-46).

C_training / C_inference (memory) and S_training / S_inference (FLOPs) for a
linear layer as functions of the kept rank, across layer sizes. Validates
the qualitative claims: ratios grow as the model grows / rank shrinks, and
converge to 1 as rank -> full (paper §3.4).
"""
from __future__ import annotations


def flops_vanilla(b, n, i, o):
    return 2 * b * n * i * o, 4 * b * n * i * o  # fwd, bwd (Eq. 33-34)


def flops_wasi(b, n, i, o, k, r):
    r1, r2, r3 = r
    fwd = 2 * b * n * k * (i + o)                          # Eq. 35
    o_wsi = 4 * i * o * k + 2 * o * k * k                  # Eq. 36
    dims = (b, n, i)
    o_asi = 0
    for m, d in enumerate(dims):
        dp = 1
        for j, dd in enumerate(dims):
            if j != m:
                dp *= dd
        o_asi += 4 * d * dp * r[m] + 2 * d * r[m] ** 2     # Eq. 37
    bwd = 2 * b * n * k * (i + o) + b * n * o * r1 + r1 * r2 * r3 * n \
        + r1 * r3 * i * n + r1 * i * o * n                  # Eq. 38
    return fwd, o_wsi + o_asi, bwd


def mem_ratios(b, n, i, o, k, r):
    m_w_v, m_a_v = i * o, b * n * i                        # Eq. 41-42
    m_w_w = k * (i + o)                                    # Eq. 43
    r1, r2, r3 = r
    m_a_w = r1 * r2 * r3 + b * r1 + n * r2 + i * r3        # Eq. 44
    c_train = (m_w_v + m_a_v) / (m_w_w + m_a_w)            # Eq. 45
    c_inf = m_w_v / m_w_w                                  # Eq. 46
    return c_train, c_inf


def run() -> list[str]:
    rows = []
    b, n = 128, 197  # paper's ViT setting (batch 128, 196 patches + cls)
    for (i, o) in [(768, 3072), (3072, 768), (2048, 5632), (4096, 14336)]:
        full = min(i, o)
        for frac in (0.05, 0.125, 0.25, 0.5, 1.0):
            k = max(1, int(full * frac))
            r = (min(b, 32), max(1, int(n * frac)), max(1, int(i * frac)))
            fv, bv = flops_vanilla(b, n, i, o)
            fw, ow, bw = flops_wasi(b, n, i, o, k, r)
            s_train = (fv + bv) / (fw + ow + bw)            # Eq. 39
            s_inf = fv / fw                                 # Eq. 40
            c_train, c_inf = mem_ratios(b, n, i, o, k, r)
            rows.append(
                f"fig2/{i}x{o}/frac{frac},0.0,"
                f"S_train={s_train:.2f};S_inf={s_inf:.2f};"
                f"C_train={c_train:.1f};C_inf={c_inf:.2f}")
    # structural assertions from the paper's Fig. 2 narrative
    big = rows[-5]  # largest layer, smallest frac handled below
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
