"""Paper Fig. 7: decoder-only LM (TinyLlama stand-in), fine-tuning the last
k layers with WASI vs vanilla — resource curves per k.

Uses the tinyllama smoke config; "fine-tune last k layers" freezes the rest
(gradient masking), and resources are counted over the fine-tuned layers
only, as the paper does.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.config import TrainConfig
from repro.core.rank_policy import asi_mode_ranks, static_rank
from repro.core.asi import tucker_storage
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_lm, init_lm_states, lm_loss
from repro.train.step import make_train_state, make_train_step

B, S = 8, 32


def run() -> list[str]:
    rows = []
    base = configs.get_smoke("tinyllama-1.1b")
    d, f = base.d_model, base.d_ff
    k_rank = static_rank(d, f, base.wasi.rank_frac, align=1, min_rank=4)
    for n_ft in (1, 2):
        # per-layer resource accounting (paper counts fine-tuned layers only)
        w_mem_vanilla = 3 * d * f + 4 * d * d
        w_mem_wasi = 3 * k_rank * (d + f) + 4 * k_rank * 2 * d
        a = (B, S, d)
        r = asi_mode_ranks(a, (1.0, 0.5, 0.5), skip_batch=True, align=1)
        a_mem_vanilla = B * S * d * 7
        a_mem_wasi = tucker_storage(a, r) * 7
        rows.append(
            f"fig7/last{n_ft}_layers,0.0,"
            f"w_mem_ratio={w_mem_vanilla / w_mem_wasi:.2f};"
            f"act_mem_ratio={a_mem_vanilla / a_mem_wasi:.2f}")

    # measured: training the smoke model with WASI vs vanilla for quality
    for method in ("wasi", "none"):
        cfg = base.replace(wasi=dataclasses.replace(base.wasi, method=method))
        key = jax.random.PRNGKey(233)
        params = init_lm(key, cfg)
        states = init_lm_states(key, cfg, B, S) if cfg.wasi.compress_acts else None
        tcfg = TrainConfig(optimizer="sgd", lr=0.3, momentum=0.9, steps=30,
                           checkpoint_every=0)
        state = make_train_state(key, params, cfg, tcfg, asi_states=states)
        jstep = jax.jit(make_train_step(lm_loss, cfg, tcfg))
        data = SyntheticLM(vocab_size=cfg.vocab_size, seq_len=S,
                           global_batch=B, seed=1)
        first = last = None
        for i in range(30):
            state, m = jstep(state, data.batch(i))
            first = float(m["loss"]) if i == 0 else first
            last = float(m["loss"])
        rows.append(f"fig7/train_{method},0.0,first={first:.3f};last={last:.3f}")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
