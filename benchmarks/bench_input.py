"""Input-pipeline throughput: tokenize/pack/prefetch tokens/s and the
measured prefetch stall fraction under an overlapped training run.

MEASURED, not analytic — both numbers come out of the pipeline's own
telemetry (``DeviceIterator.stats()``, data/pipeline.py):

* ``train_input_tok_s`` — tokens/s the tokenize -> pack -> shuffle ->
  ``device_put`` pipeline sustains on its own (no model in the loop): the
  ceiling the input side offers the trainer;
* ``train_input_stall_frac`` — fraction of wall time the TRAIN loop spent
  blocked waiting on the host pipeline while actually training the smoke
  LM on streamed text (warmed up past jit compile, then measured). The
  acceptance criterion: < 0.15 — the background prefetcher must hide the
  host work behind the device step, or streaming text would tax every
  training run that uses it.

Emits a BENCH_train.json row (schema v3, benchmarks/common.py), gated by
``scripts/bench_gate.py --suite train`` (stall fraction regresses UP).
Like fig_comm.py this module owns its process and is NOT in run.py —
``--json`` MERGES its row into an existing records file (fig_comm's
output) rather than clobbering it.
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import jax

import repro.configs as configs
from benchmarks.common import (SCHEMA_VERSION, csv_row, row_to_record,
                               write_json)
from repro.config import TrainConfig

B, S = 8, 64
ARCH = "qwen2-0.5b"
ROW = f"input/train_stream_{ARCH}_smoke"
PIPE_BATCHES = 48       # pipeline-only measurement window
TRAIN_WARMUP = 3        # steps before the stall window opens (jit compile)
TRAIN_STEPS = 16        # measured overlapped steps


def _corpus() -> str:
    from repro.data.source import write_corpus
    root = os.path.join(tempfile.gettempdir(), "repro_bench_corpus")
    if not os.path.isdir(root) or not os.listdir(root):
        write_corpus(root, n_shards=4, docs_per_shard=256, seed=0)
    return root


def _dataset():
    from repro.data.registry import TextDataset
    return TextDataset(os.path.join(_corpus(), "*.txt"), seq_len=S,
                       global_batch=B, seed=0)


def _pipeline_only() -> tuple[float, float]:
    """(tok/s, us per batch) of the bare pipeline — no model consuming."""
    it = _dataset().iterator(prefetch=2)
    try:
        for _ in range(4):                  # shuffle-buffer fill + warmup
            it.next_batch()
        it.reset_stats()
        t0 = time.perf_counter()
        for _ in range(PIPE_BATCHES):
            it.next_batch()
        wall = time.perf_counter() - t0
        tok_s = it.stats()["tok_s"]
    finally:
        it.close()
    return tok_s, wall / PIPE_BATCHES * 1e6


def _overlapped_stall() -> float:
    """Stall fraction while the smoke LM actually trains on the stream."""
    from repro.models.lm import init_lm, lm_loss
    from repro.train.step import make_train_state, make_train_step

    ds = _dataset()
    cfg = configs.get_smoke(ARCH)
    if ds.vocab_size > cfg.vocab_size:
        cfg = cfg.replace(vocab_size=ds.vocab_size)
    from repro import api
    api.install(api.resolve(cfg, batch=B, seq=S))
    tcfg = TrainConfig(optimizer="sgd", lr=0.1, checkpoint_every=0)
    key = jax.random.PRNGKey(0)
    state = make_train_state(key, init_lm(key, cfg), cfg, tcfg)
    step = jax.jit(make_train_step(lm_loss, cfg, tcfg), donate_argnums=0)
    it = ds.iterator(prefetch=2)
    try:
        for _ in range(TRAIN_WARMUP):
            state, m = step(state, it.next_batch())
        jax.block_until_ready(m)
        it.reset_stats()
        for _ in range(TRAIN_STEPS):
            state, m = step(state, it.next_batch())
        jax.block_until_ready(m)
        stall = it.stats()["stall_frac"]
    finally:
        it.close()
    return stall


def run() -> list[str]:
    tok_s, us = _pipeline_only()
    stall = _overlapped_stall()
    derived = ";".join([
        f"train_input_tok_s={tok_s:.0f}",
        f"train_input_stall_frac={stall:.4f}",
        f"batch={B}", f"seq={S}", f"prefetch=2",
    ])
    return [csv_row(ROW, us, derived)]


def merge_json(path: str, records: list[dict]) -> None:
    """Merge records into ``path`` by row name (fig_comm's rows survive)."""
    existing: list[dict] = []
    if os.path.exists(path):
        with open(path) as f:
            payload = json.load(f)
        if payload.get("schema_version") != SCHEMA_VERSION:
            raise SystemExit(f"bench_input: {path} is schema "
                             f"{payload.get('schema_version')}, expected "
                             f"{SCHEMA_VERSION} — refusing to merge")
        new_names = {r["name"] for r in records}
        existing = [r for r in payload.get("records", [])
                    if r["name"] not in new_names]
    write_json(path, sorted(existing + records, key=lambda r: r["name"]))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="",
                    help="merge this row into a stable-schema JSON file "
                         "(creates it if absent)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    records = []
    for row in run():
        print(row)
        records.append(row_to_record(row))
    if args.json:
        merge_json(args.json, records)


if __name__ == "__main__":
    main()
