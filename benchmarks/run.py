"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Roofline terms come from the
dry-run (launch/dryrun.py + launch/roofline.py) — see EXPERIMENTS.md.
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    import benchmarks.fig2_ratios as fig2
    import benchmarks.fig3_wsi_vs_svd as fig3
    import benchmarks.fig4_activation_spectra as fig4
    import benchmarks.fig5_tab1_resources as fig5
    import benchmarks.fig7_tinyllama as fig7
    import benchmarks.tab2_latency as tab2

    print("name,us_per_call,derived")
    for mod in (fig2, fig4, fig3, fig7, tab2):
        try:
            for row in mod.run():
                print(row)
        except Exception:
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
            raise
    for row in fig5.run("mlp"):
        print(row)
    for row in fig5.run("all"):
        print(row.replace("fig5/", "tab1/"))


if __name__ == "__main__":
    main()
