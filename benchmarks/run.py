"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows; ``--json PATH`` additionally
writes every row as a record under the stable schema in
benchmarks/common.py (sorted keys, explicit units, measured-memory columns
``meas_*`` kept apart from analytic ones) so BENCH_*.json files diff
cleanly across commits. Roofline terms come from the dry-run
(launch/dryrun.py + launch/roofline.py) — see EXPERIMENTS.md.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    import benchmarks.fig2_ratios as fig2
    import benchmarks.fig3_wsi_vs_svd as fig3
    import benchmarks.fig4_activation_spectra as fig4
    import benchmarks.fig5_tab1_resources as fig5
    import benchmarks.fig7_tinyllama as fig7
    import benchmarks.tab2_latency as tab2
    from benchmarks.common import row_to_record, write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="", help="write stable-schema JSON")
    ap.add_argument("--fig5-steps", type=int, default=40)
    args = ap.parse_args()

    records = []
    print("name,us_per_call,derived")
    for mod in (fig2, fig4, fig3, fig7, tab2):
        try:
            for row in mod.run():
                print(row)
                records.append(row_to_record(row))
        except Exception:
            print(f"{mod.__name__},ERROR,", file=sys.stderr)
            traceback.print_exc()
            raise
    # fig5/tab1 produce structured records natively (measured memory rides
    # along); CSV is derived from them, not the other way around
    records += fig5.run_both(steps=args.fig5_steps)
    if args.json:
        write_json(args.json, records)
        print(f"[bench] wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
