"""Shared benchmark utilities: timing, CSV rows, and the stable JSON schema.

Benchmark output has two faces. The human one is the historical CSV
(``name,us_per_call,derived`` with ``k=v;k=v`` derived pairs). The machine
one is JSON with a STABLE schema so `BENCH_*.json` files from different
commits diff cleanly:

* top-level: {"schema_version", "units", "records"} — serialized with
  ``sort_keys=True`` and a fixed indent, so byte diffs are semantic diffs;
* every record is flat, keys sorted, numbers plain (no locale formatting);
* units are EXPLICIT in the key name where ambiguity is possible
  (``*_us``, ``*_mib``, ``*_bytes``) and summarized in the ``units`` map;
* measured-vs-analytic memory columns are distinguished by prefix:
  ``meas_*`` is an actual observation (utils/memprof.py), everything else
  is formula-derived. A measured value the backend cannot observe is
  ``null``, never an analytic stand-in.
"""
from __future__ import annotations

import json
import time

import jax

SCHEMA_VERSION = 3

UNITS = {
    "us_per_call": "microseconds (wall, median)",
    "*_us": "microseconds",
    "p50_*": "50th percentile over requests",
    "p95_*": "95th percentile over requests",
    "*_mib": "mebibytes (2**20 bytes)",
    "*_bytes": "bytes",
    "*_flops": "floating-point operations",
    "acc": "fraction in [0, 1]",
    "meas_*": "measured (utils/memprof.py); null = backend cannot observe",
}


def time_call(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time (us) of a jitted call."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"


def _parse_scalar(v: str):
    try:
        f = float(v)
    except ValueError:
        return v
    return int(f) if f.is_integer() and "." not in v and "e" not in v.lower() \
        else f


def row_to_record(row: str) -> dict:
    """Parse a ``name,us_per_call,derived`` CSV row into a flat record."""
    name, us, derived = row.split(",", 2)
    rec: dict = {"name": name}
    try:
        rec["us_per_call"] = float(us)
    except ValueError:
        rec["us_per_call"] = None
    for pair in filter(None, derived.split(";")):
        if "=" in pair:
            k, v = pair.split("=", 1)
            rec[k] = _parse_scalar(v)
    return rec


def write_json(path: str, records: list[dict]) -> None:
    """Write records under the stable schema (sorted keys, fixed indent)."""
    payload = {"schema_version": SCHEMA_VERSION, "units": UNITS,
               "records": records}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
