"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time (us) of a jitted call."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    times.sort()
    return times[len(times) // 2]


def csv_row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
