"""Paper Tab. 2 / Fig. 8: wall-clock per-iteration train + inference time,
WASI vs ASI vs vanilla across eps (the CPU host stands in for the paper's
Raspberry Pi — same relative comparison, different absolute scale).

Serving columns (beyond-paper): prefill throughput of the token-parallel
path vs the seed's scanned (token-by-token) prefill, steady-state decode
throughput, engine requests/sec, and the fused vs two-launch lowrank
kernel.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro import api
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticLM
from repro.models.lm import (
    init_lm,
    init_lm_cache,
    init_lm_states,
    lm_decode_step,
    lm_forward,
    lm_loss,
    lm_prefill,
)
from repro.serve import ServeEngine
from repro.train.step import make_train_state, make_train_step
from benchmarks.common import time_call

B, S = 8, 64
SERVE_B, SERVE_P, SERVE_NEW = 4, 32, 16


def run() -> list[str]:
    rows = []
    base = configs.get_smoke("qwen2-0.5b")
    data = SyntheticLM(vocab_size=base.vocab_size, seq_len=S, global_batch=B,
                       seed=1)
    batch = data.batch(0)
    for method, frac in [("none", 1.0), ("asi", 1.0), ("wasi", 0.25),
                         ("wasi", 0.5)]:
        cfg = base.replace(wasi=dataclasses.replace(
            base.wasi, method=method, rank_frac=frac))
        key = jax.random.PRNGKey(0)
        params = init_lm(key, cfg)
        states = init_lm_states(key, cfg, B, S) if cfg.wasi.compress_acts else None
        tcfg = TrainConfig(optimizer="sgd", lr=0.05, checkpoint_every=0)
        state = make_train_state(key, params, cfg, tcfg, asi_states=states)
        jstep = jax.jit(make_train_step(lm_loss, cfg, tcfg))
        t_train = time_call(jstep, state, batch)
        fwd = jax.jit(lambda p, t: lm_forward(p, t, cfg)[0])
        t_infer = time_call(fwd, params, batch["tokens"])
        name = f"{method}" + (f"_frac{frac}" if method == "wasi" else "")
        rows.append(f"tab2/train_{name},{t_train:.1f},per_iter_us")
        rows.append(f"tab2/infer_{name},{t_infer:.1f},per_iter_us")
    rows += serve_rows()
    return rows


def serve_rows() -> list[str]:
    """Serving columns: prefill throughput (batched one-forward vs the seed
    scanned token-by-token loop), decode throughput, requests/sec."""
    rows = []
    cfg = configs.get_smoke("qwen2-0.5b")
    plan = api.install(api.resolve(cfg))   # one resolved plan for all rows
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg, jnp.dtype(cfg.dtype))
    prompt = jax.random.randint(key, (SERVE_B, SERVE_P), 0, cfg.vocab_size)
    max_cache = SERVE_P + SERVE_NEW + 1
    dtype = jnp.dtype(cfg.dtype)

    # scanned prefill: the seed serving path (decode step per prompt token)
    step = jax.jit(lambda pr, t, c, pos: lm_decode_step(pr, t, c, pos, cfg))

    def scanned(params, prompt):
        caches = init_lm_cache(cfg, SERVE_B, max_cache, dtype=dtype)
        logits = None
        for i in range(SERVE_P):
            logits, caches = step(params, prompt[:, i:i + 1], caches, i)
        return logits

    # batched prefill: one token-parallel forward writes all caches
    # (last_only: the serving path projects one next-token row per prompt)
    prefill = jax.jit(
        lambda pr, t, c: lm_prefill(pr, t, cfg, caches=c, last_only=True))

    def batched(params, prompt):
        caches = init_lm_cache(cfg, SERVE_B, max_cache, dtype=dtype)
        return prefill(params, prompt, caches)

    tokens = SERVE_B * SERVE_P
    us_scan = time_call(scanned, params, prompt)
    us_batch = time_call(batched, params, prompt)
    rows.append(f"tab2/prefill_scanned,{us_scan:.1f},"
                f"{tokens / (us_scan * 1e-6):.0f}_tok_s")
    rows.append(f"tab2/prefill_batched,{us_batch:.1f},"
                f"{tokens / (us_batch * 1e-6):.0f}_tok_s")

    # decode throughput + requests/sec through the continuous-batching engine
    engine = ServeEngine(params, plan=plan, max_slots=SERVE_B,
                         max_cache=max_cache)
    for i in range(SERVE_B):  # warmup compiles
        engine.submit(list(map(int, prompt[i])), max_new=2)
    engine.run()
    engine.reset_stats()
    for i in range(SERVE_B):
        engine.submit(list(map(int, prompt[i])), max_new=SERVE_NEW)
    engine.run()
    s = engine.summary()
    rows.append(f"tab2/serve_decode,{s['wall_s'] * 1e6:.1f},"
                f"{s['decode_tok_s']:.0f}_tok_s")
    rows.append(f"tab2/serve_requests,{s['wall_s'] * 1e6:.1f},"
                f"{s['requests_s']:.2f}_req_s")

    # fused vs two-launch lowrank kernel (serve-shape linear). Off-TPU both
    # run in Pallas interpret mode, where the ratio measures dispatch
    # overhead only — the VMEM-residency win needs real hardware, so the
    # rows are labeled accordingly.
    from repro.kernels import lowrank_matmul_fused, lowrank_matmul_unfused
    from repro.kernels.ops import INTERPRET
    suffix = "_interpret" if INTERPRET else ""
    x = jax.random.normal(key, (SERVE_B * SERVE_P, 896))
    R = jax.random.normal(key, (224, 896))
    L = jax.random.normal(key, (896, 224))
    us_f = time_call(lowrank_matmul_fused, x, R, L)
    us_u = time_call(lowrank_matmul_unfused, x, R, L)
    rows.append(f"tab2/lowrank_fused{suffix},{us_f:.1f},per_call_us")
    rows.append(f"tab2/lowrank_unfused{suffix},{us_u:.1f},per_call_us")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
