"""Paper Tab. 2 / Fig. 8: wall-clock per-iteration train + inference time,
WASI vs ASI vs vanilla across eps (the CPU host stands in for the paper's
Raspberry Pi — same relative comparison, different absolute scale).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

import repro.configs as configs
from repro.config import TrainConfig
from repro.data.synthetic import SyntheticLM
from repro.models.lm import init_lm, init_lm_states, lm_forward, lm_loss
from repro.train.step import make_train_state, make_train_step
from benchmarks.common import time_call

B, S = 8, 64


def run() -> list[str]:
    rows = []
    base = configs.get_smoke("qwen2-0.5b")
    data = SyntheticLM(vocab_size=base.vocab_size, seq_len=S, global_batch=B,
                       seed=1)
    batch = data.batch(0)
    for method, frac in [("none", 1.0), ("asi", 1.0), ("wasi", 0.25),
                         ("wasi", 0.5)]:
        cfg = base.replace(wasi=dataclasses.replace(
            base.wasi, method=method, rank_frac=frac))
        key = jax.random.PRNGKey(0)
        params = init_lm(key, cfg)
        states = init_lm_states(key, cfg, B, S) if cfg.wasi.compress_acts else None
        tcfg = TrainConfig(optimizer="sgd", lr=0.05, checkpoint_every=0)
        state = make_train_state(key, params, cfg, tcfg, asi_states=states)
        jstep = jax.jit(make_train_step(lm_loss, cfg, tcfg))
        t_train = time_call(jstep, state, batch)
        fwd = jax.jit(lambda p, t: lm_forward(p, t, cfg)[0])
        t_infer = time_call(fwd, params, batch["tokens"])
        name = f"{method}" + (f"_frac{frac}" if method == "wasi" else "")
        rows.append(f"tab2/train_{name},{t_train:.1f},per_iter_us")
        rows.append(f"tab2/infer_{name},{t_infer:.1f},per_iter_us")
    return rows


def main():
    for row in run():
        print(row)


if __name__ == "__main__":
    main()
